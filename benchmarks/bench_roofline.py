"""Deliverable (g): render the roofline table from dry-run artifacts."""
from __future__ import annotations

import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def load(tag="baseline"):
    path = os.path.join(ART, f"dryrun_{tag}.json")
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        return json.load(f)


def main():
    data = load("baseline")
    if not data:
        print("fig_roofline/missing,-1,run_repro.launch.dryrun_first")
        return
    for key in sorted(data):
        v = data[key]
        if "error" in v:
            print(f"roofline/{key},-1,{v['error'][:40]}")
            continue
        r = v["roofline"]
        name = key.replace("|", "/")
        us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        print(f"roofline/{name},{us:.0f},"
              f"dom={r['dominant']};roof%={100 * r.get('roofline_fraction', 0):.3f};"
              f"comp={r['compute_s']:.3e};mem={r['memory_s']:.3e};"
              f"coll={r['collective_s']:.3e};useful={r.get('useful_compute_ratio', 0):.2f}")


if __name__ == "__main__":
    main()
