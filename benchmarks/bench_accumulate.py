"""Streaming accumulated sweep vs the monolithic sweep (ISSUE 5 tentpole).

The accumulated lane's contract is *bounded memory at matched math*: the
identical fused-kernel sweep runs per microbatch slice and the reduce
specs fold sequentially, so the only cost question is the streaming
overhead — per-slice kernel launches, the scan carry, the remainder
trace — against the one-shot monolithic sweep at the same effective
batch.  Lanes per shape (N, D, H, C), mixed first+second-order workload
{batch_l2, variance, diag_ggn, kflr} with fused kernels on:

  accumulate/fused/mono         monolithic fused sweep (the 1× baseline)
  accumulate/fused/k4           plan.accumulate(4) — same numbers
  accumulate/fused/k8           plan.accumulate(8)
  accumulate/fused/bigbatch_k8  a batch several× the monolithic lanes',
                                runnable at microbatch-sized peak
                                activation/factor memory — the lane that
                                exercises batches past the device-memory
                                heuristics the other suites stop at
  accumulate/baseline/jnp_k4    accumulate(4) on the pure-jnp path (the
                                per-extension baseline; ungated)
  accumulate/fused/ckpt_none    the host-driven SweepStream executor
                                (run_checkpointed, no checkpointer) —
                                what preemption-safety costs before any
                                snapshot is written
  accumulate/fused/ckpt_every2  the same stream snapshotting accumulator
                                state + cursor to disk every 2 work units
                                (SweepCheckpointer, keep=1)

``derived`` carries the ratio vs accumulate/fused/mono (for the big
batch, its microbatch row count; for the ckpt lanes, the ratio vs the
unsnapshotted stream).  The fused lanes are gated by
``benchmarks/check_regression.py`` against ``BENCH_smoke_accumulate.json``
like every other fused claim.
"""
from __future__ import annotations

import shutil
import tempfile

import jax

from benchmarks.common import emit, quick_mode, time_group
from repro.core import (
    Activation,
    CrossEntropyLoss,
    Dense,
    ExtensionConfig,
    Sequential,
    by_name,
    plan_sweeps,
    run,
)

# (N, D, H, C): batch, input dim, hidden, classes
SHAPES = [(256, 64, 128, 32)]
QUICK_SHAPES = [(32, 16, 32, 8)]
BIG_FACTOR = 4  # bigbatch lane: N * BIG_FACTOR rows, still k=8 slices

EXT_NAMES = ("batch_l2", "variance", "diag_ggn", "kflr")


def _make(n, d, h, c, seed=0):
    model = Sequential([Dense(d, h), Activation("sigmoid"), Dense(h, c)])
    params = model.init(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, d))
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, c)
    return model, params, x, y


def _sweep_fn(model, plan_or_none, exts, cfg, loss):
    if plan_or_none is None:
        def mono(params, x, y):
            res = run(model, params, x, y, loss, extensions=exts, cfg=cfg)
            return res.loss, res.ext["diag_ggn"]

        return jax.jit(mono)

    def acc(params, x, y):
        res = plan_or_none.run(model, params, x, y, loss, cfg=cfg)
        return res.loss, res.ext["diag_ggn"]

    return jax.jit(acc)


def main():
    shapes = QUICK_SHAPES if quick_mode() else SHAPES
    loss = CrossEntropyLoss()
    exts = tuple(by_name(nm) for nm in EXT_NAMES)
    for (n, d, h, c) in shapes:
        model, params, x, y = _make(n, d, h, c)
        fused = ExtensionConfig(use_kernels=True)
        naive = ExtensionConfig(use_kernels=False)
        plan_f = plan_sweeps(exts, fused)
        plan_n = plan_sweeps(exts, naive)
        tag = f"N{n}_d{d}_h{h}_c{c}"

        lanes = {
            "accumulate/fused/mono":
                _sweep_fn(model, None, exts, fused, loss),
            "accumulate/fused/k4":
                _sweep_fn(model, plan_f.accumulate(4), exts, fused, loss),
            "accumulate/fused/k8":
                _sweep_fn(model, plan_f.accumulate(8), exts, fused, loss),
            "accumulate/baseline/jnp_k4":
                _sweep_fn(model, plan_n.accumulate(4), exts, naive, loss),
        }
        thunks = {name: (lambda f=f: f(params, x, y))
                  for name, f in lanes.items()}
        times = time_group(thunks)
        base = times["accumulate/fused/mono"]
        for name, us in times.items():
            emit(f"{name}/{tag}", us, f"x{us / base:.2f}_vs_mono")

        # The beyond-memory lane: BIG_FACTOR× the batch, streamed in k=8
        # slices — peak per-slice working set stays at bigN/8 rows.
        big_n = n * BIG_FACTOR
        _, _, xb, yb = _make(big_n, d, h, c, seed=7)
        big = _sweep_fn(model, plan_f.accumulate(8), exts, fused, loss)
        t = time_group({"big": lambda: big(params, xb, yb)})["big"]
        emit(f"accumulate/fused/bigbatch_k8/N{big_n}_d{d}_h{h}_c{c}", t,
             f"microbatch_rows={-(-big_n // 8)}")

        # Checkpoint overhead: the same accumulate(8) schedule through the
        # host-driven SweepStream executor, without snapshots vs snapshot
        # every 2 work units.  One stream instance is rewound to its
        # initial state between iterations so the lanes measure the
        # steady-state stream (host dispatch + snapshot serialization +
        # disk), not per-call retracing; each snapshotting iteration
        # starts from a clean dir so every run writes the same files.
        from repro.train.checkpoint import SweepCheckpointer

        stream = plan_f.accumulate(8).stream(model, params, x, y, loss,
                                             cfg=fused)
        state0 = jax.device_get(stream.state_arrays())

        def ckpt_run(store=None, every=2):
            stream.load_state(0, state0)
            while not stream.done:
                stream.step()
                if store is not None and (stream.done
                                          or stream.cursor % every == 0):
                    store.save(stream.cursor, stream.state_arrays(),
                               stream.schedule_meta())
            return stream.result().loss

        ckpt_dir = tempfile.mkdtemp(prefix="bench_sweep_ckpt_")
        try:
            def ckpt_every2():
                shutil.rmtree(ckpt_dir, ignore_errors=True)
                return ckpt_run(SweepCheckpointer(ckpt_dir, keep=1))

            tc = time_group({
                "accumulate/fused/ckpt_none": lambda: ckpt_run(),
                "accumulate/fused/ckpt_every2": ckpt_every2,
            })
        finally:
            shutil.rmtree(ckpt_dir, ignore_errors=True)
        none_us = tc["accumulate/fused/ckpt_none"]
        emit(f"accumulate/fused/ckpt_none/{tag}", none_us,
             f"x{none_us / base:.2f}_vs_mono")
        every2_us = tc["accumulate/fused/ckpt_every2"]
        emit(f"accumulate/fused/ckpt_every2/{tag}", every2_us,
             f"x{every2_us / none_us:.2f}_vs_ckpt_none")


if __name__ == "__main__":
    main()
