"""Empirical NTK sweep: fused cross-block kernel vs einsum, streamed vs
monolithic (ISSUE 6 tentpole).

Two claims to hold:

* the fused Pallas path (within-block ``dot`` accumulator + the
  ``cross_dot`` cross-block kernel) forms the per-parameter Gram blocks
  without materializing ``[N, a, b]`` per-sample Jacobian stacks — timed
  against the pure-jnp einsum baseline that does;
* the streamed row-block lane (``plan.accumulate(k)``: diagonal blocks
  from the main scan + one pair pass per slice pair) reproduces the
  monolithic sweep at bounded per-slice memory and tolerable overhead.

Lanes per shape (N, D, H, C), extensions {ntk, ntk_classwise}:

  ntk/fused/mono            monolithic fused sweep (the 1× baseline)
  ntk/fused/k4              plan.accumulate(4) — same numbers, streamed
  ntk/fused/cross_dot       the raw cross-block kernel, standalone
  ntk/baseline/jnp_mono     monolithic einsum path (ungated)
  ntk/baseline/cross_einsum the cross-block einsum the kernel replaces
                            (ungated)

``derived`` carries the ratio vs ntk/fused/mono (kernel lanes: vs their
einsum counterpart).  The fused lanes are gated by
``benchmarks/check_regression.py`` against ``BENCH_smoke_ntk.json`` like
every other fused claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, quick_mode, time_group
from repro.core import (
    Activation,
    CrossEntropyLoss,
    Dense,
    ExtensionConfig,
    Sequential,
    by_name,
    ntk_total,
    plan_sweeps,
    run,
)
from repro.kernels import ops as kops
from repro.kernels import ref as kref

# (N, D, H, C): batch, input dim, hidden, classes
SHAPES = [(128, 64, 128, 16)]
QUICK_SHAPES = [(24, 16, 32, 6)]

EXT_NAMES = ("ntk", "ntk_classwise")


def _make(n, d, h, c, seed=0):
    model = Sequential([Dense(d, h), Activation("tanh"), Dense(h, c)])
    params = model.init(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, d))
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, c)
    return model, params, x, y


def _sweep_fn(model, plan_or_none, exts, cfg, loss):
    if plan_or_none is None:
        def mono(params, x, y):
            res = run(model, params, x, y, loss, extensions=exts, cfg=cfg)
            return ntk_total(res.ext["ntk"])

        return jax.jit(mono)

    def acc(params, x, y):
        res = plan_or_none.run(model, params, x, y, loss, cfg=cfg)
        return ntk_total(res.ext["ntk"])

    return jax.jit(acc)


def _cross_block_lanes(n, c, h, tag):
    """The off-diagonal primitive standalone: [E, N1, R, a/b] factor
    blocks → [E, N1, N2] cross Gram, kernel vs the einsum it replaces."""
    half = n // 2
    rng = jax.random.PRNGKey(9)
    ka, kb, kc, kd = jax.random.split(rng, 4)
    A1 = jax.random.normal(ka, (c, half, 1, h), jnp.float32)
    B1 = jax.random.normal(kb, (c, half, 1, c), jnp.float32)
    A2 = jax.random.normal(kc, (c, n - half, 1, h), jnp.float32)
    B2 = jax.random.normal(kd, (c, n - half, 1, c), jnp.float32)
    kern = jax.jit(lambda: kops.cross_dot(A1, B1, A2, B2))
    ein = jax.jit(lambda: kref.cross_dot(A1, B1, A2, B2))
    times = time_group({f"ntk/fused/cross_dot/{tag}": kern,
                        f"ntk/baseline/cross_einsum/{tag}": ein})
    base = times[f"ntk/baseline/cross_einsum/{tag}"]
    for name, us in times.items():
        emit(name, us, f"x{us / base:.2f}_vs_einsum")


def main():
    shapes = QUICK_SHAPES if quick_mode() else SHAPES
    loss = CrossEntropyLoss()
    exts = tuple(by_name(nm) for nm in EXT_NAMES)
    for (n, d, h, c) in shapes:
        model, params, x, y = _make(n, d, h, c)
        fused = ExtensionConfig(use_kernels=True)
        naive = ExtensionConfig(use_kernels=False)
        tag = f"N{n}_d{d}_h{h}_c{c}"

        lanes = {
            "ntk/fused/mono":
                _sweep_fn(model, None, exts, fused, loss),
            "ntk/fused/k4":
                _sweep_fn(model, plan_sweeps(exts, fused).accumulate(4),
                          exts, fused, loss),
            "ntk/baseline/jnp_mono":
                _sweep_fn(model, None, exts, naive, loss),
        }
        thunks = {name: (lambda f=f: f(params, x, y))
                  for name, f in lanes.items()}
        times = time_group(thunks)
        base = times["ntk/fused/mono"]
        for name, us in times.items():
            emit(f"{name}/{tag}", us, f"x{us / base:.2f}_vs_mono")

        _cross_block_lanes(n, c, h, tag)


if __name__ == "__main__":
    main()
