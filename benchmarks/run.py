"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` additionally
dumps all rows as JSON (the CI quick-bench artifact), and ``--quick`` runs a
short mode for smoke lanes: fewer timing iterations everywhere, plus
smaller shapes where a benchmark defines them (currently ``fused``).

  fig3  individual gradients: for-loop vs vectorized     (paper Fig. 3)
  fig6  extension overhead vs plain gradient             (paper Fig. 6)
  fig7  curvature optimizers vs SGD/Adam                 (paper Fig. 7/10/11)
  fig8  KFLR vs KFAC output-dimension scaling            (paper Fig. 8)
  fig9  Hessian diag vs GGN diag with sigmoid, plus the fused
        second-order sweep vs per-extension baseline     (paper Fig. 9 /
                                                          ISSUE 2 tentpole)
  kernels   Pallas kernels (interpret)                   (deliverable c)
  fused     fused first-order kernel vs per-extension    (ISSUE 1 tentpole)
  accumulate  streaming accumulated sweep vs monolithic,
            incl. a beyond-memory-scale batch lane       (ISSUE 5 tentpole)
  ntk       empirical NTK sweep: fused cross-block
            kernel vs einsum, streamed vs monolithic     (ISSUE 6 tentpole)
  ntk_apps  NTK consumers: GP regression (cholesky/eigh/
            Lanczos-PCG/streamed), influence, subset
            selection, vs a jacrev-materialized baseline (ISSUE 10 tentpole)
  obs       observability overhead: instrumented vs
            uninstrumented fused sweep + SweepStream,
            ratio lanes gated at 1.05x in CI             (ISSUE 8 tentpole)
  laplace   posterior fit + fused predictive-variance
            kernel vs naive Jacobian baseline; also
            refreshes BENCH_laplace.json (repo root, or
            $BENCH_OUT_DIR when set — CI artifact mode)  (ISSUE 3 tentpole)
  matfree   matrix-free curvature: GGN-vp / CG / kernel-
            NGD cost vs one gradient, plus the implicit-
            vs-explicit-factor crossover in C            (ISSUE 9 tentpole)
  roofline  dry-run roofline table                       (deliverable g)

CI's bench-smoke job gates the fused lanes against the committed
quick-mode ``BENCH_smoke_*.json`` baselines via
``benchmarks.check_regression`` (>1.5× slowdown fails the job).

Usage: ``PYTHONPATH=src python -m benchmarks.run [--quick] [--json OUT]
[names...]``
"""
import argparse
import json
import os

from benchmarks import common


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--quick", action="store_true",
                    help="short mode: fewer iters, smaller shapes")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="also write all rows as JSON to this path")
    ap.add_argument("which", nargs="*", help="benchmark names (default: all)")
    args = ap.parse_args()
    if args.json_path:
        # Fail before minutes of benchmarking, not after.
        parent = os.path.dirname(os.path.abspath(args.json_path))
        if not os.path.isdir(parent):
            ap.error(f"--json: directory does not exist: {parent}")
    if args.quick:
        os.environ["BENCH_QUICK"] = "1"

    # Import after --quick is in the environment (modules read it lazily,
    # but keep the ordering obvious).
    from benchmarks import (
        bench_accumulate,
        bench_c_scaling,
        bench_fused_first_order,
        bench_hessian_diag,
        bench_individual,
        bench_kernels,
        bench_laplace,
        bench_matfree,
        bench_ntk,
        bench_ntk_apps,
        bench_optimizers,
        bench_overhead,
        bench_roofline,
    )

    all_benches = {
        "fig3": bench_individual.main,
        "fig6": bench_overhead.main,
        "fig7": bench_optimizers.main,
        "fig8": bench_c_scaling.main,
        "fig9": bench_hessian_diag.main,
        "kernels": bench_kernels.main,
        "fused": bench_fused_first_order.main,
        "accumulate": bench_accumulate.main,
        "matfree": bench_matfree.main,
        "ntk": bench_ntk.main,
        "ntk_apps": bench_ntk_apps.main,
        "obs": bench_overhead.obs_overhead,
        "laplace": bench_laplace.main,
        "roofline": bench_roofline.main,
    }

    which = args.which or list(all_benches)
    unknown = [w for w in which if w not in all_benches]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; "
                 f"choose from {sorted(all_benches)}")
    print("name,us_per_call,derived")
    for name in which:
        all_benches[name]()
    if args.json_path:
        with open(args.json_path, "w") as f:
            json.dump(common.ROWS, f, indent=2)
        print(f"# wrote {len(common.ROWS)} rows to {args.json_path}")


if __name__ == "__main__":
    main()
