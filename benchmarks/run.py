"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig3  individual gradients: for-loop vs vectorized     (paper Fig. 3)
  fig6  extension overhead vs plain gradient             (paper Fig. 6)
  fig7  curvature optimizers vs SGD/Adam                 (paper Fig. 7/10/11)
  fig8  KFLR vs KFAC output-dimension scaling            (paper Fig. 8)
  fig9  Hessian diag vs GGN diag with sigmoid            (paper Fig. 9)
  kernels   Pallas kernels (interpret)                   (deliverable c)
  roofline  dry-run roofline table                       (deliverable g)
"""
import sys

from benchmarks import (
    bench_c_scaling,
    bench_hessian_diag,
    bench_individual,
    bench_kernels,
    bench_optimizers,
    bench_overhead,
    bench_roofline,
)

ALL = {
    "fig3": bench_individual.main,
    "fig6": bench_overhead.main,
    "fig7": bench_optimizers.main,
    "fig8": bench_c_scaling.main,
    "fig9": bench_hessian_diag.main,
    "kernels": bench_kernels.main,
    "roofline": bench_roofline.main,
}


def main() -> None:
    which = sys.argv[1:] or list(ALL)
    print("name,us_per_call,derived")
    for name in which:
        ALL[name]()


if __name__ == "__main__":
    main()
