"""Fig. 9: Hessian diagonal vs GGN diagonal once a non-piecewise-linear
activation (sigmoid) appears — residual ± factors make DiagHessian an
order of magnitude more expensive."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.configs.papernets import mlp
from repro.core import CrossEntropyLoss, DiagGGN, DiagHessian, run


def main():
    loss = CrossEntropyLoss()
    for act, tag in (("relu", "relu"), ("sigmoid", "sigmoid")):
        model = mlp(n_classes=10, in_dim=32, hidden=(64, 48), act=act)
        params = model.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)

        ggn_fn = jax.jit(lambda p: run(model, p, x, y, loss,
                                       extensions=(DiagGGN,)).ext)
        t_ggn = time_fn(ggn_fn, params)
        emit(f"fig9/diag_ggn/{tag}", t_ggn, "")

        h_fn = jax.jit(lambda p: run(model, p, x, y, loss,
                                     extensions=(DiagHessian,)).ext)
        t_h = time_fn(h_fn, params)
        emit(f"fig9/diag_hessian/{tag}", t_h, f"x{t_h / t_ggn:.1f}_vs_ggn")


if __name__ == "__main__":
    main()
