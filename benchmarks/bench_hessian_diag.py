"""Fig. 9 + fused curvature sweep: second-order cost structure.

Two sections:

* ``fig9/...`` — the paper's Fig. 9: Hessian diagonal vs GGN diagonal once
  a non-piecewise-linear activation (sigmoid) appears — residual ± factors
  make DiagHessian an order of magnitude more expensive.

* ``fused_second_order/...`` — the ISSUE-2 tentpole claim: with the fused
  curvature kernel, computing {diag_ggn + kflr} together costs ≤ 1.5× of
  diag_ggn alone (the B-factor rides the same kernel launch and the same
  VMEM-resident S tile), where the per-extension baseline pays additively
  (separate broadcast-einsum / kernel passes over the same (A, S) pair).
  Lanes (interleaved min-of-k timing via ``time_group``):

    fused/diag_only        DiagGGN,        use_kernels=True  (the 1× base)
    fused/diag+kflr        DiagGGN + KFLR, use_kernels=True
    fused/diag+kflr+trace  + GGNTrace — the third output is ~free
    baseline/diag_only     DiagGGN,        per-extension jnp path
    baseline/diag+kflr     DiagGGN + KFLR, per-extension jnp path

  ``derived`` carries the ratio vs the same path's diag_only lane, plus
  the ``plan_sweeps`` description of the fused curvature workload.  The
  model is the paper's 2c2d conv net: its unfold gives R = 64 patch
  positions per sample, so the fused kernel is genuinely on the timed
  path (R==1 layers deliberately skip it for closed forms).
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn, time_group
from repro.configs.papernets import c2d2, mlp
from repro.core import (
    CrossEntropyLoss,
    DiagGGN,
    DiagHessian,
    ExtensionConfig,
    GGNTrace,
    KFLR,
    plan_sweeps,
    run,
)


def _fig9():
    loss = CrossEntropyLoss()
    for act, tag in (("relu", "relu"), ("sigmoid", "sigmoid")):
        model = mlp(n_classes=10, in_dim=32, hidden=(64, 48), act=act)
        params = model.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32))
        y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)

        ggn_fn = jax.jit(lambda p: run(model, p, x, y, loss,
                                       extensions=(DiagGGN,)).ext)
        t_ggn = time_fn(ggn_fn, params)
        emit(f"fig9/diag_ggn/{tag}", t_ggn, "")

        h_fn = jax.jit(lambda p: run(model, p, x, y, loss,
                                     extensions=(DiagHessian,)).ext)
        t_h = time_fn(h_fn, params)
        emit(f"fig9/diag_hessian/{tag}", t_h, f"x{t_h / t_ggn:.1f}_vs_ggn")


def _fused_second_order():
    loss = CrossEntropyLoss()
    model = c2d2(n_classes=10, in_ch=1, img=8)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 8, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)
    fused_cfg = ExtensionConfig(use_kernels=True)
    base_cfg = ExtensionConfig(use_kernels=False)

    def lane(exts, cfg):
        fn = jax.jit(lambda p: run(model, p, x, y, loss, extensions=exts,
                                   cfg=cfg).ext)
        return lambda: fn(params)

    times = time_group({
        "fused/diag_only": lane((DiagGGN,), fused_cfg),
        "fused/diag+kflr": lane((DiagGGN, KFLR), fused_cfg),
        "fused/diag+kflr+trace": lane((DiagGGN, KFLR, GGNTrace), fused_cfg),
        "baseline/diag_only": lane((DiagGGN,), base_cfg),
        "baseline/diag+kflr": lane((DiagGGN, KFLR), base_cfg),
    })
    plan = plan_sweeps((DiagGGN, KFLR), fused_cfg)
    for name, t in times.items():
        base = times[name.split("/")[0] + "/diag_only"]
        note = f"ratio={t / base:.2f}"
        if name == "fused/diag+kflr":
            note += f";{plan.describe()}"
        emit(f"fused_second_order/{name}", t, note)


def main():
    _fig9()
    _fused_second_order()


if __name__ == "__main__":
    main()
