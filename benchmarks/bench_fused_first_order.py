"""Fused first-order kernel vs per-extension kernels vs pure jnp.

The tentpole claim: computing {batch_l2, second_moment, batch_dot} together
through ONE fused pass costs ≤ 1.5× batch_l2 alone, where the
one-kernel-per-extension path pays ~3× (three passes over the same
(grad_out, input) pair).  Lanes per Dense benchmark shape (N, R, a, b):

  fused/l2_only     fused kernel, mask = {l2}            (the 1× baseline)
  fused/all3        fused kernel, mask = {l2, moment, dot}
  per_ext/all3      the seed's per-extension path: batch_l2 kernel +
                    per_sample_moment kernel + jnp Gram-einsum batch_dot
                    (no standalone dot kernel ever existed)
  jnp/all3          pure-jnp einsum oracles

``derived`` carries the ratio vs fused/l2_only.  Numbers here are
interpret-mode (CPU correctness path) — on TPU the same dispatch compiles
Mosaic, and the HBM-traffic argument only gets stronger.

Scaling note: the dot output adds N²·a·b FLOPs on top of the N·R·a·b the
baseline already spends forming G, i.e. a marginal cost of ~N/R of the
baseline; moment and l2 are O(N·a·b) elementwise.  The shapes below are
sequence workloads (R ≥ 4N, the regime per-sample statistics target —
DP-SGD / gradient telemetry over tokens or conv patches), where all three
together stay well under 1.5×.  Batch-dominant shapes (N ≳ R) pay up to
~1 + N/R for the Gram matrix — unavoidable work, not kernel overhead.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, quick_mode, time_group
from repro.kernels import ops, ref

# Dense benchmark shapes: (N, R, a, b) — batch, sequence, fan-in, fan-out.
SHAPES = [(16, 128, 256, 256), (32, 128, 512, 256)]
QUICK_SHAPES = [(8, 32, 128, 128)]


def _fused(A, B, wl, wm, wd):
    return ops.fused_first_order(A, B, want_l2=wl, want_moment=wm,
                                 want_dot=wd)


_batch_dot_jnp = jax.jit(lambda A, B: ref.batch_dot(A, B))


def _per_ext(A, B):
    return (ops.batch_l2(A, B),
            ops.per_sample_moment(A, B),
            _batch_dot_jnp(A, B))


def _jnp_all(A, B):
    return (ref.batch_l2(A, B), ref.per_sample_moment(A, B),
            ref.batch_dot(A, B))


def main():
    shapes = QUICK_SHAPES if quick_mode() else SHAPES
    k = jax.random.PRNGKey(0)
    for n, r, a, b in shapes:
        tag = f"N{n}xR{r}x{a}x{b}"
        A = jax.random.normal(k, (n, r, a))
        B = jax.random.normal(jax.random.fold_in(k, 1), (n, r, b))
        jnp_all = jax.jit(_jnp_all)
        times = time_group({
            "fused/l2_only": lambda: _fused(A, B, True, False, False),
            "fused/all3": lambda: _fused(A, B, True, True, True),
            "per_ext/all3": lambda: _per_ext(A, B),
            "jnp/all3": lambda: jnp_all(A, B),
        })
        base = times["fused/l2_only"]
        for lane, t in times.items():
            emit(f"fused_first_order/{tag}/{lane}", t,
                 f"ratio={t / base:.2f}")


if __name__ == "__main__":
    main()
