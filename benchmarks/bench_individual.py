"""Fig. 3: individual gradients — for-loop vs vectorized extended backprop.

The paper's headline efficiency claim: N separate backward passes vs one
batched pass that simply skips the sum over samples (Eq. 5).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs.papernets import c3d3
from repro.core import BatchGrad, CrossEntropyLoss, oracle, run


def main():
    loss = CrossEntropyLoss()
    model = c3d3(n_classes=10, in_ch=3, img=16)
    params = model.init(jax.random.PRNGKey(0))
    for n in (4, 16, 32):
        x = jax.random.normal(jax.random.PRNGKey(1), (n, 16, 16, 3))
        y = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, 10)

        grad_fn = jax.jit(lambda p: oracle.grad(model, loss, p, x, y))
        t_grad = time_fn(grad_fn, params)
        emit(f"fig3/grad/N{n}", t_grad, "baseline")

        vec_fn = jax.jit(lambda p: run(model, p, x, y, loss,
                                       extensions=(BatchGrad,)).ext)
        t_vec = time_fn(vec_fn, params)
        emit(f"fig3/indiv_vectorized/N{n}", t_vec,
             f"x{t_vec / t_grad:.2f}_vs_grad")

        # literal for-loop (one fwd+bwd per sample) — paper's naive baseline
        oracle.per_sample_grads_loop(model, loss, params, x, y)  # warm jit
        t0 = time.perf_counter()
        oracle.per_sample_grads_loop(model, loss, params, x, y)
        t_loop = (time.perf_counter() - t0) * 1e6
        emit(f"fig3/indiv_forloop/N{n}", t_loop,
             f"x{t_loop / t_vec:.1f}_vs_vectorized")


if __name__ == "__main__":
    main()
