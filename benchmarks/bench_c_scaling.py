"""Fig. 8: KFLR (exact factor, C columns) vs KFAC (MC factor, 1 column)
as the output dimension C grows — the paper's CIFAR-100 scaling argument."""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.configs.papernets import mlp
from repro.core import CrossEntropyLoss, ExtensionConfig, KFAC, KFLR, run


def main():
    loss = CrossEntropyLoss()
    for C in (10, 50, 100):
        model = mlp(n_classes=C, in_dim=64, hidden=(128, 128), act="relu")
        params = model.init(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
        y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, C)

        kfac_fn = jax.jit(lambda p, r: run(model, p, x, y, loss,
                                           extensions=(KFAC,), rng=r).ext)
        t_kfac = time_fn(kfac_fn, params, jax.random.PRNGKey(3))
        emit(f"fig8/kfac/C{C}", t_kfac, "mc_1col")

        kflr_fn = jax.jit(lambda p, r: run(model, p, x, y, loss,
                                           extensions=(KFLR,), rng=r).ext)
        t_kflr = time_fn(kflr_fn, params, jax.random.PRNGKey(3))
        emit(f"fig8/kflr/C{C}", t_kflr, f"x{t_kflr / t_kfac:.1f}_vs_kfac")


if __name__ == "__main__":
    main()
