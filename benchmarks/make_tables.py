"""Render EXPERIMENTS.md tables from dry-run artifacts."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def baseline_table(mesh="single"):
    d = json.load(open(os.path.join(ART, "dryrun_baseline.json")))
    lines = [
        "| arch | shape | params | dominant | compute s | memory s | "
        "collective s | roofline % | useful ratio | temp GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for k in sorted(d):
        v = d[k]
        if v.get("mesh") != mesh or "error" in v:
            continue
        r = v["roofline"]
        lines.append(
            f"| {v['arch']} | {v['shape']} | {v['n_params']/1e9:.2f}B | "
            f"{r['dominant'].replace('_s','')} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{100*r.get('roofline_fraction',0):.3f} | "
            f"{r.get('useful_compute_ratio',0):.2f} | "
            f"{v['memory']['temp_size_in_bytes']/2**30:.1f} |")
    return "\n".join(lines)


def multi_pod_summary():
    d = json.load(open(os.path.join(ART, "dryrun_baseline.json")))
    n_ok = sum(1 for v in d.values()
               if v.get("mesh") == "multi" and "error" not in v)
    n_err = sum(1 for v in d.values()
                if v.get("mesh") == "multi" and "error" in v)
    return n_ok, n_err


def hillclimb_table(prefix):
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, f"dryrun_{prefix}*.json"))):
        tag = os.path.basename(f).replace("dryrun_", "").replace(".json", "")
        d = json.load(open(f))
        for v in d.values():
            if "error" in v:
                rows.append((tag, None, v["error"][:50]))
            else:
                rows.append((tag, v, None))
    lines = ["| iteration | opts | compute s | memory s | collective s | "
             "roofline % | temp GB/dev |",
             "|---|---|---|---|---|---|---|"]
    for tag, v, err in rows:
        if err:
            lines.append(f"| {tag} | — | — | — | — | ERROR | {err} |")
            continue
        r = v["roofline"]
        opts = ",".join(v["opts"]) or "(baseline)"
        if v.get("curvature"):
            opts += f" curv={v['curvature']}"
        lines.append(
            f"| {tag} | {opts} | {r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.4f} | {100*r.get('roofline_fraction',0):.3f} | "
            f"{v['memory']['temp_size_in_bytes']/2**30:.0f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    if which == "baseline":
        print(baseline_table())
    elif which == "multi":
        print(multi_pod_summary())
    else:
        print(hillclimb_table(which))
