"""Fig. 7/10/11: the naive damped update (Eq. 7) with BackPACK curvatures
vs momentum-SGD / Adam baselines, per-iteration progress on synthetic
classification (DeepOBS protocol, scaled to CPU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs.papernets import c2d2, mlp
from repro.core import (
    CrossEntropyLoss,
    DiagGGN,
    DiagGGNMC,
    ExtensionConfig,
    KFAC,
    KFLR,
    KFRA,
    run,
)
from repro.optim import adamw, curvature_optimizer, momentum_sgd
from repro.optim.optimizers import apply_updates

LOSS = CrossEntropyLoss()
STEPS = 60


def _data(key, n=256, d=32, c=10):
    x = jax.random.normal(key, (n, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, c))
    y = jnp.argmax(x @ w + 0.5 * jax.random.normal(
        jax.random.fold_in(key, 2), (n, c)), axis=-1)
    return x, y


def _train(model, params, opt, ext, cfg, x, y, batch=64):
    opt_state = opt.init(params)
    losses = []
    n = x.shape[0]

    @jax.jit
    def step(params, opt_state, i):
        lo = (i * batch) % n
        xb = jax.lax.dynamic_slice_in_dim(x, lo, batch)
        yb = jax.lax.dynamic_slice_in_dim(y, lo, batch)
        if ext is None:
            res = run(model, params, xb, yb, LOSS)
            ups, new_os = opt.update(res.grads, opt_state, params)
        else:
            res = run(model, params, xb, yb, LOSS, extensions=(ext,),
                      cfg=cfg, rng=jax.random.fold_in(jax.random.PRNGKey(7), i))
            ups, new_os = opt.update(res.grads, opt_state, params,
                                     curv=res.ext[ext.name])
        return apply_updates(params, ups), new_os, res.loss

    for i in range(STEPS):
        params, opt_state, lv = step(params, opt_state, jnp.int32(i))
        losses.append(float(lv))
    return losses


def main():
    x, y = _data(jax.random.PRNGKey(0))
    runs = [
        ("momentum", momentum_sgd(0.05), None),
        ("adam", adamw(3e-3), None),
        ("diag_ggn", curvature_optimizer(0.5, 1e-1, "diag_ggn"), DiagGGN),
        ("diag_ggn_mc", curvature_optimizer(0.5, 1e-1, "diag_ggn_mc"), DiagGGNMC),
        ("kfac", curvature_optimizer(0.5, 1e-1, "kfac", stat_decay=0.5), KFAC),
        ("kflr", curvature_optimizer(0.5, 1e-1, "kflr", stat_decay=0.5), KFLR),
        ("kfra", curvature_optimizer(0.5, 1e-1, "kfra", stat_decay=0.5), KFRA),
    ]
    for name, opt, ext in runs:
        model = mlp(n_classes=10, in_dim=32, hidden=(64,), act="tanh")
        params = model.init(jax.random.PRNGKey(1))
        losses = _train(model, params, opt, ext, ExtensionConfig(), x, y)
        emit(f"fig7/mlp/{name}", -1.0,
             f"loss0={losses[0]:.3f}_loss{STEPS}={losses[-1]:.3f}")


if __name__ == "__main__":
    main()
