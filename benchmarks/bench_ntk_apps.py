"""NTK consumers: GP regression / influence / selection lanes (ISSUE 10).

The claims to hold:

* the Gram-space GP pipeline (one engine NTK sweep + an [N, N] solve)
  beats the materialized-Jacobian construction it replaces — the
  ``jacrev`` baseline pays O(N·C·P) memory traffic for the same kernel;
* the alternative solvers (dense eigh, Lanczos-top-k preconditioned CG)
  and the streamed row-block lane stay within a constant factor of the
  Cholesky path — they exist for truncation / beyond-memory reach, not
  speed at smoke scale;
* influence (BatchGrad rows + batched PCG against the GGN operator) and
  both subset selectors run at interactive cost on pool-scale kernels.

Lanes per shape (N_train, N_test, D, H, C):

  ntk_apps/gp/cholesky        full gp_predict, direct solve (1× base)
  ntk_apps/gp/eigh            dense eigendecomposition solver
  ntk_apps/gp/lanczos         Lanczos-top-k preconditioned CG solver
  ntk_apps/gp/streamed_k4     microbatches=4 row-block streaming
  ntk_apps/influence          train→test scores, batched PCG solve
  ntk_apps/self_influence     per-train-point self scores
  ntk_apps/select/diversity   greedy max-variance coreset (k picks)
  ntk_apps/select/bait        BAIT Fisher-trace selection (k picks)
  ntk_apps_ref/gp_jacrev      materialized-Jacobian GP oracle (ungated)

``derived`` carries the ratio vs ntk_apps/gp/cholesky (the jacrev
baseline reports its ratio the other way).  The ``ntk_apps/`` lanes are
gated against ``BENCH_smoke_ntk_apps.json`` in the bench-smoke CI job
(``--pattern '^ntk_apps/'``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, quick_mode, time_group
from repro.configs.papernets import mlp
from repro.core import CrossEntropyLoss
from repro.ntk_apps import (
    gp_predict,
    influence_scores,
    select_subset,
    self_influence,
)

# (N_train, N_test, D, H, C)
SHAPES = [(96, 24, 48, 64, 8)]
QUICK_SHAPES = [(24, 8, 16, 32, 4)]

SELECT_K = 6


def _make(n_tr, n_te, d, h, c, seed=0):
    model = mlp(n_classes=c, in_dim=d, hidden=(h,))
    params = model.init(jax.random.PRNGKey(seed))
    x_tr = jax.random.normal(jax.random.PRNGKey(seed + 1), (n_tr, d))
    y_tr = jax.random.randint(jax.random.PRNGKey(seed + 2), (n_tr,), 0, c)
    x_te = jax.random.normal(jax.random.PRNGKey(seed + 3), (n_te, d))
    y_te = jax.random.randint(jax.random.PRNGKey(seed + 4), (n_te,), 0, c)
    return model, params, x_tr, y_tr, x_te, y_te


def _jacrev_gp(model, params, x_tr, y_tr, x_te, ridge):
    """The O(N·C·P) construction gp_predict avoids: materialize the full
    Jacobian, form the kernel explicitly, solve."""
    from jax.flatten_util import ravel_pytree

    flat, unravel = ravel_pytree(params)
    x = jnp.concatenate([x_tr, x_te], axis=0)
    J = jax.jacrev(lambda f: model.apply(unravel(f), x))(flat)
    n, c = x_tr.shape[0], J.shape[1]
    Jf = J.reshape(-1, flat.size)
    K = jnp.einsum("ap,bp->ab", Jf, Jf).reshape(
        x.shape[0], c, x.shape[0], c)
    K = jnp.einsum("ncmc->nm", K)
    A = K[:n, :n] + ridge * jnp.eye(n)
    Y = jax.nn.one_hot(y_tr, c)
    alpha = jnp.linalg.solve(A, Y)
    mean = K[n:, :n] @ alpha
    var = jnp.diag(K[n:, n:]) - jnp.einsum(
        "sn,ns->s", K[n:, :n], jnp.linalg.solve(A, K[:n, n:]))
    return mean, var


def main():
    shapes = QUICK_SHAPES if quick_mode() else SHAPES
    loss = CrossEntropyLoss()
    for (n_tr, n_te, d, h, c) in shapes:
        model, params, x_tr, y_tr, x_te, y_te = _make(n_tr, n_te, d, h, c)
        tag = f"N{n_tr}+{n_te}_d{d}_h{h}_c{c}"
        ridge, damping = 1e-2, 1e-2
        rank = max(4, n_tr // 4)

        def gp(solver="cholesky", **kw):
            return gp_predict(model, params, x_tr, y_tr, x_te, loss,
                              ridge=ridge, solver=solver, **kw)

        lanes = {
            "ntk_apps/gp/cholesky": lambda: gp().mean,
            "ntk_apps/gp/eigh": lambda: gp("eigh").mean,
            "ntk_apps/gp/lanczos":
                lambda: gp("lanczos", rank=rank, cg_tol=1e-8).mean,
            "ntk_apps/gp/streamed_k4": lambda: gp(microbatches=4).mean,
            "ntk_apps/influence":
                lambda: influence_scores(model, params, x_tr, y_tr, x_te,
                                         y_te, loss,
                                         damping=damping).scores,
            "ntk_apps/self_influence":
                lambda: self_influence(model, params, x_tr, y_tr, loss,
                                       damping=damping).scores,
            "ntk_apps/select/diversity":
                lambda: select_subset(model, params, x_tr, y_tr, loss,
                                      SELECT_K,
                                      method="diversity").indices,
            "ntk_apps/select/bait":
                lambda: select_subset(model, params, x_tr, y_tr, loss,
                                      SELECT_K, method="bait",
                                      lam=damping).indices,
            "ntk_apps_ref/gp_jacrev":
                lambda: _jacrev_gp(model, params, x_tr, y_tr, x_te,
                                   ridge)[0],
        }
        times = time_group(lanes)
        base = times["ntk_apps/gp/cholesky"]
        ref = times["ntk_apps_ref/gp_jacrev"]
        for name, us in times.items():
            if name.startswith("ntk_apps_ref/"):
                emit(f"{name}/{tag}", us, f"x{us / base:.2f}_vs_gram_gp")
            elif name.startswith("ntk_apps/gp/"):
                emit(f"{name}/{tag}", us, f"x{us / ref:.2f}_vs_jacrev")
            else:
                emit(f"{name}/{tag}", us, f"x{us / base:.2f}_vs_gp")


if __name__ == "__main__":
    main()
