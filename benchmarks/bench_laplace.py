"""Laplace lanes: posterior fit + the predictive-variance hot path.

Sections (per-lane steady-state timing — see the note in
``_predvar_lanes`` for why the interleaved ``time_group`` estimator is
wrong for this pairing):

* ``laplace/fit/...`` — DiagLaplace / KronLaplace fit cost on the paper's
  2c2d conv net (one engine sweep + tree assembly; the posterior reuses
  the fused curvature kernels, so this lane tracks the whole fit stack).

* ``laplace/predvar/...`` — the ISSUE-3 tentpole claim: the fused
  ``predictive_var`` kernel computes ``diag(J Σ Jᵀ)`` without ever
  materializing the per-sample Jacobian tensor ``[C, N, a, b]``, vs the
  naive baseline that materializes it, squares it and reduces it (3 full
  passes of HBM traffic).  Kernel-level lanes at batch 128 in the
  serving-shaped regime (short reduce axis, wide features) where the
  baseline is memory-bound; ``derived`` carries the speedup with the
  acceptance target (≥ 3× at batch ≥ 128).

* ``laplace/glm/...`` — end-to-end ``glm_predictive`` (sweep propagation +
  per-layer contraction) on a sequence model with a fused-regime hidden
  layer, fused vs naive per-sample-Jacobian path.

``main`` also dumps its rows to ``BENCH_laplace.json`` so the Laplace
perf trajectory accumulates in-repo across PRs — at the repo root for
local runs, or under ``$BENCH_OUT_DIR`` when set.  CI sets the latter:
runners must never mutate the *committed* baseline in place (the
refreshed file is uploaded as an artifact only, and the committed copy
is what the bench-regression gate diffs against).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import ROWS, emit, quick_mode, time_fn
from repro.configs.papernets import c2d2
from repro.core import (
    Activation,
    CrossEntropyLoss,
    Dense,
    ExtensionConfig,
    Lambda,
    Sequential,
)
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.laplace import (DiagLaplace, FitOptions, KronLaplace,
                           glm_predictive)


def _fit_lanes():
    loss = CrossEntropyLoss()
    n = 8 if quick_mode() else 16
    model = c2d2(n_classes=10, in_ch=1, img=8)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 8, 8, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, 10)
    cfg = ExtensionConfig(use_kernels=True)
    # Return the curvature pytree, not the posterior dataclass: time_fn's
    # block_until_ready sees through pytrees of arrays only, and fit()'s
    # async-dispatched sweep must be awaited inside the timed window.
    opts = FitOptions(cfg=cfg)
    t_diag = time_fn(lambda: DiagLaplace.fit(model, params, x, y, loss,
                                             options=opts).curv,
                     warmup=1, iters=3)
    emit("laplace/fit/diag", t_diag, f"c2d2_n{n}")
    t_kron = time_fn(lambda: KronLaplace.fit(model, params, x, y, loss,
                                             options=opts).kron,
                     warmup=1, iters=3)
    emit("laplace/fit/kron", t_kron, f"c2d2_n{n}")


def _predvar_lanes():
    """Fused kernel vs naive per-sample-Jacobian baseline, batch >= 128."""
    n = 32 if quick_mode() else 128
    r, a, b, c = 8, 512, 256, 16
    k = jax.random.PRNGKey(0)
    A = jax.random.normal(k, (n, r, a))
    S = jax.random.normal(jax.random.fold_in(k, 1), (c, n, r, b))
    Sigma = jax.random.uniform(jax.random.fold_in(k, 2), (a, b))
    naive = jax.jit(ref.predictive_var)
    iters = 3 if quick_mode() else 7
    # Steady-state per-lane timing (NOT the interleaved time_group): the
    # naive lane's GB-scale [C, N, a, b] intermediate evicts the fused
    # lane's cache-resident working set, so alternating lanes charges the
    # baseline's memory damage to the kernel under test (~2× measured).
    # A serving hot path runs one configuration repeatedly — each lane is
    # timed in its own warmed block.
    # 256/128 tiles: ~half-L2-sized contraction slabs measure fastest on
    # CPU interpret (the auto 512-cap tile is tuned for launch-count
    # amortization in the fused-stats kernels, not this streaming one).
    t_fused = time_fn(lambda: kops.predictive_var(A, S, Sigma,
                                                  block_a=256, block_b=128),
                      warmup=2, iters=iters)
    t_naive = time_fn(lambda: naive(A, S, Sigma), warmup=2, iters=iters)
    ratio = t_naive / t_fused
    shape = f"n{n}_r{r}_a{a}_b{b}_c{c}"
    emit("laplace/predvar/fused", t_fused,
         f"{shape};x{ratio:.2f}_vs_naive(target>=3)")
    emit("laplace/predvar/naive", t_naive, shape)


def _glm_lanes():
    """End-to-end GLM predictive: sweep + contraction, fused vs naive."""
    loss = CrossEntropyLoss()
    n, t = (32, 4) if quick_mode() else (128, 8)
    model = Sequential([
        Dense(512, 256), Activation("relu"),
        Lambda(lambda z: jnp.mean(z, axis=1)),
        Dense(256, 10),
    ])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (n, t, 512))
    y = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, 10)
    post = KronLaplace.fit(
        model, params, x, y, loss,
        options=FitOptions(cfg=ExtensionConfig(use_kernels=True)))
    # jit over (params, x) — closing over them as constants would let XLA
    # fold parts of the workload at compile time (every sibling bench
    # passes its arguments for the same reason).
    fused = jax.jit(lambda p, xx: glm_predictive(model, p, post, xx,
                                                 use_kernels=True))
    naive = jax.jit(lambda p, xx: glm_predictive(model, p, post, xx,
                                                 use_kernels=False))
    iters = 3 if quick_mode() else 5
    # Per-lane steady-state timing, same rationale as _predvar_lanes.
    t_fused = time_fn(fused, params, x, warmup=2, iters=iters)
    t_naive = time_fn(naive, params, x, warmup=2, iters=iters)
    ratio = t_naive / t_fused
    emit("laplace/glm/fused", t_fused, f"n{n}_seq{t};x{ratio:.2f}_vs_naive")
    emit("laplace/glm/naive", t_naive, f"n{n}_seq{t}")


def main():
    start = len(ROWS)
    _fit_lanes()
    _predvar_lanes()
    _glm_lanes()
    # Perf-trajectory artifact: this module's rows, refreshed on every run
    # (git history carries the trajectory for local runs; CI redirects to
    # an output dir via BENCH_OUT_DIR and uploads it as an artifact so the
    # committed baseline is never mutated on a runner).
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out_dir = os.environ.get("BENCH_OUT_DIR") or root
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "BENCH_laplace.json")
    with open(path, "w") as f:
        json.dump({"quick": quick_mode(), "rows": ROWS[start:]}, f, indent=2)
    print(f"# wrote {len(ROWS) - start} laplace rows to {path}")


if __name__ == "__main__":
    main()
