"""Shared benchmark utilities: timing + CSV emission."""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup=2, iters=5, **kw):
    """Median wall time (µs) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
