"""Shared benchmark utilities: timing + CSV emission + row capture."""
from __future__ import annotations

import os
import time

import jax

# Every emit() lands here so run.py can dump the whole session as JSON
# (the CI quick-bench artifact).
ROWS = []


def quick_mode() -> bool:
    """Short mode for CI smoke runs (set by ``run.py --quick``)."""
    return os.environ.get("BENCH_QUICK") == "1"


def time_fn(fn, *args, warmup=2, iters=5, **kw):
    """Median wall time (µs) of a jitted callable."""
    if quick_mode():
        warmup, iters = 1, 2
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def time_group(fns, warmup=2, iters=9):
    """Interleaved timing of {lane: thunk} → {lane: min µs}.

    Round-robin across lanes each iteration so slow machine drift (noisy
    shared CPU) hits every lane equally; min-of-k is the standard robust
    estimator for ratio benchmarks.
    """
    if quick_mode():
        warmup, iters = 1, 3
    for _ in range(warmup):
        for fn in fns.values():
            jax.block_until_ready(fn())
    best = {name: float("inf") for name in fns}
    for _ in range(iters):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[name] = min(best[name], time.perf_counter() - t0)
    return {name: t * 1e6 for name, t in best.items()}


def emit(name, us, derived=""):
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})
    print(f"{name},{us:.1f},{derived}")
