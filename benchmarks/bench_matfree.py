"""Matrix-free curvature lanes (ISSUE 9 tentpole).

Three claims, measured:

* a GGN-vector product costs a small constant multiple of one gradient
  (the forward-over-reverse contraction — no factor, O(P) memory);
* a full implicit CG-NGD direction (k products) and the Gram-space
  kernel solve are each one jittable unit;
* the matrix-free vs explicit-factor **crossover**: as the output
  dimension C grows, the explicit KFLR fit's `[C, C]` factor work blows
  up while the implicit solve's per-product cost stays flat — the lane
  that motivates `--optimizer cg_ngd` for LM heads.

Gated lanes are the ``matfree/`` ones (the claims); the ``matfree_ref/``
gradient and explicit-factor baselines exist to be compared against and
are allowed to drift.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, quick_mode, time_fn
from repro.core import (
    Activation,
    CrossEntropyLoss,
    Dense,
    KFLR,
    Sequential,
    run,
)
from repro.curv import GGNOperator, cg_solve, ggn_vp, kernel_ngd_direction


def _mlp(d, h, c, key=0):
    model = Sequential([Dense(d, h), Activation("relu"), Dense(h, c)])
    return model, model.init(jax.random.PRNGKey(key))


def _batch(n, d, c, key=1):
    kx, ky = jax.random.split(jax.random.PRNGKey(key))
    return (jax.random.normal(kx, (n, d)),
            jax.random.randint(ky, (n,), 0, c))


def _product_lanes(loss):
    n, d, h, c = (16, 32, 64, 10) if quick_mode() else (64, 64, 256, 10)
    model, params = _mlp(d, h, c)
    x, y = _batch(n, d, c)
    shape = f"mlp_n{n}_d{d}_h{h}_c{c}"

    grad_fn = jax.jit(
        jax.grad(lambda p: loss.value(model.apply(p, x), y)))
    t_g = time_fn(grad_fn, params)
    emit(f"matfree_ref/grad/{shape}", t_g)

    v = jax.tree.map(jnp.ones_like, params)
    gv_fn = jax.jit(lambda p, t: ggn_vp(model, p, x, y, loss, t))
    t_gv = time_fn(gv_fn, params, v)
    emit(f"matfree/ggn_vp/{shape}", t_gv, f"{t_gv / t_g:.2f}x grad")

    op = GGNOperator(model, params, x, y, loss, damping=1e-2)
    g = grad_fn(params)
    k = 3 if quick_mode() else 8
    cg_fn = jax.jit(lambda b: cg_solve(op.mv, b, maxiter=k).x)
    t_cg = time_fn(cg_fn, g)
    emit(f"matfree/cg{k}/{shape}", t_cg, f"{t_cg / t_g:.2f}x grad")

    ngd_fn = jax.jit(lambda p: kernel_ngd_direction(
        model, p, x, y, loss, damping=1e-2)[0])
    t_k = time_fn(ngd_fn, params)
    emit(f"matfree/kernel_ngd/{shape}", t_k, f"{t_k / t_g:.2f}x grad")


def _crossover_lanes(loss):
    """Explicit KFLR fit vs implicit CG direction as C grows: the
    factor's C² work vs the product's C-linear work."""
    n, d, h = (8, 16, 32) if quick_mode() else (16, 32, 64)
    cs = (8, 64) if quick_mode() else (8, 64, 256, 512)
    k = 3 if quick_mode() else 5
    for c in cs:
        model, params = _mlp(d, h, c)
        x, y = _batch(n, d, c)
        shape = f"c{c}"

        kflr_fn = jax.jit(lambda p, m=model, xx=x, yy=y: run(
            m, p, xx, yy, loss, extensions=(KFLR,)).ext["kflr"])
        t_f = time_fn(kflr_fn, params)
        emit(f"matfree_ref/kflr_fit/{shape}", t_f)

        def direction(p, m=model, xx=x, yy=y):
            op = GGNOperator(m, p, xx, yy, loss, damping=1e-2)
            g = jax.grad(lambda q: loss.value(m.apply(q, xx), yy))(p)
            return cg_solve(op.mv, g, maxiter=k).x

        t_m = time_fn(jax.jit(direction), params)
        winner = "matfree" if t_m < t_f else "explicit"
        emit(f"matfree/cg_direction/{shape}", t_m,
             f"{t_m / t_f:.2f}x kflr ({winner} wins)")


def main():
    loss = CrossEntropyLoss()
    _product_lanes(loss)
    _crossover_lanes(loss)
