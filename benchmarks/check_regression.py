"""Bench-regression gate: diff a fresh bench JSON against its committed
repo-root ``BENCH_*.json`` baseline and fail on fused-lane slowdowns.

The bench-smoke CI job runs each suite in ``--quick`` mode and then calls
this once per (fresh JSON, committed baseline) pair; a gated lane slower
than ``threshold ×`` its baseline fails the job.  Only the *fused* lanes
are gated by default — they are the claims this repo makes; the naive /
per-extension baselines are allowed to drift (they exist to be beaten,
and gating them would double the noise surface).  A gated lane that
disappears from the fresh run also fails: renaming a lane must come with
a baseline refresh, otherwise the gate silently thins out.

Baselines are quick-mode runs committed at the repo root
(``BENCH_smoke_fused.json`` etc.).  CI-runner vs. baseline-machine skew is
what the 1.5× headroom is for; a genuine fused-lane regression (a kernel
losing its fusion, a dispatch cache miss per step) shows up as 2–20×.

Usage::

    python -m benchmarks.check_regression CURRENT BASELINE \
        [--threshold 1.5] [--pattern '/fused(/|$)']

(The default pattern matches a ``fused`` *path segment* — lane names like
``fused_second_order/baseline/...`` carry the module prefix but are
baselines, not fused lanes.)
"""
from __future__ import annotations

import argparse
import json
import re
import sys


def load_rows(path):
    """``{lane name: us_per_call}`` from a bench JSON (bare row list or
    the ``{"quick": ..., "rows": [...]}`` artifact form)."""
    with open(path) as f:
        data = json.load(f)
    rows = data["rows"] if isinstance(data, dict) else data
    return {r["name"]: float(r["us_per_call"]) for r in rows}


def check(current, baseline, threshold, pattern):
    """Compare gated lanes; returns (failures, checked) name lists."""
    pat = re.compile(pattern)
    failures, checked = [], []
    for name, base_us in sorted(baseline.items()):
        if not pat.search(name):
            continue
        if name not in current:
            print(f"FAIL {name}: gated lane missing from current run "
                  "(rename requires a baseline refresh)")
            failures.append(name)
            continue
        ratio = current[name] / base_us
        ok = ratio <= threshold
        print(f"{'ok  ' if ok else 'FAIL'} {name}: {current[name]:.1f}us "
              f"vs baseline {base_us:.1f}us "
              f"(x{ratio:.2f}, limit x{threshold})")
        checked.append(name)
        if not ok:
            failures.append(name)
    for name in sorted(set(current) - set(baseline)):
        if pat.search(name):
            print(f"note {name}: new gated lane (not in baseline — refresh "
                  "the committed BENCH_*.json to start gating it)")
    return failures, checked


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("current", help="fresh bench JSON (this run)")
    ap.add_argument("baseline", help="committed repo-root BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="fail when current/baseline exceeds this (1.5)")
    ap.add_argument("--pattern", default="/fused(/|$)",
                    help="regex selecting gated lane names "
                         "('/fused(/|$)': fused path segments only)")
    args = ap.parse_args(argv)
    current = load_rows(args.current)
    baseline = load_rows(args.baseline)
    failures, checked = check(current, baseline, args.threshold, args.pattern)
    if not checked and not failures:
        print(f"FAIL: no lanes matching '{args.pattern}' in {args.baseline}")
        return 1
    if failures:
        print(f"bench-regression gate: {len(failures)} failure(s) "
              f"of {len(checked) + len(failures)} gated lane(s)")
        return 1
    print(f"bench-regression gate: {len(checked)} gated lane(s) within "
          f"x{args.threshold}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
