"""Fig. 6: overhead of gradient + extension vs gradient alone.

Reported on the paper's 3C3D conv net (reduced for CPU) and on a reduced
transformer — the quantities that reuse the standard sweep (L2 norm,
moments, variance, DiagGGN-MC, KFAC) should cost a small multiple of the
gradient; exact-factor quantities scale with the output dimension.
"""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import emit, time_fn
from repro.configs import ARCHS, SHAPES
from repro.configs.papernets import c3d3
from repro.core import (
    BatchGrad,
    BatchL2,
    CrossEntropyLoss,
    DiagGGN,
    DiagGGNMC,
    ExtensionConfig,
    KFAC,
    KFLR,
    SecondMoment,
    Variance,
    run,
)
from repro.data.synthetic import batch_for
from repro.nn.models import build_model

EXT_SETS = [
    ("grad", ()),
    ("batch_grad", (BatchGrad,)),
    ("batch_l2", (BatchL2,)),
    ("second_moment", (SecondMoment,)),
    ("variance", (Variance,)),
    ("diag_ggn_mc", (DiagGGNMC,)),
    ("kfac", (KFAC,)),
    ("diag_ggn_exact", (DiagGGN,)),
    ("kflr", (KFLR,)),
]


def _bench(tag, model, params, x, y, cfg=None):
    loss = CrossEntropyLoss()
    base = None
    for name, exts in EXT_SETS:
        fn = jax.jit(lambda p, r: run(model, p, x, y, loss, extensions=exts,
                                      cfg=cfg or ExtensionConfig(), rng=r).ext
                     if exts else run(model, p, x, y, loss).grads)
        try:
            t = time_fn(fn, params, jax.random.PRNGKey(1))
        except Exception as e:  # exact factors can legitimately OOM-scale
            emit(f"fig6/{tag}/{name}", -1.0, f"skipped:{type(e).__name__}")
            continue
        if base is None:
            base = t
        emit(f"fig6/{tag}/{name}", t, f"x{t / base:.2f}_vs_grad")


def main():
    model = c3d3(n_classes=10, in_ch=3, img=8)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 8, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)
    _bench("conv3c3d", model, params, x, y)

    cfg = ARCHS["stablelm-1.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16, global_batch=4)
    batch = batch_for(cfg, shape, 0)
    _bench("transformer", model, params, batch["inputs"], batch["labels"],
           cfg=ExtensionConfig(class_chunk=97))


if __name__ == "__main__":
    main()
