"""Fig. 6: overhead of gradient + extension vs gradient alone.

Reported on the paper's 3C3D conv net (reduced for CPU) and on a reduced
transformer — the quantities that reuse the standard sweep (L2 norm,
moments, variance, DiagGGN-MC, KFAC) should cost a small multiple of the
gradient; exact-factor quantities scale with the output dimension.

``obs_overhead`` (bench name ``obs``) is the observability cost lane:
the same fused sweep instrumented (recording ``repro.obs`` registry) vs
uninstrumented (the no-op ``NullRegistry``), for both the jitted
monolithic sweep (instrumentation records at trace time — steady state
must be identical) and the host-driven ``SweepStream`` (per-work-unit
spans fire on every call — the honest per-unit cost).  The
``obs_overhead/*/ratio`` lanes emit the ratio scaled by 1000 so CI can
gate them against a committed parity baseline (1000.0) with
``check_regression --threshold 1.05`` — instrumented must stay within
5% of uninstrumented.
"""
from __future__ import annotations

import dataclasses
import gc
import time

import jax

from benchmarks.common import emit, quick_mode, time_fn
from repro.configs import ARCHS, SHAPES
from repro.configs.papernets import c3d3
from repro.core import (
    BatchGrad,
    BatchL2,
    CrossEntropyLoss,
    DiagGGN,
    DiagGGNMC,
    ExtensionConfig,
    KFAC,
    KFLR,
    SecondMoment,
    Variance,
    run,
)
from repro.data.synthetic import batch_for
from repro.nn.models import build_model

EXT_SETS = [
    ("grad", ()),
    ("batch_grad", (BatchGrad,)),
    ("batch_l2", (BatchL2,)),
    ("second_moment", (SecondMoment,)),
    ("variance", (Variance,)),
    ("diag_ggn_mc", (DiagGGNMC,)),
    ("kfac", (KFAC,)),
    ("diag_ggn_exact", (DiagGGN,)),
    ("kflr", (KFLR,)),
]


def _bench(tag, model, params, x, y, cfg=None):
    loss = CrossEntropyLoss()
    base = None
    for name, exts in EXT_SETS:
        fn = jax.jit(lambda p, r: run(model, p, x, y, loss, extensions=exts,
                                      cfg=cfg or ExtensionConfig(), rng=r).ext
                     if exts else run(model, p, x, y, loss).grads)
        try:
            t = time_fn(fn, params, jax.random.PRNGKey(1))
        except Exception as e:  # exact factors can legitimately OOM-scale
            emit(f"fig6/{tag}/{name}", -1.0, f"skipped:{type(e).__name__}")
            continue
        if base is None:
            base = t
        emit(f"fig6/{tag}/{name}", t, f"x{t / base:.2f}_vs_grad")


def _paired(lanes, rounds, reps):
    """Interleaved min-of-rounds timing of {lane: thunk} → {lane: µs}.

    Like ``time_group`` but with explicit rounds/reps: the overhead gate
    compares two nearly-identical lanes at a 5% threshold, so it needs
    more interleaved rounds than the quick-mode default (3) and ``reps``
    inner calls per sample to push timer noise below the gate.  GC is
    paused during the timed region — a gen-2 collection landing inside
    one lane's sample skews a paired ratio by far more than 5%."""
    for fn in lanes.values():
        jax.block_until_ready(fn())
    best = {name: float("inf") for name in lanes}
    gc_was_on = gc.isenabled()
    gc.collect()
    gc.disable()
    try:
        for _ in range(rounds):
            for name, fn in lanes.items():
                t0 = time.perf_counter()
                for _ in range(reps):
                    jax.block_until_ready(fn())
                best[name] = min(best[name],
                                 (time.perf_counter() - t0) / reps)
    finally:
        if gc_was_on:
            gc.enable()
    return {name: t * 1e6 for name, t in best.items()}


def obs_overhead():
    from repro import obs
    from repro.core import Activation, Dense, Sequential, by_name, plan_sweeps
    from repro.obs import NullRegistry, ObsRegistry

    n, d, h, c = (32, 16, 32, 8) if quick_mode() else (128, 64, 128, 16)
    tag = f"N{n}_d{d}_h{h}_c{c}"
    model = Sequential([Dense(d, h), Activation("sigmoid"), Dense(h, c)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    y = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, c)
    loss = CrossEntropyLoss()
    exts = tuple(by_name(nm) for nm in ("batch_l2", "variance", "diag_ggn"))
    cfg = ExtensionConfig(use_kernels=True)
    plan = plan_sweeps(exts, cfg)
    null = NullRegistry()
    live = ObsRegistry()  # one long-lived registry — the realistic setup
    rounds = 9 if quick_mode() else 15

    # -- jitted monolithic fused sweep: obs records at trace time only,
    # so the steady-state call path must be byte-identical.  Per-call cost
    # is tens of µs, so many inner reps amortize timer noise below the gate.
    fn = jax.jit(lambda p: plan.run(model, p, x, y, loss, cfg=cfg).loss)

    def mono(reg):
        with obs.use(reg):
            return fn(params)

    t = _paired({"off": lambda: mono(null),
                 "on": lambda: mono(live)},
                rounds, reps=50)
    ratio = t["on"] / t["off"]
    emit(f"obs_overhead/fused/uninstrumented/{tag}", t["off"], "1x_baseline")
    emit(f"obs_overhead/fused/instrumented/{tag}", t["on"],
         f"x{ratio:.3f}_vs_uninstrumented")
    emit(f"obs_overhead/fused/ratio/{tag}", ratio * 1000.0,
         "ratio_x1000_gate_le_1050")

    # -- host-driven SweepStream: per-work-unit spans + cursor gauges fire
    # on every drive — the honest recurring instrumentation cost.  One
    # stream instance is rewound between iterations (no retracing).
    stream = plan.accumulate(4).stream(model, params, x, y, loss, cfg=cfg)
    state0 = jax.device_get(stream.state_arrays())

    def drive(reg):
        with obs.use(reg):
            stream.load_state(0, state0)
            while not stream.done:
                stream.step()
            return stream.result().loss

    t = _paired({"off": lambda: drive(null),
                 "on": lambda: drive(live)},
                rounds, reps=1)
    ratio = t["on"] / t["off"]
    emit(f"obs_overhead/stream/uninstrumented/{tag}", t["off"],
         "1x_baseline")
    emit(f"obs_overhead/stream/instrumented/{tag}", t["on"],
         f"x{ratio:.3f}_vs_uninstrumented")
    emit(f"obs_overhead/stream/ratio/{tag}", ratio * 1000.0,
         "ratio_x1000_gate_le_1050")


def main():
    model = c3d3(n_classes=10, in_ch=3, img=8)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 8, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)
    _bench("conv3c3d", model, params, x, y)

    cfg = ARCHS["stablelm-1.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16, global_batch=4)
    batch = batch_for(cfg, shape, 0)
    _bench("transformer", model, params, batch["inputs"], batch["labels"],
           cfg=ExtensionConfig(class_chunk=97))


if __name__ == "__main__":
    main()
