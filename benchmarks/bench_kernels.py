"""Pallas kernel timings (interpret mode — correctness path on CPU) vs the
jnp reference path.  On-TPU the kernels fuse the square/accumulate into
VMEM; here the numbers only document that the interpret path is exercised.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref


def main():
    k = jax.random.PRNGKey(0)
    A = jax.random.normal(k, (8, 64, 128))
    B = jax.random.normal(jax.random.fold_in(k, 1), (8, 64, 128))
    for name, kfn, rfn, args in [
        ("sq_matmul", ops.sq_matmul, ref.sq_matmul, (A[:, 0], B[:, 0])),
        ("per_sample_moment", ops.per_sample_moment, ref.per_sample_moment,
         (A, B)),
        ("batch_l2", ops.batch_l2, ref.batch_l2, (A, B)),
    ]:
        t_ref = time_fn(jax.jit(rfn), *args)
        t_k = time_fn(kfn, *args)
        emit(f"kernels/{name}/jnp_ref", t_ref, "")
        emit(f"kernels/{name}/pallas_interpret", t_k, "correctness_path")


if __name__ == "__main__":
    main()
