"""Property tests for the Laplace subsystem (repro.laplace).

Three families, mirroring the `test_kron_property.py` oracle pattern:

* posterior structure — diag/Kron log-determinants and samples against
  *dense* oracles on tiny nets (the Kronecker identities
  ``logdet(A'⊗B') = b·logdet A' + a·logdet B'`` and
  ``Cov(vec θ) = (A'⊗B')⁻¹`` are pinned against materialized matrices);
* predictives — the fused `predictive_var` kernel against the naive
  per-sample-Jacobian baseline (the ISSUE-3 acceptance differential, rtol
  1e-4, on a papernets conv net where R = 64 puts the kernel on the hot
  path), and GLM vs MC predictive agreement at small posterior covariance;
* marginal likelihood — evidence monotonicity under prior-precision grid
  refinement, and the jit-compiled optimizer improving on its init (full
  lane); plus the ExtensionConfig.mc_seed determinism fix and the
  actionable-misconfiguration errors driven by
  ``SweepPlan.posterior_structures()``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.papernets import c2d2
from repro.core import (
    CrossEntropyLoss,
    Dense,
    DiagGGN,
    DiagGGNMC,
    ExtensionConfig,
    KFAC,
    Sequential,
    kron as K,
    plan_sweeps,
    run,
)
from repro.kernels import ops as kops
from repro.kernels import ref
from repro.laplace import (
    DiagLaplace,
    FitOptions,
    KronLaplace,
    LaplaceStructureError,
    LastLayerLaplace,
    fit_posterior,
    glm_predictive,
    log_marglik,
    mc_predictive,
    optimize_marglik,
    probit_predictive,
)
from repro.laplace.posterior import _map_kron

from _oracles import dense_ggn, tiny_mlp

N, D, H, C = 9, 6, 7, 4
LOSS = CrossEntropyLoss()


@pytest.fixture(scope="module")
def setup():
    return tiny_mlp(N, D, H, C, act="sigmoid")


@pytest.fixture(scope="module")
def conv_setup():
    model = c2d2(n_classes=10, in_ch=1, img=8)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 8, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)
    return model, params, x, y


# ---------------------------------------------------------------------------
# posterior structure vs dense oracles
# ---------------------------------------------------------------------------


_FIT_CACHE = {}


def _fitted(structure):
    """One engine fit per structure for the hypothesis sweeps (the
    hypothesis fallback shim cannot mix @given with pytest fixtures);
    prior precision is applied at evaluation time, not fit time."""
    if structure not in _FIT_CACHE:
        model, params, x, y = tiny_mlp(N, D, H, C, act="sigmoid")
        _FIT_CACHE[structure] = fit_posterior(model, params, x, y, LOSS,
                                              structure=structure)
    return _FIT_CACHE[structure]


@settings(max_examples=8, deadline=None)
@given(lam=st.floats(1e-2, 50.0))
def test_diag_logdet_matches_dense_oracle(lam):
    post = _fitted("diag")
    prec = jnp.concatenate([
        l.reshape(-1) for l in jax.tree.leaves(post.precision(lam))])
    want = jnp.linalg.slogdet(jnp.diag(prec))[1] - prec.size * jnp.log(lam)
    np.testing.assert_allclose(float(post.log_det_ratio(lam)), float(want),
                               rtol=2e-4)


@settings(max_examples=8, deadline=None)
@given(lam=st.floats(1e-2, 50.0))
def test_kron_logdet_matches_dense_oracle(lam):
    """Closed form b·logdet A' + a·logdet B' vs materialized kron blocks."""
    post = _fitted("kron")
    terms = []

    def dense_ld(mean_leaf, block):
        Ad, Bd = post.damped_factors(block, prior_prec=lam)
        M = Bd if Ad is None else K.kron_dense(Ad, Bd)
        terms.append(jnp.linalg.slogdet(M)[1])

    _map_kron(dense_ld, post.mean, post.kron)
    want = sum(terms) - post.n_params() * jnp.log(lam)
    np.testing.assert_allclose(float(post.log_det_ratio(lam)), float(want),
                               rtol=2e-4)


def test_diag_curvature_matches_dense_ggn_diagonal(setup):
    """The fitted diag posterior's curvature tree == diag(Jᵀ H J) of the
    materialized mean-loss GGN (the shared `_oracles` construction)."""
    model, params, x, y = setup
    post = _fitted("diag")
    G, flat, _ = dense_ggn(model, params, x, y, LOSS)
    got = jnp.concatenate([
        l.reshape(-1) for l in jax.tree.leaves(post.curv)])
    np.testing.assert_allclose(np.asarray(got), np.asarray(jnp.diag(G)),
                               rtol=3e-5, atol=3e-5)


def test_diag_sampling_covariance_matches_inverse_precision(setup):
    model, params, x, y = setup
    post = DiagLaplace.fit(model, params, x, y, LOSS,
                           options=FitOptions(prior_prec=2.0))
    thetas = post.sample(jax.random.PRNGKey(3), 4000)
    w = jax.tree.leaves(thetas)[0]          # first Dense weight, [K, D, H]
    var = jnp.var(w, axis=0)
    want = 1.0 / jax.tree.leaves(post.precision())[0]
    np.testing.assert_allclose(np.asarray(var), np.asarray(want),
                               rtol=0.2, atol=1e-4)


def test_kron_sampling_covariance_matches_dense_inverse():
    """Cov(vec θ) of matrix-normal samples == (A'⊗B')⁻¹ (dense oracle)."""
    model = Sequential([Dense(3, 2)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (32,), 0, 2)
    post = KronLaplace.fit(model, params, x, y, LOSS,
                           options=FitOptions(prior_prec=1.5))
    thetas = post.sample(jax.random.PRNGKey(3), 6000)
    w = thetas[0]["w"].reshape(6000, -1)     # vec in [a, b] row-major
    emp = jnp.cov(w.T)
    Ad, Bd = post.damped_factors(post.kron[0]["w"])
    want = jnp.linalg.inv(K.kron_dense(Ad, Bd))
    np.testing.assert_allclose(np.asarray(emp), np.asarray(want),
                               atol=0.12 * float(jnp.max(jnp.abs(want))))


# ---------------------------------------------------------------------------
# predictives: fused kernel vs naive baseline, GLM vs MC
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 9), r=st.integers(2, 10), a=st.integers(2, 140),
       b=st.integers(2, 70), c=st.integers(1, 6), with_sigma=st.booleans(),
       seed=st.integers(0, 2 ** 16))
def test_predictive_var_kernel_matches_oracle(n, r, a, b, c, with_sigma,
                                              seed):
    k = jax.random.PRNGKey(seed)
    A = jax.random.normal(k, (n, r, a))
    S = jax.random.normal(jax.random.fold_in(k, 1), (c, n, r, b))
    Sigma = (jax.random.uniform(jax.random.fold_in(k, 2), (a, b))
             if with_sigma else None)
    got = kops.predictive_var(A, S, Sigma)
    want = ref.predictive_var(A, S, Sigma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("structure", ["diag", "kron"])
def test_glm_predictive_fused_matches_naive_on_papernet(conv_setup,
                                                        structure):
    """ISSUE-3 acceptance: KronLaplace.fit + glm_predictive on a papernets
    model, fused predictive-variance kernel vs naive per-sample-Jacobian
    baseline to rtol 1e-4 (c2d2's unfold gives R = 64, so the kernel is
    genuinely on the timed path)."""
    model, params, x, y = conv_setup
    post = fit_posterior(model, params, x, y, LOSS, structure=structure,
                         options=FitOptions(prior_prec=3.0))
    m1, v1 = glm_predictive(model, params, post, x, use_kernels=True)
    m2, v2 = glm_predictive(model, params, post, x, use_kernels=False)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                               rtol=1e-4, atol=1e-7)
    assert np.all(np.asarray(v1) > 0)


@pytest.mark.parametrize("structure", ["diag", "kron"])
def test_glm_matches_mc_predictive_at_small_covariance(setup, structure):
    """Linearization is exact in the small-Σ limit: GLM variance must match
    the MC variance over posterior samples (tight prior → tiny Σ)."""
    model, params, x, y = setup
    post = fit_posterior(model, params, x, y, LOSS, structure=structure,
                         options=FitOptions(prior_prec=1e4))
    gm, gv = glm_predictive(model, params, post, x)
    mm, mv = mc_predictive(model, params, post, x, jax.random.PRNGKey(3),
                           n_samples=4000)
    np.testing.assert_allclose(np.asarray(mm), np.asarray(gm),
                               atol=3e-2)
    np.testing.assert_allclose(np.asarray(mv), np.asarray(gv),
                               rtol=0.15)


@pytest.mark.parametrize("structure", ["diag", "kron"])
def test_dense_head_closed_form_matches_generic_sweep(structure):
    """The seed-free closed form used for bare Dense heads (the
    LM-vocabulary-scale path) must equal the generic Jacobian-factor
    sweep, which Sequential([Dense]) still routes through."""
    head = Dense(5, 3)
    params = head.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 5))
    y = jax.random.randint(jax.random.PRNGKey(2), (7,), 0, 3)
    post = fit_posterior(head, params, x, y, LOSS, structure=structure,
                         options=FitOptions(prior_prec=2.0))
    m_fast, v_fast = glm_predictive(head, params, post, x)
    wrapped = Sequential([head])
    m_gen, v_gen = glm_predictive(wrapped, params=(params,),
                                  posterior=_wrap_blocks(post), x=x)
    np.testing.assert_allclose(np.asarray(m_fast), np.asarray(m_gen),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(v_fast), np.asarray(v_gen),
                               rtol=1e-5, atol=1e-7)


def _wrap_blocks(post):
    """Same posterior with its layer blocks nested one Sequential deep."""
    return dataclasses.replace(
        post, **({"curv": (post.curv,)} if hasattr(post, "curv")
                 else {"kron": (post.kron,)}))


def test_last_layer_predictive_and_sampling(setup):
    model, params, x, y = setup
    post = fit_posterior(model, params, x, y, LOSS, structure="kron",
                         last_layer=True,
                         options=FitOptions(prior_prec=5.0))
    mean, var = glm_predictive(model, params, post, x)
    assert mean.shape == (N, C) and var.shape == (N, C)
    assert np.all(np.asarray(var) > 0)
    probs = probit_predictive(mean, var)
    np.testing.assert_allclose(np.asarray(probs.sum(-1)), 1.0, rtol=1e-5)
    thetas = post.sample(jax.random.PRNGKey(4), 3)
    assert all(l.shape[0] == 3 for l in jax.tree.leaves(thetas))
    # sampled full trees drive the plain forward pass
    zs = jax.vmap(lambda p: model.apply(p, x))(thetas)
    assert zs.shape == (3, N, C)


# ---------------------------------------------------------------------------
# marginal likelihood
# ---------------------------------------------------------------------------


def test_marglik_monotone_under_prior_refinement(setup):
    """Refining the prior-precision grid around the coarse argmax can only
    improve the evidence (the satellite's monotonicity property)."""
    model, params, x, y = setup
    post = DiagLaplace.fit(model, params, x, y, LOSS)
    coarse = np.logspace(-2, 2, 5)
    vals_c = [float(log_marglik(post, d)) for d in coarse]
    i = int(np.argmax(vals_c))
    lo = coarse[max(i - 1, 0)]
    hi = coarse[min(i + 1, len(coarse) - 1)]
    refined = np.logspace(np.log10(lo), np.log10(hi), 9)
    vals_r = [float(log_marglik(post, d)) for d in refined]
    assert max(vals_r) >= max(vals_c) - 1e-6
    # the grid argmax is interior at this resolution — evidence is unimodal
    assert 0 < int(np.argmax(vals_r)) < len(refined) - 1


@pytest.mark.slow
@pytest.mark.parametrize("structure", ["diag", "kron"])
def test_optimize_marglik_improves_evidence(setup, structure):
    """The jit-compiled evidence-ascent loop beats its init and a coarse
    grid (full-lane: runs the scan for both structures)."""
    model, params, x, y = setup
    post = fit_posterior(model, params, x, y, LOSS, structure=structure,
                         options=FitOptions(prior_prec=100.0))
    before = float(log_marglik(post))
    tuned, res = optimize_marglik(post, n_steps=300, lr=0.2)
    after = float(log_marglik(tuned))
    assert after > before
    assert after >= max(float(log_marglik(post, d))
                        for d in np.logspace(-2, 2, 5))
    assert res.history.shape == (300,)
    assert res.prior_prec > 0


# ---------------------------------------------------------------------------
# MC seeding + misconfiguration errors
# ---------------------------------------------------------------------------


def test_mc_seed_makes_repeated_runs_deterministic(setup):
    model, params, x, y = setup
    cfg = ExtensionConfig(mc_seed=7)
    r1 = run(model, params, x, y, LOSS, extensions=(DiagGGNMC, KFAC), cfg=cfg)
    r2 = run(model, params, x, y, LOSS, extensions=(DiagGGNMC, KFAC), cfg=cfg)
    for a, b in zip(jax.tree.leaves(r1.ext), jax.tree.leaves(r2.ext)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    r3 = run(model, params, x, y, LOSS, extensions=(DiagGGNMC,),
             cfg=ExtensionConfig(mc_seed=8))
    assert not all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(r1.ext["diag_ggn_mc"]),
                        jax.tree.leaves(r3.ext["diag_ggn_mc"])))
    # explicit rng still takes precedence; no seed at all stays an error
    with pytest.raises(ValueError, match="mc_seed"):
        run(model, params, x, y, LOSS, extensions=(DiagGGNMC,))


def test_mc_fit_is_deterministic_by_default(setup):
    model, params, x, y = setup
    p1 = DiagLaplace.fit(model, params, x, y, LOSS,
                         options=FitOptions(mc=True))
    p2 = DiagLaplace.fit(model, params, x, y, LOSS,
                         options=FitOptions(mc=True))
    for a, b in zip(jax.tree.leaves(p1.curv), jax.tree.leaves(p2.curv)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_plan_reports_posterior_structures():
    cfg = ExtensionConfig()
    plan = plan_sweeps((DiagGGN,), cfg)
    assert plan.posterior_structures() == ("diag", "last_layer")
    assert "laplace=['diag', 'last_layer']" in plan.describe()
    assert plan_sweeps((KFAC,), cfg).posterior_structures() == (
        "kron", "last_layer")
    assert plan_sweeps((), cfg).posterior_structures() == ()
    assert "laplace=None" in plan_sweeps((), cfg).describe()


def test_misconfigured_fits_raise_actionable_errors(setup):
    model, params, x, y = setup
    # kron fit over a diag-only extension set: the plan is in the message
    with pytest.raises(LaplaceStructureError, match="kron.*KFLR/KFAC"):
        KronLaplace.fit(model, params, x, y, LOSS,
                        options=FitOptions(extensions=(DiagGGN,)))
    with pytest.raises(LaplaceStructureError, match="diag"):
        DiagLaplace.fit(model, params, x, y, LOSS,
                        options=FitOptions(extensions=(KFAC,),
                                           cfg=ExtensionConfig(mc_seed=0)))
    with pytest.raises(LaplaceStructureError, match="Sequential"):
        LastLayerLaplace.fit(Dense(3, 2), Dense(3, 2).init(
            jax.random.PRNGKey(0)), x, y, LOSS)
    with pytest.raises(LaplaceStructureError, match="structure"):
        fit_posterior(model, params, x, y, LOSS, structure="full")


def test_fit_legacy_keywords_warn_but_work(setup):
    """Pre-FitOptions keywords are shims: same result, DeprecationWarning,
    and typos still raise TypeError like a real signature."""
    model, params, x, y = setup
    with pytest.warns(DeprecationWarning, match="FitOptions"):
        old = DiagLaplace.fit(model, params, x, y, LOSS, prior_prec=2.0)
    new = DiagLaplace.fit(model, params, x, y, LOSS,
                          options=FitOptions(prior_prec=2.0))
    assert old.prior_prec == new.prior_prec == 2.0
    for a, b in zip(jax.tree.leaves(old.curv), jax.tree.leaves(new.curv)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.warns(DeprecationWarning, match="fit_posterior"):
        fit_posterior(model, params, x, y, LOSS, structure="kron",
                      last_layer=True, mc=True)
    with pytest.raises(TypeError, match="unexpected keyword"):
        DiagLaplace.fit(model, params, x, y, LOSS, pror_prec=2.0)


def test_loop_marglik_callback_records_evidence():
    """Online-marglik callback: evidence + tuned prior land in history."""
    from repro.configs import ARCHS, SHAPES
    from repro.nn.models import build_model
    from repro.optim import adamw
    from repro.train.loop import LoopConfig, fit

    cfg = ARCHS["stablelm-1.6b"].reduced()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16,
                                global_batch=4)
    model = build_model(cfg)
    _, _, hist, _ = fit(model, cfg, shape, adamw(1e-3),
                        LoopConfig(steps=2, marglik_every=2,
                                   marglik_steps=5, log_every=1000),
                        log_fn=lambda *_: None)
    assert "marglik" in hist[1] and "prior_prec" in hist[1]
    assert np.isfinite(hist[1]["marglik"])
