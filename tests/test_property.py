"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    Activation,
    BatchGrad,
    BatchL2,
    CrossEntropyLoss,
    Dense,
    DiagGGN,
    KFLR,
    SecondMoment,
    Sequential,
    Variance,
    kron,
    run,
)

LOSS = CrossEntropyLoss()


def _model(d, h, c):
    return Sequential([Dense(d, h), Activation("tanh"), Dense(h, c)])


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 8), d=st.integers(2, 8), c=st.integers(2, 6),
       seed=st.integers(0, 2 ** 16))
def test_variance_nonneg_and_moment_identity(n, d, c, seed):
    model = _model(d, d + 1, c)
    params = model.init(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, d))
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, c)
    res = run(model, params, x, y, LOSS,
              extensions=(BatchGrad, SecondMoment, Variance, BatchL2))
    for v in jax.tree.leaves(res["variance"]):
        assert float(jnp.min(v)) >= -1e-5
    # Σ_j second_moment_j / N == E‖∇ℓ‖²/N relation with batch_l2:
    sm_sum = sum(float(jnp.sum(l)) for l in jax.tree.leaves(res["second_moment"]))
    l2_sum = sum(float(jnp.sum(l)) for l in jax.tree.leaves(res["batch_l2"]))
    np.testing.assert_allclose(sm_sum, n * l2_sum, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 6), d=st.integers(2, 6), c=st.integers(2, 5),
       seed=st.integers(0, 2 ** 16))
def test_ggn_psd_via_factors(n, d, c, seed):
    model = _model(d, d, c)
    params = model.init(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, d))
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, c)
    res = run(model, params, x, y, LOSS, extensions=(DiagGGN, KFLR))
    for l in jax.tree.leaves(res["diag_ggn"]):
        assert float(jnp.min(l)) >= -1e-7
    for slot in (0, 2):
        f = res["kflr"][slot]
        for mat in (f["w"]["A"], f["w"]["B"]):
            m = np.asarray(mat, np.float64)
            np.testing.assert_allclose(m, m.T, atol=1e-6)
            assert np.linalg.eigvalsh(m).min() >= -1e-6


@settings(max_examples=10, deadline=None)
@given(a=st.integers(2, 7), b=st.integers(2, 7), lam=st.floats(1e-3, 1.0),
       seed=st.integers(0, 2 ** 16))
def test_kron_solve_matches_dense(a, b, lam, seed):
    k = jax.random.PRNGKey(seed)
    MA = jax.random.normal(k, (a, a))
    MB = jax.random.normal(jax.random.fold_in(k, 1), (b, b))
    A = MA @ MA.T / a
    B = MB @ MB.T / b
    g = jax.random.normal(jax.random.fold_in(k, 2), (a, b))
    got = kron.kron_solve(A, B, g, lam)
    # dense reference with the SAME π-split damping (Eq. 28)
    pi = kron.pi_factor(A, B)
    Ad = A + pi * jnp.sqrt(lam) * jnp.eye(a)
    Bd = B + jnp.sqrt(lam) / pi * jnp.eye(b)
    dense = jnp.kron(Ad, Bd)
    want = jnp.linalg.solve(dense, g.reshape(-1)).reshape(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 6), seed=st.integers(0, 2 ** 16))
def test_loss_sqrt_factor_squares_to_hessian(n, seed):
    c = 5
    z = jax.random.normal(jax.random.PRNGKey(seed), (n, c))
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0, c)
    S = LOSS.sqrt_hessian(z, y)  # [C·1? , n, c] — per-unit columns
    H_from_S = jnp.einsum("kni,knj->nij", S, S)
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (n, c))
    hv = jnp.einsum("nij,nj->ni", H_from_S, v)
    want = LOSS.hessian_vec(z, y, v)
    np.testing.assert_allclose(np.asarray(hv), np.asarray(want),
                               rtol=1e-4, atol=1e-6)
