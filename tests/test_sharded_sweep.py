"""Batch-sharded sweep lane: reducer properties + sharded-vs-single parity.

In-process tests build the mesh from however many devices the process owns
— 1 in the default lanes (the shard_map path, the scale-corrected loss and
every reducer still execute), 8 in the ``tests-multidevice`` CI lane
(``XLA_FLAGS=--xla_force_host_platform_device_count=8`` exported before
jax initializes).  A subprocess test (marked ``sharding``) guarantees
genuine multi-device exactness even when the running process owns a single
device; it skips itself where the in-process tests are already
multi-device.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    ALL_EXTENSIONS,
    Activation,
    CrossEntropyLoss,
    Dense,
    DiagGGNMC,
    ExtensionConfig,
    Sequential,
    by_name,
    plan_sweeps,
    reduce_spec,
    run,
)
from repro.core.engine import _chan_merge, local_loss_and_grad
from repro.launch.mesh import make_data_mesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

N, D_IN, H, C = 16, 6, 7, 4


@pytest.fixture(scope="module")
def setup():
    model = Sequential([Dense(D_IN, H), Activation("sigmoid"), Dense(H, C)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (N, D_IN))
    y = jax.random.randint(jax.random.PRNGKey(2), (N,), 0, C)
    return model, params, x, y


# ---------------------------------------------------------------------------
# reducer declarations
# ---------------------------------------------------------------------------


def test_reduce_spec_table():
    from repro.core import Reducer

    spec = reduce_spec(ALL_EXTENSIONS)
    assert all(isinstance(r, Reducer) for r in spec.values())
    assert {nm: r.name for nm, r in spec.items()} == {
        "batch_grad": "concat",
        "batch_l2": "concat",
        "batch_dot": "gram",
        "second_moment": "psum",
        "variance": "moment_merge",
        "diag_ggn": "psum",
        "diag_ggn_mc": "psum",
        "kflr": "kron",
        "kfac": "kron",
        "kfra": "pmean",
        "diag_hessian": "psum",
        "ggn_trace": "concat",
        "ggn_gram": "gram_pair",
        "ntk": "gram",
        "ntk_classwise": "gram",
    }


def test_describe_reports_placement(setup):
    model, params, x, y = setup
    mesh = make_data_mesh()
    exts = (by_name("batch_l2"), by_name("variance"), by_name("kfac"))
    desc = plan_sweeps(exts, ExtensionConfig()).shard(mesh, "data").describe()
    assert "shard_axes=['data']" in desc
    assert f"shards={jax.device_count()}" in desc
    assert "batch_l2:concat->sharded(axis0)" in desc
    assert "variance:moment_merge->replicated" in desc
    assert "kfac:kron->replicated" in desc
    assert "grads:psum->replicated" in desc


# ---------------------------------------------------------------------------
# pairwise moment merge (the 'moment_merge' reducer's arithmetic)
# ---------------------------------------------------------------------------


@given(n_shards=st.integers(min_value=1, max_value=8),
       per_shard=st.integers(min_value=1, max_value=6),
       offset=st.floats(min_value=-100.0, max_value=100.0),
       seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_moment_merge_property(n_shards, per_shard, offset, seed):
    """A binary tree of Chan merges over per-shard (count, mean, M2)
    triples reproduces the global n·M2 == n·Σg² − (Σg)² exactly."""
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(n_shards * per_shard, 3)) * 2.0 + offset
    parts = []
    for s in range(n_shards):
        loc = g[s * per_shard:(s + 1) * per_shard]
        nl = float(per_shard)
        mean = loc.sum(0) / nl
        m2 = (loc ** 2).sum(0) - loc.sum(0) ** 2 / nl
        parts.append((nl, mean, m2))
    while len(parts) > 1:
        merged = [_chan_merge(parts[i], parts[i + 1])
                  for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            merged.append(parts[-1])
        parts = merged
    n, _, m2 = parts[0]
    direct = (g.shape[0] * (g ** 2).sum(0) - g.sum(0) ** 2)
    np.testing.assert_allclose(n * m2, direct, rtol=1e-9, atol=1e-7)


def test_moment_merge_beats_naive_cancellation():
    """The merge path never forms the catastrophically cancelling global
    Σg² − (Σg)²/n between large intermediates: with a large common offset
    in float32 it stays near the float64 truth where the naive single-pass
    formula has lost most of its bits."""
    rng = np.random.default_rng(0)
    g64 = rng.normal(size=(64,)) * 1e-2 + 1e4
    g = g64.astype(np.float32)
    truth = float(len(g64) * (((g64 - g64.mean()) ** 2).sum()))
    parts = []
    for s in range(8):
        loc = g[s * 8:(s + 1) * 8].astype(np.float32)
        nl = np.float32(8.0)
        mean = loc.sum() / nl
        m2 = ((loc - mean) ** 2).sum()
        parts.append((nl, mean, m2))
    while len(parts) > 1:
        parts = [_chan_merge(parts[i], parts[i + 1])
                 for i in range(0, len(parts), 2)]
    merged = float(parts[0][0] * parts[0][2])
    naive = float(
        np.float32(len(g)) * np.float32((g ** 2).sum())
        - np.float32(g.sum()) ** 2)
    assert abs(merged - truth) <= abs(naive - truth)
    np.testing.assert_allclose(merged, truth, rtol=5e-2)


# ---------------------------------------------------------------------------
# sharded lane behavior
# ---------------------------------------------------------------------------


def test_sharded_loss_logits_grads(setup):
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    mesh = make_data_mesh()
    ref = run(model, params, x, y, loss)
    plan = plan_sweeps((), ExtensionConfig())
    res = plan.shard(mesh, "data").run(model, params, x, y, loss)
    np.testing.assert_allclose(np.asarray(res.loss), np.asarray(ref.loss),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(res.logits),
                               np.asarray(ref.logits), rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(ref.grads), jax.tree.leaves(res.grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_sharded_masked_loss_scaling(setup):
    """Uneven padding masks across shards: the psum'd unit count keeps the
    global 1/M normalization exact (a pmean of local means would not)."""
    model, params, x, _ = setup
    loss = CrossEntropyLoss()
    # first half of the batch almost fully masked — shard unit counts differ
    y = jax.random.randint(jax.random.PRNGKey(5), (N,), 0, C)
    y = y.at[: N // 2].set(-1)
    y = y.at[0].set(1)  # keep at least one valid unit in the first shards
    mesh = make_data_mesh()
    ref = run(model, params, x, y, loss, extensions=(by_name("batch_l2"),))
    res = plan_sweeps((by_name("batch_l2"),), ExtensionConfig()).shard(
        mesh, "data").run(model, params, x, y, loss)
    np.testing.assert_allclose(np.asarray(res.loss), np.asarray(ref.loss),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(res.ext["batch_l2"][0]["w"]),
                               np.asarray(ref.ext["batch_l2"][0]["w"]),
                               rtol=3e-5, atol=3e-6)


def test_local_loss_and_grad_is_unreduced_seam(setup):
    """psum(local contributions) == the engine's global gradient — the
    compressed-DP step's compression seam."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    model, params, x, y = setup
    loss = CrossEntropyLoss()
    mesh = make_data_mesh()

    def body(p, xx, yy):
        lv, g = local_loss_and_grad(model, p, xx, yy, loss, ("data",))
        return lv, jax.tree.map(lambda a: jax.lax.psum(a, ("data",)), g)

    lv, g = shard_map(body, mesh=mesh,
                      in_specs=(P(), P(("data",)), P(("data",))),
                      out_specs=(P(), P()), check_rep=False)(params, x, y)
    ref = run(model, params, x, y, loss)
    np.testing.assert_allclose(np.asarray(lv), np.asarray(ref.loss),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(ref.grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_sharded_mc_needs_seed_or_rng(setup):
    model, params, x, y = setup
    sp = plan_sweeps((DiagGGNMC,), ExtensionConfig()).shard(
        make_data_mesh(), "data")
    with pytest.raises(ValueError, match="rng"):
        sp.run(model, params, x, y, CrossEntropyLoss())


@pytest.mark.skipif(jax.device_count() < 2
                    and not os.environ.get("REPRO_REQUIRE_MULTIDEVICE"),
                    reason="needs a multi-device process: divisibility is "
                           "trivially satisfied at 1 device, so the check "
                           "only bites on a real mesh; the tests-multidevice "
                           "CI lane (8 virtual devices) runs it with "
                           "REPRO_REQUIRE_MULTIDEVICE=1")
def test_sharded_batch_divisibility_error(setup):
    # under the require flag a 1-device process is a lane misconfiguration,
    # not a reason to skip
    assert jax.device_count() >= 2, (
        "REPRO_REQUIRE_MULTIDEVICE is set but the process owns "
        f"{jax.device_count()} device(s) — the multidevice lane must set "
        "XLA_FLAGS=--xla_force_host_platform_device_count=8 before jax "
        "initializes")
    model, params, x, y = setup
    sp = plan_sweeps((), ExtensionConfig()).shard(make_data_mesh(), "data")
    with pytest.raises(ValueError, match="divisible"):
        sp.run(model, params, x[:jax.device_count() + 1],
               y[:jax.device_count() + 1], CrossEntropyLoss())


def test_dist_kfac_step_matches_single_device(setup):
    """The end-to-end consumer: one sharded sweep → Kronecker factors →
    preconditioned update equals the single-device extended step (factor
    compression off for exact comparison).  Runs on the process's devices
    — 1 in the default lanes, 8 in tests-multidevice."""
    from repro.distributed import make_dist_kfac_step
    from repro.optim import curvature_optimizer
    from repro.train.step import make_extended_train_step

    model, params, x, y = setup
    loss = CrossEntropyLoss()
    batch = {"inputs": x, "labels": y}
    opt = curvature_optimizer(1e-2, curvature="kfac")
    state = opt.init(params)
    cfg = ExtensionConfig(mc_seed=0)
    rng = jax.random.PRNGKey(3)
    dist = make_dist_kfac_step(model, loss, opt, (by_name("kfac"),),
                               make_data_mesh(), cfg=cfg, compress=False)
    p1, _, m1 = dist(params, state, batch, jnp.int32(0), rng)
    single = make_extended_train_step(model, loss, opt, (by_name("kfac"),),
                                      cfg)
    p2, _, m2 = single(params, state, batch, jnp.int32(0), rng)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_dist_kfac_step_rejects_dataless_mesh(setup):
    from repro.distributed import make_dist_kfac_step
    from repro.launch.mesh import make_mesh
    from repro.optim import curvature_optimizer

    model, *_ = setup
    opt = curvature_optimizer(1e-2, curvature="kflr")
    with pytest.raises(ValueError, match="data-parallel axis"):
        make_dist_kfac_step(model, CrossEntropyLoss(), opt,
                            (by_name("kflr"),), make_mesh((1,), ("model",)))
    with pytest.raises(ValueError, match="curvature extension"):
        make_dist_kfac_step(model, CrossEntropyLoss(), opt, (),
                            make_data_mesh())


# ---------------------------------------------------------------------------
# genuine multi-device exactness from a single-device session (subprocess)
# ---------------------------------------------------------------------------

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import itertools, json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import (ALL_EXTENSIONS, Activation, CrossEntropyLoss,
                            Dense, ExtensionConfig, Sequential, run,
                            plan_sweeps)
    from repro.launch.mesh import make_mesh

    model = Sequential([Dense(6, 7), Activation("sigmoid"), Dense(7, 4)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 6))
    y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, 4)
    loss = CrossEntropyLoss()
    exts = tuple(ALL_EXTENSIONS)
    rng = jax.random.PRNGKey(42)
    checked = 0
    for nd in (2, 8):
        mesh = make_mesh((nd,), ("data",))
        for uk in (False, True):
            cfg = ExtensionConfig(use_kernels=uk)
            ref = run(model, params, x, y, loss, extensions=exts, cfg=cfg,
                      rng=rng)
            res = plan_sweeps(exts, cfg).shard(mesh, "data").run(
                model, params, x, y, loss, cfg=cfg, rng=rng)
            np.testing.assert_allclose(np.asarray(res.loss),
                                       np.asarray(ref.loss), rtol=1e-6)
            for name in ref.ext:
                ra = jax.tree.leaves(ref.ext[name])
                rb = jax.tree.leaves(res.ext[name])
                assert len(ra) == len(rb) and ra, name
                for a, b in zip(ra, rb):
                    assert a.shape == b.shape, (name, nd, uk)
                    np.testing.assert_allclose(
                        np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5,
                        err_msg=f"{{name}} nd={{nd}} uk={{uk}}")
                    checked += 1
    print(json.dumps({{"ok": True, "checked": checked}}))
""")


@pytest.mark.slow
@pytest.mark.sharding
def test_sharded_exactness_8dev_subprocess():
    if jax.device_count() >= 2:
        pytest.skip("in-process sharded tests already run multi-device")
    code = _SUBPROC.format(src=os.path.abspath(SRC))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"] and res["checked"] > 0
