"""Streaming accumulated sweep lane: sequential-reducer properties,
error paths, and the microbatch wiring of the downstream consumers.

The differential suite (tests/test_differential.py) pins
``accumulate(k) == monolithic`` for every extension subset × kernel
configuration; this module covers the pieces around it — the Chan-merge
algebra the sequential 'moment_merge' fold relies on, the actionable
rejection of reducers without a sequential accumulator, and the
``ExtensionConfig(microbatch_size=...)`` plumbing through the train step,
the training loop, and the Laplace fits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Activation,
    CrossEntropyLoss,
    Dense,
    DiagGGNMC,
    ExtensionConfig,
    Sequential,
    by_name,
    plan_sweeps,
    run,
)
from repro.core.engine import _chan_merge
from repro.launch.mesh import make_data_mesh

N, D_IN, H, C = 10, 6, 7, 4


@pytest.fixture(scope="module")
def setup():
    model = Sequential([Dense(D_IN, H), Activation("sigmoid"), Dense(H, C)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (N, D_IN))
    y = jax.random.randint(jax.random.PRNGKey(2), (N,), 0, C)
    return model, params, x, y


# ---------------------------------------------------------------------------
# the sequential Chan fold (the 'moment_merge' accumulator's arithmetic)
# ---------------------------------------------------------------------------


def _triple(rows):
    nl = float(len(rows))
    s = rows.sum(0)
    return nl, s / nl, (rows ** 2).sum(0) - s ** 2 / nl


@given(sizes=st.lists(st.integers(min_value=1, max_value=7), min_size=1,
                      max_size=6),
       offset=st.floats(min_value=-100.0, max_value=100.0),
       seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_chan_sequential_fold_property(sizes, offset, seed):
    """The accumulated lane's *sequential left fold* of Chan merges over
    arbitrarily-sized (uneven) microbatch triples is associative-in-effect:
    it reproduces both the direct whole-batch ``n·Σg² − (Σg)²`` and the
    sharded lane's binary merge tree over the same partition."""
    rng = np.random.default_rng(seed)
    slices = [rng.normal(size=(s, 3)) * 2.0 + offset for s in sizes]
    g = np.concatenate(slices, 0)

    # sequential left fold (zero-initialized, as the scan carry is)
    acc = (0.0, np.zeros(3), np.zeros(3))
    for sl in slices:
        acc = _chan_merge(acc, _triple(sl))

    # binary merge tree (the sharded reducer's schedule)
    parts = [_triple(sl) for sl in slices]
    while len(parts) > 1:
        merged = [_chan_merge(parts[i], parts[i + 1])
                  for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            merged.append(parts[-1])
        parts = merged

    direct = g.shape[0] * (g ** 2).sum(0) - g.sum(0) ** 2
    for n, _, m2 in (acc, parts[0]):
        assert n == g.shape[0]
        np.testing.assert_allclose(n * m2, direct, rtol=1e-9, atol=1e-6)


# ---------------------------------------------------------------------------
# plan construction + error paths
# ---------------------------------------------------------------------------


def test_accumulate_rejects_non_streaming_reducers():
    """BatchDot ('gram') and KFRA ('pmean') stream now; the capability
    gate remains for third-party reducers that genuinely need the whole
    batch resident — ``supports_streaming = False`` must fail fast with
    the extension and reducer names, not with a shape error three layers
    deep."""
    from repro.core import Extension, Reducer

    class WholeBatchReducer(Reducer):
        name = "whole_batch_test"
        supports_streaming = False

    ext = Extension("_whole_batch_stat", "first", reduce=WholeBatchReducer())
    plan = plan_sweeps((ext,), ExtensionConfig()).accumulate(2)
    with pytest.raises(ValueError, match="sequential accumulator") as ei:
        plan._check_extensions((ext,))
    assert "_whole_batch_stat" in str(ei.value)
    assert "whole_batch_test" in str(ei.value)
    assert "supports_streaming" in str(ei.value)


def test_accumulate_streams_gram_and_pmean(setup):
    """The former rejection cases: BatchDot's Gram matrix and KFRA's Ḡ
    recursion now stream — row-block scatter and partial-mean replay —
    and match the monolithic sweep (depth covered by the differential
    suite; this is the fast direct check on the lifted restriction)."""
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    exts = (by_name("batch_dot"), by_name("kfra"))
    ref = run(model, params, x, y, loss, extensions=exts)
    res = plan_sweeps(exts, ExtensionConfig()).accumulate(3).run(
        model, params, x, y, loss)
    for name in ("batch_dot", "kfra"):
        for a, b in zip(jax.tree.leaves(ref.ext[name]),
                        jax.tree.leaves(res.ext[name])):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=3e-5, atol=3e-5, err_msg=name)


def test_accumulate_validates_num_microbatches():
    with pytest.raises(ValueError, match="num_microbatches"):
        plan_sweeps((), ExtensionConfig()).accumulate(0)
    # the sharded construction path must validate identically
    sp = plan_sweeps((), ExtensionConfig()).shard(make_data_mesh(), "data")
    with pytest.raises(ValueError, match="num_microbatches"):
        sp.accumulate(0)


def test_accumulated_mc_needs_seed_or_rng(setup):
    model, params, x, y = setup
    plan = plan_sweeps((DiagGGNMC,), ExtensionConfig()).accumulate(2)
    with pytest.raises(ValueError, match="rng"):
        plan.run(model, params, x, y, CrossEntropyLoss())


def test_describe_reports_accumulation(setup):
    cfg = ExtensionConfig(use_kernels=True)
    exts = (by_name("batch_l2"), by_name("variance"), by_name("kflr"))
    desc = plan_sweeps(exts, cfg).accumulate(4).describe()
    assert "accumulate=4 microbatches" in desc
    assert "variance:moment_merge(sequential Chan merge)" in desc
    assert "kflr:kron(weighted A mean + B sum)" in desc
    grid = plan_sweeps(exts, cfg).shard(make_data_mesh(), "data") \
        .accumulate(4).describe()
    assert "shard_axes=['data']" in grid and "accumulate=4" in grid


def test_masked_targets_accumulate_exactly(setup):
    """Uneven padding masks across microbatches: the driver's global
    mask-aware unit count keeps the 1/M normalization exact even when one
    slice is almost fully masked (a per-slice mean would not be)."""
    model, params, x, _ = setup
    y = jax.random.randint(jax.random.PRNGKey(5), (N,), 0, C)
    y = y.at[:4].set(-1).at[0].set(1)  # first slice nearly all padding
    loss = CrossEntropyLoss()
    exts = (by_name("batch_l2"), by_name("diag_ggn"))
    ref = run(model, params, x, y, loss, extensions=exts)
    res = plan_sweeps(exts, ExtensionConfig()).accumulate(3).run(
        model, params, x, y, loss)
    np.testing.assert_allclose(np.asarray(res.loss), np.asarray(ref.loss),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(ref.ext["batch_l2"]),
                    jax.tree.leaves(res.ext["batch_l2"])):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=3e-5, atol=3e-6)
    for a, b in zip(jax.tree.leaves(ref.ext["diag_ggn"]),
                    jax.tree.leaves(res.ext["diag_ggn"])):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=3e-5, atol=3e-6)


def test_shard_accumulate_uneven_local_schedule(setup):
    """Shard × accumulate with an *uneven* local microbatch schedule: 16
    local rows per shard (1 device) / 2 rows (8 devices) split into k=3 →
    a remainder slice inside the shard body.  One mixed
    first+second-order subset under the fused kernels — the cheap
    composition probe next to the differential grid's even-k sweep."""
    model, params, _, _ = setup
    x = jax.random.normal(jax.random.PRNGKey(7), (16, D_IN))
    y = jax.random.randint(jax.random.PRNGKey(8), (16,), 0, C)
    loss = CrossEntropyLoss()
    cfg = ExtensionConfig(use_kernels=True)
    exts = (by_name("variance"), by_name("kflr"), by_name("batch_l2"))
    rng = jax.random.PRNGKey(42)
    ref = run(model, params, x, y, loss, extensions=exts, cfg=cfg, rng=rng)
    res = plan_sweeps(exts, cfg).shard(make_data_mesh(), "data") \
        .accumulate(3).run(model, params, x, y, loss, cfg=cfg, rng=rng)
    np.testing.assert_allclose(np.asarray(res.loss), np.asarray(ref.loss),
                               rtol=1e-6)
    for name in ref.ext:
        for a, b in zip(jax.tree.leaves(ref.ext[name]),
                        jax.tree.leaves(res.ext[name])):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       rtol=3e-5, atol=3e-5, err_msg=name)


def test_accumulate_jits(setup):
    """The whole accumulated run must trace under jax.jit (the training
    step wraps it) — lax.scan driver, eval_shape zero-init and all."""
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    exts = (by_name("variance"), by_name("kflr"))
    plan = plan_sweeps(exts, ExtensionConfig()).accumulate(3)

    @jax.jit
    def f(p, xx, yy):
        res = plan.run(model, p, xx, yy, loss)
        return res.loss, res.ext["variance"], res.ext["kflr"]

    lv, var, kflr = f(params, x, y)
    ref = run(model, params, x, y, loss, extensions=exts)
    np.testing.assert_allclose(np.asarray(lv), np.asarray(ref.loss),
                               rtol=1e-6)
    for a, b in zip(jax.tree.leaves(ref.ext["variance"]),
                    jax.tree.leaves(var)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# consumer wiring (ExtensionConfig.microbatch_size)
# ---------------------------------------------------------------------------


def test_extended_train_step_microbatch_matches(setup):
    from repro.optim import curvature_optimizer
    from repro.train.step import make_extended_train_step

    model, params, x, y = setup
    loss = CrossEntropyLoss()
    batch = {"inputs": x, "labels": y}
    opt = curvature_optimizer(1e-2, curvature="kfac")
    state = opt.init(params)
    rng = jax.random.PRNGKey(3)
    ref_step = make_extended_train_step(
        model, loss, opt, (by_name("kfac"),), ExtensionConfig(mc_seed=0))
    p1, _, m1 = jax.jit(ref_step)(params, state, batch, jnp.int32(0), rng)
    mb_step = make_extended_train_step(
        model, loss, opt, (by_name("kfac"),),
        ExtensionConfig(mc_seed=0, microbatch_size=3))
    p2, _, m2 = jax.jit(mb_step)(params, state, batch, jnp.int32(0), rng)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)


def test_laplace_fit_microbatch_matches(setup):
    from repro import laplace

    model, params, x, y = setup
    loss = CrossEntropyLoss()
    ref = laplace.fit_posterior(model, params, x, y, loss, structure="kron")
    mb = laplace.fit_posterior(model, params, x, y, loss, structure="kron",
                               options=laplace.FitOptions(
                                   microbatch_size=4))
    for a, b in zip(jax.tree.leaves(ref.kron), jax.tree.leaves(mb.kron)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=3e-5, atol=3e-6)
    np.testing.assert_allclose(ref.loss_map, mb.loss_map, rtol=1e-6)
    # MC + diag structure through the same plumbing (cfg-borne size)
    ref_d = laplace.fit_posterior(
        model, params, x, y, loss, structure="diag",
        options=laplace.FitOptions(mc=True, cfg=ExtensionConfig(mc_seed=0)))
    mb_d = laplace.fit_posterior(
        model, params, x, y, loss, structure="diag",
        options=laplace.FitOptions(
            mc=True, cfg=ExtensionConfig(mc_seed=0, microbatch_size=3)))
    for a, b in zip(jax.tree.leaves(ref_d.curv), jax.tree.leaves(mb_d.curv)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=3e-5, atol=3e-6)


def test_last_layer_laplace_microbatch(setup):
    from repro import laplace

    model, params, x, y = setup
    loss = CrossEntropyLoss()
    ref = laplace.fit_posterior(model, params, x, y, loss, structure="kron",
                                last_layer=True)
    mb = laplace.fit_posterior(model, params, x, y, loss, structure="kron",
                               last_layer=True,
                               options=laplace.FitOptions(
                                   microbatch_size=3))
    for a, b in zip(jax.tree.leaves(ref.inner.kron),
                    jax.tree.leaves(mb.inner.kron)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=3e-5, atol=3e-6)
