"""Sharding rules + a scaled-down dry-run on 8 fake devices (subprocess —
jax locks the device count at first init, so multi-device tests must not
share this process)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs import ARCHS
from repro.core.module import Axes
from repro.sharding.rules import rules_for

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_rules_tables():
    r = rules_for("std", multi_pod=False)
    assert r.get("heads") == "model"
    assert r.get("batch") == ("data",)
    rm = rules_for("std", multi_pod=True)
    assert rm.get("batch") == ("pod", "data")
    rl = rules_for("long", multi_pod=False)
    assert rl.get("batch") is None and rl.get("kv_seq") == ("data",)


def test_param_axes_cover_all_archs():
    from repro.nn.models import build_model
    import jax

    for name, cfg in ARCHS.items():
        model = build_model(cfg.reduced())
        axes = jax.tree.leaves(model.param_axes(),
                               is_leaf=lambda x: isinstance(x, Axes))
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        leaves = jax.tree.leaves(params)
        assert len(axes) == len(leaves), name


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import ARCHS, SHAPES, input_specs
    import dataclasses
    from repro.core import CrossEntropyLoss
    from repro.launch.mesh import make_mesh
    from repro.sharding import partition_specs, rules_for, input_shardings
    from repro.train.step import make_train_step, make_decode_step
    from repro.optim import adamw
    from repro.launch.dryrun import opt_shardings

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = ARCHS[{arch!r}].reduced()
    shape = dataclasses.replace(SHAPES[{shape!r}], seq_len=32,
                                global_batch=8)
    from repro.nn.models import build_model
    model = build_model(cfg)
    rules = rules_for("std", True)
    kind, specs = input_specs(cfg, shape, model=model)
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = partition_specs(model.param_axes(), params_spec, rules, mesh)
    in_sh = input_shardings(kind, specs, rules, mesh)
    loss = CrossEntropyLoss()
    if kind == "train":
        opt = adamw(1e-3)
        opt_spec = jax.eval_shape(opt.init, params_spec)
        o_sh = opt_shardings(p_sh, mesh)
        step = make_train_step(model, loss, opt)
        fn = jax.jit(step, in_shardings=(p_sh, o_sh, in_sh,
                                         NamedSharding(mesh, P())))
        compiled = fn.lower(params_spec, opt_spec, specs,
                            jax.ShapeDtypeStruct((), jnp.int32)).compile()
        # actually EXECUTE on the 8 fake devices
        params = jax.device_put(model.init(jax.random.PRNGKey(0)), p_sh)
        opt_state = jax.device_put(opt.init(params), o_sh)
        from repro.data.synthetic import batch_for
        batch = batch_for(cfg, shape, 0)
        p2, o2, m = fn(params, opt_state, batch, jnp.int32(0))
        print(json.dumps({{"ok": True, "loss": float(m["loss"])}}))
    else:
        step = make_decode_step(model)
        cache_sh = partition_specs(model.cache_axes(), specs["caches"],
                                   rules, mesh)
        fn = jax.jit(step, in_shardings=(p_sh, cache_sh, in_sh["tokens"],
                                         in_sh["pos"]))
        compiled = fn.lower(params_spec, specs["caches"], specs["tokens"],
                            specs["pos"]).compile()
        print(json.dumps({{"ok": True}}))
""")


def _run_sub(arch, shape):
    code = _SUBPROC.format(src=os.path.abspath(SRC), arch=arch, shape=shape)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.sharding
@pytest.mark.parametrize("arch", ["stablelm-1.6b", "granite-moe-1b-a400m",
                                  "rwkv6-3b"])
def test_sharded_train_step_executes(arch):
    res = _run_sub(arch, "train_4k")
    assert res["ok"] and res["loss"] > 0


@pytest.mark.slow
@pytest.mark.sharding
def test_sharded_decode_compiles():
    res = _run_sub("stablelm-1.6b", "decode_32k")
    assert res["ok"]
