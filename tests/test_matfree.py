"""Matrix-free curvature lane (repro.curv) against dense oracles.

Every implicit quantity is pinned to an explicitly materialized one on
paper-scale nets (P small enough for `jax.jacrev` / `jax.hessian`):

* GGN-vp and HVP against the dense ``Jᵀ H J`` / ``∇²L`` (ISSUE tolerance
  3e-5), monolithic and through the streaming / sharded compositions with
  uneven final slices (the ``_ScaledLoss`` differential at k ∈ {2, 3});
* the batched PCG solver against ``jnp.linalg.solve``;
* the GGNGram extension against the Jacobian-factor Gram
  ``J'J'ᵀ, J' = √Hᵀ J`` and the kernel-space NGD direction against the
  dense ``(G + δI)⁻¹ g`` it Woodbury-inverts;
* SLQ log-det against the Kronecker closed form
  ``logdet(A ⊗ B) = b·logdet A + a·logdet B`` and the matfree evidence's
  log-det ratio against its dense counterpart (MC tolerance).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import (
    CrossEntropyLoss,
    ExtensionConfig,
    GGNGram,
    MSELoss,
    gram_total,
    run,
)
from repro.curv import (
    GGNOperator,
    HessianOperator,
    cg_solve,
    ggn_vp,
    hvp,
    kernel_ngd_direction,
    slq_logdet,
)

from _oracles import (TOL, dense_ggn as _dense_ggn,
                      dense_hessian as _dense_hess, scaled_jacobian,
                      tiny_mlp)

N, D, H, C = 11, 5, 7, 3


@pytest.fixture(scope="module")
def setup():
    return tiny_mlp(N, D, H, C)


@pytest.mark.parametrize("loss", [CrossEntropyLoss(), MSELoss()],
                         ids=["ce", "mse"])
def test_ggn_vp_matches_dense_oracle(setup, loss):
    model, params, x, y = setup
    if isinstance(loss, MSELoss):
        y = jax.random.normal(jax.random.PRNGKey(3), (N, C))
    G, flat, unravel = _dense_ggn(model, params, x, y, loss)
    v = unravel(jax.random.normal(jax.random.PRNGKey(4), flat.shape))
    gv = ggn_vp(model, params, x, y, loss, v)
    np.testing.assert_allclose(np.asarray(ravel_pytree(gv)[0]),
                               np.asarray(G @ ravel_pytree(v)[0]), **TOL)


def test_hvp_matches_dense_hessian(setup):
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    Hd, flat, unravel = _dense_hess(model, params, x, y, loss)
    v = unravel(jax.random.normal(jax.random.PRNGKey(4), flat.shape))
    hv = hvp(model, params, x, y, loss, v)
    np.testing.assert_allclose(np.asarray(ravel_pytree(hv)[0]),
                               np.asarray(Hd @ ravel_pytree(v)[0]), **TOL)


@pytest.mark.parametrize("k", [2, 3])
def test_streamed_products_match_monolithic(setup, k):
    """accumulate(k) with an uneven final slice (N=11) is exact — the
    per-slice 1/M_local → 1/M_global rescale sums to the monolithic
    product."""
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    flat, unravel = ravel_pytree(params)
    v = unravel(jax.random.normal(jax.random.PRNGKey(4), flat.shape))
    cfg = ExtensionConfig(microbatch_size=k)
    for fn in (ggn_vp, hvp):
        mono = fn(model, params, x, y, loss, v)
        st = fn(model, params, x, y, loss, v, cfg=cfg)
        np.testing.assert_allclose(np.asarray(ravel_pytree(st)[0]),
                                   np.asarray(ravel_pytree(mono)[0]),
                                   rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("k", [2, 3])
def test_shard_accumulate_product_differential(k):
    """mesh × microbatch composition applies exactly one global-unit
    correction (runs the shard_map path on however many devices the
    process owns; 8 in the multidevice CI lane)."""
    from repro.launch.mesh import make_data_mesh

    n = 16  # divisible by the multidevice lane's 8 devices
    model, params, x, y = tiny_mlp(n, D, H, C)
    loss = CrossEntropyLoss()
    flat, unravel = ravel_pytree(params)
    v = unravel(jax.random.normal(jax.random.PRNGKey(4), flat.shape))
    mono = ggn_vp(model, params, x, y, loss, v)
    both = ggn_vp(model, params, x, y, loss, v,
                  cfg=ExtensionConfig(microbatch_size=k),
                  mesh=make_data_mesh())
    np.testing.assert_allclose(np.asarray(ravel_pytree(both)[0]),
                               np.asarray(ravel_pytree(mono)[0]),
                               rtol=2e-5, atol=2e-6)


def test_cg_matches_dense_solve(setup):
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    damping = 0.1
    G, flat, unravel = _dense_ggn(model, params, x, y, loss)
    op = GGNOperator(model, params, x, y, loss, damping=damping)
    assert op.dim == flat.size
    b = unravel(jax.random.normal(jax.random.PRNGKey(5), flat.shape))
    sol = cg_solve(op.mv, b, tol=1e-8, maxiter=200)
    want = jnp.linalg.solve(
        G + damping * jnp.eye(flat.size), ravel_pytree(b)[0])
    np.testing.assert_allclose(np.asarray(ravel_pytree(sol.x)[0]),
                               np.asarray(want), rtol=1e-4, atol=1e-5)
    assert int(sol.iters) < 200  # converged by tolerance, not budget


def test_cg_batched_rhs(setup):
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    damping = 0.2
    G, flat, unravel = _dense_ggn(model, params, x, y, loss)
    op = GGNOperator(model, params, x, y, loss, damping=damping)
    B = jax.vmap(unravel)(
        jax.random.normal(jax.random.PRNGKey(5), (3,) + flat.shape))
    sol = cg_solve(op.mv_stacked, B, tol=1e-8, maxiter=200, batched=True)
    want = jnp.linalg.solve(
        G + damping * jnp.eye(flat.size),
        jax.vmap(lambda t: ravel_pytree(t)[0])(B).T).T
    got = jax.vmap(lambda t: ravel_pytree(t)[0])(sol.x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_hessian_operator_is_symmetric(setup):
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    op = HessianOperator(model, params, x, y, loss)
    flat, unravel = ravel_pytree(params)
    key1, key2 = jax.random.split(jax.random.PRNGKey(6))
    u = unravel(jax.random.normal(key1, flat.shape))
    w = unravel(jax.random.normal(key2, flat.shape))
    uhw = jnp.vdot(ravel_pytree(op.mv(w))[0], ravel_pytree(u)[0])
    whu = jnp.vdot(ravel_pytree(op.mv(u))[0], ravel_pytree(w)[0])
    np.testing.assert_allclose(float(uhw), float(whu), rtol=1e-5)


# ---------------------------------------------------------------------------
# Gram extension + kernel-space NGD
# ---------------------------------------------------------------------------


def test_ggn_gram_matches_jacobian_factor_gram(setup):
    """gram_total(ggn_gram) == J'J'ᵀ with J' the loss-scaled Jacobian
    factor the paper's exact extensions propagate (√Hᵀ J)."""
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    Jp, flat, unravel = scaled_jacobian(model, params, x, y, loss)
    want = jnp.einsum("cnp,dmp->nmcd", Jp, Jp)      # [N, N, C, C]
    res = run(model, params, x, y, loss, extensions=(GGNGram,))
    got = gram_total(res.ext["ggn_gram"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-6)


@pytest.mark.parametrize("k", [2, 3])
def test_ggn_gram_streams_exactly(setup, k):
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    mono = gram_total(run(model, params, x, y, loss,
                          extensions=(GGNGram,)).ext["ggn_gram"])
    cfg = ExtensionConfig(microbatch_size=k)
    st = gram_total(run(model, params, x, y, loss, extensions=(GGNGram,),
                        cfg=cfg).ext["ggn_gram"])
    np.testing.assert_allclose(np.asarray(st), np.asarray(mono),
                               rtol=3e-5, atol=3e-6)


def test_kernel_ngd_matches_dense_natural_gradient(setup):
    """Gram-space (Woodbury) solve == dense (G + δI)⁻¹ g on a net whose
    parameters the Dense Gram blocks fully cover."""
    model, params, x, y = setup
    loss = CrossEntropyLoss()
    damping = 0.05
    G, flat, unravel = _dense_ggn(model, params, x, y, loss)
    d, res = kernel_ngd_direction(model, params, x, y, loss,
                                  damping=damping)
    want = jnp.linalg.solve(G + damping * jnp.eye(flat.size),
                            ravel_pytree(res.grads)[0])
    np.testing.assert_allclose(np.asarray(ravel_pytree(d)[0]),
                               np.asarray(want), rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# stochastic log-determinant
# ---------------------------------------------------------------------------


def test_slq_logdet_matches_kron_closed_form():
    """SLQ over A ⊗ B vs logdet(A ⊗ B) = b·logdet A + a·logdet B."""
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    Ra = jax.random.normal(ka, (6, 6))
    Rb = jax.random.normal(kb, (8, 8))
    A = Ra @ Ra.T + 0.5 * jnp.eye(6)
    B = Rb @ Rb.T + 0.5 * jnp.eye(8)
    M = jnp.kron(A, B)
    want = (B.shape[0] * jnp.linalg.slogdet(A)[1]
            + A.shape[0] * jnp.linalg.slogdet(B)[1])
    est = slq_logdet(lambda v: M @ v, jnp.zeros(48),
                     rng=jax.random.PRNGKey(1), probes=64, iters=40)
    np.testing.assert_allclose(float(est.logdet), float(want), rtol=0.05)
    assert est.per_probe.shape == (64,)


def test_matfree_evidence_matches_dense_logdet(setup):
    """log_marglik_matfree's Occam term vs the dense
    logdet(I + (M/δ)·G) it estimates; exact pieces match DiagLaplace's
    conventions identically."""
    from repro.laplace import log_marglik_matfree

    model, params, x, y = setup
    loss = CrossEntropyLoss()
    delta = 2.0
    ev = log_marglik_matfree(model, params, x, y, loss, prior_prec=delta,
                             probes=64, iters=60,
                             rng=jax.random.PRNGKey(7))
    G, flat, _ = _dense_ggn(model, params, x, y, loss)
    m = float(loss.num_units(y))
    want = jnp.linalg.slogdet(
        jnp.eye(flat.size) + (m / delta) * G)[1]
    np.testing.assert_allclose(float(ev.log_det_ratio), float(want),
                               rtol=0.12)
    # exact pieces: −M·loss and the MAP scatter term
    res = run(model, params, x, y, loss, extensions=())
    np.testing.assert_allclose(float(ev.log_lik), -m * float(res.loss),
                               rtol=1e-6)
    assert float(ev.log_marglik) == pytest.approx(
        float(ev.log_lik) - 0.5 * float(ev.scatter)
        - 0.5 * float(ev.log_det_ratio))
