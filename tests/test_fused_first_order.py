"""Fused first-order kernel: parity, masks, registry, engine routing."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    BatchDot,
    BatchGrad,
    BatchL2,
    CrossEntropyLoss,
    ExtensionConfig,
    SecondMoment,
    Variance,
    first_order_mask,
    plan_sweeps,
    run,
)
from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]
TOL = {jnp.float32: dict(rtol=3e-5, atol=3e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}
LOSS = CrossEntropyLoss()


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _pair(e, n, r, a, b, dtype=jnp.float32, seed=0):
    k = jax.random.PRNGKey(seed)
    return (_rand(k, (e, n, r, a), dtype),
            _rand(jax.random.fold_in(k, 1), (e, n, r, b), dtype))


# --- kernel vs oracle parity -------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("e,n,r,a,b", [
    (1, 3, 5, 17, 9),       # nothing block-aligned
    (1, 6, 1, 33, 65),      # R=1 rank-1 case, odd features
    (2, 4, 7, 130, 24),     # grouped
    (3, 1, 2, 8, 300),      # single sample, wide output
])
def test_fused_parity_all_outputs(e, n, r, a, b, dtype):
    A, B = _pair(e, n, r, a, b, dtype, seed=e * n + a)
    got = ops.fused_first_order(A, B, want_l2=True, want_moment=True,
                                want_dot=True)
    want = ref.fused_first_order(A, B, want_l2=True, want_moment=True,
                                 want_dot=True)
    for key in ("l2", "moment", "dot"):
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]), **TOL[dtype])


@pytest.mark.parametrize("block_a,block_b", [(8, 8), (16, 32), (32, 16)])
def test_fused_parity_multi_tile(block_a, block_b):
    """Force feature tiling so the cross-tile l2/dot accumulation
    (zero-init at grid step (0,0) + `+=` across (i, j)) is exercised —
    the auto block policy would otherwise make every test single-tile."""
    A, B = _pair(2, 5, 3, 50, 41, seed=7)
    got = ops.fused_first_order(A, B, want_l2=True, want_moment=True,
                                want_dot=True, block_a=block_a,
                                block_b=block_b)
    want = ref.fused_first_order(A, B, want_l2=True, want_moment=True,
                                 want_dot=True)
    for key in ("l2", "moment", "dot"):
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]),
                                   rtol=3e-5, atol=3e-5, err_msg=key)


def test_fused_all_mask_combinations():
    """Every 2^3 mask: requested keys present and correct, others absent."""
    A, B = _pair(1, 5, 3, 19, 11)
    for wl, wm, wd in itertools.product([False, True], repeat=3):
        if not (wl or wm or wd):
            with pytest.raises(ValueError):
                ops.fused_first_order(A, B, want_l2=False, want_moment=False,
                                      want_dot=False)
            continue
        got = ops.fused_first_order(A, B, want_l2=wl, want_moment=wm,
                                    want_dot=wd)
        want = ref.fused_first_order(A, B, want_l2=wl, want_moment=wm,
                                     want_dot=wd)
        assert set(got) == set(want)
        for key in got:
            np.testing.assert_allclose(np.asarray(got[key]),
                                       np.asarray(want[key]),
                                       rtol=3e-5, atol=3e-5)


def test_fused_internal_consistency():
    """diag(dot) == l2, and moment == Σ_n of the per-sample outer squares."""
    A, B = _pair(1, 7, 4, 23, 13)
    got = ops.fused_first_order(A, B, want_l2=True, want_moment=True,
                                want_dot=True)
    np.testing.assert_allclose(np.asarray(jnp.diagonal(got["dot"][0])),
                               np.asarray(got["l2"][0]), rtol=3e-5, atol=3e-5)
    g = jnp.einsum("nra,nrb->nab", A[0], B[0])
    np.testing.assert_allclose(np.asarray(got["moment"][0]),
                               np.asarray(jnp.sum(g * g, 0)),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 8), r=st.integers(1, 6), a=st.integers(1, 33),
       b=st.integers(1, 33), seed=st.integers(0, 2 ** 16))
def test_fused_hypothesis_parity(n, r, a, b, seed):
    A, B = _pair(1, n, r, a, b, seed=seed)
    got = ops.fused_first_order(A, B, want_l2=True, want_moment=True,
                                want_dot=True)
    want = ref.fused_first_order(A, B, want_l2=True, want_moment=True,
                                 want_dot=True)
    for key in got:
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]),
                                   rtol=5e-5, atol=5e-5)
    assert (np.asarray(got["l2"]) >= -1e-6).all()


# --- dispatch registry -------------------------------------------------------

def test_registry_contents_and_specs():
    names = ops.registered()
    for expected in ("sq_matmul", "per_sample_moment", "batch_l2",
                     "ggn_diag", "fused_first_order"):
        assert expected in names
        spec = ops.get_spec(expected)
        assert spec.ref is not None and spec.description
    with pytest.raises(KeyError):
        ops.dispatch("no_such_kernel", jnp.zeros((2, 2)))


def test_registry_jit_cache_is_config_keyed():
    ops.clear_cache()
    A, B = _pair(1, 4, 2, 16, 8)
    ops.fused_first_order(A, B, want_l2=True)
    n0 = ops.cache_stats()["total"]
    ops.fused_first_order(A, B, want_l2=True)          # same config: cached
    assert ops.cache_stats()["total"] == n0
    A2, B2 = _pair(1, 4, 2, 24, 8)
    got = ops.fused_first_order(A2, B2, want_l2=True)  # new shape: same entry
    assert ops.cache_stats()["total"] == n0            # (jax.jit retraces)
    np.testing.assert_allclose(
        np.asarray(got["l2"]),
        np.asarray(ref.fused_first_order(A2, B2, want_l2=True)["l2"]),
        rtol=3e-5, atol=3e-5)
    ops.fused_first_order(A, B, want_l2=True, want_dot=True)  # new static opts
    stats = ops.cache_stats()
    assert stats["total"] == n0 + 1
    assert stats["fused_first_order"] >= 2


# --- engine routing ----------------------------------------------------------

ALL_FIRST = (BatchGrad, BatchL2, SecondMoment, Variance, BatchDot)


def test_sweep_plan_fused_mask():
    plan = plan_sweeps(ALL_FIRST)
    assert plan.fused_mask.l2 and plan.fused_mask.moment and plan.fused_mask.dot
    assert not plan.fused_active  # default config: jnp path
    assert "fused_first_order=None" in plan.describe()
    active = plan_sweeps(ALL_FIRST, ExtensionConfig(use_kernels=True))
    assert active.fused_active
    assert "fused_first_order=['l2', 'moment', 'dot']" in active.describe()
    legacy = plan_sweeps(ALL_FIRST, ExtensionConfig(use_kernels=True,
                                                    use_fused=False))
    assert not legacy.fused_active
    plan = plan_sweeps((BatchGrad,))
    assert not plan.fused_mask.any()
    mask = first_order_mask({"variance"})
    assert mask.moment and not (mask.l2 or mask.dot)
    assert mask.wants() == dict(want_l2=False, want_moment=True,
                                want_dot=False)


def _paper_nets():
    from repro.configs.papernets import c2d2, logreg, mlp

    k = jax.random.PRNGKey(3)
    x_img = jax.random.normal(k, (4, 8, 8, 1))
    x_flat = jax.random.normal(k, (4, 12))
    return [
        ("logreg", logreg(n_classes=5, in_dim=12), x_flat),
        ("mlp", mlp(n_classes=5, in_dim=12, hidden=(9,)), x_flat),
        ("2c2d", c2d2(n_classes=5, in_ch=1, img=8), x_img),
    ]


@pytest.mark.parametrize("name,model,x", _paper_nets(),
                         ids=[n for n, _, _ in _paper_nets()])
def test_engine_fused_matches_jnp_on_papernets(name, model, x):
    """use_kernels=True (fused) ≡ pure-jnp path to 1e-5, all first-order
    extensions, on the paper's benchmark architectures."""
    params = model.init(jax.random.PRNGKey(0))
    y = jax.random.randint(jax.random.PRNGKey(1), (x.shape[0],), 0, 5)
    res_jnp = run(model, params, x, y, LOSS, extensions=ALL_FIRST,
                  cfg=ExtensionConfig(use_kernels=False))
    res_fused = run(model, params, x, y, LOSS, extensions=ALL_FIRST,
                    cfg=ExtensionConfig(use_kernels=True))
    for ext in ("batch_grad", "batch_l2", "second_moment", "variance",
                "batch_dot"):
        ja, fu = (jax.tree.leaves(res_jnp.ext[ext]),
                  jax.tree.leaves(res_fused.ext[ext]))
        assert len(ja) == len(fu) and ja
        for a, b in zip(ja, fu):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5, err_msg=ext)


def test_engine_legacy_kernel_path_still_matches():
    """use_fused=False keeps the one-kernel-per-extension baseline correct."""
    from repro.configs.papernets import mlp

    model = mlp(n_classes=4, in_dim=10, hidden=(8,))
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 10))
    y = jax.random.randint(jax.random.PRNGKey(2), (5,), 0, 4)
    res_jnp = run(model, params, x, y, LOSS, extensions=ALL_FIRST,
                  cfg=ExtensionConfig(use_kernels=False))
    res_leg = run(model, params, x, y, LOSS, extensions=ALL_FIRST,
                  cfg=ExtensionConfig(use_kernels=True, use_fused=False))
    for ext in ("batch_l2", "second_moment", "variance", "batch_dot"):
        for a, b in zip(jax.tree.leaves(res_jnp.ext[ext]),
                        jax.tree.leaves(res_leg.ext[ext])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5, err_msg=ext)


def test_batched_dense_expert_moment_fused():
    """MoE experts: fused kernel (expert group axis) ≡ the einsum formula."""
    from repro.core.extensions import SecondMoment as SM
    from repro.nn.layers import BatchedDense

    mod = BatchedDense(3, 7, 5)
    params = mod.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 7))
    y, tape = mod.forward_tape(params, x)
    g = jax.random.normal(jax.random.PRNGKey(2), y.shape)
    _, _, st_jnp = mod.backward(params, tape, g, (SM,),
                                ExtensionConfig(use_kernels=False))
    _, _, st_ker = mod.backward(params, tape, g, (SM,),
                                ExtensionConfig(use_kernels=True))
    np.testing.assert_allclose(np.asarray(st_ker["_sum_grad2"]["w"]),
                               np.asarray(st_jnp["_sum_grad2"]["w"]),
                               rtol=1e-5, atol=1e-5)
    # use_fused=False must fall back to the einsum baseline for experts too
    _, _, st_leg = mod.backward(params, tape, g, (SM,),
                                ExtensionConfig(use_kernels=True,
                                                use_fused=False))
    np.testing.assert_allclose(np.asarray(st_leg["_sum_grad2"]["w"]),
                               np.asarray(st_jnp["_sum_grad2"]["w"]),
                               rtol=1e-6, atol=1e-6)


# --- variance invariants (property) -----------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 8), d=st.integers(2, 9), c=st.integers(2, 5),
       seed=st.integers(0, 2 ** 16))
def test_fused_variance_nonneg_and_identity(n, d, c, seed):
    """Fused-path variance ≥ 0 and equals N·Σ g² − (Σ g)² = smom − N²·mean²."""
    from repro.configs.papernets import mlp

    model = mlp(n_classes=c, in_dim=d, hidden=(d + 1,))
    params = model.init(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, d))
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, c)
    res = run(model, params, x, y, LOSS,
              extensions=(BatchGrad, SecondMoment, Variance),
              cfg=ExtensionConfig(use_kernels=True))
    for v in jax.tree.leaves(res["variance"]):
        assert float(jnp.min(v)) >= -1e-5
    # variance == second_moment − N² · mean² with mean = (Σ_n g_n)/N
    for var, sm, bg in zip(jax.tree.leaves(res["variance"]),
                           jax.tree.leaves(res["second_moment"]),
                           jax.tree.leaves(res["batch_grad"])):
        mean = jnp.sum(bg, 0) / n
        np.testing.assert_allclose(
            np.asarray(var), np.asarray(sm - (n * mean) ** 2 / 1.0),
            rtol=2e-4, atol=2e-5)
