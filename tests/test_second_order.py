"""Second-order extensions vs explicit-GGN / jax.hessian oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import (
    Activation,
    CrossEntropyLoss,
    Dense,
    DiagGGN,
    DiagGGNMC,
    DiagHessian,
    ExtensionConfig,
    KFAC,
    KFLR,
    KFRA,
    Sequential,
    kron,
    oracle,
    run,
)

N, D, H, C = 6, 5, 7, 4


@pytest.fixture(scope="module")
def setup():
    model = Sequential([Dense(D, H), Activation("sigmoid"), Dense(H, C)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    y = jax.random.randint(jax.random.PRNGKey(2), (N,), 0, C)
    loss = CrossEntropyLoss()
    return model, params, x, y, loss


def test_diag_ggn_exact(setup):
    model, params, x, y, loss = setup
    res = run(model, params, x, y, loss, extensions=(DiagGGN,))
    want = oracle.ggn_diag(model, loss, params, x, y)
    got, _ = ravel_pytree(res["diag_ggn"])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


def test_diag_ggn_class_chunked(setup):
    model, params, x, y, loss = setup
    full = run(model, params, x, y, loss, extensions=(DiagGGN,))
    for chunk in (1, 3, 4):
        part = run(model, params, x, y, loss, extensions=(DiagGGN,),
                   cfg=ExtensionConfig(class_chunk=chunk))
        for a, b in zip(jax.tree.leaves(part["diag_ggn"]),
                        jax.tree.leaves(full["diag_ggn"])):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-7)


def test_diag_hessian(setup):
    model, params, x, y, loss = setup
    res = run(model, params, x, y, loss, extensions=(DiagHessian,))
    want = oracle.hessian_diag(model, loss, params, x, y)
    got, _ = ravel_pytree(res["diag_hessian"])
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-6)


def test_diag_hessian_equals_ggn_for_relu(setup):
    """Piecewise-linear nets: Hessian diag == GGN diag (Martens 2014)."""
    _, _, x, y, loss = setup
    model = Sequential([Dense(D, H), Activation("relu"), Dense(H, C)])
    params = model.init(jax.random.PRNGKey(3))
    res = run(model, params, x, y, loss, extensions=(DiagHessian, DiagGGN))
    a, _ = ravel_pytree(res["diag_hessian"])
    b, _ = ravel_pytree(res["diag_ggn"])
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-7)


def test_kflr_exact_single_layer(setup):
    """N=1 single linear layer: A ⊗ B equals the exact GGN block."""
    _, _, _, _, loss = setup
    m1 = Sequential([Dense(D, C)])
    p1 = m1.init(jax.random.PRNGKey(0))
    x1 = jax.random.normal(jax.random.PRNGKey(5), (1, D))
    y1 = jnp.array([1])
    r1 = run(m1, p1, x1, y1, loss, extensions=(KFLR, DiagGGN))
    G = oracle.ggn_matrix(m1, loss, p1, x1, y1)
    kf = r1["kflr"][0]
    GW = kron.kron_dense(kf["w"]["A"], kf["w"]["B"])
    np.testing.assert_allclose(GW, G[C:, C:], rtol=1e-4, atol=1e-7)  # W block
    np.testing.assert_allclose(kf["b"]["B"], G[:C, :C], rtol=1e-4, atol=1e-7)


def test_diag_ggn_mc_converges(setup):
    model, params, x, y, loss = setup
    exact = run(model, params, x, y, loss, extensions=(DiagGGN,))
    mc = run(model, params, x, y, loss, extensions=(DiagGGNMC,),
             cfg=ExtensionConfig(mc_samples=128), rng=jax.random.PRNGKey(7))
    a, _ = ravel_pytree(mc["diag_ggn_mc"])
    b, _ = ravel_pytree(exact["diag_ggn"])
    corr = np.corrcoef(np.asarray(a), np.asarray(b))[0, 1]
    assert corr > 0.97, corr
    # unbiasedness: relative error of the mean shrinks with samples
    rel = np.abs(a - b).sum() / np.abs(b).sum()
    assert rel < 0.35, rel


def test_kfac_b_matches_kflr_in_expectation(setup):
    model, params, x, y, loss = setup
    exact = run(model, params, x, y, loss, extensions=(KFLR,))
    mc = run(model, params, x, y, loss, extensions=(KFAC,),
             cfg=ExtensionConfig(mc_samples=256), rng=jax.random.PRNGKey(11))
    B_mc = mc["kfac"][2]["w"]["B"]
    B_ex = exact["kflr"][2]["w"]["B"]
    rel = np.abs(B_mc - B_ex).sum() / np.abs(B_ex).sum()
    assert rel < 0.25, rel


def test_kfra_chain(setup):
    model, params, x, y, loss = setup
    res = run(model, params, x, y, loss, extensions=(KFRA,))
    for slot in (0, 2):
        f = res["kfra"][slot]
        B = f["w"]["B"]
        np.testing.assert_allclose(B, B.T, atol=1e-6)
        evals = np.linalg.eigvalsh(np.asarray(B, np.float64))
        assert evals.min() > -1e-6  # PSD


def test_ggn_diag_nonnegative(setup):
    model, params, x, y, loss = setup
    res = run(model, params, x, y, loss, extensions=(DiagGGN,))
    for l in jax.tree.leaves(res["diag_ggn"]):
        assert float(jnp.min(l)) >= -1e-8
