"""The bench-regression gate's comparison logic (benchmarks/check_regression).

The gate itself runs in the bench-smoke CI job; these tests pin its
semantics — fused-segment selection, the 1.5× threshold, and the
missing-lane failure mode — without timing anything.
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import check, load_rows, main  # noqa: E402

BASE = {
    "fused_first_order/N8/fused/all3": 100.0,
    "fused_first_order/N8/per_ext/all3": 900.0,   # baseline lane: not gated
    "fused_second_order/baseline/diag": 500.0,    # module prefix: not gated
    "laplace/predvar/fused": 200.0,
    "kernels/batch_l2/pallas_interpret": 50.0,    # not a fused lane
}
PAT = "/fused(/|$)"


def test_within_threshold_passes():
    cur = dict(BASE, **{"fused_first_order/N8/fused/all3": 140.0})
    failures, checked = check(cur, BASE, 1.5, PAT)
    assert failures == []
    assert sorted(checked) == ["fused_first_order/N8/fused/all3",
                               "laplace/predvar/fused"]


def test_slowdown_fails_only_gated_lanes():
    cur = dict(BASE, **{
        "fused_first_order/N8/fused/all3": 160.0,       # 1.6x: fail
        "fused_first_order/N8/per_ext/all3": 9000.0,    # 10x but ungated
        "kernels/batch_l2/pallas_interpret": 5000.0,    # ungated
    })
    failures, _ = check(cur, BASE, 1.5, PAT)
    assert failures == ["fused_first_order/N8/fused/all3"]


def test_missing_gated_lane_fails():
    cur = {k: v for k, v in BASE.items() if k != "laplace/predvar/fused"}
    failures, _ = check(cur, BASE, 1.5, PAT)
    assert failures == ["laplace/predvar/fused"]


def test_load_rows_accepts_both_artifact_forms(tmp_path):
    rows = [{"name": "a/fused", "us_per_call": 1.5, "derived": ""}]
    bare = tmp_path / "bare.json"
    bare.write_text(json.dumps(rows))
    wrapped = tmp_path / "wrapped.json"
    wrapped.write_text(json.dumps({"quick": True, "rows": rows}))
    assert load_rows(bare) == {"a/fused": 1.5}
    assert load_rows(wrapped) == {"a/fused": 1.5}


def test_main_exit_codes(tmp_path):
    cur = tmp_path / "cur.json"
    base = tmp_path / "base.json"
    base.write_text(json.dumps(
        [{"name": "x/fused", "us_per_call": 100.0}]))
    cur.write_text(json.dumps([{"name": "x/fused", "us_per_call": 120.0}]))
    assert main([str(cur), str(base)]) == 0
    cur.write_text(json.dumps([{"name": "x/fused", "us_per_call": 200.0}]))
    assert main([str(cur), str(base)]) == 1
    # baseline with no gated lanes at all: configuration error, fail
    base.write_text(json.dumps([{"name": "x/naive", "us_per_call": 1.0}]))
    assert main([str(cur), str(base)]) == 1
