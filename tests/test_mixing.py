"""Mixing primitives: chunked attention, WKV scan, MoE routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import functional as F
from repro.nn.moe import capacity, route


# --- attention ---------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 13])
@pytest.mark.parametrize("chunks", [(16, 16), (8, 32), (64, 64)])
def test_sdpa_chunked_matches_naive(window, chunks):
    n, t, h, kv, dh = 2, 64, 8, 4, 16
    k0 = jax.random.PRNGKey(0)
    q = jax.random.normal(k0, (n, t, h, dh))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (n, t, kv, dh))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (n, t, kv, dh))
    a = F.sdpa(q, k, v, causal=True, window=window)
    b = F.sdpa_chunked(q, k, v, causal=True, window=window,
                       q_chunk=chunks[0], k_chunk=chunks[1])
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-5, atol=3e-5)


def test_sdpa_chunked_grads_match():
    n, t, h, kv, dh = 2, 32, 4, 2, 8
    k0 = jax.random.PRNGKey(3)
    q = jax.random.normal(k0, (n, t, h, dh))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (n, t, kv, dh))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (n, t, kv, dh))
    ga = jax.grad(lambda q_: F.sdpa(q_, k, v).sum())(q)
    gb = jax.grad(lambda q_: F.sdpa_chunked(q_, k, v, q_chunk=8,
                                            k_chunk=8).sum())(q)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                               rtol=2e-4, atol=2e-5)


# --- WKV (RWKV6/SSD) ---------------------------------------------------------

def _wkv_naive(r, k, v, log_w, u, state0=None):
    n, t, h, dk = r.shape
    dv = v.shape[-1]
    S = jnp.zeros((n, h, dk, dv)) if state0 is None else state0
    w = jnp.exp(jnp.clip(log_w, -60.0, -1e-6))
    w = jnp.broadcast_to(w, r.shape)
    ys = []
    for i in range(t):
        y = jnp.einsum("nhd,nhde->nhe", r[:, i], S)
        if u is not None:
            diag = jnp.einsum("nhd,hd,nhd->nh", r[:, i], u, k[:, i])
            y = y + diag[..., None] * v[:, i]
        S = w[:, i][..., None] * S + k[:, i][..., None] * v[:, i][..., None, :]
        ys.append(y)
    return jnp.stack(ys, 1), S


@pytest.mark.parametrize("chunk", [1, 4, 8, 16])
@pytest.mark.parametrize("with_u", [True, False])
def test_wkv_chunked_matches_recurrence(chunk, with_u):
    n, t, h, dk, dv = 2, 16, 3, 4, 5
    k0 = jax.random.PRNGKey(0)
    r = jax.random.normal(k0, (n, t, h, dk))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (n, t, h, dk))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (n, t, h, dv))
    log_w = -jnp.exp(jax.random.normal(jax.random.fold_in(k0, 3),
                                       (n, t, h, dk)) * 0.5)
    u = jax.random.normal(jax.random.fold_in(k0, 4), (h, dk)) if with_u else None
    y1, s1 = F.wkv_chunked(r, k, v, log_w, u=u, chunk=chunk)
    y2, s2 = _wkv_naive(r, k, v, log_w, u)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_wkv_scalar_decay_broadcast():
    """SSD mode: per-head scalar decay, log_w [N,T,H,1]."""
    n, t, h, dk, dv = 2, 8, 2, 4, 4
    k0 = jax.random.PRNGKey(7)
    r = jax.random.normal(k0, (n, t, h, dk))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (n, t, h, dk))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (n, t, h, dv))
    lw1 = -jnp.exp(jax.random.normal(jax.random.fold_in(k0, 3), (n, t, h, 1)))
    y1, _ = F.wkv_chunked(r, k, v, lw1, chunk=4)
    y2, _ = F.wkv_chunked(r, k, v, jnp.broadcast_to(lw1, r.shape), chunk=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


def test_wkv_step_matches_chunked():
    n, t, h, dk, dv = 2, 6, 2, 4, 4
    k0 = jax.random.PRNGKey(9)
    r = jax.random.normal(k0, (n, t, h, dk))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (n, t, h, dk))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (n, t, h, dv))
    log_w = -jnp.exp(jax.random.normal(jax.random.fold_in(k0, 3),
                                       (n, t, h, dk)))
    u = jax.random.normal(jax.random.fold_in(k0, 4), (h, dk))
    y_all, _ = F.wkv_chunked(r, k, v, log_w, u=u, chunk=3)
    state = jnp.zeros((n, h, dk, dv))
    for i in range(t):
        y, state = F.wkv_step(r[:, i], k[:, i], v[:, i], log_w[:, i], u, state)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_all[:, i]),
                                   rtol=2e-4, atol=2e-4)


# --- MoE routing --------------------------------------------------------------

def test_route_properties():
    m, e, k = 64, 8, 2
    logits = jax.random.normal(jax.random.PRNGKey(0), (m, e))
    gates, idx, pos, probs = route(logits, k)
    assert gates.shape == (m, k) and idx.shape == (m, k)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), np.ones(m),
                               rtol=1e-5)
    # positions within each expert are unique and contiguous from 0
    idx_f = np.asarray(idx).reshape(-1)
    pos_f = np.asarray(pos).reshape(-1)
    for ex in range(e):
        p = np.sort(pos_f[idx_f == ex])
        np.testing.assert_array_equal(p, np.arange(len(p)))


def test_moe_block_grads_flow_to_router_and_experts():
    from repro.configs import ARCHS
    from repro.core import CrossEntropyLoss
    from repro.nn.models import build_model
    from repro.data.synthetic import batch_for
    import dataclasses
    from repro.configs import SHAPES

    cfg = ARCHS["granite-moe-1b-a400m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16, global_batch=4)
    batch = batch_for(cfg, shape, 0)
    loss = CrossEntropyLoss()

    def lf(p):
        return loss.value(model.apply(p, batch["inputs"]), batch["labels"])

    g = jax.grad(lf)(params)
    block_g = g[1]  # ScanStack of AttnMoEBlock
    router_g = float(sum(jnp.sum(jnp.abs(l))
                         for l in jax.tree.leaves(block_g["router"])))
    expert_g = float(sum(jnp.sum(jnp.abs(l))
                         for l in jax.tree.leaves(block_g["e_down"])))
    assert router_g > 0 and expert_g > 0
