"""Fused second-order kernel: parity, masks, chunking, registry, routing."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    CrossEntropyLoss,
    DiagGGN,
    DiagGGNMC,
    ExtensionConfig,
    GGNTrace,
    KFAC,
    KFLR,
    plan_sweeps,
    run,
    second_order_mask,
)
from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]
TOL = {jnp.float32: dict(rtol=3e-5, atol=3e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}
LOSS = CrossEntropyLoss()
ALL_KEYS = ("diag", "kron", "trace")


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _pair(c, n, r, a, b, dtype=jnp.float32, seed=0):
    k = jax.random.PRNGKey(seed)
    return (_rand(k, (n, r, a), dtype),
            _rand(jax.random.fold_in(k, 1), (c, n, r, b), dtype))


def _all(A, S, **kw):
    return ops.fused_second_order(A, S, want_diag=True, want_kron=True,
                                  want_trace=True, **kw)


# --- kernel vs oracle parity -------------------------------------------------

@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("c,n,r,a,b", [
    (3, 4, 5, 17, 9),       # nothing block-aligned
    (1, 5, 1, 33, 65),      # C=1, R=1
    (4, 1, 7, 130, 24),     # N=1, a just over a sublane multiple
    (10, 2, 3, 8, 300),     # class axis ≫ batch, wide output
])
def test_fused_second_parity_all_outputs(c, n, r, a, b, dtype):
    A, S = _pair(c, n, r, a, b, dtype, seed=c * n + a)
    got = _all(A, S)
    want = ref.fused_second_order(A, S, want_diag=True, want_kron=True,
                                  want_trace=True)
    for key in ALL_KEYS:
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]), **TOL[dtype],
                                   err_msg=key)


@pytest.mark.parametrize("block_a,block_b", [(8, 8), (16, 32), (32, 16)])
def test_fused_second_parity_multi_tile(block_a, block_b):
    """Force feature tiling so the cross-tile accumulators are exercised:
    diag accumulates per (i, j) tile over class chunks, kron only on the
    i == 0 lane, trace across every grid step."""
    A, S = _pair(5, 3, 4, 50, 41, seed=7)
    got = _all(A, S, block_a=block_a, block_b=block_b)
    want = ref.fused_second_order(A, S, want_diag=True, want_kron=True,
                                  want_trace=True)
    for key in ALL_KEYS:
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]),
                                   rtol=3e-5, atol=3e-5, err_msg=key)


def test_fused_second_all_mask_combinations():
    """Every 2^3 mask: requested keys present and correct, others absent."""
    A, S = _pair(3, 4, 3, 19, 11)
    for wd, wk, wt in itertools.product([False, True], repeat=3):
        if not (wd or wk or wt):
            with pytest.raises(ValueError):
                ops.fused_second_order(A, S, want_diag=False,
                                       want_kron=False, want_trace=False)
            continue
        got = ops.fused_second_order(A, S, want_diag=wd, want_kron=wk,
                                     want_trace=wt)
        want = ref.fused_second_order(A, S, want_diag=wd, want_kron=wk,
                                      want_trace=wt)
        assert set(got) == set(want)
        for key in got:
            np.testing.assert_allclose(np.asarray(got[key]),
                                       np.asarray(want[key]),
                                       rtol=3e-5, atol=3e-5, err_msg=key)


def test_fused_second_class_chunk_schedules():
    """Chunk schedule invariance: any chunking of the class-grid axis gives
    the same result (allclose across schedules), and each schedule is
    deterministic (bitwise-identical on rerun)."""
    A, S = _pair(6, 3, 4, 21, 13, seed=3)
    want = ref.fused_second_order(A, S, want_diag=True, want_kron=True,
                                  want_trace=True)
    for chunk in (1, 2, 3, 6, None):
        got = _all(A, S, class_chunk=chunk)
        again = _all(A, S, class_chunk=chunk)
        for key in ALL_KEYS:
            np.testing.assert_allclose(np.asarray(got[key]),
                                       np.asarray(want[key]),
                                       rtol=3e-5, atol=3e-5,
                                       err_msg=f"{key} chunk={chunk}")
            assert np.array_equal(np.asarray(got[key]),
                                  np.asarray(again[key])), (key, chunk)


def test_fused_second_internal_consistency():
    """Σ_n trace[n] == Σ_ab diag[a, b], and kron == Σ SᵀS exactly."""
    A, S = _pair(4, 5, 3, 23, 13, seed=11)
    got = _all(A, S)
    np.testing.assert_allclose(float(jnp.sum(got["trace"])),
                               float(jnp.sum(got["diag"])), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(got["kron"]),
        np.asarray(jnp.einsum("cnri,cnrj->ij", S, S)),
        rtol=3e-5, atol=3e-5)
    # PSD-ness of the factor and nonnegativity of the squares
    evals = np.linalg.eigvalsh(np.asarray(got["kron"], np.float64))
    assert evals.min() >= -1e-5
    assert (np.asarray(got["diag"]) >= -1e-6).all()
    assert (np.asarray(got["trace"]) >= -1e-6).all()


@settings(max_examples=20, deadline=None)
@given(c=st.integers(1, 6), n=st.integers(1, 6), r=st.integers(1, 5),
       a=st.integers(1, 33), b=st.integers(1, 33),
       seed=st.integers(0, 2 ** 16))
def test_fused_second_hypothesis_parity(c, n, r, a, b, seed):
    A, S = _pair(c, n, r, a, b, seed=seed)
    got = _all(A, S)
    want = ref.fused_second_order(A, S, want_diag=True, want_kron=True,
                                  want_trace=True)
    for key in ALL_KEYS:
        np.testing.assert_allclose(np.asarray(got[key]),
                                   np.asarray(want[key]),
                                   rtol=5e-5, atol=5e-5, err_msg=key)


# --- registry ----------------------------------------------------------------

def test_fused_second_registered_with_oracle():
    assert "fused_second_order" in ops.registered()
    spec = ops.get_spec("fused_second_order")
    assert spec.ref is ref.fused_second_order and spec.description
    A, S = _pair(2, 3, 2, 10, 7)
    ops.clear_cache()
    ops.fused_second_order(A, S, want_diag=True)
    n0 = ops.cache_stats()["total"]
    ops.fused_second_order(A, S, want_diag=True)           # cached config
    assert ops.cache_stats()["total"] == n0
    ops.fused_second_order(A, S, want_diag=True, want_kron=True)
    assert ops.cache_stats()["fused_second_order"] >= 2    # new static opts


# --- sweep plan + engine routing ---------------------------------------------

def test_sweep_plan_second_order_lane():
    plan = plan_sweeps((DiagGGN, KFLR))
    assert plan.fused_second_mask.diag and plan.fused_second_mask.kron
    assert not plan.fused_second_mask.trace
    assert "fused_second_order=['diag', 'kron']" in plan.describe()
    assert not plan.fused_active  # default config: jnp path
    active = plan_sweeps((DiagGGN, KFLR, GGNTrace),
                         ExtensionConfig(use_kernels=True))
    assert active.fused_active
    assert "fused_second_order=['diag', 'kron', 'trace']" in active.describe()
    # MC extensions land on the same kernel outputs
    mask = second_order_mask((DiagGGNMC, KFAC))
    assert mask.diag and mask.kron and not mask.trace
    assert mask.wants() == dict(want_diag=True, want_kron=True,
                                want_trace=False)
    assert not plan_sweeps((DiagGGN,)).fused_second_mask.kron
    assert plan_sweeps(()).fused_second_mask.any() is False


def _fixture(seed=0, n=5, d=6, h=7, c=4):
    from repro.configs.papernets import mlp

    model = mlp(n_classes=c, in_dim=d, hidden=(h,))
    params = model.init(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, d))
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, c)
    return model, params, x, y


def test_engine_fused_second_matches_jnp():
    """use_kernels=True (fused curvature) ≡ pure-jnp path on exact + MC."""
    model, params, x, y = _fixture()
    exts = (DiagGGN, KFLR, GGNTrace, DiagGGNMC, KFAC)
    rng = jax.random.PRNGKey(9)
    res_jnp = run(model, params, x, y, LOSS, extensions=exts,
                  cfg=ExtensionConfig(use_kernels=False), rng=rng)
    res_fus = run(model, params, x, y, LOSS, extensions=exts,
                  cfg=ExtensionConfig(use_kernels=True), rng=rng)
    for ext in ("diag_ggn", "kflr", "ggn_trace", "diag_ggn_mc", "kfac"):
        ja, fu = (jax.tree.leaves(res_jnp.ext[ext]),
                  jax.tree.leaves(res_fus.ext[ext]))
        assert len(ja) == len(fu) and ja
        for a, b in zip(ja, fu):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-5, err_msg=ext)


def test_engine_fused_second_matches_jnp_conv():
    """R > 1 (conv patch positions): the fused kernel itself — not the
    rank-1 closed forms — is on the engine path, and matches jnp."""
    from repro.configs.papernets import c2d2

    model = c2d2(n_classes=4, in_ch=1, img=8)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 8, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (3,), 0, 4)
    exts = (DiagGGN, KFLR, GGNTrace)
    res_jnp = run(model, params, x, y, LOSS, extensions=exts,
                  cfg=ExtensionConfig(use_kernels=False))
    res_fus = run(model, params, x, y, LOSS, extensions=exts,
                  cfg=ExtensionConfig(use_kernels=True))
    for ext in ("diag_ggn", "kflr", "ggn_trace"):
        ja, fu = (jax.tree.leaves(res_jnp.ext[ext]),
                  jax.tree.leaves(res_fus.ext[ext]))
        assert len(ja) == len(fu) and ja
        for a, b in zip(ja, fu):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-5, atol=3e-5, err_msg=ext)


def test_engine_ggn_trace_sums_to_diag_trace():
    """Σ_n ggn_trace[n] per layer == Σ diag_ggn of that layer's params —
    the per-sample trace is an exact decomposition of the GGN trace."""
    model, params, x, y = _fixture(seed=4)
    res = run(model, params, x, y, LOSS, extensions=(DiagGGN, GGNTrace),
              cfg=ExtensionConfig(use_kernels=True))
    tr_total = sum(float(jnp.sum(l))
                   for l in jax.tree.leaves(res["ggn_trace"]))
    diag_total = sum(float(jnp.sum(l))
                     for l in jax.tree.leaves(res["diag_ggn"]))
    np.testing.assert_allclose(tr_total, diag_total, rtol=1e-5)
    for l in jax.tree.leaves(res["ggn_trace"]):
        assert l.shape == (x.shape[0],)
        assert float(jnp.min(l)) >= -1e-6
