"""Property tests for core/kron.py against dense Kronecker oracles.

``kron_solve`` implements the Martens–Grosse π-split damping (Eq. 28/29):
its exact oracle is the *dense* solve of the same split-damped system
``(A + π√λ I) ⊗ (B + √λ/π I)``, materialized via ``kron_dense``.  The
properties below pin that equivalence over hypothesis-generated SPD
factors — dense-A, diagonal-A (the embedding case) and the bias-block
variant — plus the structural identities (`kron_mat_vec` vs the dense
matrix, inverse-consistency of solve∘matvec).
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import kron


def _spd(key, dim):
    m = jax.random.normal(key, (dim, dim))
    return m @ m.T / dim + 0.1 * jnp.eye(dim)


def _damped_dense(A, B, lam):
    """Dense (A + π√λ I) ⊗ (B + √λ/π I) — kron_solve's exact oracle."""
    pi = kron.pi_factor(A, B)
    sd = jnp.sqrt(lam)
    if A.ndim == 1:
        Ad = jnp.diag(A + pi * sd)
    else:
        Ad = A + pi * sd * jnp.eye(A.shape[0])
    Bd = B + sd / pi * jnp.eye(B.shape[0])
    return jnp.kron(Ad, Bd)


@settings(max_examples=15, deadline=None)
@given(a=st.integers(2, 7), b=st.integers(2, 7),
       lam=st.floats(1e-3, 10.0), seed=st.integers(0, 2 ** 16))
def test_kron_solve_dense_a_matches_dense_oracle(a, b, lam, seed):
    k = jax.random.PRNGKey(seed)
    A = _spd(k, a)
    B = _spd(jax.random.fold_in(k, 1), b)
    g = jax.random.normal(jax.random.fold_in(k, 2), (a, b))
    got = kron.kron_solve(A, B, g, lam)
    want = jnp.linalg.solve(_damped_dense(A, B, lam),
                            g.reshape(-1)).reshape(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(a=st.integers(2, 7), b=st.integers(2, 7),
       lam=st.floats(1e-3, 10.0), seed=st.integers(0, 2 ** 16))
def test_kron_solve_diagonal_a_matches_dense_oracle(a, b, lam, seed):
    """Diagonal-A factors (stored as a vector — the embedding case)."""
    k = jax.random.PRNGKey(seed)
    A = jax.random.uniform(k, (a,), minval=0.05, maxval=2.0)
    B = _spd(jax.random.fold_in(k, 1), b)
    g = jax.random.normal(jax.random.fold_in(k, 2), (a, b))
    got = kron.kron_solve(A, B, g, lam)
    want = jnp.linalg.solve(_damped_dense(A, B, lam),
                            g.reshape(-1)).reshape(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(b=st.integers(2, 9), lam=st.floats(1e-3, 10.0),
       seed=st.integers(0, 2 ** 16))
def test_kron_solve_bias_matches_dense_oracle(b, lam, seed):
    """Bias blocks carry only the B factor: oracle is (B + λI)⁻¹ g."""
    k = jax.random.PRNGKey(seed)
    B = _spd(k, b)
    g = jax.random.normal(jax.random.fold_in(k, 1), (b,))
    got = kron.kron_solve_bias(B, g, lam)
    want = jnp.linalg.solve(B + lam * jnp.eye(b), g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(a=st.integers(1, 6), b=st.integers(1, 6), diag_a=st.booleans(),
       seed=st.integers(0, 2 ** 16))
def test_kron_mat_vec_matches_kron_dense(a, b, diag_a, seed):
    k = jax.random.PRNGKey(seed)
    A = (jax.random.uniform(k, (a,), minval=0.1, maxval=2.0) if diag_a
         else _spd(k, a))
    B = _spd(jax.random.fold_in(k, 1), b)
    g = jax.random.normal(jax.random.fold_in(k, 2), (a, b))
    got = kron.kron_mat_vec(A, B, g)
    want = (kron.kron_dense(A, B) @ g.reshape(-1)).reshape(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(a=st.integers(2, 6), b=st.integers(2, 6),
       lam=st.floats(1e-2, 1.0), seed=st.integers(0, 2 ** 16))
def test_kron_solve_inverts_damped_mat_vec(a, b, lam, seed):
    """solve(A, B, matvec_damped(g)) == g: the solve really is the inverse
    of the split-damped operator it claims to apply."""
    k = jax.random.PRNGKey(seed)
    A = _spd(k, a)
    B = _spd(jax.random.fold_in(k, 1), b)
    g = jax.random.normal(jax.random.fold_in(k, 2), (a, b))
    pi = kron.pi_factor(A, B)
    sd = jnp.sqrt(lam)
    Ad = A + pi * sd * jnp.eye(a)
    Bd = B + sd / pi * jnp.eye(b)
    y = kron.kron_mat_vec(Ad, Bd, g)
    back = kron.kron_solve(A, B, y, lam)
    np.testing.assert_allclose(np.asarray(back), np.asarray(g),
                               rtol=5e-3, atol=5e-4)
