"""The curated top-level surface (`import repro`) and its hygiene.

Two families:

* surface pinning — ``repro.__all__`` is an API contract: every name
  resolves, and adding/removing one is a deliberate diff to the pinned
  set below, not an accident of a refactor;
* deprecation hygiene — the library's own flows (engine sweeps, Laplace
  fits through ``FitOptions``, matrix-free products) emit **zero**
  DeprecationWarnings, i.e. internal callers are fully migrated off the
  shimmed spellings (string reduce aliases, pre-``FitOptions`` keywords).
"""
import jax
import jax.numpy as jnp
import pytest

import repro

# The contract.  Additions land here on purpose, with docs (docs/api.md)
# in the same diff.
EXPECTED_SURFACE = {
    # engine
    "ExtensionConfig", "Results", "SweepPlan", "plan_sweeps", "run",
    # losses
    "CrossEntropyLoss", "MSELoss",
    # extensions
    "BatchDot", "BatchGrad", "BatchL2", "DiagGGN", "DiagGGNMC",
    "DiagHessian", "Extension", "GGNGram", "GGNTrace", "KFAC", "KFLR",
    "KFRA", "NTK", "NTKClasswise", "SecondMoment", "Variance",
    # reducers
    "Reducer", "register_reducer",
    # matrix-free curvature
    "GGNOperator", "HessianOperator", "cg_solve", "ggn_vp", "hvp",
    "lanczos_topk", "slq_logdet",
    # NTK consumers
    "gp_predict", "influence_scores", "ntk_kernel", "select_subset",
    "self_influence",
    # uncertainty
    "fit_posterior",
    # observability
    "obs",
}


def test_surface_is_pinned():
    assert set(repro.__all__) == EXPECTED_SURFACE
    assert len(repro.__all__) == len(set(repro.__all__))


def test_every_name_resolves():
    for name in repro.__all__:
        assert getattr(repro, name) is not None, name


def test_version_present():
    major, minor, patch = repro.__version__.split(".")
    assert all(s.isdigit() for s in (major, minor, patch))


def _tiny():
    from repro.core import Activation, Dense, Sequential

    model = Sequential([Dense(5, 6), Activation("tanh"), Dense(6, 3)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 5))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 3)
    return model, params, x, y


@pytest.mark.filterwarnings("error::DeprecationWarning")
def test_library_flows_emit_zero_deprecation_warnings():
    """`-W error::DeprecationWarning` clean: every internal caller uses
    Reducer objects and FitOptions — no shimmed spelling survives on any
    library-owned path."""
    from repro.laplace import FitOptions, fit_posterior, optimize_marglik

    model, params, x, y = _tiny()
    loss = repro.CrossEntropyLoss()
    cfg = repro.ExtensionConfig()
    # engine: monolithic + accumulated sweep over Reducer-reduce extensions
    repro.run(model, params, x, y, loss,
              extensions=(repro.DiagGGN, repro.Variance, repro.GGNGram))
    repro.plan_sweeps((repro.KFLR,), cfg).accumulate(3).run(
        model, params, x, y, loss, cfg=cfg)
    # laplace: the FitOptions path, fit through evidence tuning
    post = fit_posterior(model, params, x, y, loss, structure="kron",
                         options=FitOptions(mc=True,
                                            cfg=repro.ExtensionConfig(
                                                mc_seed=0)))
    optimize_marglik(post, n_steps=3)
    # matrix-free lane: products + solver
    v = jax.tree.map(jnp.ones_like, params)
    gv = repro.ggn_vp(model, params, x, y, loss, v)
    op = repro.GGNOperator(model, params, x, y, loss, damping=0.1)
    repro.cg_solve(op.mv, gv, maxiter=3)
