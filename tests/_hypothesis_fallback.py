"""Deterministic stand-in for `hypothesis` when it isn't installed.

The pinned CI environment installs the real hypothesis (see
requirements-dev.txt); this container image does not ship it and nothing may
be pip-installed, so ``conftest.py`` registers this shim instead of letting
the property-test modules fail collection.  It implements the tiny slice of
the API the test-suite uses — ``given``, ``settings`` and the ``integers`` /
``floats`` / ``sampled_from`` / ``booleans`` strategies — by drawing
``max_examples`` pseudo-random examples from a PRNG seeded with the test
name, so runs are reproducible and failures print the falsifying example.
No shrinking, no database: a fallback, not a replacement.
"""
from __future__ import annotations

import functools
import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred, _tries=100):
        def draw(rng):
            for _ in range(_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")

        return _Strategy(draw)


def integers(min_value=0, max_value=2 ** 31 - 1):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def just(value):
    return _Strategy(lambda rng: value)


def tuples(*strategies):
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def lists(elements, min_size=0, max_size=8):
    return _Strategy(lambda rng: [
        elements.draw(rng)
        for _ in range(rng.randint(min_size, max_size))
    ])


class settings:
    """Decorator stub: records max_examples, ignores deadline/profiles."""

    def __init__(self, max_examples=10, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._fallback_settings = self
        return fn


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise TypeError(
            "hypothesis fallback supports keyword strategies only")

    def deco(fn):
        pre = getattr(fn, "_fallback_settings", None)

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fallback_settings", None) or pre
            n = cfg.max_examples if cfg is not None else 10
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as exc:
                    raise AssertionError(
                        f"{fn.__qualname__}: falsifying example "
                        f"#{i + 1}/{n}: {drawn}"
                    ) from exc

        # pytest follows __wrapped__ to the original signature and would
        # treat the strategy kwargs as missing fixtures — hide it.
        del wrapper.__wrapped__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return deco


def install():
    """Register this shim as `hypothesis` (+ `.strategies`) in sys.modules."""
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = __doc__
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None)
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans", "just",
                 "tuples", "lists"):
        setattr(st_mod, name, globals()[name])
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
    return mod
