"""Conv substrate (the paper's own benchmark nets) vs oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs.papernets import c2d2, c3d3, logreg, mlp
from repro.core import (
    BatchGrad,
    BatchL2,
    CrossEntropyLoss,
    DiagGGN,
    KFAC,
    KFLR,
    ExtensionConfig,
    SecondMoment,
    Variance,
    oracle,
    run,
)

LOSS = CrossEntropyLoss()


@pytest.fixture(scope="module")
def conv_setup():
    model = c2d2(n_classes=4, in_ch=1, img=8)
    # shrink for oracle feasibility
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 8, 8, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (3,), 0, 4)
    return model, params, x, y


def test_conv_grads_and_batch_stats(conv_setup):
    model, params, x, y = conv_setup
    res = run(model, params, x, y, LOSS,
              extensions=(BatchGrad, BatchL2, SecondMoment, Variance))
    og = oracle.grad(model, LOSS, params, x, y)
    for a, b in zip(jax.tree.leaves(res.grads), jax.tree.leaves(og)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6)
    psg = oracle.per_sample_grads(model, LOSS, params, x, y)
    for a, b in zip(jax.tree.leaves(res["batch_grad"]), jax.tree.leaves(psg)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6)
    sm = jax.tree.map(lambda g: 3 * jnp.sum(g ** 2, 0), psg)
    for a, b in zip(jax.tree.leaves(res["second_moment"]), jax.tree.leaves(sm)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-8)


def test_conv_diag_ggn_small():
    # tiny conv chain: the explicit-GGN oracle materializes [P, P]
    from repro.core import Activation, Dense, Sequential
    from repro.nn.layers import Conv2d, Flatten, MaxPool2d

    model = Sequential([
        Conv2d(1, 4, kernel=3), Activation("relu"), MaxPool2d(2),
        Conv2d(4, 6, kernel=3), Activation("relu"), MaxPool2d(2),
        Flatten(), Dense(6, 3),
    ])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 4, 4, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (2,), 0, 3)
    res = run(model, params, x, y, LOSS, extensions=(DiagGGN,))
    want = oracle.ggn_diag(model, LOSS, params, x, y)
    got, _ = ravel_pytree(res["diag_ggn"])
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-6)


def test_logreg_and_mlp_train():
    from repro.optim import curvature_optimizer
    from repro.optim.optimizers import apply_updates
    from repro.core.engine import run as erun

    model = mlp(n_classes=4, in_dim=10, hidden=(16,))
    params = model.init(jax.random.PRNGKey(0))
    opt = curvature_optimizer(1.0, damping=1e-1, curvature="kfac")
    opt_state = opt.init(params)
    k = jax.random.PRNGKey(1)
    x = jax.random.normal(k, (32, 10))
    y = (x[:, 0] > 0).astype(jnp.int32) + 2 * (x[:, 1] > 0).astype(jnp.int32)
    losses = []
    for i in range(20):
        res = erun(model, params, x, y, LOSS, extensions=(KFAC,),
                   cfg=ExtensionConfig(), rng=jax.random.fold_in(k, i))
        ups, opt_state = opt.update(res.grads, opt_state, params,
                                    curv=res.ext["kfac"])
        params = apply_updates(params, ups)
        losses.append(float(res.loss))
    assert losses[-1] < 0.5 * losses[0], losses


def test_kflr_kfac_factor_shapes(conv_setup):
    model, params, x, y = conv_setup
    res = run(model, params, x, y, LOSS, extensions=(KFLR, KFAC),
              rng=jax.random.PRNGKey(5))
    f = res["kflr"][0]  # first conv layer
    a_dim = 5 * 5 * 1
    assert f["w"]["A"].shape == (a_dim, a_dim)
    assert f["w"]["B"].shape == (32, 32)
    f2 = res["kfac"][0]
    assert f2["w"]["B"].shape == (32, 32)
