"""Observability layer: span nesting, JSONL round-trip, disabled no-op
identity, jit-safety of kernel-dispatch telemetry, cache hit/miss counters,
padding-waste accounting, and the streaming-sweep trace acceptance check
(per-slice span count matches the described schedule; the offline renderer
agrees with the in-memory report)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import (
    Activation,
    CrossEntropyLoss,
    Dense,
    ExtensionConfig,
    Sequential,
    by_name,
    plan_sweeps,
)
from repro.kernels import ops
from repro.obs import NullRegistry, ObsRegistry
from repro.obs.reporting import load_jsonl, render

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(autouse=True)
def _clean_registry():
    """Every test starts and ends with the disabled module registry."""
    obs.disable()
    yield
    obs.disable()


def _tiny_problem(n=8, d=4, h=6, c=3, seed=0):
    model = Sequential([Dense(d, h), Activation("sigmoid"), Dense(h, c)])
    params = model.init(jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, d))
    y = jax.random.randint(jax.random.PRNGKey(seed + 2), (n,), 0, c)
    return model, params, x, y, CrossEntropyLoss()


# ---------------------------------------------------------------------------
# registry core
# ---------------------------------------------------------------------------


def test_span_nesting_paths_and_attrs():
    reg = ObsRegistry()
    with obs.use(reg):
        with obs.span("outer", n=2):
            with obs.span("inner", bytes=128) as sp:
                sp.set(rows=7)
            with obs.span("inner"):
                pass
    spans = [e for e in reg.events if e["kind"] == "span"]
    assert [tuple(e["path"]) for e in spans] == [
        ("outer", "inner"), ("outer", "inner"), ("outer",)]
    assert spans[0]["attrs"] == {"bytes": 128, "rows": 7}
    assert spans[2]["attrs"] == {"n": 2}
    assert all(e["dur_s"] >= 0.0 for e in spans)
    # children accounted inside the parent's duration
    assert spans[2]["dur_s"] >= spans[0]["dur_s"] + spans[1]["dur_s"]


def test_counters_and_gauges():
    reg = ObsRegistry()
    with obs.use(reg):
        obs.count("steps")
        obs.count("steps", 4)
        obs.gauge("cursor", 3)
        obs.gauge("cursor", 9)
    assert reg.counters == {"steps": 5}
    assert reg.gauges == {"cursor": 9}


def test_use_restores_previous_registry_on_error():
    before = obs.get()
    with pytest.raises(RuntimeError):
        with obs.use(ObsRegistry()):
            assert obs.enabled()
            raise RuntimeError("boom")
    assert obs.get() is before
    assert not obs.enabled()


def test_enable_disable_module_registry():
    assert not obs.enabled()
    obs.enable()
    assert obs.enabled()
    obs.count("x")
    assert obs.get().counters == {"x": 1}
    obs.disable()
    assert isinstance(obs.get(), NullRegistry)


def test_jsonl_round_trip(tmp_path):
    trace = str(tmp_path / "trace.jsonl")
    reg = ObsRegistry(trace_jsonl=trace)
    with obs.use(reg):
        with obs.span("work", n=np.int64(3), frac=np.float32(0.5),
                      tag="slice"):
            obs.count("calls", 2)
        obs.gauge("cursor", 1)
    reg.close()
    events = load_jsonl(trace)
    assert events == list(reg.events)
    # every attr value landed as a JSON primitive, not a numpy repr
    for line in open(trace):
        for v in json.loads(line).get("attrs", {}).values():
            assert isinstance(v, (int, float, str, bool))


def test_load_jsonl_tolerates_torn_tail(tmp_path):
    trace = tmp_path / "trace.jsonl"
    trace.write_text(
        json.dumps({"kind": "count", "name": "a", "value": 1}) + "\n"
        + '{"kind": "span", "name": "tru')
    events = load_jsonl(str(trace))
    assert len(events) == 1 and events[0]["name"] == "a"


# ---------------------------------------------------------------------------
# disabled path: a no-op, and numerically invisible
# ---------------------------------------------------------------------------


def test_null_registry_is_shared_singleton_noop():
    s1 = obs.span("a", n=1)
    s2 = obs.span("b")
    assert s1 is s2  # one preallocated null span, no per-call allocation
    with s1 as sp:
        sp.set(bytes=4)
    assert obs.get().events == ()
    assert obs.get().counters == {}
    assert "disabled" in obs.report()


def test_disabled_and_enabled_sweeps_agree():
    model, params, x, y, loss = _tiny_problem()
    exts = tuple(by_name(nm) for nm in ("batch_l2", "variance", "diag_ggn"))
    cfg = ExtensionConfig(use_kernels=True)
    plan = plan_sweeps(exts, cfg)
    off = plan.run(model, params, x, y, loss, cfg=cfg)
    reg = ObsRegistry()
    with obs.use(reg):
        on = plan.run(model, params, x, y, loss, cfg=cfg)
    assert len(reg.events) > 0  # instrumentation did record
    np.testing.assert_allclose(off.loss, on.loss)
    jax.tree.map(np.testing.assert_array_equal, off.ext, on.ext)


# ---------------------------------------------------------------------------
# kernel dispatch telemetry
# ---------------------------------------------------------------------------


def test_cache_hit_miss_counters():
    ops.clear_cache()
    A = jnp.ones((4, 5, 3))
    B = jnp.ones((4, 5, 2))
    reg = ObsRegistry()
    with obs.use(reg):
        ops.batch_l2(A, B)
        ops.batch_l2(A, B)
    stats = ops.cache_stats()
    assert stats["misses"]["batch_l2"] == 1
    assert stats["hits"]["batch_l2"] == 1
    assert isinstance(stats["total"], int)  # legacy shape preserved
    assert reg.counters["kernel.cache_miss.batch_l2"] == 1
    assert reg.counters["kernel.cache_hit.batch_l2"] == 1
    assert reg.counters["kernel.calls.batch_l2"] == 2


def test_padding_waste_matches_hand_computed_bytes():
    ops.clear_cache()
    # batch_l2 pads axis 1 (R) of both operands up to block_r: R=5 with
    # block_r=8 zero-fills 3 rows of [a]/[b] float32 per sample
    N, R, a, b = 4, 5, 3, 2
    A = jnp.ones((N, R, a), jnp.float32)
    B = jnp.ones((N, R, b), jnp.float32)
    pad = (-R) % 8
    expected = pad * N * a * 4 + pad * N * b * 4
    reg = ObsRegistry()
    with obs.use(reg):
        ops.batch_l2(A, B, block_r=8)
        ops.batch_l2(A, B, block_r=8)  # cached shapes: waste replayed
    assert reg.counters["kernel.padding_waste_bytes.batch_l2"] == 2 * expected


def test_dispatch_records_at_trace_time_not_per_eval():
    """Inside jit, dispatch (and its obs counters) runs once at trace time;
    steady-state calls of the jitted wrapper must not grow the counters."""
    ops.clear_cache()
    A = jnp.ones((4, 5, 3))
    B = jnp.ones((4, 5, 2))
    fn = jax.jit(lambda A, B: ops.batch_l2(A, B))
    reg = ObsRegistry()
    with obs.use(reg):
        for _ in range(3):
            jax.block_until_ready(fn(A, B))
    assert reg.counters["kernel.calls.batch_l2"] == 1  # the trace, only
    with obs.use(reg):
        ops.batch_l2(A, B)  # eager: dispatch really runs
    assert reg.counters["kernel.calls.batch_l2"] == 2


# ---------------------------------------------------------------------------
# acceptance: streaming sweep trace matches the described schedule
# ---------------------------------------------------------------------------


def test_stream_trace_matches_schedule_and_renders(tmp_path):
    model, params, x, y, loss = _tiny_problem(n=8)
    exts = tuple(by_name(nm) for nm in ("batch_l2", "variance"))
    cfg = ExtensionConfig(use_kernels=True)
    stream = plan_sweeps(exts, cfg).accumulate(4).stream(
        model, params, x, y, loss, cfg=cfg)
    trace = str(tmp_path / "trace.jsonl")
    reg = ObsRegistry(trace_jsonl=trace)
    with obs.use(reg):
        while not stream.done:
            stream.step()
        res = stream.result()
    reg.close()
    assert np.isfinite(float(res.loss))
    assert f"stream: {stream.n_slices} slice" in stream.describe()

    events = load_jsonl(trace)
    slices = [e for e in events
              if e["kind"] == "span" and e["name"] == "engine/stream/slice"]
    assert len(slices) == stream.n_slices == len(stream.units)
    assert [e["attrs"]["t"] for e in slices] == list(range(stream.n_slices))
    # finalize spans: one per reducer-carried extension (variance); the
    # row-concat extension (batch_l2) has no finalize step by design
    finals = [e for e in events
              if e["kind"] == "span" and e["name"] == "engine/finalize"]
    assert sorted(e["attrs"]["ext"] for e in finals) == \
        sorted(stream.carry_names)
    assert reg.gauges["engine.stream.cursor"] == len(stream.units)

    # offline renderer and the in-memory report agree on the same trace
    report = render(events)
    assert "engine/stream/slice" in report
    assert f"{stream.n_slices:>6d}" in report
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "obs_report.py"), trace],
        capture_output=True, text=True, cwd=REPO)
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.strip() == report.strip()


def test_report_renders_tree_counters_gauges():
    events = [
        {"kind": "span", "name": "a", "path": ["a"], "dur_s": 0.25,
         "attrs": {"bytes": 100, "step": 7}},
        {"kind": "span", "name": "b", "path": ["a", "b"], "dur_s": 0.1,
         "attrs": {"bytes": 40}},
        {"kind": "span", "name": "b", "path": ["a", "b"], "dur_s": 0.1,
         "attrs": {"bytes": 2}},
        {"kind": "count", "name": "calls", "value": 3},
        {"kind": "gauge", "name": "cursor", "value": 5},
    ]
    out = render(events)
    lines = out.splitlines()
    (a_line,) = [ln for ln in lines if ln.startswith("a ")]
    (b_line,) = [ln for ln in lines if ln.lstrip().startswith("b ")]
    assert lines.index(b_line) > lines.index(a_line)  # child under parent
    assert "bytes=42" in b_line
    assert "step=" not in a_line  # identifiers are not summed
    assert "calls = 3" in out and "cursor = 5" in out
    assert render([]) == "no events recorded"
