"""Docs-site validators that run without mkdocs installed.

The docs CI lane runs ``mkdocs build --strict`` on a runner that has the
doc toolchain; the hermetic test container does not.  These tests pin the
failure modes ``--strict`` would catch that are checkable statically —
nav entries pointing at missing pages, broken relative links/anchors, and
``::: identifier`` blocks naming objects that do not exist (the
mkdocstrings collection step) — so a docs breakage fails tier-1, not just
the docs lane.
"""
import importlib
import os
import re

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = os.path.join(ROOT, "docs")
MKDOCS_YML = os.path.join(ROOT, "mkdocs.yml")


def _nav_targets():
    """Page paths from mkdocs.yml's nav (string-literal parse — the file
    is plain YAML with `key: value.md` leaves; no yaml dep needed)."""
    targets = []
    in_nav = False
    with open(MKDOCS_YML, encoding="utf-8") as f:
        for line in f:
            if line.startswith("nav:"):
                in_nav = True
                continue
            if in_nav:
                if line.strip() and not line.startswith((" ", "-")):
                    break  # nav block ended
                m = re.search(r":\s*([\w./-]+\.md)\s*$", line)
                if m:
                    targets.append(m.group(1))
    return targets


def test_nav_entries_exist():
    targets = _nav_targets()
    assert len(targets) >= 8, f"nav looks truncated: {targets}"
    for t in targets:
        assert os.path.exists(os.path.join(DOCS, t)), f"nav -> missing {t}"


def test_all_docs_pages_in_nav():
    """Orphan pages don't fail --strict but do rot; keep nav exhaustive."""
    targets = set(_nav_targets())
    pages = {f for f in os.listdir(DOCS) if f.endswith(".md")}
    assert pages == targets, (
        f"docs/ pages vs nav mismatch: only-in-docs={pages - targets}, "
        f"only-in-nav={targets - pages}")


def test_mkdocstrings_identifiers_importable():
    """Every `::: dotted.path` must collect — the docs lane's equivalent
    failure is mkdocstrings aborting the strict build."""
    idents = []
    for page in os.listdir(DOCS):
        if not page.endswith(".md"):
            continue
        with open(os.path.join(DOCS, page), encoding="utf-8") as f:
            idents += [(page, m.group(1)) for m in
                       re.finditer(r"^::: ([\w.]+)$", f.read(), re.M)]
    assert idents, "API page lost its mkdocstrings blocks"
    for page, ident in idents:
        module, _, attr = ident.rpartition(".")
        try:
            obj = importlib.import_module(ident)
        except ModuleNotFoundError:
            mod = importlib.import_module(module)
            assert hasattr(mod, attr), f"{page}: ::: {ident} not found"
            obj = getattr(mod, attr)
        assert obj is not None


def test_relative_links_resolve():
    import sys
    sys.path.insert(0, os.path.join(ROOT, "tools"))
    try:
        import check_links
    finally:
        sys.path.pop(0)
    assert check_links.main([DOCS, os.path.join(ROOT, "README.md")]) == 0


def test_readme_points_at_docs():
    with open(os.path.join(ROOT, "README.md"), encoding="utf-8") as f:
        readme = f.read()
    assert "docs/" in readme and "mkdocs" in readme.lower(), (
        "README should stay a short pointer to the docs site")
    # the README stays a pointer + quickstart, not a second copy of the
    # subsystem docs (the pre-site README was 233 lines)
    assert readme.count("\n") < 120, "README grew back into a docs mirror"


@pytest.mark.slow
def test_mkdocs_strict_build_if_available():
    """When the doc toolchain happens to be installed (dev machines),
    run the real strict build; elsewhere skip — CI's docs lane owns it
    and sets REPRO_REQUIRE_MKDOCS=1, turning a missing toolchain there
    into a hard failure instead of a silent perpetual skip."""
    if os.environ.get("REPRO_REQUIRE_MKDOCS"):
        import mkdocs  # noqa: F401 — the docs lane must never skip this
    else:
        pytest.importorskip(
            "mkdocs",
            reason="mkdocs not installed: the hermetic tier-1 lanes skip "
                   "the strict build by design; the docs CI lane (which "
                   "installs requirements-docs.txt) runs it with "
                   "REPRO_REQUIRE_MKDOCS=1")
    import subprocess
    import sys as _sys
    out = subprocess.run(
        [_sys.executable, "-m", "mkdocs", "build", "--strict",
         "--site-dir", os.path.join(ROOT, ".mkdocs-test-site")],
        cwd=ROOT, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
