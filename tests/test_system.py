"""End-to-end behaviour: the paper's Fig. 1 workflow + loss factorizations."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, SHAPES
from repro.core import (
    BatchGrad,
    CrossEntropyLoss,
    DiagGGNMC,
    ExtensionConfig,
    KFAC,
    MSELoss,
    Variance,
    run,
)
from repro.data.synthetic import batch_for
from repro.nn.models import build_model


def test_fig1_workflow():
    """The paper's README example: gradient AND variance from one pass."""
    cfg = ARCHS["stablelm-1.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16, global_batch=4)
    batch = batch_for(cfg, shape, 0)
    res = run(model, params, batch["inputs"], batch["labels"],
              CrossEntropyLoss(), extensions=(Variance, BatchGrad))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(res.grads))
    assert all(float(jnp.min(v)) > -1e-5 for v in jax.tree.leaves(res["variance"]))
    for bg, g in zip(jax.tree.leaves(res["batch_grad"]),
                     jax.tree.leaves(res.grads)):
        np.testing.assert_allclose(np.asarray(jnp.sum(bg, 0)), np.asarray(g),
                                   rtol=5e-3, atol=5e-5)


def test_curvature_on_full_transformer():
    """KFAC + DiagGGN-MC extract on a reduced gemma3 (nested-scan stacks)."""
    cfg = ARCHS["gemma3-12b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16, global_batch=2)
    batch = batch_for(cfg, shape, 0)

    f = jax.jit(lambda p, r: run(model, p, batch["inputs"], batch["labels"],
                                 CrossEntropyLoss(),
                                 extensions=(KFAC, DiagGGNMC),
                                 cfg=ExtensionConfig(mc_samples=1), rng=r).ext)
    out = f(params, jax.random.PRNGKey(1))
    for l in jax.tree.leaves(out["diag_ggn_mc"]):
        assert float(jnp.min(l)) >= -1e-7  # MC GGN diag is a sum of squares
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(out))


def test_ce_factorizations():
    loss = CrossEntropyLoss()
    z = jax.random.normal(jax.random.PRNGKey(0), (4, 3, 6))
    y = jax.random.randint(jax.random.PRNGKey(1), (4, 3), 0, 6)
    g = jax.grad(lambda zz: loss.value(zz, y))(z)
    np.testing.assert_allclose(np.asarray(loss.grad(z, y)), np.asarray(g),
                               rtol=1e-5, atol=1e-7)
    # exact factor squares to the Hessian (via hessian_vec oracle)
    S = loss.sqrt_hessian(z, y)  # [U·C, 4, 3, 6]
    v = jax.random.normal(jax.random.PRNGKey(2), z.shape)
    # factor columns are per-sample blocks: contract keeping n separate
    sv = jnp.einsum("kntc,ntc->kn", S, v)
    hv = jnp.einsum("kn,kntc->ntc", sv, S)
    want = loss.hessian_vec(z, y, v)
    np.testing.assert_allclose(np.asarray(hv), np.asarray(want),
                               rtol=1e-4, atol=1e-6)
    # chunked slices agree with the full factor
    for lo, sz in ((0, 5), (5, 7), (12, 6)):
        Sc = loss.sqrt_hessian_chunk(z, y, lo, sz)
        np.testing.assert_allclose(np.asarray(Sc),
                                   np.asarray(S[lo:lo + sz]),
                                   rtol=1e-5, atol=1e-7)


def test_mse_factorization():
    loss = MSELoss()
    z = jax.random.normal(jax.random.PRNGKey(0), (3, 4))
    y = jax.random.normal(jax.random.PRNGKey(1), (3, 4))
    S = loss.sqrt_hessian(z, y)
    sv = jnp.einsum("knc,nc->kn", S, z)
    hv = jnp.einsum("kn,knc->nc", sv, S)
    np.testing.assert_allclose(np.asarray(hv),
                               np.asarray(loss.hessian_vec(z, y, z)),
                               rtol=1e-5, atol=1e-6)
