"""NTK consumers (repro.ntk_apps) against closed-form oracles.

Oracle-grade coverage for the three consumer lanes:

* **GP regression** — predictive mean/variance on papernets configs vs
  the dense closed form on the materialized kernel (`_oracles`), at the
  3e-5 acceptance tolerance; the three solvers (Cholesky / eigh /
  Lanczos-preconditioned CG) agree; truncated eigh matches an
  independently computed spectral oracle; streamed (`microbatches=k`)
  and sharded ('master' assembly) lanes match monolithic.
* **Influence** — on a convex problem (linear head + MSE at its ridge
  optimum) influence scores rank-match *actual* leave-one-out
  retraining deltas (closed-form retrains, Spearman ≥ 0.9) and
  self-influence matches its closed form; streamed == monolithic.
* **Selection** — greedy max-diversity picks equal brute-force
  log-det maximization step by step; the BAIT kernel-space objective
  equals the parameter-space Fisher trace it Woodbury-avoids; streamed
  selection is exact.

Plus the `curv.lanczos_topk` spectral primitive against dense `eigh`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.papernets import logreg, mlp
from repro.core import CrossEntropyLoss, Dense, MSELoss, Sequential
from repro.curv import lanczos_topk
from repro.ntk_apps import (
    bait_select,
    gp_predict,
    greedy_max_diversity,
    influence_scores,
    kernel_solve,
    ntk_kernel,
    select_subset,
    self_influence,
)

from _oracles import (TOL, materialized_ntk, scaled_jacobian, spearman,
                      tiny_mlp)

LOSS = CrossEntropyLoss()


# ---------------------------------------------------------------------------
# GP regression vs the dense closed form
# ---------------------------------------------------------------------------


def _papernet(name):
    if name == "logreg":
        model = logreg(n_classes=3, in_dim=6)
    else:
        model = mlp(n_classes=3, in_dim=6, hidden=(8,))
    params = model.init(jax.random.PRNGKey(0))
    x_tr = jax.random.normal(jax.random.PRNGKey(1), (12, 6))
    y_tr = jax.random.randint(jax.random.PRNGKey(2), (12,), 0, 3)
    x_te = jax.random.normal(jax.random.PRNGKey(3), (4, 6))
    return model, params, x_tr, y_tr, x_te


def _dense_gp_oracle(model, params, x_tr, y_tr, x_te, ridge):
    """Closed-form kernel regression on the materialized class-traced
    NTK: mean = K_st α, var = diag(K_ss − K_st (K_tt+λI)⁻¹ K_ts)."""
    n = x_tr.shape[0]
    x = jnp.concatenate([x_tr, x_te], axis=0)
    K4 = materialized_ntk(model, params, x)
    K = np.einsum("ncmc->nm", K4)
    Y = np.asarray(jax.nn.one_hot(y_tr, K4.shape[1]))
    A = K[:n, :n] + ridge * np.eye(n)
    alpha = np.linalg.solve(A, Y)
    W = np.linalg.solve(A, K[:n, n:])
    mean = K[n:, :n] @ alpha
    var = np.diag(K[n:, n:]) - np.einsum("sn,ns->s", K[n:, :n], W)
    return mean, var


@pytest.mark.parametrize("arch", ["logreg", "mlp"])
def test_gp_predictive_matches_dense_oracle_on_papernets(arch):
    model, params, x_tr, y_tr, x_te = _papernet(arch)
    # ridge sized so cond(K+λI) ≲ 60: the oracle and the pipeline solve
    # *different* float32 linearizations of the same kernel, and their
    # disagreement is cond · O(eps_f32) — at λ=1e-2 (cond ~7e3) that
    # amplifies past the 3e-5 contract without testing anything extra
    ridge = 2.0
    want_mean, want_var = _dense_gp_oracle(model, params, x_tr, y_tr,
                                           x_te, ridge)
    gp = gp_predict(model, params, x_tr, y_tr, x_te, LOSS, ridge=ridge)
    np.testing.assert_allclose(np.asarray(gp.mean), want_mean, **TOL)
    np.testing.assert_allclose(np.asarray(gp.var), want_var, **TOL)
    assert gp.info.method == "cholesky"
    assert float(gp.var.min()) > 0.0  # λ > 0 keeps the posterior proper


def test_gp_solvers_agree():
    model, params, x_tr, y_tr, x_te = _papernet("mlp")
    ridge = 2.0  # same conditioning bound as the oracle test above
    base = gp_predict(model, params, x_tr, y_tr, x_te, LOSS, ridge=ridge)
    eig = gp_predict(model, params, x_tr, y_tr, x_te, LOSS, ridge=ridge,
                     solver="eigh")
    lan = gp_predict(model, params, x_tr, y_tr, x_te, LOSS, ridge=ridge,
                     solver="lanczos", rank=8, cg_tol=1e-12)
    for other in (eig, lan):
        np.testing.assert_allclose(np.asarray(other.mean),
                                   np.asarray(base.mean), **TOL)
        np.testing.assert_allclose(np.asarray(other.var),
                                   np.asarray(base.var), **TOL)
    assert lan.info.iters > 0 and float(lan.info.resid) < 1e-5


def test_truncated_eigh_matches_spectral_oracle():
    """rank-r kernel_solve == the independently-computed truncated
    spectral solve: top-r eigenspace at 1/(λ_i+λ), tail at 1/λ."""
    rng = np.random.default_rng(0)
    R = rng.normal(size=(10, 10)).astype(np.float32)
    K = R @ R.T / 10
    B = rng.normal(size=(10, 2)).astype(np.float32)
    ridge = 1e-1
    X, info = kernel_solve(jnp.asarray(K), jnp.asarray(B), ridge=ridge,
                           solver="eigh", rank=4)
    w, U = np.linalg.eigh(K)
    Ur, wr = U[:, ::-1][:, :4], w[::-1][:4]
    proj = Ur.T @ B
    want = Ur @ (proj / (wr + ridge)[:, None]) + (B - Ur @ proj) / ridge
    np.testing.assert_allclose(np.asarray(X), want, rtol=1e-4, atol=1e-5)
    assert info.rank == 4
    # full-rank truncation degenerates to the exact solve
    X_full, _ = kernel_solve(jnp.asarray(K), jnp.asarray(B), ridge=ridge,
                             solver="eigh", rank=10)
    X_chol, _ = kernel_solve(jnp.asarray(K), jnp.asarray(B), ridge=ridge)
    np.testing.assert_allclose(np.asarray(X_full), np.asarray(X_chol),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("k", [2, 3])
def test_streamed_gp_matches_monolithic(k):
    model, params, x_tr, y_tr, x_te = _papernet("mlp")
    mono = gp_predict(model, params, x_tr, y_tr, x_te, LOSS, ridge=1e-2)
    st = gp_predict(model, params, x_tr, y_tr, x_te, LOSS, ridge=1e-2,
                    microbatches=k)
    np.testing.assert_allclose(np.asarray(st.kernel),
                               np.asarray(mono.kernel), **TOL)
    np.testing.assert_allclose(np.asarray(st.mean), np.asarray(mono.mean),
                               **TOL)
    np.testing.assert_allclose(np.asarray(st.var), np.asarray(mono.var),
                               **TOL)


def test_sharded_gp_matches_monolithic():
    """'master' assembly on however many devices the process owns (8 in
    the multidevice CI lane): the factorization runs on shard 0's full
    kernel and matches the single-device run."""
    from repro.launch.mesh import make_data_mesh

    model, params, x_tr, y_tr, x_te = _papernet("mlp")  # 12 + 4 rows
    mono = gp_predict(model, params, x_tr, y_tr, x_te, LOSS, ridge=1e-2)
    sh = gp_predict(model, params, x_tr, y_tr, x_te, LOSS, ridge=1e-2,
                    mesh=make_data_mesh(), gram_assembly="master")
    np.testing.assert_allclose(np.asarray(sh.mean), np.asarray(mono.mean),
                               **TOL)
    np.testing.assert_allclose(np.asarray(sh.var), np.asarray(mono.var),
                               **TOL)


def test_kernel_solve_rejects_bad_config():
    K = jnp.eye(4)
    b = jnp.ones((4,))
    with pytest.raises(ValueError, match="unknown solver"):
        kernel_solve(K, b, ridge=1e-2, solver="qr")
    with pytest.raises(ValueError, match="needs rank"):
        kernel_solve(K, b, ridge=1e-2, solver="lanczos")


# ---------------------------------------------------------------------------
# the Lanczos spectral primitive
# ---------------------------------------------------------------------------


def test_lanczos_topk_matches_dense_eigh():
    rng = np.random.default_rng(1)
    R = rng.normal(size=(40, 40)).astype(np.float32)
    A = R @ R.T / 40 + np.eye(40, dtype=np.float32)
    res = lanczos_topk(lambda v: jnp.asarray(A) @ v,
                       jnp.zeros((40,), jnp.float32),
                       rng=jax.random.PRNGKey(0), k=5, iters=40)
    w, U = np.linalg.eigh(A)
    np.testing.assert_allclose(np.asarray(res.eigvals), w[::-1][:5],
                               rtol=1e-4)
    # Ritz vectors align with the dense eigenvectors up to sign
    cos = np.abs(np.sum(np.asarray(res.eigvecs) * U[:, ::-1][:, :5].T,
                        axis=1))
    np.testing.assert_allclose(cos, np.ones(5), atol=1e-3)
    with pytest.raises(ValueError, match="exceeds operator dim"):
        lanczos_topk(lambda v: v, jnp.zeros((3,)),
                     rng=jax.random.PRNGKey(0), k=5)


# ---------------------------------------------------------------------------
# influence vs leave-one-out retraining (convex closed forms)
# ---------------------------------------------------------------------------


def _ridge_problem():
    """Linear head + MSE at the exact optimum of the ridge objective
    J(W) = (1/n)Σ ½‖Wᵀx_i − y_i‖² + (δ/2)‖W‖² — the setting where
    influence theory is exact up to the LOO reweighting."""
    # n large enough that the O(1/n) LOO-reweighting error influence
    # functions ignore stays below the rank resolution (Spearman ≥ 0.99
    # here; at n=10 one test point drops to 0.7), and not divisible by 3
    # so the streamed differential keeps an uneven final microbatch.
    n, d, c, delta = 22, 4, 2, 0.3
    X = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (n, d)),
                   np.float64)
    Y = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (n, c)),
                   np.float64)
    W = np.linalg.solve(X.T @ X / n + delta * np.eye(d), X.T @ Y / n)
    model = Sequential([Dense(d, c, use_bias=False)])
    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(lambda _: jnp.asarray(W, jnp.float32), params)
    return model, params, X, Y, W, delta


def _loo_weights(X, Y, skip, delta):
    keep = [i for i in range(X.shape[0]) if i != skip]
    Xk, Yk, m = X[keep], Y[keep], len(keep)
    return np.linalg.solve(Xk.T @ Xk / m + delta * np.eye(X.shape[1]),
                           Xk.T @ Yk / m)


def test_influence_rank_matches_loo_retraining():
    model, params, X, Y, W, delta = _ridge_problem()
    n = X.shape[0]
    x_te = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (3, 4)),
                      np.float64)
    y_te = np.asarray(jax.random.normal(jax.random.PRNGKey(4), (3, 2)),
                      np.float64)
    inf = influence_scores(model, params, jnp.asarray(X, jnp.float32),
                           jnp.asarray(Y, jnp.float32),
                           jnp.asarray(x_te, jnp.float32),
                           jnp.asarray(y_te, jnp.float32), MSELoss(),
                           damping=delta, cg_tol=1e-10)
    # exact closed-form retrains: the test loss delta from dropping i
    def test_losses(Wm):
        return 0.5 * ((x_te @ Wm - y_te) ** 2).sum(axis=1)

    base = test_losses(W)
    deltas = np.stack([test_losses(_loo_weights(X, Y, i, delta)) - base
                       for i in range(n)])              # [n, n_test]
    for j in range(x_te.shape[0]):
        rho = spearman(np.asarray(inf.scores)[:, j], deltas[:, j])
        assert rho >= 0.9, f"test point {j}: spearman {rho:.3f}"


def test_self_influence_matches_closed_form():
    """Linear + MSE: s_i = (r_iᵀr_i) · x_iᵀ (XᵀX/n + δI)⁻¹ x_i with
    r_i the residual — the Gram/residual factorization of
    ∇ℓ_iᵀ (G + δI)⁻¹ ∇ℓ_i."""
    model, params, X, Y, W, delta = _ridge_problem()
    n, d = X.shape
    si = self_influence(model, params, jnp.asarray(X, jnp.float32),
                        jnp.asarray(Y, jnp.float32), MSELoss(),
                        damping=delta, cg_tol=1e-10)
    R = X @ W - Y
    hat = np.einsum("id,de,ie->i", X,
                    np.linalg.inv(X.T @ X / n + delta * np.eye(d)), X)
    want = (R ** 2).sum(axis=1) * hat
    np.testing.assert_allclose(np.asarray(si.scores), want, rtol=1e-4,
                               atol=1e-5)


@pytest.mark.parametrize("k", [2, 3])
def test_streamed_influence_matches_monolithic(k):
    model, params, x, y = tiny_mlp()
    x_te = jax.random.normal(jax.random.PRNGKey(7), (4, 5))
    y_te = jax.random.randint(jax.random.PRNGKey(8), (4,), 0, 3)
    mono = influence_scores(model, params, x, y, x_te, y_te, LOSS,
                            damping=1e-2, cg_tol=1e-10)
    st = influence_scores(model, params, x, y, x_te, y_te, LOSS,
                          damping=1e-2, cg_tol=1e-10, microbatches=k)
    np.testing.assert_allclose(np.asarray(st.scores),
                               np.asarray(mono.scores), **TOL)
    mono_s = self_influence(model, params, x, y, LOSS, damping=1e-2,
                            cg_tol=1e-10)
    st_s = self_influence(model, params, x, y, LOSS, damping=1e-2,
                          cg_tol=1e-10, microbatches=k)
    np.testing.assert_allclose(np.asarray(st_s.scores),
                               np.asarray(mono_s.scores), **TOL)


# ---------------------------------------------------------------------------
# subset selection vs brute force
# ---------------------------------------------------------------------------


def test_greedy_diversity_matches_bruteforce_logdet():
    model, params, x, y = tiny_mlp()
    K = np.einsum("ncmc->nm", materialized_ntk(model, params, x))
    jitter = 1e-4
    idx, gains = greedy_max_diversity(jnp.asarray(K), 4, jitter=jitter)
    idx = [int(i) for i in idx]
    Kj = K + jitter * np.eye(K.shape[0])
    chosen = []
    for t in range(4):
        # brute force: the next pick maximizes logdet(K_{S∪j})
        best = max((j for j in range(K.shape[0]) if j not in chosen),
                   key=lambda j: np.linalg.slogdet(
                       Kj[np.ix_(chosen + [j], chosen + [j])])[1])
        assert idx[t] == best, f"step {t}: greedy {idx[t]} != {best}"
        chosen.append(best)
    # gains are the picked conditional variances: positive, non-increasing
    g = np.asarray(gains)
    assert (g > 0).all() and (np.diff(g) <= 1e-6).all()


def test_bait_kernel_objective_matches_param_space():
    """The Woodbury/Gram evaluation of tr((F_S+λI)⁻¹F_pool) equals the
    parameter-space computation from materialized scaled Jacobians, for
    every greedy prefix — and each greedy pick is the parameter-space
    argmin."""
    model, params, x, y = tiny_mlp(n=8)
    lam = 0.5
    sel = select_subset(model, params, x, y, LOSS, 3, method="bait",
                        lam=lam)
    Jp, flat, _ = scaled_jacobian(model, params, x, y, LOSS)
    Phi = np.asarray(Jp.transpose(1, 0, 2), np.float64)   # [N, C̃, P]
    F = np.einsum("ncp,ncq->npq", Phi, Phi)               # per-sample Fisher
    F_pool = F.sum(0)
    P = flat.size

    def param_obj(S):
        FS = F[list(S)].sum(0)
        return np.trace(np.linalg.solve(FS + lam * np.eye(P), F_pool))

    picked = [int(i) for i in sel.indices]
    for t in range(3):
        S = picked[:t + 1]
        np.testing.assert_allclose(float(sel.scores[t]), param_obj(S),
                                   rtol=1e-4)
        best = min((j for j in range(8) if j not in picked[:t]),
                   key=lambda j: param_obj(picked[:t] + [j]))
        assert picked[t] == best, f"step {t}: bait {picked[t]} != {best}"


@pytest.mark.parametrize("method", ["diversity", "bait"])
def test_streamed_selection_matches_monolithic(method):
    model, params, x, y = tiny_mlp()
    mono = select_subset(model, params, x, y, LOSS, 3, method=method)
    st = select_subset(model, params, x, y, LOSS, 3, method=method,
                       microbatches=3)
    np.testing.assert_allclose(np.asarray(st.kernel),
                               np.asarray(mono.kernel), **TOL)
    assert [int(i) for i in st.indices] == [int(i) for i in mono.indices]


def test_selectors_reject_bad_k():
    K = jnp.eye(5)
    with pytest.raises(ValueError, match="outside"):
        greedy_max_diversity(K, 6)
    with pytest.raises(ValueError, match="outside"):
        bait_select(K, 0)
    model, params, x, y = tiny_mlp(n=4)
    with pytest.raises(ValueError, match="unknown method"):
        select_subset(model, params, x, y, LOSS, 2, method="random")


def test_ntk_kernel_matches_materialized_oracle():
    """The public ntk_kernel entry point == einsum('ncmc->nm') of the
    4-index oracle (the class-traced convention)."""
    model, params, x, y = tiny_mlp()
    K = ntk_kernel(model, params, x, y, LOSS)
    want = np.einsum("ncmc->nm", materialized_ntk(model, params, x))
    np.testing.assert_allclose(np.asarray(K), want, rtol=1e-5, atol=1e-5)
