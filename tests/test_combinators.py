"""Generalized backprop through scan / residual / parallel / mixers /
embeddings — the beyond-paper structural extensions, vs autodiff oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.core import (
    BatchGrad,
    BatchL2,
    CrossEntropyLoss,
    Dense,
    DiagGGN,
    DiagGGNMC,
    Embedding,
    ExtensionConfig,
    KFAC,
    Module,
    Parallel,
    Residual,
    RMSNorm,
    ScanStack,
    SecondMoment,
    Sequential,
    Variance,
    oracle,
    run,
)

V, D, T, N, L = 11, 8, 5, 4, 2


class GateMixer(Module):
    def apply(self, params, x):
        a, b = x
        return a * jax.nn.sigmoid(b)


@pytest.fixture(scope="module")
def setup():
    block = Residual(Sequential([
        RMSNorm(D),
        Parallel([Dense(D, D), Dense(D, D, use_bias=False)]),
        GateMixer(),
        Dense(D, D),
    ]))
    model = Sequential([
        Embedding(V, D),
        ScanStack(block, L),
        RMSNorm(D),
        Dense(D, V, use_bias=False),
    ])
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (N, T), 0, V)
    y = jax.random.randint(jax.random.PRNGKey(2), (N, T), 0, V)
    loss = CrossEntropyLoss()
    res = run(model, params, tok, y, loss,
              extensions=(BatchGrad, BatchL2, SecondMoment, Variance, DiagGGN),
              rng=jax.random.PRNGKey(3))
    return model, params, tok, y, loss, res


def test_grads(setup):
    model, params, tok, y, loss, res = setup
    og = oracle.grad(model, loss, params, tok, y)
    for a, b in zip(jax.tree.leaves(res.grads), jax.tree.leaves(og)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6)


def test_batch_grad_scan_axis_order(setup):
    """Per-sample stats for scan-stacked params are [N, L, ...]."""
    model, params, tok, y, loss, res = setup
    psg = oracle.per_sample_grads(model, loss, params, tok, y)
    for a, b in zip(jax.tree.leaves(res["batch_grad"]), jax.tree.leaves(psg)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-6)


def test_moments_and_l2(setup):
    model, params, tok, y, loss, res = setup
    psg = oracle.per_sample_grads(model, loss, params, tok, y)
    sm = jax.tree.map(lambda g: N * jnp.sum(g ** 2, 0), psg)
    for a, b in zip(jax.tree.leaves(res["second_moment"]), jax.tree.leaves(sm)):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=1e-7)
    for a, g in zip(jax.tree.leaves(res["batch_l2"]), jax.tree.leaves(psg)):
        want = jnp.sum(g.reshape(a.shape + (-1,)) ** 2, -1)
        np.testing.assert_allclose(a, want, rtol=2e-4, atol=1e-9)


def test_diag_ggn_deep_seq(setup):
    """Exact GGN diag through scan+attention-like mixing (per-unit exact
    factor columns — the token-factored correction)."""
    model, params, tok, y, loss, res = setup
    want = oracle.ggn_diag(model, loss, params, tok, y)
    got, _ = ravel_pytree(res["diag_ggn"])
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-6)


def test_mc_on_seq_model_unbiased(setup):
    model, params, tok, y, loss, res = setup
    mc = run(model, params, tok, y, loss, extensions=(DiagGGNMC,),
             cfg=ExtensionConfig(mc_samples=64), rng=jax.random.PRNGKey(9))
    a, _ = ravel_pytree(mc["diag_ggn_mc"])
    b, _ = ravel_pytree(res["diag_ggn"])
    corr = np.corrcoef(np.asarray(a), np.asarray(b))[0, 1]
    assert corr > 0.95, corr


def test_engine_jits(setup):
    model, params, tok, y, loss, _ = setup
    f = jax.jit(lambda p, t, yy, r: run(
        model, p, t, yy, loss, extensions=(Variance, KFAC), rng=r).ext)
    out = f(params, tok, y, jax.random.PRNGKey(4))
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(out))
