"""First-order extensions vs autodiff oracles (paper §2.2 / App. A.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Activation,
    BatchGrad,
    BatchL2,
    CrossEntropyLoss,
    Dense,
    MSELoss,
    SecondMoment,
    Sequential,
    Variance,
    oracle,
    run,
)

N, D, H, C = 6, 5, 7, 4


@pytest.fixture(scope="module")
def setup():
    model = Sequential([Dense(D, H), Activation("sigmoid"), Dense(H, C)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    y = jax.random.randint(jax.random.PRNGKey(2), (N,), 0, C)
    loss = CrossEntropyLoss()
    res = run(model, params, x, y, loss,
              extensions=(BatchGrad, BatchL2, SecondMoment, Variance))
    psg = oracle.per_sample_grads(model, loss, params, x, y)
    og = oracle.grad(model, loss, params, x, y)
    return model, params, x, y, loss, res, psg, og


def test_loss_and_grads(setup):
    model, params, x, y, loss, res, psg, og = setup
    np.testing.assert_allclose(
        res.loss, oracle.loss_fn(model, loss, params, x, y), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(res.grads), jax.tree.leaves(og)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_batch_grad(setup):
    *_, res, psg, og = setup
    for a, b in zip(jax.tree.leaves(res["batch_grad"]), jax.tree.leaves(psg)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_batch_grad_sums_to_grad(setup):
    *_, res, psg, og = setup
    for a, b in zip(jax.tree.leaves(res["batch_grad"]), jax.tree.leaves(og)):
        np.testing.assert_allclose(jnp.sum(a, 0), b, rtol=1e-4, atol=1e-6)


def test_batch_l2(setup):
    *_, res, psg, og = setup
    for a, g in zip(jax.tree.leaves(res["batch_l2"]), jax.tree.leaves(psg)):
        np.testing.assert_allclose(
            a, jnp.sum(g.reshape(N, -1) ** 2, -1), rtol=1e-4, atol=1e-9)


def test_second_moment_and_variance(setup):
    *_, res, psg, og = setup
    sm = jax.tree.map(lambda g: N * jnp.sum(g ** 2, 0), psg)
    for a, b in zip(jax.tree.leaves(res["second_moment"]), jax.tree.leaves(sm)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-8)
    var = jax.tree.map(lambda s, g: s - g ** 2, sm, og)
    for a, b in zip(jax.tree.leaves(res["variance"]), jax.tree.leaves(var)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-7)


def test_mse_loss_path():
    model = Sequential([Dense(D, C)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    y = jax.random.normal(jax.random.PRNGKey(2), (N, C))
    loss = MSELoss()
    res = run(model, params, x, y, loss, extensions=(BatchGrad,))
    psg = oracle.per_sample_grads(model, loss, params, x, y)
    for a, b in zip(jax.tree.leaves(res["batch_grad"]), jax.tree.leaves(psg)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_padding_mask_tokens_excluded():
    """y = -1 positions must not contribute to loss or stats."""
    model = Sequential([Dense(D, C)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (N, 3, D))
    y = jax.random.randint(jax.random.PRNGKey(2), (N, 3), 0, C)
    y_mask = y.at[:, -1].set(-1)
    loss = CrossEntropyLoss()
    r1 = run(model, params, x, y_mask, loss, extensions=(BatchGrad,))
    # oracle: zero-out masked positions by slicing
    r2 = run(model, params, x[:, :2], y[:, :2], loss, extensions=(BatchGrad,))
    for a, b in zip(jax.tree.leaves(r1.grads), jax.tree.leaves(r2.grads)):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_batch_dot_matches_oracle(setup):
    from repro.core import BatchDot

    model, params, x, y, loss, _, psg, _ = setup
    res = run(model, params, x, y, loss, extensions=(BatchDot, BatchL2))
    for d, g, l2 in zip(jax.tree.leaves(res["batch_dot"]),
                        jax.tree.leaves(psg),
                        jax.tree.leaves(res["batch_l2"])):
        gf = np.asarray(g, np.float32).reshape(N, -1)
        np.testing.assert_allclose(np.asarray(d), gf @ gf.T,
                                   rtol=2e-4, atol=1e-8)
        # diagonal of the pairwise dots == batch_l2
        np.testing.assert_allclose(np.asarray(jnp.diagonal(d)),
                                   np.asarray(l2), rtol=2e-4, atol=1e-8)
