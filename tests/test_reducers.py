"""Reducer protocol conformance, for every registered reducer.

The engine's three drivers (shard_map reducer, lax.scan sequential
accumulator, shard × accumulate grid) fold partial results through the
same :class:`repro.core.Reducer` protocol — so the algebra every driver
relies on is pinned here once, with hypothesis, for the whole registry:

* ``merge`` is associative (the sharded binary tree and the sequential
  left fold must agree);
* ``merge`` is order-invariant whenever the reducer declares
  ``commutative`` (concat is by-design order-dependent);
* folding ``update`` over a partition in any order, then ``finalize``,
  is permutation-invariant for commutative reducers (microbatch schedule
  independence).

Plus the deprecated string-alias path: strings resolve with a
``DeprecationWarning`` naming the replacement, unknown names fail with
the registry contents, and third-party reducers round-trip through
``register_reducer``.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import REDUCERS, Reducer, register_reducer, resolve_reducer
from repro.core.extensions import Extension

ALL_NAMES = sorted(REDUCERS)
COMMUTATIVE_NAMES = [n for n in ALL_NAMES if REDUCERS[n].commutative]


def _partial(name, rng):
    """A random accumulated partial in reducer ``name``'s algebra."""
    if name == "kron":
        return {"w": {"A": jnp.asarray(rng.normal(size=(3, 3))),
                      "B": jnp.asarray(rng.normal(size=(2, 2)))}}
    if name == "moment_merge":
        rows = rng.normal(size=(4, 3)) * 2.0
        s = rows.sum(0)
        return {"n": jnp.float32(4.0), "mean": jnp.asarray(s / 4.0),
                "m2": jnp.asarray((rows ** 2).sum(0) - s ** 2 / 4.0)}
    if name == "concat":
        return jnp.asarray(rng.normal(size=(int(rng.integers(1, 4)), 3)))
    if name == "gram":
        # streamed Gram partials are disjoint-block scatters into a
        # shared [N, N] zero frame; merging = adding the frames
        full = np.zeros((6, 6))
        i = int(rng.integers(0, 3)) * 2
        full[i:i + 2, i:i + 2] = rng.normal(size=(2, 2))
        return jnp.asarray(full)
    if name == "gram_pair":
        # same disjoint-block scatter with a trailing column pair (the
        # ggn_gram [N, N, C̃, C̃] layout): a symmetric-in-(block, pair)
        # diagonal scatter plus one mirrored off-diagonal pair, so the
        # partial respects the pair-kernel symmetry the driver maintains
        full = np.zeros((6, 6, 2, 2))
        i = int(rng.integers(0, 3)) * 2
        blk = rng.normal(size=(2, 2, 2, 2))
        full[i:i + 2, i:i + 2] = blk + blk.transpose(1, 0, 3, 2)
        j = (i + 2) % 6
        off = rng.normal(size=(2, 2, 2, 2))
        full[i:i + 2, j:j + 2] = off
        full[j:j + 2, i:i + 2] = off.transpose(1, 0, 3, 2)
        return jnp.asarray(full)
    return jnp.asarray(rng.normal(size=(3, 2)))


def _assert_tree_close(a, b, **kw):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-6, **kw)


@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_merge_is_associative(seed):
    for name in ALL_NAMES:
        red = REDUCERS[name]
        rng = np.random.default_rng(seed)
        a, b, c = (_partial(name, rng) for _ in range(3))
        _assert_tree_close(red.merge(red.merge(a, b), c),
                           red.merge(a, red.merge(b, c)),
                           err_msg=f"{name} merge associativity")


@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_merge_is_commutative_when_declared(seed):
    for name in COMMUTATIVE_NAMES:
        red = REDUCERS[name]
        rng = np.random.default_rng(seed)
        a, b = _partial(name, rng), _partial(name, rng)
        _assert_tree_close(red.merge(a, b), red.merge(b, a),
                           err_msg=f"{name} merge commutativity")


@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1),
       perm_seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_update_fold_is_order_invariant(seed, perm_seed):
    """init → update* (any microbatch order) → finalize is schedule-
    independent for commutative reducers — the invariant that makes the
    accumulated lane's results independent of how the batch is sliced."""
    for name in COMMUTATIVE_NAMES:
        red = REDUCERS[name]
        rng = np.random.default_rng(seed)
        parts = [_partial(name, rng) for _ in range(4)]
        weights = [2.0, 3.0, 1.0, 4.0]
        meta_fin = {"total_batch": float(sum(weights))}
        perm = np.random.default_rng(perm_seed).permutation(len(parts))

        def fold(order):
            acc = red.init(jax.tree.map(jnp.zeros_like, parts[0]))
            for i in order:
                acc = red.update(acc, parts[i], {"weight": weights[i]})
            return red.finalize(acc, meta_fin)

        _assert_tree_close(fold(range(len(parts))), fold(perm),
                           err_msg=f"{name} update order invariance")


def test_placement_and_streaming_form_are_reported():
    assert REDUCERS["psum"].placement == "replicated"
    assert REDUCERS["concat"].placement == "sharded(axis0)"
    assert REDUCERS["gram"].placement == "sharded(axis0)"
    assert REDUCERS["gram"].pairwise and REDUCERS["gram"].local_rows
    for red in REDUCERS.values():
        assert isinstance(red.streaming_form, str) and red.streaming_form


def test_gram_pair_capability_flags():
    """gram_pair inherits the full Gram driver contract: the streamed
    pair passes and the sharded row-block assembly both key off these."""
    red = REDUCERS["gram_pair"]
    assert red.pairwise and red.local_rows and red.commutative
    assert red.placement == "sharded(axis0)"
    assert red.streaming_form


@given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_gram_pair_transpose_block_mirrors_sample_and_column_pair(seed):
    """transpose_block on a [B1, B2, C, C] pair block: entry
    (n, m, c, c') lands at (m, n, c', c) — the mirror the streamed pair
    pass writes for block (q, p) — and applying it twice is identity.
    The plain gram mirror only swaps the sample axes."""
    rng = np.random.default_rng(seed)
    blk = jnp.asarray(rng.normal(size=(3, 2, 4, 4)))
    t = REDUCERS["gram_pair"].transpose_block(blk)
    assert t.shape == (2, 3, 4, 4)
    np.testing.assert_allclose(np.asarray(t),
                               np.asarray(blk).transpose(1, 0, 3, 2))
    _assert_tree_close(REDUCERS["gram_pair"].transpose_block(t), blk)
    sq = jnp.asarray(rng.normal(size=(3, 3, 4, 4)))
    np.testing.assert_allclose(
        np.asarray(REDUCERS["gram"].transpose_block(sq)),
        np.asarray(sq).transpose(1, 0, 2, 3))


def test_string_alias_warns_with_replacement():
    with pytest.warns(DeprecationWarning, match="PSUM"):
        r = resolve_reducer("psum")
    assert r is REDUCERS["psum"]


def test_extension_resolves_string_alias_with_warning():
    with pytest.warns(DeprecationWarning, match="GRAM"):
        e = Extension("_tmp_stat", "first", reduce="gram")
    assert e.reduce is REDUCERS["gram"]


def test_reducer_instance_passes_through_silently():
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_reducer(REDUCERS["kron"]) is REDUCERS["kron"]


def test_unknown_string_raises_with_registry():
    with pytest.raises(ValueError, match="registered reducers"):
        resolve_reducer("definitely_not_a_reducer")


def test_bad_spec_type_raises():
    with pytest.raises(TypeError, match="Reducer"):
        resolve_reducer(42)


def test_register_reducer_roundtrip():
    class MyReducer(Reducer):
        name = "my_test_reducer"

    r = register_reducer(MyReducer())
    try:
        with pytest.warns(DeprecationWarning):
            assert resolve_reducer("my_test_reducer") is r
    finally:
        del REDUCERS["my_test_reducer"]
