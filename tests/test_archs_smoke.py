"""Per-architecture smoke: reduced config, one train step + decode on CPU.

Gradients from our generalized backprop are cross-checked against
``jax.grad`` of the same model — per arch, per family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.core import CrossEntropyLoss
from repro.core.engine import loss_and_grad
from repro.data.synthetic import batch_for
from repro.nn.models import build_model

LOSS = CrossEntropyLoss()
N, T = 2, 16


def _batch(cfg):
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=T, global_batch=N)
    return batch_for(cfg, shape, 0)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_and_grads(name):
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    lv, grads = jax.jit(
        lambda p: loss_and_grad(model, p, batch["inputs"], batch["labels"], LOSS)
    )(params)
    assert jnp.isfinite(lv)
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g)))

    def lf(p):
        z = model.apply(p, batch["inputs"])
        return LOSS.value(z, batch["labels"])

    og = jax.grad(lf)(params)
    for a, b in zip(jax.tree.leaves(grads), jax.tree.leaves(og)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-5)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step(name):
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if cfg.kind == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(1), (N, T, cfg.d_model))
        enc_out = model.encode(params, frames)
        caches = model.init_serve_cache(params, N, T, jnp.float32,
                                        enc_out=enc_out)
    else:
        caches = model.init_serve_cache(params, N, 32, jnp.float32)
    step = jax.jit(model.serve_step)
    logits, caches = step(params, caches, jnp.zeros((N,), jnp.int32),
                          jnp.int32(0))
    logits, _ = step(params, caches, jnp.ones((N,), jnp.int32), jnp.int32(1))
    assert logits.shape == (N, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_output_shapes(name):
    cfg = ARCHS[name].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    z = model.apply(params, batch["inputs"])
    if cfg.kind == "encdec":
        assert z.shape == (N, cfg.dec_len, cfg.vocab)
    else:
        assert z.shape == (N, T, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(z.astype(jnp.float32))))


def test_decode_matches_full_forward():
    """Token-by-token decode must reproduce the training forward logits."""
    cfg = ARCHS["stablelm-1.6b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (N, 8), 0, cfg.vocab)
    full = model.apply(params, tok)  # [N, 8, V]
    caches = model.init_serve_cache(params, N, 8, jnp.float32)
    step = jax.jit(model.serve_step)
    for t in range(8):
        logits, caches = step(params, caches, tok[:, t], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_decode_matches_full_forward_rwkv():
    cfg = ARCHS["rwkv6-3b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (N, 8), 0, cfg.vocab)
    full = model.apply(params, tok)
    caches = model.init_serve_cache(params, N, 8, jnp.float32)
    step = jax.jit(model.serve_step)
    for t in range(8):
        logits, caches = step(params, caches, tok[:, t], jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   rtol=5e-4, atol=5e-4)
