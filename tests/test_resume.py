"""Preemption-safe streaming sweeps: interrupt/resume exactness and the
checkpoint-layer fixes underneath.

The contract under test: a sweep killed mid-stream by ``FailureInjector``
and resumed from its ``SweepCheckpointer`` snapshot produces results
identical (3e-5, the monolithic-vs-accumulated differential tolerance) to
the uninterrupted run — including deterministic ``mc_seed`` MC draws and
the Variance reducer's Chan ``(n, mean, M2)`` triples — on both the
single-device accumulated lane and the shard × accumulate grid, plus the
elastic N→M-device resume (multidevice lane).  The satellite regression
tests cover ``train/checkpoint.py``: stale ``.tmp_save_*`` sweeping,
``keep < 1`` rejection, and treedef/per-leaf-shape restore validation.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Activation,
    CrossEntropyLoss,
    Dense,
    Extension,
    ExtensionConfig,
    Reducer,
    Sequential,
    by_name,
    plan_sweeps,
)
from repro.launch.mesh import make_data_mesh
from repro.train import checkpoint as ckpt
from repro.train.checkpoint import SweepCheckpointer
from repro.train.fault import (
    FailureInjector,
    SimulatedFailure,
    run_sweep_with_restarts,
)

N, D_IN, H, C = 10, 6, 7, 4
TOL = dict(rtol=3e-5, atol=3e-5)

# One extension per accumulator family: psum rows/concat (batch_grad,
# batch_l2), the Chan moment triple (variance), MC factor draws
# (diag_ggn_mc + kfac — keyed per global sample index), kron, the KFRA
# pmean/replay chain, and both pairwise row-block streams (batch_dot, ntk).
EXTS = ("batch_grad", "batch_l2", "variance", "diag_ggn_mc", "kfac",
        "kfra", "batch_dot", "ntk")


@pytest.fixture(scope="module")
def setup():
    model = Sequential([Dense(D_IN, H), Activation("sigmoid"), Dense(H, C)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (N, D_IN))
    y = jax.random.randint(jax.random.PRNGKey(2), (N,), 0, C)
    return model, params, x, y


def _plan(k=3, mesh=None):
    cfg = ExtensionConfig(mc_seed=7)
    plan = plan_sweeps(tuple(by_name(n) for n in EXTS), cfg)
    if mesh is not None:
        plan = plan.shard(mesh)
    return plan.accumulate(k), cfg


def _assert_results_match(ref, res, names=EXTS, label=""):
    np.testing.assert_allclose(ref.loss, res.loss, err_msg=f"{label}loss",
                               **TOL)
    for part in ("grads", "logits"):
        for u, v in zip(jax.tree.leaves(getattr(ref, part)),
                        jax.tree.leaves(getattr(res, part))):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       err_msg=f"{label}{part}", **TOL)
    for nm in names:
        for u, v in zip(jax.tree.leaves(ref.ext[nm]),
                        jax.tree.leaves(res.ext[nm])):
            np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                       err_msg=f"{label}{nm}", **TOL)


# ---------------------------------------------------------------------------
# the stream lane itself (no faults): slice schedule == scan lane
# ---------------------------------------------------------------------------


def test_stream_matches_accumulated_scan(setup):
    """run_checkpointed without a checkpointer is just the stepwise
    executor — it must match the in-scan accumulated lane (and hence the
    monolithic sweep) for every accumulator family at once."""
    model, params, x, y = setup
    plan, cfg = _plan(k=3)
    ref = plan.run(model, params, x, y, CrossEntropyLoss(), cfg=cfg)
    res = plan.run_checkpointed(model, params, x, y, CrossEntropyLoss(),
                                cfg=cfg)
    _assert_results_match(ref, res)


def test_stream_state_is_arrays_only(setup):
    """Snapshots must be pure array pytrees (that is what makes them
    checkpointable); the cursor lives outside as the step number."""
    model, params, x, y = setup
    plan, cfg = _plan(k=3)
    stream = plan.stream(model, params, x, y, CrossEntropyLoss(), cfg=cfg)
    stream.step()
    for leaf in jax.tree.leaves(stream.state_arrays()):
        assert hasattr(leaf, "shape") and hasattr(leaf, "dtype"), leaf
    meta = stream.schedule_meta()
    import json

    json.dumps(meta)  # manifest-safe
    assert meta["n"] == N and meta["work_units"] == stream.num_units


def test_variance_chan_triple_rides_the_snapshot(setup):
    """The Variance accumulator snapshots as raw mergeable Chan triples
    — n/mean/M2 leaves, not a finalized variance — so a resumed fold
    continues the merge algebra exactly."""
    model, params, x, y = setup
    plan, cfg = _plan(k=3)
    stream = plan.stream(model, params, x, y, CrossEntropyLoss(), cfg=cfg)
    stream.step()
    carry = stream.state_arrays()["carry"]["variance"]

    def keys(node):
        if isinstance(node, dict) and set(node) == {"n", "mean", "m2"}:
            found.append(node)
        elif isinstance(node, dict):
            for v in node.values():
                keys(v)
        elif isinstance(node, (tuple, list)):
            for v in node:
                keys(v)

    found = []
    keys(carry)
    assert found, f"no Chan triples in variance carry: {carry!r}"
    # after one m-row slice the folded count must be that slice's rows
    assert float(jax.tree.leaves(found[0]["n"])[0]) == float(stream.m)


# ---------------------------------------------------------------------------
# interrupt + resume differentials (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fail_at", [1, 2, 4])
def test_interrupt_resume_exact_single_device(setup, tmp_path, fail_at):
    """Kill the stream at work unit ``fail_at`` (slice and pair-pass
    cursors both covered), resume from disk, and match the uninterrupted
    run exactly — MC draws and Chan triples included."""
    model, params, x, y = setup
    plan, cfg = _plan(k=3)
    loss = CrossEntropyLoss()
    ref = plan.run_checkpointed(model, params, x, y, loss, cfg=cfg)
    store = SweepCheckpointer(str(tmp_path / "sweep"))
    with pytest.raises(SimulatedFailure):
        plan.run_checkpointed(model, params, x, y, loss, cfg=cfg,
                              checkpointer=store,
                              injector=FailureInjector(fail_at_step=fail_at))
    assert store.latest() == fail_at  # snapshot cadence: every unit
    res = plan.resume(model, params, x, y, loss, store, cfg=cfg)
    _assert_results_match(ref, res, label=f"fail@{fail_at}:")


def test_interrupt_resume_exact_grid(setup, tmp_path):
    """Same differential on the shard × accumulate grid (a genuine
    multi-shard mesh in the multidevice lane, 1-device elsewhere)."""
    model, params, x, y = setup
    mesh = make_data_mesh()
    n_dev = mesh.shape["data"]
    n = 16 if 16 % n_dev == 0 else 8 * n_dev
    x = jax.random.normal(jax.random.PRNGKey(1), (n, D_IN))
    y = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, C)
    plan, cfg = _plan(k=2, mesh=mesh)
    loss = CrossEntropyLoss()
    mono = plan_sweeps(tuple(by_name(nm) for nm in EXTS), cfg).run(
        model, params, x, y, loss, cfg=cfg)
    store = SweepCheckpointer(str(tmp_path / "sweep"))
    with pytest.raises(SimulatedFailure):
        plan.run_checkpointed(model, params, x, y, loss, cfg=cfg,
                              checkpointer=store,
                              injector=FailureInjector(fail_at_step=1))
    res = plan.resume(model, params, x, y, loss, store, cfg=cfg)
    _assert_results_match(mono, res, label="grid:")


def _elastic_resume_body(tmp_dir):
    """Checkpoint on an N-device mesh, resume on N/2 devices: the
    snapshot is mesh-agnostic, so the resumed sweep still matches the
    monolithic single-device run."""
    model = Sequential([Dense(D_IN, H), Activation("sigmoid"), Dense(H, C)])
    params = model.init(jax.random.PRNGKey(0))
    n_dev = len(jax.devices())
    n = 4 * n_dev
    x = jax.random.normal(jax.random.PRNGKey(1), (n, D_IN))
    y = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, C)
    loss = CrossEntropyLoss()
    plan_n, cfg = _plan(k=2, mesh=make_data_mesh(n_dev))
    plan_m, _ = _plan(k=2, mesh=make_data_mesh(n_dev // 2))
    mono = plan_sweeps(tuple(by_name(nm) for nm in EXTS), cfg).run(
        model, params, x, y, loss, cfg=cfg)
    store = SweepCheckpointer(os.path.join(tmp_dir, "sweep"))
    with pytest.raises(SimulatedFailure):
        plan_n.run_checkpointed(model, params, x, y, loss, cfg=cfg,
                                checkpointer=store,
                                injector=FailureInjector(fail_at_step=1))
    res = plan_m.resume(model, params, x, y, loss, store, cfg=cfg)
    _assert_results_match(mono, res, label="elastic:")


def test_elastic_resume_n_to_m_devices(tmp_path):
    """Elastic resume, on real shards: in-process when this lane already
    has >= 2 devices (the multidevice CI lane), otherwise in a fresh
    4-virtual-device subprocess (jax locks the device count at first
    init, so a single-device process cannot host it directly)."""
    if len(jax.devices()) >= 2:
        _elastic_resume_body(str(tmp_path))
        return
    import subprocess
    import sys

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    code = (
        "import sys; sys.path.insert(0, {src!r}); "
        "sys.path.insert(0, {here!r}); "
        "import test_resume; "
        "test_resume._elastic_resume_body({tmp!r}); "
        "print('ELASTIC_OK')"
    ).format(src=src, here=os.path.dirname(os.path.abspath(__file__)),
             tmp=str(tmp_path))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS="cpu")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "ELASTIC_OK" in out.stdout


def test_run_sweep_with_restarts(setup, tmp_path):
    """The fault-driver wrapper: one injected kill → one restart, exact
    results, restart count reported."""
    model, params, x, y = setup
    plan, cfg = _plan(k=3)
    loss = CrossEntropyLoss()
    ref = plan.run(model, params, x, y, loss, cfg=cfg)
    res, restarts = run_sweep_with_restarts(
        plan, model, params, x, y, loss,
        SweepCheckpointer(str(tmp_path / "sweep")), cfg=cfg,
        injector=FailureInjector(fail_at_step=2))
    assert restarts == 1
    _assert_results_match(ref, res)


def test_resume_validates_schedule_meta(setup, tmp_path):
    """A rebuilt stream whose rng/mc_seed differs from the snapshot's
    must be rejected with the offending field named — silently resuming
    would desynchronize the MC draw streams."""
    model, params, x, y = setup
    plan, cfg = _plan(k=3)
    loss = CrossEntropyLoss()
    store = SweepCheckpointer(str(tmp_path / "sweep"))
    with pytest.raises(SimulatedFailure):
        plan.run_checkpointed(model, params, x, y, loss, cfg=cfg,
                              checkpointer=store,
                              injector=FailureInjector(fail_at_step=2))
    with pytest.raises(ValueError, match="'rng'"):
        plan.resume(model, params, x, y, loss, store,
                    cfg=ExtensionConfig(mc_seed=8))


def test_strict_resume_requires_snapshot(setup, tmp_path):
    model, params, x, y = setup
    plan, cfg = _plan(k=3)
    with pytest.raises(FileNotFoundError, match="no sweep snapshot"):
        plan.resume(model, params, x, y, CrossEntropyLoss(),
                    SweepCheckpointer(str(tmp_path / "empty")), cfg=cfg)


def test_supports_checkpoint_gate(setup):
    """Reducers whose accumulator cannot round-trip declare
    supports_checkpoint=False and must be rejected at stream build with
    the extension + reducer named (the streaming scan still takes them)."""
    model, params, x, y = setup

    class OpaqueReducer(Reducer):
        name = "opaque_test"
        supports_checkpoint = False

    ext = Extension("_opaque_stat", "first", reduce=OpaqueReducer())
    plan = plan_sweeps((ext,), ExtensionConfig()).accumulate(2)
    with pytest.raises(ValueError, match="supports_checkpoint") as ei:
        plan.stream(model, params, x, y, CrossEntropyLoss())
    assert "_opaque_stat" in str(ei.value)
    assert "opaque_test" in str(ei.value)


def test_laplace_resumable_fit(setup, tmp_path):
    """A killed streaming Laplace fit resumes to the exact uninterrupted
    posterior; a checkpointed fit without the streaming lane is rejected
    actionably."""
    from repro import laplace

    model, params, x, y = setup
    loss = CrossEntropyLoss()
    cfg = ExtensionConfig(mc_seed=5)
    opts = laplace.FitOptions(mc=True, cfg=cfg, microbatch_size=4)
    ref = laplace.fit_posterior(model, params, x, y, loss, structure="diag",
                                options=opts)
    d = str(tmp_path / "fit")
    with pytest.raises(SimulatedFailure):
        laplace.fit_posterior(
            model, params, x, y, loss, structure="diag",
            options=opts.replace(
                ckpt_dir=d, injector=FailureInjector(fail_at_step=1)))
    post = laplace.fit_posterior(
        model, params, x, y, loss, structure="diag",
        options=opts.replace(ckpt_dir=d, resume=True))
    for u, v in zip(jax.tree.leaves(ref.curv), jax.tree.leaves(post.curv)):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v), **TOL)
    with pytest.raises(laplace.LaplaceStructureError,
                       match="streaming accumulated sweep"):
        laplace.fit_posterior(
            model, params, x, y, loss, structure="diag",
            options=laplace.FitOptions(mc=True, cfg=cfg, ckpt_dir=d))


# ---------------------------------------------------------------------------
# checkpoint-layer regressions (the satellite bugfixes)
# ---------------------------------------------------------------------------


def test_gc_sweeps_stale_tmp_dirs(tmp_path):
    """A save killed between mkdtemp and the atomic rename leaves a
    ``.tmp_save_*`` dir that step-pruning never touched — the next gc
    must sweep it."""
    d = str(tmp_path)
    params = {"w": jnp.ones((3, 2))}
    os.makedirs(os.path.join(d, ".tmp_save_orphan"))
    ckpt.save(d, 1, params)
    assert not [f for f in os.listdir(d) if f.startswith(".tmp_save_")]
    assert os.path.isdir(os.path.join(d, "step_00000001"))


def test_gc_keep_zero_rejected(tmp_path):
    """keep=0 used to slice steps[:-0] == [] and silently keep
    everything; both save() and _gc now reject keep < 1."""
    d = str(tmp_path)
    params = {"w": jnp.ones((2,))}
    with pytest.raises(ValueError, match="keep must be >= 1"):
        ckpt.save(d, 1, params, keep=0)
    assert not os.listdir(d) if os.path.isdir(d) else True  # nothing written
    ckpt.save(d, 1, params, keep=1)
    ckpt.save(d, 2, params, keep=1)
    steps = [f for f in os.listdir(d) if f.startswith("step_")]
    assert steps == ["step_00000002"]
    with pytest.raises(ValueError, match="keep must be >= 1"):
        ckpt._gc(d, 0)


def test_restore_validates_treedef(tmp_path):
    """Same leaf count, different structure: restore must fail on the
    recorded treedef instead of zipping arrays into the wrong leaves."""
    d = str(tmp_path)
    ckpt.save(d, 1, {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="tree structure"):
        ckpt.restore(d, 1, {"w": jnp.ones((3, 2)), "c": jnp.zeros((2,))})


def test_restore_validates_leaf_shapes(tmp_path):
    """Same treedef, drifted leaf shape: the error must name the first
    offending leaf (the astype cast used to mask this entirely)."""
    d = str(tmp_path)
    ckpt.save(d, 1, {"w": jnp.ones((3, 2)), "b": jnp.zeros((2,))})
    with pytest.raises(ValueError, match=r"\['params'\]\['b'\]"):
        ckpt.restore(d, 1, {"w": jnp.ones((3, 2)), "b": jnp.zeros((3,))})
    # the happy path still round-trips (and still applies dtype policy)
    p, _ = ckpt.restore(d, 1, {"w": jnp.ones((3, 2), jnp.bfloat16),
                               "b": jnp.zeros((2,))})
    assert p["w"].dtype == jnp.bfloat16


def test_sweep_checkpointer_roundtrip(tmp_path):
    store = SweepCheckpointer(str(tmp_path), keep=2)
    state = {"loss": jnp.float32(1.5), "carry": {"v": jnp.arange(4.0)}}
    assert store.restore_latest(state) is None
    for cursor in (1, 2, 3):
        store.save(cursor, state, {"n": 10})
    cur, st, meta = store.restore_latest(state)
    assert cur == 3 and meta["n"] == 10
    np.testing.assert_allclose(st["carry"]["v"], np.arange(4.0))
    kept = [f for f in os.listdir(str(tmp_path)) if f.startswith("step_")]
    assert sorted(kept) == ["step_00000002", "step_00000003"]  # keep=2
    with pytest.raises(ValueError, match="keep must be >= 1"):
        SweepCheckpointer(str(tmp_path), keep=0)
