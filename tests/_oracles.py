"""Shared dense oracles for the extension / curvature / NTK suites.

Every suite that pins an engine quantity to an explicitly materialized
counterpart (`jax.jacrev` Jacobians, `jax.hessian` losses, the 4-index
NTK) imports from here instead of re-deriving the construction —
one implementation, one set of conventions:

* Jacobians are of ``model.apply`` w.r.t. the raveled parameter vector;
* the dense GGN is ``Jᵀ H J`` with ``H`` the *mean*-loss Hessian in
  logit space (the engine's 1/M normalization);
* the scaled Jacobian ``J' = √Hᵀ J`` carries the loss factorization the
  exact second-order extensions propagate — ``J'J'ᵀ`` is the
  ``ggn_gram`` kernel, ``J'ᵀJ'`` the GGN;
* the materialized NTK is the raw (loss-free) 4-index kernel
  ``K[n, c, m, c'] = ⟨J_c(n), J_{c'}(m)⟩``.

These are exactly the O(N·C·P) / O(P²) constructions the library
avoids; keep them on paper-scale nets.
"""
import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core import Activation, Dense, Sequential

TOL = dict(rtol=3e-5, atol=3e-5)


def tiny_mlp(n=11, d=5, h=7, c=3, act="tanh", seeds=(0, 1, 2)):
    """The suites' standard paper-scale net + batch:
    ``(model, params, x [n, d], y [n] ints < c)``."""
    model = Sequential([Dense(d, h), Activation(act), Dense(h, c)])
    params = model.init(jax.random.PRNGKey(seeds[0]))
    x = jax.random.normal(jax.random.PRNGKey(seeds[1]), (n, d))
    y = jax.random.randint(jax.random.PRNGKey(seeds[2]), (n,), 0, c)
    return model, params, x, y


def flat_jacobian(model, params, x):
    """``(flat, unravel, J [N, C, P])`` — the raveled-parameter Jacobian."""
    flat, unravel = ravel_pytree(params)
    return flat, unravel, jax.jacrev(
        lambda f: model.apply(unravel(f), x))(flat)


def dense_ggn(model, params, x, y, loss):
    """``(Jᵀ H J, flat, unravel)`` with the full-batch (block-diagonal)
    mean-loss Hessian."""
    flat, unravel, J = flat_jacobian(model, params, x)
    z = model.apply(params, x)
    Hl = jax.hessian(
        lambda zf: loss.value(zf.reshape(z.shape), y))(z.reshape(-1))
    Jf = J.reshape(-1, flat.size)
    return Jf.T @ Hl @ Jf, flat, unravel


def dense_hessian(model, params, x, y, loss):
    """``(∇²L(θ), flat, unravel)`` — the full mean-loss Hessian."""
    flat, unravel = ravel_pytree(params)
    return jax.hessian(
        lambda f: loss.value(model.apply(unravel(f), x), y))(flat), \
        flat, unravel


def scaled_jacobian(model, params, x, y, loss):
    """``J' = √Hᵀ J`` as ``[C̃, N, P]`` rows — the loss-scaled Jacobian
    factor; ``einsum('cnp,dmp->nmcd')`` of it is the ``ggn_gram``
    oracle, ``J'ᵀJ'`` the dense GGN."""
    flat, unravel, J = flat_jacobian(model, params, x)
    z = model.apply(params, x)
    S = loss.sqrt_hessian(z, y)                      # [C̃, N, C]
    return jnp.einsum("cnv,nvp->cnp", S, J), flat, unravel


def materialized_ntk(model, params, x):
    """Full 4-index empirical NTK ``K[n, c, m, c']`` from the
    materialized Jacobian.  ``einsum('ncmc->nm')`` is the class-traced
    ``ntk`` convention, ``'ncmc->nmc'`` the classwise one."""
    n = jax.tree.leaves(x)[0].shape[0]
    flat, _, J = flat_jacobian(model, params, x)
    c = J.shape[1]
    Jf = J.reshape(n * c, flat.size)
    return np.asarray((Jf @ Jf.T).reshape(n, c, n, c))


def spearman(a, b):
    """Spearman rank correlation (ties broken by position — fine for
    the continuous scores the influence tests compare)."""
    a, b = np.asarray(a).ravel(), np.asarray(b).ravel()

    def ranks(v):
        r = np.empty(v.size)
        r[np.argsort(v)] = np.arange(v.size)
        return r

    ra, rb = ranks(a), ranks(b)
    ra, rb = ra - ra.mean(), rb - rb.mean()
    denom = np.sqrt((ra ** 2).sum() * (rb ** 2).sum())
    return float((ra * rb).sum() / max(denom, 1e-30))
