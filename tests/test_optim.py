"""Optimizers, schedules, buffer masking, compression."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compress import compress_with_ef, init_error_feedback
from repro.optim import adamw, constant, cosine, linear_warmup, momentum_sgd, sgd
from repro.optim.optimizers import apply_updates


def _quad_problem():
    params = {"w": jnp.array([3.0, -2.0]), "window_buf": jnp.array(7)}

    def grad_fn(p):
        return {"w": 2 * p["w"], "window_buf": jnp.array(0)}

    return params, grad_fn


def test_sgd_and_momentum_descend():
    for opt in (sgd(0.1), momentum_sgd(0.02), adamw(0.3)):
        params, grad_fn = _quad_problem()
        state = opt.init(params)
        for _ in range(100):
            ups, state = opt.update(grad_fn(params), state, params)
            params = apply_updates(params, ups)
        assert float(jnp.sum(params["w"] ** 2)) < 0.05


def test_buffers_frozen():
    params, grad_fn = _quad_problem()
    opt = adamw(0.5)
    state = opt.init(params)
    g = grad_fn(params)
    g["window_buf"] = jnp.array(99)  # even with a bogus gradient
    ups, _ = opt.update(g, state, params)
    assert int(ups["window_buf"]) == 0  # masked by *_buf convention


def test_schedules():
    assert float(constant()(100)) == 1.0
    w = linear_warmup(10)
    np.testing.assert_allclose(float(w(0)), 0.1, rtol=1e-5)
    np.testing.assert_allclose(float(w(9)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(w(50)), 1.0, rtol=1e-5)
    c = cosine(100, warmup_steps=10, final=0.1)
    assert float(c(10)) > float(c(99)) >= 0.1 - 1e-6


def test_error_feedback_unbiased_accumulation():
    """Sum of (compressed + residual) equals the sum of true gradients."""
    key = jax.random.PRNGKey(0)
    params = {"w": jnp.zeros((64,))}
    ef = init_error_feedback(params)
    total_true = jnp.zeros((64,))
    total_sent = jnp.zeros((64,))
    for i in range(30):
        g = {"w": 1e-3 * jax.random.normal(jax.random.fold_in(key, i), (64,))}
        comp, ef = compress_with_ef(g, ef)
        total_true += g["w"]
        total_sent += comp["w"].astype(jnp.float32)
    # residual bounds the accumulated error
    np.testing.assert_allclose(np.asarray(total_sent + ef["w"]),
                               np.asarray(total_true), rtol=1e-4, atol=1e-6)
