"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]
TOL = {jnp.float32: dict(rtol=3e-5, atol=3e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n,a,b", [(3, 16, 8), (37, 130, 65), (256, 128, 128),
                                   (1, 7, 300)])
def test_sq_matmul(n, a, b, dtype):
    k = jax.random.PRNGKey(n + a)
    A, B = _rand(k, (n, a), dtype), _rand(jax.random.fold_in(k, 1), (n, b), dtype)
    np.testing.assert_allclose(
        np.asarray(ops.sq_matmul(A, B)), np.asarray(ref.sq_matmul(A, B)),
        **TOL[dtype])


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n,r,a,b", [(2, 5, 16, 8), (5, 23, 130, 70),
                                     (8, 64, 128, 128), (1, 1, 9, 400)])
def test_per_sample_moment(n, r, a, b, dtype):
    k = jax.random.PRNGKey(r + a)
    A = _rand(k, (n, r, a), dtype)
    B = _rand(jax.random.fold_in(k, 1), (n, r, b), dtype)
    np.testing.assert_allclose(
        np.asarray(ops.per_sample_moment(A, B)),
        np.asarray(ref.per_sample_moment(A, B)), **TOL[dtype])


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("n,r,a,b", [(2, 5, 6, 8), (6, 37, 50, 40),
                                     (3, 130, 16, 16)])
def test_batch_l2(n, r, a, b, dtype):
    k = jax.random.PRNGKey(r * a)
    A = _rand(k, (n, r, a), dtype)
    B = _rand(jax.random.fold_in(k, 1), (n, r, b), dtype)
    np.testing.assert_allclose(
        np.asarray(ops.batch_l2(A, B)), np.asarray(ref.batch_l2(A, B)),
        **TOL[dtype])


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("c,n,r,a,b", [(2, 3, 4, 8, 8), (4, 3, 17, 33, 21),
                                       (1, 2, 9, 140, 130)])
def test_ggn_diag(c, n, r, a, b, dtype):
    k = jax.random.PRNGKey(c * n + r)
    A = _rand(k, (n, r, a), dtype)
    S = _rand(jax.random.fold_in(k, 1), (c, n, r, b), dtype)
    np.testing.assert_allclose(
        np.asarray(ops.ggn_diag(A, S)), np.asarray(ref.ggn_diag(A, S)),
        **TOL[dtype])


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 12), r=st.integers(1, 9), a=st.integers(1, 40),
       b=st.integers(1, 40), seed=st.integers(0, 2 ** 16))
def test_per_sample_moment_hypothesis(n, r, a, b, seed):
    k = jax.random.PRNGKey(seed)
    A = jax.random.normal(k, (n, r, a))
    B = jax.random.normal(jax.random.fold_in(k, 1), (n, r, b))
    np.testing.assert_allclose(
        np.asarray(ops.per_sample_moment(A, B)),
        np.asarray(ref.per_sample_moment(A, B)), rtol=5e-5, atol=5e-5)
    # invariant: the moment of a single sample is the squared gradient
    if n == 1:
        g = np.einsum("ra,rb->ab", np.asarray(A[0]), np.asarray(B[0]))
        np.testing.assert_allclose(
            np.asarray(ops.per_sample_moment(A, B)), g * g,
            rtol=5e-5, atol=5e-5)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 10), r=st.integers(1, 8), a=st.integers(1, 24),
       b=st.integers(1, 24), seed=st.integers(0, 2 ** 16))
def test_batch_l2_hypothesis_nonneg_and_match(n, r, a, b, seed):
    k = jax.random.PRNGKey(seed)
    A = jax.random.normal(k, (n, r, a))
    B = jax.random.normal(jax.random.fold_in(k, 1), (n, r, b))
    got = np.asarray(ops.batch_l2(A, B))
    assert (got >= -1e-6).all()
    np.testing.assert_allclose(got, np.asarray(ref.batch_l2(A, B)),
                               rtol=5e-5, atol=5e-5)


# --- ggn_diag edge shapes + chunk schedules ----------------------------------

@pytest.mark.parametrize("c,n,r,a,b", [
    (1, 1, 1, 1, 1),        # everything degenerate
    (1, 4, 3, 17, 5),       # C=1, odd features
    (3, 1, 7, 9, 129),      # N=1, b one over a tile boundary
    (5, 2, 11, 131, 33),    # nothing tile- or sublane-aligned
    (2, 3, 1, 257, 1),      # R=1, scalar output dim
])
def test_ggn_diag_edge_shapes(c, n, r, a, b):
    k = jax.random.PRNGKey(c * 31 + a)
    A = jax.random.normal(k, (n, r, a))
    S = jax.random.normal(jax.random.fold_in(k, 1), (c, n, r, b))
    np.testing.assert_allclose(
        np.asarray(ops.ggn_diag(A, S)), np.asarray(ref.ggn_diag(A, S)),
        rtol=3e-5, atol=3e-5)


def test_ggn_diag_class_chunk_invariance():
    """Engine-style class chunking (run the kernel on C-slices, sum) agrees
    with the one-shot kernel for chunk ∈ {1, 3, C}, and every chunk
    schedule is deterministic: the float32 accumulation order is fixed per
    schedule, so a rerun is bitwise identical."""
    c, n, r, a, b = 6, 3, 4, 21, 13
    k = jax.random.PRNGKey(0)
    A = jax.random.normal(k, (n, r, a))
    S = jax.random.normal(jax.random.fold_in(k, 1), (c, n, r, b))
    full = np.asarray(ops.ggn_diag(A, S))
    for chunk in (1, 3, c):
        def sched():
            acc = jnp.zeros((a, b), jnp.float32)
            for i in range(0, c, chunk):
                acc = acc + ops.ggn_diag(A, S[i:i + chunk])
            return np.asarray(acc)

        got = sched()
        np.testing.assert_allclose(got, full, rtol=3e-5, atol=3e-5,
                                   err_msg=f"chunk={chunk}")
        assert np.array_equal(got, sched()), f"chunk={chunk} not bitwise-stable"


# --- flash attention kernel ---------------------------------------------------

@pytest.mark.parametrize("window", [None, 13])
@pytest.mark.parametrize("dims", [(2, 64, 8, 4, 16), (1, 32, 4, 4, 8)])
def test_flash_attention_kernel(window, dims):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.nn.functional import sdpa

    n, t, h, kv, dh = dims
    k0 = jax.random.PRNGKey(0)
    q = jax.random.normal(k0, (n, t, h, dh))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (n, t, kv, dh))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (n, t, kv, dh))
    want = sdpa(q, k, v, causal=True, window=window)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([16, 48, 64]), bq=st.sampled_from([8, 16]),
       bk=st.sampled_from([8, 16]), seed=st.integers(0, 2 ** 10))
def test_flash_attention_block_invariance(t, bq, bk, seed):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.nn.functional import sdpa

    k0 = jax.random.PRNGKey(seed)
    q = jax.random.normal(k0, (1, t, 4, 8))
    k = jax.random.normal(jax.random.fold_in(k0, 1), (1, t, 2, 8))
    v = jax.random.normal(jax.random.fold_in(k0, 2), (1, t, 2, 8))
    want = sdpa(q, k, v, causal=True)
    got = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-5, atol=5e-5)


# --- WKV kernel ---------------------------------------------------------------

@pytest.mark.parametrize("chunk", [4, 8, 16])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_wkv_kernel(chunk, dtype):
    from repro.kernels.wkv import wkv_pallas
    from repro.nn.functional import wkv_chunked

    n, t, h, dk, dv = 2, 32, 3, 8, 8
    k0 = jax.random.PRNGKey(0)
    r = jax.random.normal(k0, (n, t, h, dk), dtype)
    k = jax.random.normal(jax.random.fold_in(k0, 1), (n, t, h, dk), dtype)
    v = jax.random.normal(jax.random.fold_in(k0, 2), (n, t, h, dv), dtype)
    lw = -jnp.exp(jax.random.normal(jax.random.fold_in(k0, 3),
                                    (n, t, h, dk)) * 0.5)
    u = jax.random.normal(jax.random.fold_in(k0, 4), (h, dk))
    want, _ = wkv_chunked(r, k, v, lw, u=u, chunk=8)
    got = wkv_pallas(r, k, v, lw, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)
