"""Runtime: training loop, checkpoint/restore, fault injection + restart,
watchdog, serving, preconditioned optimizer end-to-end."""
import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES
from repro.core import (
    CrossEntropyLoss,
    DiagGGNMC,
    ExtensionConfig,
    KFAC,
    Variance,
)
from repro.data.synthetic import batch_for, lm_batch, DataConfig
from repro.nn.models import build_model
from repro.optim import adamw, curvature_optimizer, momentum_sgd
from repro.serve.engine import ServeConfig, generate
from repro.train import checkpoint as ckpt
from repro.train.fault import (
    FailureInjector,
    SimulatedFailure,
    Watchdog,
    run_with_restarts,
)
from repro.train.loop import LoopConfig, fit

CFG = ARCHS["stablelm-1.6b"].reduced()
SHAPE = dataclasses.replace(SHAPES["train_4k"], seq_len=24, global_batch=8)


def test_data_determinism_and_host_sharding():
    dc = DataConfig(vocab=97, seq_len=16, global_batch=8)
    b1 = lm_batch(dc, 5)
    b2 = lm_batch(dc, 5)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = lm_batch(dc, 6)
    assert not np.array_equal(b1["inputs"], b3["inputs"])
    # host split shapes
    dc2 = dataclasses.replace(dc, n_hosts=2, host_id=1)
    assert lm_batch(dc2, 5)["inputs"].shape == (4, 16)


def test_fit_decreases_loss():
    model = build_model(CFG)
    _, _, hist, wd = fit(model, CFG, SHAPE, adamw(1e-3),
                         LoopConfig(steps=25, log_every=1000))
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert not wd.stalled()


def test_checkpoint_roundtrip_and_keep_k():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30, 40):
            ckpt.save(d, s, params, opt_state, keep=2)
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert steps == ["step_00000030", "step_00000040"]
        assert ckpt.latest_step(d) == 40
        p2, o2, manifest = ckpt.restore(d, 40, params, opt_state)
        assert manifest["step"] == 40
        for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_failure_injection_and_restart_resumes():
    model = build_model(CFG)
    opt = adamw(1e-3)
    with tempfile.TemporaryDirectory() as d:
        steps_run = []

        def make_and_run(resume):
            inj = FailureInjector(fail_at_step=15) if resume is None else None
            lc = LoopConfig(steps=20, ckpt_dir=d, ckpt_every=5, log_every=1000)
            _, _, hist, _ = fit(model, CFG, SHAPE, opt, lc, injector=inj,
                                resume=resume is not None)
            steps_run.append(len(hist))
            return 20

        final, restarts = run_with_restarts(make_and_run, max_restarts=2)
        assert final == 20 and restarts == 1
        # second run resumed from step 15's checkpoint, not from scratch
        assert steps_run[-1] <= 6


def test_restart_budget_exhausted():
    def always_fail(resume):
        raise SimulatedFailure("boom")

    with pytest.raises(SimulatedFailure):
        run_with_restarts(always_fail, max_restarts=2)


def test_watchdog_straggler_detection():
    wd = Watchdog(straggler_factor=2.0)
    for i in range(10):
        wd.beat(i, 0.1)
    assert wd.beat(10, 0.5) is False
    assert wd.straggler_steps == [10]
    assert wd.beat(11, 0.1) is True


def test_generate_shapes_and_determinism():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.zeros((2, 4), jnp.int32)
    out1 = generate(model, params, prompts, ServeConfig(max_len=12))
    out2 = generate(model, params, prompts, ServeConfig(max_len=12))
    assert out1.shape == (2, 12)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_curvature_optimizer_trains():
    """Paper §4: damped preconditioned update with DiagGGN-MC curvature."""
    model = build_model(CFG)
    opt = curvature_optimizer(0.2, damping=1e-1, curvature="diag_ggn_mc")
    _, _, hist, _ = fit(model, CFG, SHAPE, opt,
                        LoopConfig(steps=20, log_every=1000),
                        extensions=(DiagGGNMC,),
                        ext_cfg=ExtensionConfig(mc_samples=1))
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_kfac_optimizer_trains():
    model = build_model(CFG)
    opt = curvature_optimizer(0.3, damping=1e-1, curvature="kfac",
                              stat_decay=0.5)
    _, _, hist, _ = fit(model, CFG, SHAPE, opt,
                        LoopConfig(steps=15, log_every=1000),
                        extensions=(KFAC,),
                        ext_cfg=ExtensionConfig(mc_samples=1))
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_variance_telemetry_tracked():
    model = build_model(CFG)
    _, _, hist, _ = fit(model, CFG, SHAPE, adamw(1e-3),
                        LoopConfig(steps=3, log_every=1000),
                        extensions=(Variance,), track=("variance",))
    assert "variance_mean" in hist[0]
    assert hist[0]["variance_mean"] >= 0
