import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Keep the single-host test session inside RAM: compiled executables
    accumulate across modules otherwise (OOM on 35 GB hosts)."""
    yield
    jax.clear_caches()
