import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401  (the real package, when installed)
except ModuleNotFoundError:
    # Container images without hypothesis (nothing may be pip-installed
    # there) get the deterministic shim; the pinned CI env has the real one.
    import _hypothesis_fallback

    _hypothesis_fallback.install()

import jax
import pytest

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running tests (subprocess sweeps, full-suite lane only)")
    config.addinivalue_line(
        "markers",
        "sharding: multi-device subprocess tests (need spare RAM/CPU)")


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_per_module():
    """Keep the single-host test session inside RAM: compiled executables
    accumulate across modules otherwise (OOM on 35 GB hosts)."""
    yield
    jax.clear_caches()
