"""Empirical NTK extension family, against a ``jax.jacrev`` oracle.

``NTK`` / ``NTKClasswise`` ride the engine's raw-Jacobian ("jac") sweep:
identity cotangents per class through the shared transposed-Jacobian
backward give per-sample Jacobian factors, and the per-parameter Gram
blocks they induce sum (``ntk_total``) to the empirical kernel
Θ(x, x') = J(x) J(x')ᵀ.  The oracle here materializes the full Jacobian
with ``jax.jacrev`` — exactly the O(N·C·P) construction the extension
avoids — and pins both conventions:

* ``ntk``: class-diagonal sum, ``T[n, m] = Σ_c ⟨J_c(n), J_c(m)⟩``
  (``einsum('ncmc->nm')`` of the full 4-index kernel);
* ``ntk_classwise``: trailing class axis, ``T[n, m, c] = ⟨J_c(n), J_c(m)⟩``.

The fused cross-block Pallas kernel, the streamed row-block lanes
(accumulate(k), uneven finals), the sharded lane with its three assembly
modes ('split' / 'all' / 'master') and the shard × accumulate grid are
all compared against the same monolithic run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CrossEntropyLoss,
    ExtensionConfig,
    by_name,
    ntk_total,
    plan_sweeps,
    run,
)
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.launch.mesh import make_data_mesh

from _oracles import materialized_ntk, tiny_mlp

N, D, H, C = 11, 5, 7, 3
LOSS = CrossEntropyLoss()
NTK_EXTS = (by_name("ntk"), by_name("ntk_classwise"))


@pytest.fixture(scope="module")
def setup():
    return tiny_mlp(N, D, H, C)


@pytest.fixture(scope="module")
def oracle_kernel(setup):
    """Full 4-index kernel K[n, c, m, c'] from the materialized Jacobian."""
    model, params, x, _ = setup
    return materialized_ntk(model, params, x)


def _run(setup, cfg=ExtensionConfig(), exts=NTK_EXTS):
    model, params, x, y = setup
    return run(model, params, x, y, LOSS, extensions=exts, cfg=cfg,
               rng=jax.random.PRNGKey(42))


def test_ntk_matches_jacrev_oracle(setup, oracle_kernel):
    res = _run(setup)
    np.testing.assert_allclose(
        np.asarray(ntk_total(res.ext["ntk"])),
        np.einsum("ncmc->nm", oracle_kernel), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ntk_total(res.ext["ntk_classwise"])),
        np.einsum("ncmc->nmc", oracle_kernel), rtol=1e-5, atol=1e-5)


def test_classwise_sums_to_total(setup):
    res = _run(setup)
    np.testing.assert_allclose(
        np.asarray(ntk_total(res.ext["ntk_classwise"]).sum(-1)),
        np.asarray(ntk_total(res.ext["ntk"])), rtol=1e-5, atol=1e-5)


def test_per_parameter_blocks_are_gram(setup):
    """Each per-parameter leaf is itself a PSD Gram matrix."""
    res = _run(setup)
    for leaf in jax.tree.leaves(res.ext["ntk"]):
        m = np.asarray(leaf)
        np.testing.assert_allclose(m, m.T, rtol=1e-5, atol=1e-6)
        assert np.linalg.eigvalsh(m).min() > -1e-4


def test_kernel_path_matches_reference(setup):
    ref = _run(setup, ExtensionConfig(use_kernels=False))
    for cfg in (ExtensionConfig(use_kernels=True, use_fused=True),
                ExtensionConfig(use_kernels=True, use_fused=False)):
        res = _run(setup, cfg)
        for name in ("ntk", "ntk_classwise"):
            for a, b in zip(jax.tree.leaves(ref.ext[name]),
                            jax.tree.leaves(res.ext[name])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=3e-5, atol=3e-5)


def test_ntk_requires_flat_outputs(setup):
    model, params, _, _ = setup
    x3 = jax.random.normal(jax.random.PRNGKey(5), (4, 3, D))
    y3 = jax.random.randint(jax.random.PRNGKey(6), (4, 3), 0, C)
    with pytest.raises(ValueError, match="flat \\[N, C\\]"):
        run(model, params, x3, y3, LOSS, extensions=(by_name("ntk"),))


def test_ntk_total_rejects_empty_tree():
    with pytest.raises(ValueError, match="empty NTK stats tree"):
        ntk_total({})


def test_cross_dot_kernel_matches_ref():
    """The fused cross-block J·Jᵀ kernel — the off-diagonal primitive the
    streamed Gram scatter relies on — against its einsum oracle, including
    shapes that force tile padding."""
    rng = np.random.default_rng(0)
    for (e, n1, n2, r, a, b) in [(2, 3, 4, 1, 8, 8), (3, 5, 7, 2, 33, 21),
                                 (1, 130, 70, 1, 16, 8)]:
        A1, B1 = (jnp.asarray(rng.normal(size=(e, n1, r, s)), jnp.float32)
                  for s in (a, b))
        A2, B2 = (jnp.asarray(rng.normal(size=(e, n2, r, s)), jnp.float32)
                  for s in (a, b))
        got = kops.cross_dot(A1, B1, A2, B2)
        want = kref.cross_dot(A1, B1, A2, B2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# streamed row-block lanes
# ---------------------------------------------------------------------------


def test_streamed_matches_monolithic(setup):
    """accumulate(k) streams diagonal Gram blocks through the main scan
    and off-diagonal cross blocks through the pair passes; k ∈ {2, 3} on
    N=11 exercises uneven final microbatches (6+5 and 4+4+3)."""
    for cfg in (ExtensionConfig(), ExtensionConfig(use_kernels=True)):
        ref = _run(setup, cfg)
        for k in (2, 3):
            model, params, x, y = setup
            res = plan_sweeps(NTK_EXTS, cfg).accumulate(k).run(
                model, params, x, y, LOSS, cfg=cfg,
                rng=jax.random.PRNGKey(42))
            for name in ("ntk", "ntk_classwise"):
                for a, b in zip(jax.tree.leaves(ref.ext[name]),
                                jax.tree.leaves(res.ext[name])):
                    np.testing.assert_allclose(
                        np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5,
                        err_msg=f"streamed {name} at k={k} under {cfg}")


# ---------------------------------------------------------------------------
# sharded lane: assembly modes
# ---------------------------------------------------------------------------

NS = 16  # divisible by any power-of-two device count the CI lanes use


@pytest.fixture(scope="module")
def sharded_setup(setup):
    model, params, _, _ = setup
    x = jax.random.normal(jax.random.PRNGKey(3), (NS, D))
    y = jax.random.randint(jax.random.PRNGKey(4), (NS,), 0, C)
    return model, params, x, y, make_data_mesh()


def _ref_total(sharded_setup):
    model, params, x, y, _ = sharded_setup
    res = run(model, params, x, y, LOSS, extensions=NTK_EXTS,
              rng=jax.random.PRNGKey(42))
    return np.asarray(ntk_total(res.ext["ntk"]))


@pytest.mark.parametrize("accumulate", [None, 2],
                         ids=["monolithic", "grid-k2"])
def test_sharded_assembly_modes(sharded_setup, accumulate):
    """'split' leaves row blocks on their shards (out-spec concatenates
    them back to the global [N, N]); 'all' all-gathers the full kernel to
    every shard; 'master' materializes it on the leading [S, ...] slot
    only, zeros elsewhere.  All three must reproduce the single-device
    kernel — on the 8-virtual-device CI lane this covers genuine
    cross-shard assembly, and the grid lane crosses it with streaming."""
    model, params, x, y, mesh = sharded_setup
    want = _ref_total(sharded_setup)
    n_dev = len(mesh.devices.flatten())
    for mode in ("split", "all", "master"):
        plan = plan_sweeps(NTK_EXTS, ExtensionConfig()).shard(
            mesh, "data", gram_assembly=mode)
        if accumulate:
            plan = plan.accumulate(accumulate)
        res = plan.run(model, params, x, y, LOSS,
                       rng=jax.random.PRNGKey(42))
        total = np.asarray(ntk_total(res.ext["ntk"]))
        if mode == "master":
            assert total.shape == (n_dev, NS, NS)
            np.testing.assert_allclose(total[0], want, rtol=3e-5, atol=3e-5)
            if n_dev > 1:
                np.testing.assert_allclose(total[1:], 0.0, atol=1e-12)
        else:
            assert total.shape == (NS, NS)
            np.testing.assert_allclose(total, want, rtol=3e-5, atol=3e-5,
                                       err_msg=f"assembly mode {mode}")


def test_unknown_assembly_mode_rejected(sharded_setup):
    *_, mesh = sharded_setup
    with pytest.raises(ValueError, match="gram assembly mode"):
        plan_sweeps(NTK_EXTS, ExtensionConfig()).shard(
            mesh, "data", gram_assembly="bogus")
