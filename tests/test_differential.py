"""Differential harness: every extension combo × every kernel config.

The paper's core claim — the same quantities come out of one shared
backward pass no matter how the reductions are scheduled — as an
executable invariant: for extension subsets drawn from ``ALL_EXTENSIONS``
(singletons plus the interesting first-/second-order combos), running the
engine under every ``use_kernels × use_fused`` configuration must produce
pairwise-allclose results.  ``use_kernels=False / use_fused=True`` is the
reference; the two kernel configurations (fused kernels on; legacy
one-kernel-per-extension) are compared leaf by leaf against it, which by
transitivity makes all pairs close.  The fourth corner of the cross
product, ``(False, False)``, is path-identical to the reference today
(``use_fused`` is only consulted when kernels are on) — it stays in the
sweep as a cheap guard that that property holds.

One fixed small chain model (Dense → sigmoid → Dense) keeps every sweep —
including the chain-only KFRA / DiagHessian — in play, and one fixed rng
makes the MC factorization identical across configurations so the
comparison is exact up to accumulation order.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ALL_EXTENSIONS,
    Activation,
    CrossEntropyLoss,
    Dense,
    ExtensionConfig,
    Sequential,
    by_name,
    plan_sweeps,
    run,
)
from repro.launch.mesh import make_data_mesh

N, D, H, C = 5, 6, 7, 4
LOSS = CrossEntropyLoss()
CONFIGS = [
    ExtensionConfig(use_kernels=uk, use_fused=uf)
    for uk, uf in itertools.product([False, True], repeat=2)
]
REFERENCE = ExtensionConfig(use_kernels=False, use_fused=True)

# Every singleton, plus the combos that share sweeps (and therefore fused
# kernel launches): all-first-order, exact-curvature, MC-curvature, and a
# mixed first+second workload.
SUBSETS = [(e.name,) for e in ALL_EXTENSIONS] + [
    ("batch_grad", "batch_l2", "second_moment", "variance", "batch_dot"),
    ("diag_ggn", "kflr", "ggn_trace"),
    ("diag_ggn_mc", "kfac"),
    ("batch_grad", "batch_l2", "diag_ggn", "kflr"),
    ("ntk", "ntk_classwise", "batch_dot"),
    ("variance", "batch_dot", "diag_ggn", "ggn_trace", "diag_ggn_mc",
     "kfac", "kfra", "diag_hessian"),
]


@pytest.fixture(scope="module")
def setup():
    model = Sequential([Dense(D, H), Activation("sigmoid"), Dense(H, C)])
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (N, D))
    y = jax.random.randint(jax.random.PRNGKey(2), (N,), 0, C)
    return model, params, x, y


def _leaves(tree):
    return [l for l in jax.tree.leaves(tree) if hasattr(l, "ndim")]


@pytest.mark.parametrize("names", SUBSETS, ids=["+".join(s) for s in SUBSETS])
def test_all_configs_agree(names, setup):
    model, params, x, y = setup
    exts = tuple(by_name(n) for n in names)
    rng = jax.random.PRNGKey(42)  # same MC draws in every configuration
    results = [run(model, params, x, y, LOSS, extensions=exts, cfg=cfg,
                   rng=rng) for cfg in CONFIGS]
    ref = results[CONFIGS.index(REFERENCE)]

    # the plain training quantities must agree too, not just the extensions
    for res in results:
        np.testing.assert_allclose(np.asarray(res.loss),
                                   np.asarray(ref.loss), rtol=1e-6)
        for a, b in zip(_leaves(res.grads), _leaves(ref.grads)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)

    for res, cfg in zip(results, CONFIGS):
        assert set(res.ext) == set(ref.ext), cfg
        for name in ref.ext:
            ra, rb = _leaves(ref.ext[name]), _leaves(res.ext[name])
            assert len(ra) == len(rb) and ra, (name, cfg)
            for a, b in zip(ra, rb):
                assert a.shape == b.shape, (name, cfg)
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5,
                    err_msg=f"{name} under {cfg}")


# ---------------------------------------------------------------------------
# batch-sharded lane: the same invariant across devices
# ---------------------------------------------------------------------------

NS = 16  # divisible by any power-of-two device count the CI lanes use


@pytest.fixture(scope="module")
def sharded_setup(setup):
    model, params, _, _ = setup
    x = jax.random.normal(jax.random.PRNGKey(3), (NS, D))
    y = jax.random.randint(jax.random.PRNGKey(4), (NS,), 0, C)
    return model, params, x, y, make_data_mesh()


@pytest.mark.parametrize("names", SUBSETS, ids=["+".join(s) for s in SUBSETS])
def test_sharded_sweep_matches_single_device(names, sharded_setup):
    """The property behind the per-extension reduce specs: for every
    extension subset and every ``use_kernels × use_fused`` configuration,
    the batch-sharded sweep (psum / kron / pmean / moment-merge reducers,
    concatenated per-sample rows, gathered Gram blocks) is allclose to the
    single-device sweep.  The mesh spans every device the process owns —
    1 in the default lanes (the lane still runs end to end), 8 in the
    ``tests-multidevice`` CI lane."""
    model, params, x, y, mesh = sharded_setup
    exts = tuple(by_name(n) for n in names)
    rng = jax.random.PRNGKey(42)
    for cfg in CONFIGS:
        ref = run(model, params, x, y, LOSS, extensions=exts, cfg=cfg,
                  rng=rng)
        res = plan_sweeps(exts, cfg).shard(mesh, "data").run(
            model, params, x, y, LOSS, cfg=cfg, rng=rng)
        np.testing.assert_allclose(np.asarray(res.loss),
                                   np.asarray(ref.loss), rtol=1e-6)
        for a, b in zip(_leaves(res.grads), _leaves(ref.grads)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6)
        assert set(res.ext) == set(ref.ext), cfg
        for name in ref.ext:
            ra, rb = _leaves(ref.ext[name]), _leaves(res.ext[name])
            assert len(ra) == len(rb) and ra, (name, cfg)
            for a, b in zip(ra, rb):
                assert a.shape == b.shape, (name, cfg)
                np.testing.assert_allclose(
                    np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5,
                    err_msg=f"sharded {name} under {cfg}")


# ---------------------------------------------------------------------------
# streaming accumulated lane: the same invariant across microbatches
# ---------------------------------------------------------------------------

# Every extension accumulates now: BatchDot / NTK ('gram') stream row
# blocks — diagonal blocks from the main scan, one extra pass per slice
# pair for the off-diagonals — and KFRA ('pmean') streams its chain
# partials with a final replay of the Ḡ recursion.  Reducers that
# genuinely cannot stream declare ``supports_streaming = False`` and are
# rejected (tests/test_accumulated_sweep.py pins the error).
ACC_SUBSETS = list(SUBSETS)


def _assert_results_match(res, ref, label):
    np.testing.assert_allclose(np.asarray(res.loss), np.asarray(ref.loss),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(res.logits),
                               np.asarray(ref.logits), rtol=1e-5, atol=1e-6)
    for a, b in zip(_leaves(res.grads), _leaves(ref.grads)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    assert set(res.ext) == set(ref.ext), label
    for name in ref.ext:
        ra, rb = _leaves(ref.ext[name]), _leaves(res.ext[name])
        assert len(ra) == len(rb) and ra, (name, label)
        for a, b in zip(ra, rb):
            assert a.shape == b.shape, (name, label)
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=3e-5, atol=3e-5,
                err_msg=f"{label} {name}")


@pytest.mark.parametrize("names", ACC_SUBSETS,
                         ids=["+".join(s) for s in ACC_SUBSETS])
def test_accumulated_sweep_matches_monolithic(names, setup):
    """``plan.accumulate(k)`` == the monolithic sweep for every extension
    subset and every ``use_kernels × use_fused`` configuration.  N=5 makes
    both tested schedules exercise an *uneven* final microbatch (k=2 →
    slices of 3+2; k=3 → 2+2+1), and the fixed rng pins the MC draws: the
    per-global-sample-index PRNG streams must make the sliced draws
    reproduce the monolithic ones exactly."""
    model, params, x, y = setup
    exts = tuple(by_name(n) for n in names)
    rng = jax.random.PRNGKey(42)
    for cfg in CONFIGS:
        ref = run(model, params, x, y, LOSS, extensions=exts, cfg=cfg,
                  rng=rng)
        for k in (2, 3):
            res = plan_sweeps(exts, cfg).accumulate(k).run(
                model, params, x, y, LOSS, cfg=cfg, rng=rng)
            _assert_results_match(res, ref, f"accumulate({k}) under {cfg}")


@pytest.mark.parametrize("names", ACC_SUBSETS,
                         ids=["+".join(s) for s in ACC_SUBSETS])
def test_shard_accumulate_grid_matches_single_device(names, sharded_setup):
    """The shard × accumulate grid: ``plan.shard(mesh).accumulate(k)`` ==
    the monolithic single-device sweep.  Each device scans over k=2
    slices of its local rows — on the 8-virtual-device CI lane that is a
    genuine 16-sample → 8 shards × 2 microbatches grid.  Both kernel
    routings run (the fused Pallas path and the pure-jnp reference); the
    per-extension legacy kernel path and uneven local schedules are
    pinned by the single-axis lanes above and
    tests/test_accumulated_sweep.py — re-crossing them here would triple
    a trace-bound test for paths the grid does not change.
    """
    model, params, x, y, mesh = sharded_setup
    exts = tuple(by_name(n) for n in names)
    rng = jax.random.PRNGKey(42)
    for cfg in (REFERENCE, ExtensionConfig(use_kernels=True, use_fused=True)):
        ref = run(model, params, x, y, LOSS, extensions=exts, cfg=cfg,
                  rng=rng)
        res = plan_sweeps(exts, cfg).shard(mesh, "data").accumulate(2).run(
            model, params, x, y, LOSS, cfg=cfg, rng=rng)
        _assert_results_match(res, ref, f"shard+accumulate(2) under {cfg}")
