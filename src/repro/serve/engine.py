"""Batched serving engine: prefill → decode with KV caches + sampling.

``generate`` runs a static-batch decode loop with greedy/temperature
sampling and per-sequence EOS tracking (finished slots keep decoding into
a scratch position — the static-shape analogue of continuous batching's
slot reuse; a production scheduler would swap in new requests).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs


@dataclasses.dataclass
class ServeConfig:
    max_len: int = 256
    temperature: float = 0.0  # 0 = greedy
    eos_id: int = -1          # -1: never stop early
    cache_dtype: str = "float32"


def prefill(model, params, caches, prompts, prompt_len):
    """Feed prompt tokens one position at a time (cache-filling).

    prompts: [N, P] int32.  Returns (caches, last_logits).
    """
    def body(carry, t):
        caches, _ = carry
        logits, caches = model.serve_step(params, caches, prompts[:, t], t)
        return (caches, logits), None

    (caches, logits), _ = jax.lax.scan(
        body, (caches, jnp.zeros((prompts.shape[0],
                                  _vocab_of(model)), jnp.float32)),
        jnp.arange(prompt_len))
    return caches, logits


def _vocab_of(model):
    head = model.mods[-1] if hasattr(model, "mods") else model.children_map["head"]
    return head.d_out


def generate(model, params, prompts, cfg: ServeConfig, rng=None):
    """prompts: [N, P] → tokens [N, max_len] (prompt + continuation)."""
    n, p = prompts.shape
    caches = model.init_serve_cache(params, n, cfg.max_len,
                                    jnp.dtype(cfg.cache_dtype))
    with obs.span("serve/prefill", n=n, tokens=int(p)):
        caches, logits = prefill(model, params, caches, prompts, p)
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    def sample(logits, key):
        if cfg.temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / cfg.temperature, axis=-1).astype(jnp.int32)

    def body(carry, t):
        caches, logits, done, key = carry
        key, sub = jax.random.split(key)
        tok = sample(logits, sub)
        tok = jnp.where(done, 0, tok)
        done = done | (tok == cfg.eos_id)
        logits, caches = model.serve_step(params, caches, tok, t)
        return (caches, logits, done, key), tok

    done0 = jnp.zeros((n,), bool)
    n_decode = cfg.max_len - p
    reg = obs.get()
    with obs.span("serve/decode", n=n, tokens=int(n_decode)):
        t0 = time.perf_counter() if reg.enabled else 0.0
        (_, _, done, _), toks = jax.lax.scan(
            body, (caches, logits, done0, rng), jnp.arange(p, cfg.max_len))
        if reg.enabled and not isinstance(toks, jax.core.Tracer):
            # block so the span/gauge measure decode completion, not just
            # dispatch — per-request latency is the serving SLO number
            # (skipped when a caller jits generate(): trace time is not a
            # latency)
            toks.block_until_ready()
            dt = time.perf_counter() - t0
            reg.count("serve.requests", n)
            reg.count("serve.tokens", n * int(n_decode))
            reg.gauge("serve.decode.s_per_token",
                      dt / max(int(n_decode), 1))
    return jnp.concatenate([prompts, toks.T.astype(jnp.int32)], axis=1)


def generate_whisper(model, params, frames, cfg: ServeConfig, bos=0,
                     rng=None):
    """Whisper: encode frames once, then decode text tokens."""
    n = frames.shape[0]
    enc_out = model.encode(params, frames)
    caches = model.init_serve_cache(params, n, model.max_dec,
                                    jnp.dtype(cfg.cache_dtype),
                                    enc_out=enc_out)
    tok0 = jnp.full((n,), bos, jnp.int32)

    def body(carry, t):
        caches, tok = carry
        logits, caches = model.serve_step(params, caches, tok, t)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        return (caches, nxt), nxt

    (_, _), toks = jax.lax.scan(body, (caches, tok0),
                                jnp.arange(min(cfg.max_len, model.max_dec)))
    return toks.T
