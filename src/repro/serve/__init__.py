from repro.serve.engine import ServeConfig, generate, generate_whisper, prefill
