"""Active-learning / coreset subset selection off streamed NTK blocks.

Two selectors over the same extracted kernels:

* :func:`greedy_max_diversity` — sequential GP-variance maximization on
  the class-traced NTK ``[N, N]``: each step picks the point with the
  largest posterior variance given the points already chosen (computed
  by an incremental pivoted-Cholesky update, O(N·k) per step).  The
  marginal-variance pick is exactly the greedy ``log det(K_SS + εI)``
  maximizer — a diverse, well-spread coreset.
* :func:`bait_select` — BAIT-style Fisher selection (Ash et al. 2021)
  on the classwise Gram ``[N, N, C̃, C̃]``: greedily minimize
  ``tr((F_S + λI)⁻¹ F_pool)`` — pick points whose Fisher information
  covers the pool's.  The parameter-space objective never materializes:
  with ``B_S`` the stacked per-sample Jacobian rows, Woodbury turns it
  into Gram space,

      tr((F_S + λI)⁻¹ F_pool)
        = (1/λ) [ tr(K) − tr((K_SS + λI)⁻¹ K_S,· K_·,Sᵀ) ]

  so every candidate evaluation is a ``[|S|·C̃]``-sized solve on blocks
  of the already-extracted kernel.

:func:`select_subset` drives either selector from the engine lanes —
streamed row blocks under ``microbatches=k``, sharded assembly under
``mesh=`` — so pool-scale kernels never need a monolithic sweep.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.engine import gram_total, ntk_total, plan_sweeps
from repro.core.extensions import ExtensionConfig, GGNGram, NTK


class SelectionResult(NamedTuple):
    indices: jnp.ndarray      # [k] selected pool indices, in pick order
    scores: jnp.ndarray       # [k] greedy objective at each pick
    kernel: jnp.ndarray       # the extracted kernel the selection ran on


def greedy_max_diversity(K, k: int, *, jitter: float = 1e-6):
    """Greedy max-variance (≡ max-logdet) selection on a PSD ``[N, N]``.

    Returns ``(indices [k], variances [k])`` — ``variances[t]`` is the
    picked point's posterior variance given the first ``t`` picks (the
    ``exp`` of its logdet gain on ``K + jitter·I``); it is non-increasing.
    """
    K = jnp.asarray(K, jnp.float32)
    n = K.shape[0]
    if not 0 < k <= n:
        raise ValueError(f"greedy_max_diversity: k={k} outside 1..{n}")
    # incremental pivoted Cholesky: d holds the residual (conditional)
    # variance of every candidate; each pick appends the column that
    # downdates it
    d = jnp.diag(K) + jnp.float32(jitter)
    C = jnp.zeros((n, k), jnp.float32)
    picked, gains = [], []
    for t in range(k):
        d_masked = d.at[jnp.array(picked, jnp.int32)].set(-jnp.inf) \
            if picked else d
        i = int(jnp.argmax(d_masked))
        v = d[i]
        c = (K[:, i].at[i].add(jitter) - C[:, :t] @ C[i, :t]) \
            / jnp.sqrt(jnp.maximum(v, 1e-30))
        C = C.at[:, t].set(c)
        d = d - c * c
        picked.append(i)
        gains.append(v)
    return jnp.array(picked, jnp.int32), jnp.stack(gains)


def _as_flat_gram(K):
    """``[N, N]`` or ``[N, N, C, C]`` → block-flattened ``[N·C, N·C]``."""
    K = jnp.asarray(K, jnp.float32)
    if K.ndim == 2:
        K = K[:, :, None, None]
    n, _, c, _ = K.shape
    return K.transpose(0, 2, 1, 3).reshape(n * c, n * c), n, c


def bait_select(K, k: int, *, lam: float = 1e-3):
    """Greedy BAIT selection.  ``K``: ``[N, N]`` or classwise
    ``[N, N, C̃, C̃]`` (``gram_total`` of the ``ggn_gram`` extension).

    Returns ``(indices [k], objectives [k])`` — ``objectives[t]`` is
    ``tr((F_S + λI)⁻¹ F_pool)`` after the ``t``-th pick (decreasing).
    """
    K2, n, c = _as_flat_gram(K)
    if not 0 < k <= n:
        raise ValueError(f"bait_select: k={k} outside 1..{n}")
    lam = jnp.float32(lam)
    tr_pool = jnp.trace(K2)

    def objective(rows):
        # Woodbury: tr((F_S+λI)⁻¹F_pool) in Gram space (module docstring)
        Kss = K2[jnp.ix_(rows, rows)]
        Ksp = K2[rows, :]
        m = rows.shape[0]
        inner = jnp.linalg.solve(Kss + lam * jnp.eye(m, dtype=K2.dtype),
                                 Ksp @ Ksp.T)
        return (tr_pool - jnp.trace(inner)) / lam

    picked, objs = [], []
    obj_batch = jax.vmap(objective)
    for _ in range(k):
        cands = np.array([j for j in range(n) if j not in picked], np.int32)
        base = (np.concatenate([np.arange(c) + i * c for i in picked])
                if picked else np.zeros((0,), np.int64))
        rows = np.stack([np.concatenate([base, np.arange(c) + j * c])
                         for j in cands])
        vals = obj_batch(jnp.asarray(rows, jnp.int32))
        a = int(jnp.argmin(vals))
        picked.append(int(cands[a]))
        objs.append(float(vals[a]))
    return jnp.array(picked, jnp.int32), jnp.array(objs, jnp.float32)


def select_subset(model, params, inputs, targets, loss, k: int, *,
                  method: str = "diversity", lam: float = 1e-3,
                  jitter: float = 1e-6, cfg=None, mesh=None,
                  shard_axes=("data",), gram_assembly: str = "master",
                  microbatches: Optional[int] = None,
                  rng=None) -> SelectionResult:
    """Pick ``k`` of the pool via the requested selector.

    ``method='diversity'`` extracts the class-traced NTK, ``'bait'`` the
    loss-scaled classwise Gram (``ggn_gram`` — Fisher blocks for the
    canonical losses).  Extraction composes with ``mesh=`` (under
    ``gram_assembly='master'`` the selection runs on shard 0's full
    copy) and ``microbatches=k`` row-block streaming.
    """
    if method not in ("diversity", "bait"):
        raise ValueError(f"select_subset: unknown method {method!r} "
                         "(want 'diversity' or 'bait')")
    cfg = cfg or ExtensionConfig()
    ext = NTK if method == "diversity" else GGNGram
    plan = plan_sweeps((ext,), cfg)
    if mesh is not None:
        plan = plan.shard(mesh, shard_axes, gram_assembly=gram_assembly)
    if microbatches and microbatches > 1:
        plan = plan.accumulate(microbatches)
    n = jax.tree.leaves(inputs)[0].shape[0]
    with obs.span("ntk_apps/select_subset", method=method, n=n, k=k,
                  sharded=mesh is not None,
                  microbatches=microbatches or 1):
        res = plan.run(model, params, inputs, targets, loss, cfg=cfg,
                       rng=rng if rng is not None else jax.random.PRNGKey(0))
        if method == "diversity":
            K = ntk_total(res.ext["ntk"])
            if K.ndim == 3:      # 'master' assembly: leading device axis
                K = K[0]
            idx, scores = greedy_max_diversity(K, k, jitter=jitter)
        else:
            K = gram_total(res.ext["ggn_gram"])
            if K.ndim == 5:      # 'master' assembly: leading device axis
                K = K[0]
            idx, scores = bait_select(K, k, lam=lam)
    return SelectionResult(indices=idx, scores=scores, kernel=K)
