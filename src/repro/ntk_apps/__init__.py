"""repro.ntk_apps — consumers of the empirical-NTK / Gram lane.

PR 6 made kernel *extraction* cheap: ``NTK`` / ``NTKClasswise`` ride the
raw-Jacobian sweep through the Reducer protocol, streamed row-block Gram
under ``accumulate(k)``, master/all/split assembly under
``SweepPlan.shard(mesh)``.  This package builds what that unlocks
(BackPACK's thesis applied one level up — the quantities are only useful
with shared, tested consumers):

* :mod:`repro.ntk_apps.regression` — empirical-NTK kernel regression and
  GP predictives (mean + variance), solved in Gram space by Cholesky,
  dense eigendecomposition (optionally truncated), or Lanczos-top-k
  preconditioned CG on the 'master'-assembled kernel.
* :mod:`repro.ntk_apps.influence` — influence functions / self-influence
  over full datasets: per-sample gradients stream through the
  ``accumulate(k)`` lane and the inverse-curvature product is
  ``curv.GGNOperator`` + PCG, so it works where factors don't fit.
* :mod:`repro.ntk_apps.selection` — active-learning / coreset subset
  selection off streamed kernel blocks: greedy max-diversity (GP
  variance reduction) and BAIT-style Fisher trace minimization in
  kernel space.

All entry points compose with ``mesh=`` (sharded sweep) and
``microbatches=`` (streaming) exactly like the Laplace fits, and thread
``repro.obs`` spans.
"""
from .regression import GPPredictive, KernelSolveInfo, gp_predict, \
    kernel_solve, ntk_kernel
from .influence import InfluenceResult, influence_scores, self_influence
from .selection import SelectionResult, bait_select, greedy_max_diversity, \
    select_subset

__all__ = [
    "GPPredictive",
    "InfluenceResult",
    "KernelSolveInfo",
    "SelectionResult",
    "bait_select",
    "gp_predict",
    "greedy_max_diversity",
    "influence_scores",
    "kernel_solve",
    "ntk_kernel",
    "select_subset",
    "self_influence",
]
