"""Empirical-NTK kernel regression and GP predictives in Gram space.

The linearized-network / GP correspondence: with ``K`` the empirical NTK
(class-traced, ``[N, N]``) over train ∪ test rows and ``Y`` the (one-hot
or regression) targets, the kernel-ridge / GP posterior is

    α     = (K_tt + λI)⁻¹ Y                        [N, C]
    mean  = K_st α                                  [N*, C]
    var_j = K_ss[j,j] − k_jᵀ (K_tt + λI)⁻¹ k_j      [N*]

All the network touches is one raw-Jacobian sweep: the kernel assembles
through the engine's NTK extension (fused ``cross_dot``, streamed
row-blocks under ``accumulate(k)``, 'master' assembly under a mesh — the
full matrix lands on shard 0 where the factorization runs).

Three solvers share the ``kernel_solve`` entry point:

* ``'cholesky'`` — direct ``cho_factor``/``cho_solve`` on ``K + λI``.
* ``'eigh'`` — dense eigendecomposition; ``rank=r`` truncates to the
  top-r eigenspace (the tail is solved at ``1/λ`` — ridge-only), the
  spectral view asdfghjkl's kernel catalogue exposes.
* ``'lanczos'`` — matrix-free: ``curv.lanczos_topk`` Ritz pairs build a
  spectral preconditioner ``M⁻¹ = U_r diag(1/(λ_r+λ)) U_rᵀ +
  (I − U_r U_rᵀ)/λ`` and ``curv.cg_solve`` runs preconditioned CG on
  ``K + λI`` — exact at convergence, fast because the dominant
  eigenspace (the hard directions) is handled spectrally.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.engine import ntk_total, plan_sweeps
from repro.core.extensions import NTK, ExtensionConfig
from repro.curv import cg_solve, lanczos_topk


class KernelSolveInfo(NamedTuple):
    method: str
    rank: Optional[int]       # truncation / preconditioner rank (None = full)
    iters: int                # CG iterations (0 for direct solvers)
    resid: jnp.ndarray        # relative residual ‖(K+λI)X − B‖/‖B‖


class GPPredictive(NamedTuple):
    mean: jnp.ndarray         # [N_test, C] posterior mean
    var: jnp.ndarray          # [N_test] posterior variance (kernel scale)
    alpha: jnp.ndarray        # [N_train, C] representer coefficients
    kernel: jnp.ndarray       # [N_train+N_test, N_train+N_test] joint NTK
    info: KernelSolveInfo


def _batch_rows(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


def _concat_batch(a, b):
    return jax.tree.map(lambda x, y: jnp.concatenate([x, y], axis=0), a, b)


def ntk_kernel(model, params, inputs, targets, loss, *, cfg=None, mesh=None,
               shard_axes=("data",), gram_assembly: str = "split",
               microbatches: Optional[int] = None, rng=None):
    """Assemble the class-traced empirical NTK ``[N, N]`` for a batch.

    One raw-Jacobian sweep through the engine's ``NTK`` extension;
    ``mesh`` runs it on the sharded lane (``gram_assembly`` picks the
    distributed layout — under ``'master'`` the result carries a leading
    device axis with the full kernel in slot 0), ``microbatches=k``
    streams it in row blocks.  ``targets`` only feed the loss value; the
    kernel is loss-independent.
    """
    cfg = cfg or ExtensionConfig()
    plan = plan_sweeps((NTK,), cfg)
    if mesh is not None:
        plan = plan.shard(mesh, shard_axes, gram_assembly=gram_assembly)
    if microbatches and microbatches > 1:
        plan = plan.accumulate(microbatches)
    with obs.span("ntk_apps/kernel", n=_batch_rows(inputs),
                  sharded=mesh is not None,
                  microbatches=microbatches or 1):
        res = plan.run(model, params, inputs, targets, loss, cfg=cfg,
                       rng=rng if rng is not None else jax.random.PRNGKey(0))
    return ntk_total(res.ext["ntk"])


def kernel_solve(K, B, *, ridge: float, solver: str = "cholesky",
                 rank: Optional[int] = None, iters: Optional[int] = None,
                 cg_tol: float = 1e-10, cg_maxiter: int = 200, rng=None):
    """Solve ``(K + ridge·I) X = B`` in Gram space.  Returns ``(X, info)``.

    ``B`` may be ``[n]`` or ``[n, C]``.  See the module docstring for the
    three solver paths; ``rank`` is required for ``'lanczos'`` and
    optional (truncation) for ``'eigh'``.
    """
    K = jnp.asarray(K, jnp.float32)
    B = jnp.asarray(B, jnp.float32)
    squeeze = B.ndim == 1
    if squeeze:
        B = B[:, None]
    n = K.shape[0]
    lam = jnp.float32(ridge)
    it = 0

    with obs.span("ntk_apps/kernel_solve", solver=solver, n=n,
                  rank=rank or 0):
        if solver == "cholesky":
            cho = jax.scipy.linalg.cho_factor(
                K + lam * jnp.eye(n, dtype=K.dtype))
            X = jax.scipy.linalg.cho_solve(cho, B)
        elif solver == "eigh":
            evals, U = jnp.linalg.eigh(K)
            if rank is None:
                X = U @ ((U.T @ B) / (evals + lam)[:, None])
            else:
                top = jnp.argsort(evals)[::-1][:rank]
                Ur, lr = U[:, top], evals[top]
                proj = Ur.T @ B
                # top-r eigenspace solved spectrally, tail at ridge-only
                X = Ur @ (proj / (lr + lam)[:, None]) \
                    + (B - Ur @ proj) / lam
        elif solver == "lanczos":
            if rank is None:
                raise ValueError("kernel_solve: solver='lanczos' needs rank=")
            top = lanczos_topk(
                lambda v: K @ v, jnp.zeros((n,), jnp.float32),
                rng=rng if rng is not None else jax.random.PRNGKey(0),
                k=rank, iters=iters)
            Ur = top.eigvecs.T                      # [n, r]
            inv = 1.0 / (top.eigvals + lam)         # [r]

            def precond(R):                         # R: [C, n] batched rows
                proj = R @ Ur                       # [C, r]
                return (proj * inv) @ Ur.T + (R - proj @ Ur.T) / lam

            result = cg_solve(lambda X: X @ K + lam * X, B.T,
                              tol=cg_tol, maxiter=cg_maxiter,
                              precond=precond, batched=True)
            X, it = result.x.T, int(result.iters)
        else:
            raise ValueError(f"kernel_solve: unknown solver {solver!r} "
                             "(want 'cholesky', 'eigh' or 'lanczos')")

        resid = (jnp.linalg.norm(K @ X + lam * X - B)
                 / jnp.maximum(jnp.linalg.norm(B), 1e-30))
    if squeeze:
        X = X[:, 0]
    return X, KernelSolveInfo(method=solver, rank=rank, iters=it,
                              resid=resid)


def gp_predict(model, params, x_train, y_train, x_test, loss, *,
               ridge: float = 1e-3, targets=None, solver: str = "cholesky",
               rank: Optional[int] = None, iters: Optional[int] = None,
               cg_tol: float = 1e-10, cg_maxiter: int = 200,
               cfg=None, mesh=None, shard_axes=("data",),
               gram_assembly: str = "master",
               microbatches: Optional[int] = None, rng=None) -> GPPredictive:
    """NTK-GP posterior mean and variance at ``x_test``.

    The joint kernel over ``[train; test]`` assembles in one sweep (so
    cross and test blocks are exact, not re-linearized), then the solve
    runs on the train block.  ``targets`` overrides the regression
    targets (default: one-hot of integer ``y_train``, identity
    otherwise).  ``mesh`` + ``gram_assembly='master'`` is the intended
    distributed path: row blocks stream on all shards, the factorization
    runs on the master copy.  ``microbatches=k`` streams the Jacobian
    sweep row-blockwise.
    """
    n_train, n_test = _batch_rows(x_train), _batch_rows(x_test)
    inputs = _concat_batch(x_train, x_test)
    # test-row targets are never consumed by the raw-Jacobian sweep —
    # fill with zeros of the train targets' structure
    y_fill = jax.tree.map(
        lambda a: jnp.zeros((n_test,) + a.shape[1:], a.dtype), y_train)
    y_all = _concat_batch(y_train, y_fill)

    with obs.span("ntk_apps/gp_predict", n_train=n_train, n_test=n_test,
                  solver=solver):
        K = ntk_kernel(model, params, inputs, y_all, loss, cfg=cfg,
                       mesh=mesh, shard_axes=shard_axes,
                       gram_assembly=gram_assembly,
                       microbatches=microbatches, rng=rng)
        if K.ndim == 3:          # 'master' assembly: [S, N, N], slot 0 full
            K = K[0]
        K = jnp.asarray(K, jnp.float32)
        Ktt = K[:n_train, :n_train]
        Kst = K[n_train:, :n_train]
        Kss = K[n_train:, n_train:]

        if targets is not None:
            Y = jnp.asarray(targets, jnp.float32)
        else:
            yt = jnp.asarray(y_train)
            if jnp.issubdtype(yt.dtype, jnp.integer):
                n_classes = jax.eval_shape(
                    lambda p: model.apply(p, x_train), params).shape[-1]
                Y = jax.nn.one_hot(yt, n_classes, dtype=jnp.float32)
            else:
                Y = yt.astype(jnp.float32)

        alpha, info = kernel_solve(Ktt, Y, ridge=ridge, solver=solver,
                                   rank=rank, iters=iters, cg_tol=cg_tol,
                                   cg_maxiter=cg_maxiter, rng=rng)
        mean = Kst @ alpha
        # posterior variance: one more solve against the cross block
        W, _ = kernel_solve(Ktt, Kst.T, ridge=ridge, solver=solver,
                            rank=rank, iters=iters, cg_tol=cg_tol,
                            cg_maxiter=cg_maxiter, rng=rng)
        var = jnp.diag(Kss) - jnp.einsum("sn,ns->s", Kst, W)
    return GPPredictive(mean=mean, var=var, alpha=alpha, kernel=K, info=info)
