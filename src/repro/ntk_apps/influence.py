"""Influence functions and self-influence at dataset scale.

Koh & Liang (2017) influence of train point ``i`` on test point ``j``:

    I(i, j) = ∇ℓ_jᵀ (H + δI)⁻¹ ∇ℓ_i

with ``H`` the curvature of the *mean* train loss at the current params
(here the PSD GGN — the Fisher for the canonical losses — so the solve
is well-posed away from an optimum too).  Removing train point ``i``
from an n-point objective moves the optimum by ``≈ (1/n)(H+δI)⁻¹∇ℓ_i``,
so ``scores / n`` approximates the leave-one-out delta of the test loss:
positive score ⇒ removing ``i`` *increases* test loss ⇒ ``i`` was
helpful for ``j``.

Everything streams:

* per-sample gradients ride the engine's ``BatchGrad`` extension through
  the ``accumulate(k)`` lane (``microbatches=k``) and/or the sharded
  sweep (``mesh=``) — full-dataset rows never need one monolithic sweep;
* the inverse-curvature product is :class:`repro.curv.GGNOperator` +
  batched PCG (:func:`repro.curv.cg_solve`) — no factor is ever
  materialized, so this works exactly where explicit factors don't fit.

The engine's per-sample rows carry the mean-loss 1/M normalization
(their sum is the mean gradient); this module rescales them by
``loss.num_units`` so scores are in per-sample-loss units, matching the
closed forms the oracle tests check.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.engine import plan_sweeps
from repro.core.extensions import BatchGrad, ExtensionConfig
from repro.curv import GGNOperator, cg_solve


class InfluenceResult(NamedTuple):
    scores: jnp.ndarray       # [N_train, N_test] (or [N_train] for self)
    iters: jnp.ndarray        # CG iterations of the inverse-curvature solve
    resid: jnp.ndarray        # final CG relative residual (per RHS)


def _batch_rows(tree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


def _with_microbatches(cfg, n: int, microbatches: Optional[int]):
    """Translate a microbatch *count* into the cfg's per-device size."""
    cfg = cfg or ExtensionConfig()
    if microbatches and microbatches > 1:
        cfg = dataclasses.replace(
            cfg, microbatch_size=-(-n // int(microbatches)))
    return cfg


def per_sample_grads(model, params, inputs, targets, loss, *, cfg=None,
                     mesh=None, shard_axes=("data",),
                     microbatches: Optional[int] = None, rng=None):
    """Per-sample gradients ``∇ℓ_i`` as a pytree with leading axis N.

    ``BatchGrad`` through the requested lane composition, rescaled from
    the engine's 1/M rows to per-sample-loss gradients.
    """
    n = _batch_rows(inputs)
    cfg = cfg or ExtensionConfig()
    plan = plan_sweeps((BatchGrad,), cfg)
    if mesh is not None:
        plan = plan.shard(mesh, shard_axes)
    if microbatches and microbatches > 1:
        plan = plan.accumulate(microbatches)
    res = plan.run(model, params, inputs, targets, loss, cfg=cfg,
                   rng=rng if rng is not None else jax.random.PRNGKey(0))
    m = loss.num_units(targets)
    return jax.tree.map(lambda r: r.astype(jnp.float32) * m,
                        res.ext["batch_grad"])


def _dots(rows_a, rows_b):
    """⟨a_i, b_j⟩ over pytree leaves → [N_a, N_b]."""
    na = _batch_rows(rows_a)
    nb = _batch_rows(rows_b)
    out = jnp.zeros((na, nb), jnp.float32)
    for a, b in zip(jax.tree.leaves(rows_a), jax.tree.leaves(rows_b)):
        out = out + a.reshape(na, -1) @ b.reshape(nb, -1).T
    return out


def _solve_curvature(model, params, x_train, y_train, loss, rhs_rows, *,
                     damping, cfg, mesh, shard_axes, cg_tol, cg_maxiter):
    op = GGNOperator(model, params, x_train, y_train, loss,
                     damping=damping, cfg=cfg, mesh=mesh,
                     shard_axes=shard_axes)
    return cg_solve(op.mv_stacked, rhs_rows, tol=cg_tol,
                    maxiter=cg_maxiter, batched=True)


def influence_scores(model, params, x_train, y_train, x_test, y_test,
                     loss, *, damping: float = 1e-3, cfg=None, mesh=None,
                     shard_axes=("data",),
                     microbatches: Optional[int] = None,
                     cg_tol: float = 1e-8, cg_maxiter: int = 200,
                     rng=None) -> InfluenceResult:
    """Influence of every train point on every test point.

    Returns ``scores[i, j] = ∇ℓ_train_iᵀ (G + δI)⁻¹ ∇ℓ_test_j`` with one
    batched CG solve over the test gradients (the cheap side: solves
    scale with N_test, the full train set only streams through
    ``BatchGrad`` rows and GGN-vector products).
    """
    n_train = _batch_rows(x_train)
    cfg = _with_microbatches(cfg, n_train, microbatches)
    with obs.span("ntk_apps/influence", n_train=n_train,
                  n_test=_batch_rows(x_test),
                  microbatches=microbatches or 1):
        g_test = per_sample_grads(model, params, x_test, y_test, loss,
                                  cfg=cfg, mesh=mesh, shard_axes=shard_axes,
                                  microbatches=microbatches, rng=rng)
        with obs.span("ntk_apps/influence/solve"):
            sol = _solve_curvature(model, params, x_train, y_train, loss,
                                   g_test, damping=damping, cfg=cfg,
                                   mesh=mesh, shard_axes=shard_axes,
                                   cg_tol=cg_tol, cg_maxiter=cg_maxiter)
        g_train = per_sample_grads(model, params, x_train, y_train, loss,
                                   cfg=cfg, mesh=mesh,
                                   shard_axes=shard_axes,
                                   microbatches=microbatches, rng=rng)
        scores = _dots(g_train, sol.x)
    return InfluenceResult(scores=scores, iters=sol.iters, resid=sol.resid)


def self_influence(model, params, x_train, y_train, loss, *,
                   damping: float = 1e-3, cfg=None, mesh=None,
                   shard_axes=("data",),
                   microbatches: Optional[int] = None,
                   cg_tol: float = 1e-8, cg_maxiter: int = 200,
                   rng=None) -> InfluenceResult:
    """``s_i = ∇ℓ_iᵀ (G + δI)⁻¹ ∇ℓ_i`` for every train point.

    The memorization / mislabel-detection score: hard or atypical points
    move the optimum most on their own behalf.  One batched CG solve with
    the train gradients as right-hand sides.
    """
    n_train = _batch_rows(x_train)
    cfg = _with_microbatches(cfg, n_train, microbatches)
    with obs.span("ntk_apps/self_influence", n_train=n_train,
                  microbatches=microbatches or 1):
        g_train = per_sample_grads(model, params, x_train, y_train, loss,
                                   cfg=cfg, mesh=mesh,
                                   shard_axes=shard_axes,
                                   microbatches=microbatches, rng=rng)
        with obs.span("ntk_apps/influence/solve"):
            sol = _solve_curvature(model, params, x_train, y_train, loss,
                                   g_train, damping=damping, cfg=cfg,
                                   mesh=mesh, shard_axes=shard_axes,
                                   cg_tol=cg_tol, cg_maxiter=cg_maxiter)
        rows = jnp.stack([
            jnp.sum(g.reshape(n_train, -1) * s.reshape(n_train, -1), axis=1)
            for g, s in zip(jax.tree.leaves(g_train),
                            jax.tree.leaves(sol.x))])
        scores = jnp.sum(rows, axis=0)
    return InfluenceResult(scores=scores, iters=sol.iters, resid=sol.resid)
