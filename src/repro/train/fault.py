"""Fault tolerance: watchdog, straggler detection, failure injection,
restart-with-resume driver.

On a real pod the watchdog feeds the cluster scheduler (kill + reschedule);
here it raises/records so the restart path is exercised end-to-end in
tests.  Elasticity comes from checkpoint.restore re-sharding onto whatever
mesh the restarted process brings up.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro import obs


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raise at a given step — wire into the loop to test restarts."""

    fail_at_step: Optional[int] = None
    fail_once: bool = True
    _fired: bool = False

    def check(self, step: int):
        if (self.fail_at_step is not None and step == self.fail_at_step
                and not (self.fail_once and self._fired)):
            self._fired = True
            raise SimulatedFailure(f"injected failure at step {step}")


class Watchdog:
    """Track step durations; flag stragglers (> factor × running median)
    and stalls (no heartbeat for `stall_s`)."""

    def __init__(self, straggler_factor=3.0, stall_s=600.0, window=64):
        self.factor = straggler_factor
        self.stall_s = stall_s
        self.window = window
        self.durations = []
        self.straggler_steps = []
        self.last_beat = time.perf_counter()

    def beat(self, step: int, duration_s: float):
        self.last_beat = time.perf_counter()
        self.durations.append(duration_s)
        if len(self.durations) > self.window:
            self.durations.pop(0)
        med = sorted(self.durations)[len(self.durations) // 2]
        if len(self.durations) >= 8 and duration_s > self.factor * med:
            self.straggler_steps.append(step)
            return False
        return True

    def stalled(self):
        return (time.perf_counter() - self.last_beat) > self.stall_s


def run_with_restarts(make_and_run: Callable[[Optional[int]], int],
                      max_restarts: int = 3, on_restart=None):
    """Drive ``make_and_run(resume_step)`` to completion across failures.

    ``make_and_run`` must: restore from its checkpoint dir when
    ``resume_step`` is not None, run, and return the final step.  Any
    exception triggers restore-from-latest + retry, up to ``max_restarts``.
    """
    restarts = 0
    resume = None
    while True:
        try:
            return make_and_run(resume), restarts
        except Exception as e:  # noqa: BLE001 — any fault triggers restart
            restarts += 1
            obs.count("fault.restarts")
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)
            resume = -1  # sentinel: restore from latest


def run_sweep_with_restarts(plan, model, params, inputs, targets, loss,
                            checkpointer, *, cfg=None, rng=None,
                            checkpoint_every: int = 1,
                            max_restarts: int = 3, injector=None,
                            on_restart=None):
    """Drive a checkpointed sweep to completion across failures.

    The sweep-level sibling of :func:`run_with_restarts`: each attempt
    calls ``plan.run_checkpointed(..., resume=True)`` — the first attempt
    is a cold start, every retry restores the latest snapshot from
    ``checkpointer`` and continues at the interrupted work unit, so the
    finished Results are identical to an uninterrupted sweep (the
    resume-exactness contract of ``repro.core.engine.SweepStream``).
    Because snapshots are mesh-elastic, a retry may even bring up a
    different device mesh (rebuild ``plan`` accordingly before calling).

    Parameters
    ----------
    plan : repro.core.AccumulatedSweepPlan
        The streaming sweep to run (optionally sharded).
    checkpointer : repro.train.checkpoint.SweepCheckpointer
        Snapshot store shared by every attempt.
    injector : FailureInjector, optional
        Deterministic mid-stream kill for tests (checked per work unit).
    on_restart : callable, optional
        ``on_restart(restart_index, exception)`` before each retry.

    Returns
    -------
    (Results, int)
        The finished sweep results and the number of restarts taken.
    """
    restarts = 0
    while True:
        try:
            res = plan.run_checkpointed(
                model, params, inputs, targets, loss, cfg=cfg, rng=rng,
                checkpointer=checkpointer, checkpoint_every=checkpoint_every,
                injector=injector, resume=True)
            return res, restarts
        except Exception as e:  # noqa: BLE001 — any fault triggers restart
            restarts += 1
            obs.count("fault.sweep_restarts")
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)
