"""Training loop: jit step + synthetic data + checkpoint + watchdog.

Small enough to run on CPU for examples/tests, structured like the real
thing: deterministic step-indexed data (resume needs no iterator state),
periodic atomic checkpoints, straggler watchdog, failure injection hook,
and the restart driver from ``fault.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import CrossEntropyLoss, ExtensionConfig
from repro.data.synthetic import batch_for
from repro.train import checkpoint as ckpt
from repro.train.fault import FailureInjector, Watchdog
from repro.train.step import make_extended_train_step, make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_keep: int = 3      # newest checkpoints retained (must be >= 1)
    log_every: int = 10
    seed: int = 0
    batch_override: Optional[int] = None
    # Online marginal-likelihood callback (repro.laplace): every
    # ``marglik_every`` steps, fit a last-layer Laplace posterior on the
    # current batch (MC curvature — LM vocabularies rule out the exact
    # factor) and tune the prior precision by evidence ascent.  The
    # evidence and tuned prior land in that step's metrics/history —
    # curvature-backed generalization telemetry riding the training loop.
    marglik_every: Optional[int] = None
    marglik_structure: str = "kron"   # 'diag' | 'kron'
    marglik_steps: int = 20           # evidence-ascent steps per callback


def fit(model, cfg, shape, opt, loop: LoopConfig,
        extensions: Sequence = (), ext_cfg: Optional[ExtensionConfig] = None,
        injector: Optional[FailureInjector] = None, resume: bool = False,
        log_fn: Callable = print, track: Sequence[str] = (),
        mesh=None, shard_axes=("data",), step_fn: Optional[Callable] = None):
    """Train `model` (built from arch config `cfg`) on synthetic data.

    With ``mesh`` the extended step runs the batch-sharded sweep lane
    (``SweepPlan.shard``) — same numbers, N devices.  With
    ``ext_cfg=ExtensionConfig(microbatch_size=...)`` the step streams each
    batch through the accumulated lane (``SweepPlan.accumulate``): the
    extended step folds every extension's sequential reducer along, and
    the plain step falls back to classic lax.scan gradient accumulation —
    either way the loop serves effective batches beyond device memory.

    With ``step_fn`` the step builders are bypassed for a prebuilt
    extended-signature step ``(params, opt_state, batch, step_idx, rng)``
    — how whole-step optimizers plug in (e.g. ``optim.make_cg_ngd_step``,
    whose implicit solve needs the batch, not just the gradient);
    ``opt.init`` still builds the state."""
    loss = CrossEntropyLoss()
    params = model.init(jax.random.PRNGKey(loop.seed))
    opt_state = opt.init(params)
    start_step = 0
    if resume and loop.ckpt_dir:
        last = ckpt.latest_step(loop.ckpt_dir)
        if last is not None:
            params, opt_state, manifest = ckpt.restore(
                loop.ckpt_dir, last, params, opt_state)
            start_step = manifest["step"]
            log_fn(f"[resume] step {start_step}")

    prebuilt = step_fn is not None
    if prebuilt:
        step_fn = jax.jit(step_fn)
    elif extensions:
        step_fn = jax.jit(make_extended_train_step(
            model, loss, opt, extensions, ext_cfg, track=track,
            mesh=mesh, shard_axes=shard_axes))
    else:
        microbatch = 1
        if ext_cfg is not None and ext_cfg.microbatch_size:
            nb = loop.batch_override or shape.global_batch
            k = max(1, -(-nb // ext_cfg.microbatch_size))
            microbatch = k
            while nb % microbatch:  # make_train_step needs even slices
                microbatch += 1
            if microbatch != k:
                # e.g. prime nb: the only even split ≥ k may be far finer
                # than asked — stay memory-safe but say so (the extended
                # path handles uneven slices exactly; this one reshapes).
                log_fn(f"[accumulate] batch {nb} has no even split into "
                       f"≤{ext_cfg.microbatch_size}-sample slices; using "
                       f"{microbatch} microbatches of {nb // microbatch}")
        step_fn = jax.jit(make_train_step(model, loss, opt,
                                          microbatch=microbatch))

    wd = Watchdog()
    history = []
    marglik_ok = True  # flips off after the first unsupported-model error
    for step in range(start_step, loop.steps):
        if injector is not None:
            injector.check(step)
        batch = batch_for(cfg, shape, step, seed=loop.seed,
                          batch=loop.batch_override)
        # perf_counter is the one wall clock for durations (monotonic on
        # every platform, highest resolution) — the obs span uses it too
        t0 = time.perf_counter()
        with obs.span("train/step", step=step):
            if extensions or prebuilt:
                rng = jax.random.fold_in(jax.random.PRNGKey(loop.seed + 1),
                                         step)
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch, jnp.int32(step), rng)
            else:
                params, opt_state, metrics = step_fn(
                    params, opt_state, batch, jnp.int32(step))
            metrics = {k: float(v) for k, v in metrics.items()}
        dur = time.perf_counter() - t0
        stalled = wd.stalled()  # gap since the previous beat, pre-beat
        ok = wd.beat(step, dur)
        # per-step duration + watchdog state ride the history so post-hoc
        # analysis needs no log scraping
        metrics["dur_s"] = dur
        metrics["stalled"] = float(stalled)
        metrics["straggler"] = float(not ok)
        obs.count("train.steps")
        if not ok:
            obs.count("train.watchdog.straggler")
        if (loop.marglik_every and marglik_ok
                and (step + 1) % loop.marglik_every == 0):
            marglik_ok = _marglik_callback(model, params, batch, loss, loop,
                                           step, metrics, log_fn)
        history.append(metrics)
        if step % loop.log_every == 0:
            log_fn(f"step {step:5d} loss {metrics['loss']:.4f} "
                   f"({dur*1e3:.0f} ms)")
        if loop.ckpt_dir and (step + 1) % loop.ckpt_every == 0:
            ckpt.save(loop.ckpt_dir, step + 1, params, opt_state,
                      keep=loop.ckpt_keep)
    if loop.ckpt_dir:
        ckpt.save(loop.ckpt_dir, loop.steps, params, opt_state,
                  keep=loop.ckpt_keep)
    return params, opt_state, history, wd


def fit_with_restarts(model, cfg, shape, opt, loop: LoopConfig,
                      max_restarts: int = 3, on_restart=None, **kw):
    """:func:`fit` under the restart driver: any fault (injected or real)
    triggers restore-from-latest-checkpoint + retry, up to
    ``max_restarts``.  ``loop.ckpt_dir`` must be set — without it a
    restart would silently retrain from scratch.  Returns
    ``((params, opt_state, history, watchdog), restarts)``."""
    if not loop.ckpt_dir:
        raise ValueError("fit_with_restarts needs loop.ckpt_dir — a "
                         "restart without checkpoints retrains from "
                         "scratch")
    from repro.train.fault import run_with_restarts

    def make_and_run(resume):
        return fit(model, cfg, shape, opt, loop,
                   resume=resume is not None, **kw)

    return run_with_restarts(make_and_run, max_restarts=max_restarts,
                             on_restart=on_restart)


def _marglik_callback(model, params, batch, loss, loop: LoopConfig, step,
                      metrics, log_fn) -> bool:
    """Fit + tune a last-layer Laplace posterior on the current batch and
    record the evidence; returns False (disabling the callback) when the
    model structure is unsupported."""
    from repro import laplace

    try:
        post = laplace.fit_posterior(
            model, params, batch["inputs"], batch["labels"], loss,
            structure=loop.marglik_structure, last_layer=True,
            options=laplace.FitOptions(
                mc=True, cfg=ExtensionConfig(mc_seed=loop.seed + step)))
    except laplace.LaplaceStructureError as e:
        log_fn(f"[marglik] disabled: {e}")
        return False
    post, res = laplace.optimize_marglik(post, n_steps=loop.marglik_steps)
    metrics["marglik"] = float(laplace.log_marglik(post))
    metrics["prior_prec"] = res.prior_prec
    log_fn(f"[marglik] step {step:5d} log-evidence {metrics['marglik']:.1f} "
           f"prior_prec {res.prior_prec:.3g}")
    return True
