"""Sharded-friendly npz checkpoints: atomic, keep-k, mesh-elastic.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json  (tmp-dir + rename for
atomicity — a crashed save can never shadow a good checkpoint).

Arrays are stored device-agnostic (gathered to host); ``restore`` re-shards
onto whatever mesh the restarted job brings up — elastic re-scaling across
restarts (e.g. 512 → 256 chips after losing a pod) "just works" because the
sharding is reapplied from the current rules, not recorded ones.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save(path, step, params, opt_state=None, extra=None, keep=3):
    os.makedirs(path, exist_ok=True)
    state = {"params": params}
    if opt_state is not None:
        state["opt"] = opt_state
    flat, treedef = _flatten(state)
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_save_")
    try:
        arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(flat)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": int(step),
            "n_arrays": len(flat),
            "treedef": str(treedef),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(path, f"step_{int(step):08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(path, keep)
    return final


def _gc(path, keep):
    steps = sorted(
        d for d in os.listdir(path)
        if d.startswith("step_") and os.path.isdir(os.path.join(path, d))
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path):
    if not os.path.isdir(path):
        return None
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(path)
        if d.startswith("step_") and
        os.path.exists(os.path.join(path, d, "manifest.json"))
    )
    return steps[-1] if steps else None


def restore(path, step, params_like, opt_like=None, shardings=None):
    """Load into the structure of ``params_like``/``opt_like``; if
    ``shardings`` (matching pytree of NamedSharding) is given, device_put
    each leaf — this is where elastic re-sharding happens."""
    d = os.path.join(path, f"step_{int(step):08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    state_like = {"params": params_like}
    if opt_like is not None:
        state_like["opt"] = opt_like
    flat_like, treedef = _flatten(state_like)
    if len(flat_like) != manifest["n_arrays"]:
        raise ValueError(
            f"checkpoint has {manifest['n_arrays']} arrays; target structure "
            f"expects {len(flat_like)} — config mismatch?")
    flat = []
    for i, l in enumerate(flat_like):
        arr = np.asarray(data[f"a{i}"])
        if hasattr(l, "dtype"):
            arr = arr.astype(l.dtype)
        flat.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, flat)
    if shardings is not None:
        for key in list(state):
            sh = shardings.get(key if key != "opt" else "opt")
            if sh is not None:
                state[key] = jax.tree.map(jax.device_put, state[key], sh)
    out = [state["params"], manifest]
    if opt_like is not None:
        out.insert(1, state["opt"])
    return tuple(out)
