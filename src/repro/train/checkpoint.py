"""Sharded-friendly npz checkpoints: atomic, keep-k, mesh-elastic.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json  (tmp-dir + rename for
atomicity — a crashed save can never shadow a good checkpoint).

Arrays are stored device-agnostic (gathered to host); ``restore`` re-shards
onto whatever mesh the restarted job brings up — elastic re-scaling across
restarts (e.g. 512 → 256 chips after losing a pod) "just works" because the
sharding is reapplied from the current rules, not recorded ones.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np

from repro import obs


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save(path, step, params, opt_state=None, extra=None, keep=3):
    if keep < 1:
        # Fail before any disk work: keep=0 used to slice steps[:-0] == []
        # in _gc and silently keep everything; a save must always retain
        # at least the checkpoint it is about to write.
        raise ValueError(f"keep must be >= 1 (got {keep}) — a save always "
                         "retains at least the checkpoint it just wrote")
    os.makedirs(path, exist_ok=True)
    state = {"params": params}
    if opt_state is not None:
        state["opt"] = opt_state
    flat, treedef = _flatten(state)
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_save_")
    with obs.span("ckpt/save", step=int(step)) as sp:
        try:
            arrays = {f"a{i}": np.asarray(x) for i, x in enumerate(flat)}
            sp.set(bytes=sum(int(a.nbytes) for a in arrays.values()),
                   arrays=len(arrays))
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": int(step),
                "n_arrays": len(flat),
                "treedef": str(treedef),
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(path, f"step_{int(step):08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        with obs.span("ckpt/gc", keep=int(keep)):
            _gc(path, keep)
    obs.count("ckpt.saves")
    return final


def _gc(path, keep):
    """Prune to the newest ``keep`` checkpoints and sweep crash debris.

    ``keep`` must be >= 1: the slice below would turn ``keep=0`` into
    ``steps[:-0] == []`` and silently keep everything, so the degenerate
    value is rejected instead of misread (delete-all is never what a
    retention policy means mid-save).

    Stale ``.tmp_save_*`` directories are also removed here: a process
    killed between ``mkdtemp`` and the atomic rename leaves its tmp dir
    behind forever (the in-process cleanup only covers exceptions), and
    they are invisible to the ``step_*`` pruning above — any tmp dir
    still present when a later save garbage-collects is by construction
    an orphan (the current save renamed its own away first).
    """
    if keep < 1:
        raise ValueError(f"keep must be >= 1 (got {keep})")
    steps = sorted(
        d for d in os.listdir(path)
        if d.startswith("step_") and os.path.isdir(os.path.join(path, d))
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)
    for d in os.listdir(path):
        if d.startswith(".tmp_save_") and os.path.isdir(
                os.path.join(path, d)):
            shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path):
    if not os.path.isdir(path):
        return None
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(path)
        if d.startswith("step_") and
        os.path.exists(os.path.join(path, d, "manifest.json"))
    )
    return steps[-1] if steps else None


def restore(path, step, params_like, opt_like=None, shardings=None):
    """Load into the structure of ``params_like``/``opt_like``; if
    ``shardings`` (matching pytree of NamedSharding) is given, device_put
    each leaf — this is where elastic re-sharding happens."""
    d = os.path.join(path, f"step_{int(step):08d}")
    with obs.span("ckpt/restore", step=int(step),
                  bytes=os.path.getsize(os.path.join(d, "arrays.npz"))):
        obs.count("ckpt.restores")
        return _restore(d, step, params_like, opt_like, shardings)


def _restore(d, step, params_like, opt_like, shardings):
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    state_like = {"params": params_like}
    if opt_like is not None:
        state_like["opt"] = opt_like
    flat_like, treedef = _flatten(state_like)
    if len(flat_like) != manifest["n_arrays"]:
        raise ValueError(
            f"checkpoint has {manifest['n_arrays']} arrays; target structure "
            f"expects {len(flat_like)} — config mismatch?")
    # Leaf count alone would happily zip a same-length but differently
    # shaped target into the wrong leaves (and the dtype cast below would
    # mask the drift): require the recorded tree structure and validate
    # every leaf's shape, naming the first offender.
    saved_treedef = manifest.get("treedef")
    if saved_treedef is not None and saved_treedef != str(treedef):
        raise ValueError(
            "checkpoint tree structure does not match the target "
            f"structure ({manifest['n_arrays']} leaves in both — config "
            "mismatch?)\n"
            f"  saved:  {saved_treedef}\n"
            f"  target: {treedef}")
    flat_paths = [
        jax.tree_util.keystr(kp)
        for kp, _ in jax.tree_util.tree_flatten_with_path(state_like)[0]
    ]
    flat = []
    for i, l in enumerate(flat_like):
        arr = np.asarray(data[f"a{i}"])
        want = tuple(getattr(l, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(
                f"checkpoint leaf {flat_paths[i]!r} (array {i} of "
                f"step_{int(step):08d}) has shape {tuple(arr.shape)}; the "
                f"target structure expects {want} — first mismatching "
                "leaf; was the model/optimizer config changed between "
                "save and restore?")
        if hasattr(l, "dtype"):
            arr = arr.astype(l.dtype)
        flat.append(arr)
    state = jax.tree_util.tree_unflatten(treedef, flat)
    if shardings is not None:
        for key in list(state):
            sh = shardings.get(key if key != "opt" else "opt")
            if sh is not None:
                state[key] = jax.tree.map(jax.device_put, state[key], sh)
    out = [state["params"], manifest]
    if opt_like is not None:
        out.insert(1, state["opt"])
    return tuple(out)


class SweepCheckpointer:
    """On-disk snapshot store for preemption-safe sweep streams.

    The duck-typed checkpointer ``repro.core.engine`` drives (core never
    imports train, so the engine only sees this interface):

    * ``save(cursor, state, meta)`` — snapshot a
      ``SweepStream.state_arrays()`` pytree at work-unit ``cursor``,
      with the stream's ``schedule_meta()`` dict riding in the manifest.
    * ``restore_latest(state_like) -> (cursor, state, meta) | None`` —
      load the newest snapshot into the structure of ``state_like``
      (``None`` on a cold start).

    Snapshots reuse the module's atomic ``step_<cursor>`` layout, so
    they inherit the crash-safe rename, keep-k pruning, tmp-dir sweeping
    and strict treedef/shape validation above.  Arrays are stored
    device-agnostic; re-ingestion onto the resuming process's (possibly
    different) mesh happens in ``SweepStream.load_state`` — elastic
    re-sharding for sweeps.

    Parameters
    ----------
    path : str
        Snapshot directory (created on first save).
    keep : int
        Newest snapshots retained (>= 1); 2 by default so one corrupt
        final write still leaves a resumable predecessor.
    """

    def __init__(self, path, keep: int = 2):
        if keep < 1:
            raise ValueError(f"keep must be >= 1 (got {keep})")
        self.path = str(path)
        self.keep = int(keep)

    def save(self, cursor, state, meta=None):
        return save(self.path, int(cursor), jax.device_get(state),
                    extra={"sweep": meta or {}}, keep=self.keep)

    def latest(self):
        """Newest snapshot cursor, or None when no snapshot exists."""
        return latest_step(self.path)

    def restore_latest(self, state_like):
        cursor = latest_step(self.path)
        if cursor is None:
            return None
        state, manifest = restore(self.path, cursor, state_like)
        return cursor, state, manifest.get("extra", {}).get("sweep", {})
