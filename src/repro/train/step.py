"""Step builders: train (grad / grad+extensions), prefill, decode.

``make_train_step`` is the production path: ``jax.grad`` backward (XLA's
fused backprop) + optimizer.  ``make_extended_train_step`` runs the
BackPACK engine instead, harvesting extension quantities in the same sweep
— used by the curvature-preconditioned optimizer (paper §4) and the noise-
scale/variance telemetry.

Options map to the §Perf hillclimb levers:
  * ``microbatch`` — gradient accumulation via lax.scan (activation memory
    ÷ microbatches; the per-microbatch psum overlaps the next microbatch's
    compute under XLA's latency-hiding scheduler).  The extended step gets
    the same lever from ``ExtensionConfig(microbatch_size=...)``, which
    routes through the engine's ``SweepPlan.accumulate`` lane so the
    accumulation carries every extension statistic along, exactly,
  * ``remat``     — rematerialize each block (checkpoint policy),
  * ``seq_shard_axis`` — Megatron-style sequence sharding of the residual
    stream between blocks (activation memory ÷ |model|).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import ExtensionConfig
from repro.core import engine as eng
from repro.optim.optimizers import apply_updates


def make_loss_fn(model, loss, remat=False):
    def loss_fn(params, inputs, labels):
        apply = model.apply
        if remat:
            apply = jax.checkpoint(apply)
        z = apply(params, inputs)
        return loss.value(z, labels)

    return loss_fn


def make_train_step(model, loss, opt, *, microbatch: int = 1,
                    remat: bool = False, grad_dtype=None):
    loss_fn = make_loss_fn(model, loss, remat=remat)

    def single(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch["inputs"], batch["labels"])

    def accumulate(params, batch):
        def reshape(x):
            return x.reshape((microbatch, x.shape[0] // microbatch) + x.shape[1:])

        mb = jax.tree.map(reshape, batch)

        def body(carry, b):
            lv, g = jax.value_and_grad(loss_fn)(params, b["inputs"], b["labels"])
            if grad_dtype is not None:
                g = jax.tree.map(lambda a: a.astype(grad_dtype), g)
            acc_l, acc_g = carry
            return (acc_l + lv, jax.tree.map(jnp.add, acc_g, g)), None

        zero_g = jax.tree.map(
            lambda p: jnp.zeros(p.shape, grad_dtype or jnp.float32), params
        )
        with jax.named_scope(f"mbscan_T{microbatch}"):
            (lv, g), _ = jax.lax.scan(body, (jnp.float32(0), zero_g), mb)
        scale = 1.0 / microbatch
        return lv * scale, jax.tree.map(lambda a: a * scale, g)

    fwd_bwd = single if microbatch == 1 else accumulate

    def step(params, opt_state, batch, step_idx):
        lv, grads = fwd_bwd(params, batch)
        ups, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, ups)
        return params, opt_state, {"loss": lv, "step": step_idx + 1}

    return step


def make_extended_train_step(model, loss, opt, extensions,
                             cfg: Optional[ExtensionConfig] = None,
                             track: Sequence[str] = (),
                             mesh=None, shard_axes=("data",)):
    """Engine-backed step: gradient + extensions in one generalized
    backprop; curvature goes to the optimizer (Eq. 7), tracked scalars
    (e.g. mean variance → gradient-noise telemetry) go to metrics.

    With ``mesh`` the sweep routes through the batch-sharded lane
    (``SweepPlan.shard`` over ``shard_axes``) — fused kernels on each
    device's batch shard, statistic-aware cross-shard reduction — and the
    step is numerically identical on 1 or N devices.

    With ``cfg.microbatch_size`` the sweep additionally routes through the
    streaming accumulated lane (``SweepPlan.accumulate`` — gradient
    accumulation that carries every extension along): each device
    processes its batch in sequential slices of at most
    ``microbatch_size`` samples with per-extension sequential reducers,
    so effective batches far beyond device memory produce the identical
    step.  Both compose: ``mesh`` + ``microbatch_size`` is the shard ×
    accumulate grid (shards whose local rows already fit the bound
    accumulate nothing).
    """
    cfg = cfg or ExtensionConfig()
    ext_names = {e.name for e in extensions}
    curv_name = next(
        (n for n in ("kfac", "kflr", "diag_ggn_mc", "diag_ggn", "kfra",
                     "diag_hessian") if n in ext_names), None)

    def sweep(params, batch, rng):
        n = jax.tree.leaves(batch["inputs"])[0].shape[0]
        plan = eng.plan_for_batch(extensions, cfg, n, mesh=mesh,
                                  shard_axes=shard_axes)
        return plan.run(model, params, batch["inputs"], batch["labels"],
                        loss, cfg=cfg, rng=rng)

    def step(params, opt_state, batch, step_idx, rng):
        res = sweep(params, batch, rng)
        kw = {}
        if curv_name is not None:
            kw["curv"] = res.ext[curv_name]
        ups, new_opt = opt.update(res.grads, opt_state, params, **kw)
        params = apply_updates(params, ups)
        metrics = {"loss": res.loss, "step": step_idx + 1}
        for name in track:
            tree = res.ext.get(name)
            if tree is not None:
                leaves = [l for l in jax.tree.leaves(tree)]
                if leaves:
                    metrics[f"{name}_mean"] = sum(
                        jnp.mean(l.astype(jnp.float32)) for l in leaves
                    ) / len(leaves)
        return params, opt_state, metrics

    return step


def make_prefill_step(model):
    def prefill(params, inputs):
        z = model.apply(params, inputs)
        return z[:, -1, :]

    return prefill


def make_decode_step(model):
    def decode(params, caches, tokens, pos):
        logits, caches = model.serve_step(params, caches, tokens, pos)
        return logits, caches

    return decode
