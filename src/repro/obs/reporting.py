"""Render recorded events into the human per-phase summary table.

``render`` is the one rendering path: the registry's ``report()`` feeds it
the in-memory event list, and ``tools/obs_report.py`` feeds it a JSONL
trace loaded with ``load_jsonl``.  Spans aggregate by their *path* (the
nesting stack of span names), so the output mirrors ``describe()``'s plan
tree — but with measured wall time, call counts, and summed numeric attrs
(bytes, rows) instead of the planned schedule.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Tuple

__all__ = ["load_jsonl", "render"]

# attr keys with additive semantics — the only ones worth summing across
# a span's calls (summing identifiers like `step` or `t` reads as garbage)
_SUM_KEYS = frozenset({"bytes", "rows", "tokens", "arrays", "n"})


def load_jsonl(path: str) -> List[Dict[str, Any]]:
    """Load a JSONL trace file back into an event list.

    Tolerates a truncated final line (preempted run mid-write)."""
    events: List[Dict[str, Any]] = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                events.append(json.loads(line))
            except json.JSONDecodeError:
                break  # torn tail write — keep everything before it
    return events


def _fmt_s(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:.3f} s"
    if seconds >= 1e-3:
        return f"{seconds * 1e3:.2f} ms"
    return f"{seconds * 1e6:.1f} us"


def render(events: List[Dict[str, Any]]) -> str:
    """Aggregate events into the span tree + counters + gauges tables."""
    # path-tuple -> [calls, total_s, {attr: sum}]
    agg: Dict[Tuple[str, ...], List[Any]] = {}
    order: List[Tuple[str, ...]] = []  # first-seen order, parents first
    counters: Dict[str, float] = {}
    gauges: Dict[str, float] = {}
    for ev in events:
        kind = ev.get("kind")
        if kind == "span":
            path = tuple(ev.get("path") or [ev.get("name", "?")])
            # register ancestors so a child seen before its parent closed
            # still renders under it
            for i in range(1, len(path) + 1):
                prefix = path[:i]
                if prefix not in agg:
                    agg[prefix] = [0, 0.0, {}]
                    order.append(prefix)
            row = agg[path]
            row[0] += 1
            row[1] += float(ev.get("dur_s", 0.0))
            for k, v in (ev.get("attrs") or {}).items():
                if (k in _SUM_KEYS and isinstance(v, (int, float))
                        and not isinstance(v, bool)):
                    row[2][k] = row[2].get(k, 0) + v
        elif kind == "count":
            counters[ev["name"]] = counters.get(ev["name"], 0) + ev.get("value", 1)
        elif kind == "gauge":
            gauges[ev["name"]] = ev.get("value")

    lines: List[str] = []
    if agg:
        # render depth-first so children sit under their parents
        first_seen = {p: i for i, p in enumerate(order)}
        order.sort(
            key=lambda p: tuple(first_seen[p[: i + 1]] for i in range(len(p)))
        )
        name_w = max(
            [2 + 2 * (len(p) - 1) + len(p[-1]) for p in order] + [len("span")]
        )
        lines.append(
            f"{'span':<{name_w}}  {'calls':>6}  {'total':>10}  {'mean':>10}"
        )
        for path in order:
            calls, total, attrs = agg[path]
            indent = "  " * (len(path) - 1)
            label = f"{indent}{path[-1]}"
            if calls == 0:  # ancestor never closed (still open / crashed)
                lines.append(f"{label:<{name_w}}  {'-':>6}  {'-':>10}  {'-':>10}")
                continue
            mean = total / calls
            row = (
                f"{label:<{name_w}}  {calls:>6d}  {_fmt_s(total):>10}  "
                f"{_fmt_s(mean):>10}"
            )
            extras = "  ".join(
                f"{k}={attrs[k]:g}" for k in sorted(attrs)
            )
            lines.append(row + ("  " + extras if extras else ""))
    if counters:
        lines.append("")
        lines.append("counters")
        for name in sorted(counters):
            lines.append(f"  {name} = {counters[name]:g}")
    if gauges:
        lines.append("")
        lines.append("gauges")
        for name in sorted(gauges):
            lines.append(f"  {name} = {gauges[name]:g}")
    if not lines:
        return "no events recorded"
    return "\n".join(lines)
