"""Trace/metrics registry: spans, counters, gauges, pluggable sinks.

The registry is the single recording surface for the whole runtime.  Three
primitives cover every instrumentation site:

* ``span(name, **attrs)`` — a context-manager wall-clock timer on
  ``time.perf_counter``.  Spans nest: each records its *path* (the stack of
  enclosing span names), so ``report()`` can render the measured tree the
  same way ``describe()`` renders the planned one.
* ``count(name, value=1)`` — a monotonic counter (kernel invocations,
  cache hits, restarts, padding-waste bytes).
* ``gauge(name, value)`` — a last-value-wins sample (stream cursor,
  tokens/s).

Two sinks: the in-memory event list (always on when enabled) and an
optional JSONL file, one event per line, written as events occur so a
preempted run still leaves a readable trace.

Off-by-default-cheap: the module-level registry starts as
:class:`NullRegistry`, whose ``span`` returns a shared no-op context
manager and whose recorders are ``pass`` — a disabled call site costs one
attribute lookup and one no-op call, and allocates nothing.

jit-safety contract: instrumentation records on the *host*, at dispatch or
trace time.  Nothing here may be called with tracers as attr values —
``_clean`` coerces non-JSON scalars via ``str`` so a stray tracer can
never poison a sink, but hot paths are expected to pass static Python
scalars only.
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Dict, IO, Iterator, List, Optional, Tuple

__all__ = [
    "ObsRegistry",
    "NullRegistry",
    "enable",
    "disable",
    "get",
    "use",
    "span",
    "count",
    "gauge",
    "enabled",
    "report",
    "snapshot",
]

_JSON_SCALARS = (bool, int, float, str, type(None))


def _clean(value: Any) -> Any:
    """Coerce an attr value to a JSON-serialisable scalar."""
    if isinstance(value, _JSON_SCALARS):
        return value
    try:  # 0-d numpy / concrete jax scalars
        return float(value)
    except Exception:
        return str(value)


class _NullSpan:
    """Shared no-op span: context manager with a dead ``set``."""

    __slots__ = ()
    dur_s = 0.0

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class NullRegistry:
    """Disabled registry: every recorder is a no-op, ``span`` allocates
    nothing (always returns the same shared null span)."""

    enabled = False
    trace_path: Optional[str] = None

    @property
    def events(self) -> Tuple[Any, ...]:
        return ()

    @property
    def counters(self) -> Dict[str, float]:
        return {}

    @property
    def gauges(self) -> Dict[str, float]:
        return {}

    def span(self, name: str, /, **attrs: Any) -> _NullSpan:
        return _NULL_SPAN

    def count(self, name: str, value: float = 1) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def report(self) -> str:
        return "observability disabled — call obs.enable() to record"

    def snapshot(self) -> Dict[str, Any]:
        return {"enabled": False, "counters": {}, "gauges": {}, "events": 0}

    def close(self) -> None:
        pass


class Span:
    """A live span.  Created by :meth:`ObsRegistry.span`; use as a context
    manager.  ``set(**attrs)`` attaches attrs any time before exit (e.g. a
    byte count known only mid-body).  After exit, ``dur_s`` holds the
    measured duration."""

    __slots__ = ("_reg", "name", "attrs", "path", "t0", "dur_s")

    def __init__(self, reg: "ObsRegistry", name: str, attrs: Dict[str, Any]):
        self._reg = reg
        self.name = name
        self.attrs = attrs
        self.path: Tuple[str, ...] = ()
        self.t0 = 0.0
        self.dur_s = 0.0

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        stack = self._reg._stack
        self.path = (stack[-1].path if stack else ()) + (self.name,)
        stack.append(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> bool:
        end = time.perf_counter()
        self.dur_s = end - self.t0
        stack = self._reg._stack
        if stack and stack[-1] is self:
            stack.pop()
        self._reg._record_span(self, end)
        return False


class ObsRegistry:
    """Recording registry: in-memory event list plus optional JSONL sink.

    Parameters
    ----------
    trace_jsonl : str, optional
        Path of a JSONL trace file.  Every event (span end, counter bump,
        gauge sample) is appended as one JSON object per line, flushed
        immediately — a preempted run keeps its partial trace.
    """

    enabled = True

    def __init__(self, trace_jsonl: Optional[str] = None):
        self.events: List[Dict[str, Any]] = []
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self._stack: List[Span] = []
        self._t_origin = time.perf_counter()
        self.trace_path = trace_jsonl
        self._sink: Optional[IO[str]] = (
            open(trace_jsonl, "w") if trace_jsonl else None
        )

    # -- recording ---------------------------------------------------------
    def span(self, name: str, /, **attrs: Any) -> Span:
        return Span(self, name, {k: _clean(v) for k, v in attrs.items()})

    def count(self, name: str, value: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + value
        self._emit({"kind": "count", "name": name, "value": _clean(value)})

    def gauge(self, name: str, value: float) -> None:
        value = _clean(value)
        self.gauges[name] = value
        self._emit({"kind": "gauge", "name": name, "value": value})

    def _record_span(self, sp: Span, end: float) -> None:
        self._emit(
            {
                "kind": "span",
                "name": sp.name,
                "path": list(sp.path),
                "t": round(end - sp.dur_s - self._t_origin, 6),
                "dur_s": round(sp.dur_s, 9),
                "attrs": sp.attrs,
            }
        )

    def _emit(self, event: Dict[str, Any]) -> None:
        self.events.append(event)
        if self._sink is not None:
            json.dump(event, self._sink)
            self._sink.write("\n")
            self._sink.flush()

    # -- introspection -----------------------------------------------------
    def report(self) -> str:
        from repro.obs.reporting import render

        return render(self.events)

    def snapshot(self) -> Dict[str, Any]:
        return {
            "enabled": True,
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "events": len(self.events),
        }

    def close(self) -> None:
        if self._sink is not None:
            self._sink.close()
            self._sink = None


# -- module-level current registry ----------------------------------------
_REGISTRY: Any = NullRegistry()


def get() -> Any:
    """The current registry (NullRegistry when disabled)."""
    return _REGISTRY


def enabled() -> bool:
    return _REGISTRY.enabled


def enable(trace_jsonl: Optional[str] = None) -> ObsRegistry:
    """Install (and return) a fresh recording registry, optionally with a
    JSONL trace sink."""
    global _REGISTRY
    _REGISTRY = ObsRegistry(trace_jsonl=trace_jsonl)
    return _REGISTRY


def disable() -> None:
    """Close any sink and restore the no-op registry."""
    global _REGISTRY
    _REGISTRY.close()
    _REGISTRY = NullRegistry()


@contextmanager
def use(reg: Any) -> Iterator[Any]:
    """Temporarily swap in ``reg`` as the current registry (benchmark /
    test scoping without touching global state on exit paths)."""
    global _REGISTRY
    prev = _REGISTRY
    _REGISTRY = reg
    try:
        yield reg
    finally:
        _REGISTRY = prev


# -- convenience forwarders (the instrumentation call surface) -------------
def span(name: str, /, **attrs: Any):
    return _REGISTRY.span(name, **attrs)


def count(name: str, value: float = 1) -> None:
    _REGISTRY.count(name, value)


def gauge(name: str, value: float) -> None:
    _REGISTRY.gauge(name, value)


def report() -> str:
    return _REGISTRY.report()


def snapshot() -> Dict[str, Any]:
    return _REGISTRY.snapshot()
