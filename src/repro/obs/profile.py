"""``jax.profiler`` integration.

``obs.profile(dir)`` wraps ``jax.profiler.start_trace`` / ``stop_trace``
as a context manager (no-op when ``dir`` is falsy), so a device trace can
be captured around any region — the sweep drivers' ``jax.named_scope``
annotations (``accumscan_T{k}``, ``gramscan_T{n}``, per-sweep scopes in
``engine.run``) make the device timeline line up with obs spans.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Iterator, Optional

__all__ = ["profile"]


@contextmanager
def profile(trace_dir: Optional[str]) -> Iterator[None]:
    """Capture a ``jax.profiler`` device trace into ``trace_dir``.

    A falsy ``trace_dir`` makes this a no-op, so call sites can pass a CLI
    flag straight through.  The host-side span is recorded too, so the obs
    trace shows exactly which wall-clock window the device trace covers.
    """
    if not trace_dir:
        yield
        return
    import jax.profiler  # deferred: keep repro.obs import-light

    from repro.obs import registry as _reg

    jax.profiler.start_trace(trace_dir)
    try:
        with _reg.span("obs/profile", dir=str(trace_dir)):
            yield
    finally:
        jax.profiler.stop_trace()
