"""repro.obs — near-zero-overhead observability for every runtime layer.

Public surface::

    from repro import obs

    reg = obs.enable(trace_jsonl="trace.jsonl")   # start recording
    with obs.span("my/phase", n=64):              # perf_counter timer
        ...
    obs.count("my.counter")                       # monotonic counter
    obs.gauge("my.gauge", 0.5)                    # last-value sample
    print(obs.report())                           # measured span tree
    obs.disable()                                 # back to the no-op registry

    with obs.profile("/tmp/jax-trace"):           # jax.profiler capture
        ...

Disabled (the default) every call is a no-op on a shared
:class:`~repro.obs.registry.NullRegistry` — see ``docs/observability.md``
for the overhead gate that holds instrumented fused sweeps within 5% of
uninstrumented.
"""
from repro.obs.profile import profile
from repro.obs.registry import (
    NullRegistry,
    ObsRegistry,
    Span,
    count,
    disable,
    enable,
    enabled,
    gauge,
    get,
    report,
    snapshot,
    span,
    use,
)
from repro.obs.reporting import load_jsonl, render

__all__ = [
    "ObsRegistry",
    "NullRegistry",
    "Span",
    "enable",
    "disable",
    "get",
    "use",
    "span",
    "count",
    "gauge",
    "enabled",
    "report",
    "snapshot",
    "profile",
    "load_jsonl",
    "render",
]
