"""Explicit data-parallel step via shard_map: compressed all-reduce with
error feedback.

The implicit-SPMD path (jit + sharded batch) reduces gradients in f32
inside XLA's backward — there is no seam to compress at.  This step makes
the DP reduction *explicit*, and it is built on the engine's sharded-sweep
machinery (``engine.local_loss_and_grad``): the shard body runs the
scale-corrected local backward — each shard's gradient contribution
already carries the *global* 1/M normalization, exactly as inside
``SweepPlan.shard``'s lane — so the compressed psum is the only
distributed arithmetic left here.  Per-shard gradients are compressed to
bf16 with a per-shard error-feedback residual, psum'd over the data axes,
and decompressed — halving the dominant DP collective's bytes while the
accumulated update stays unbiased (error feedback, Karimireddy et al.
2019).  Riding the engine seam also fixes the mean-of-local-means loss:
``local_loss_and_grad`` psums the mask-aware unit counts, so the reported
loss is the exact global mean even with uneven padding across shards.

Scope: pure-DP over ('data',) / ('pod','data'); TP-sharded params use the
implicit path (their activation collectives are latency-bound, not
bandwidth-bound).  The error-feedback tree carries a leading shard axis
([D, *param_shape]) so each data shard keeps its own residual.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import engine as eng
from repro.distributed.compress import compress_with_ef
from repro.optim.optimizers import apply_updates


def init_ef_sharded(params, n_shards):
    return jax.tree.map(
        lambda p: jnp.zeros((n_shards,) + p.shape, jnp.float32), params)


def make_compressed_dp_step(model, loss, opt, mesh, data_axes=("data",)):
    batch_spec = jax.tree.map(lambda _: P(data_axes), {"inputs": 0, "labels": 0})

    def shard_body(params, ef, batch):
        # Scale-corrected local sweep (the sharded lane's seam): lv is the
        # exact global mean loss, g the shard's unreduced contribution to
        # the global gradient.
        lv, g = eng.local_loss_and_grad(
            model, params, batch["inputs"], batch["labels"], loss, data_axes)
        ef_local = jax.tree.map(lambda e: e[0], ef)
        comp, new_ef = compress_with_ef(g, ef_local)
        g_sum = jax.tree.map(
            lambda c: jax.lax.psum(c, data_axes).astype(jnp.float32), comp)
        new_ef = jax.tree.map(lambda e: e[None], new_ef)
        return lv, g_sum, new_ef

    smapped = shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(), jax.tree.map(lambda _: P(data_axes), 0), batch_spec),
        out_specs=(P(), P(), jax.tree.map(lambda _: P(data_axes), 0)),
        check_rep=False,
    )

    def step(params, opt_state, ef, batch):
        lv, g_sum, new_ef = smapped(params, ef, batch)
        ups, opt_state = opt.update(g_sum, opt_state, params)
        params = apply_updates(params, ups)
        return params, opt_state, new_ef, lv

    return step
