"""Distributed K-FAC plumbing (Tsuji et al. 2019 / Osawa et al. style).

Under implicit SPMD the Kronecker factors computed by the engine are
already batch-global (the data-axis reduction is fused into the stats
einsums).  What remains distributed-specific:

  * ``shard_factor_inverses`` — the L per-layer factor inversions are
    embarrassingly parallel; constraining the stacked [L, a, a] factors to
    be sharded over the *data* axis makes each data shard invert L/D of
    them (round-robin inversion), after which the preconditioned updates
    are re-gathered by XLA.  The model axis is left alone — it is busy with
    TP activations.
  * ``compress_factors`` — factors are synced in bf16 (they are curvature
    *statistics*; EMA smoothing in the optimizer absorbs the rounding).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.module import is_axes


def shard_factor_inverses(curv_tree, mesh, axis="data"):
    """Apply a sharding constraint over the leading (layer-stack) axis of
    every stacked Kronecker factor so inversions are distributed."""
    size = mesh.shape[axis]

    def constrain(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 3:
            return leaf
        if leaf.shape[0] % size != 0:
            return leaf
        spec = P(axis, *([None] * (leaf.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    return jax.tree.map(constrain, curv_tree)


def compress_factors(curv_tree):
    return jax.tree.map(
        lambda x: x.astype(jnp.bfloat16).astype(jnp.float32)
        if hasattr(x, "astype") else x,
        curv_tree)
