"""Distributed K-FAC plumbing (Tsuji et al. 2019 / Osawa et al. style).

The factor *computation* now rides the engine's batch-sharded sweep lane
(``SweepPlan.shard``): each data shard runs the fused curvature kernels on
its local batch and the extensions' ``reduce`` specs psum/pmean the
Kronecker factors to their exact batch-global values —
:func:`make_dist_kfac_step` is the end-to-end step built on it.  What
remains distributed-specific here:

  * ``shard_factor_inverses`` — the L per-layer factor inversions are
    embarrassingly parallel; constraining the stacked [L, a, a] factors to
    be sharded over the *data* axis makes each data shard invert L/D of
    them (round-robin inversion), after which the preconditioned updates
    are re-gathered by XLA.  The model axis is left alone — it is busy with
    TP activations.
  * ``compress_factors`` — factors are synced in bf16 (they are curvature
    *statistics*; EMA smoothing in the optimizer absorbs the rounding).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ExtensionConfig
from repro.core import engine as eng
from repro.core.module import is_axes
from repro.optim.optimizers import apply_updates


def shard_factor_inverses(curv_tree, mesh, axis="data"):
    """Apply a sharding constraint over the leading (layer-stack) axis of
    every stacked Kronecker factor so inversions are distributed."""
    size = mesh.shape[axis]

    def constrain(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 3:
            return leaf
        if leaf.shape[0] % size != 0:
            return leaf
        spec = P(axis, *([None] * (leaf.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    return jax.tree.map(constrain, curv_tree)


def compress_factors(curv_tree):
    return jax.tree.map(
        lambda x: x.astype(jnp.bfloat16).astype(jnp.float32)
        if hasattr(x, "astype") else x,
        curv_tree)


def make_dist_kfac_step(model, loss, opt, extensions, mesh, *,
                        axes=None,
                        cfg: Optional[ExtensionConfig] = None,
                        compress: bool = True):
    """Data-parallel curvature-preconditioned step over the sharded lane.

    ONE batch-sharded engine sweep (``SweepPlan.shard``) produces the
    global gradient and the Kronecker factors — the fused Pallas kernels
    run on each shard's local batch, the reduce specs psum/pmean the
    factors — then the factors are optionally bf16-compressed, their
    inversions round-robin-sharded over the data axis, and the
    preconditioned update applies.  The same step function is exact on 1
    device and on N: only the mesh changes.

    ``opt`` is a ``curvature_optimizer`` (its ``update`` takes ``curv=``);
    ``extensions`` must include the matching curvature backend (KFAC /
    KFLR / DiagGGN(MC)).
    """
    cfg = cfg or ExtensionConfig()
    if axes is None:
        # one rules table decides which mesh axes carry data parallelism
        from repro.sharding.rules import sweep_shard_axes

        axes = sweep_shard_axes(mesh)
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(axes)
    if not axes:
        raise ValueError(
            "make_dist_kfac_step: no data-parallel axis — mesh axes "
            f"{mesh.axis_names} contain neither 'data' nor 'pod'; pass "
            "axes= explicitly for a custom axis naming")
    splan = eng.plan_sweeps(extensions, cfg).shard(mesh, axes)
    ext_names = {e.name for e in extensions}
    curv_name = next(
        (n for n in ("kfac", "kflr", "diag_ggn_mc", "diag_ggn")
         if n in ext_names), None)
    if curv_name is None:
        raise ValueError(
            "make_dist_kfac_step needs a curvature extension "
            "(KFAC/KFLR/DiagGGN/DiagGGNMC); got "
            f"{sorted(ext_names) or 'none'}")

    def step(params, opt_state, batch, step_idx, rng):
        res = splan.run(model, params, batch["inputs"], batch["labels"],
                        loss, cfg=cfg, rng=rng)
        curv = res.ext[curv_name]
        if compress:
            curv = compress_factors(curv)
        curv = shard_factor_inverses(curv, mesh, axis=axes[-1])
        ups, opt_state = opt.update(res.grads, opt_state, params, curv=curv)
        params = apply_updates(params, ups)
        return params, opt_state, {"loss": res.loss, "step": step_idx + 1}

    return step
