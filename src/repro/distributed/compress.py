"""Gradient compression with error feedback (1-bit-Adam-style, bf16 here).

``compress``/``decompress`` + residual carry: the quantization error of
step t is added back into step t+1's gradient before compressing, so the
*accumulated* update is unbiased (Karimireddy et al., 2019).  Used by the
explicit-DP step (`dp_step.py`) around its psum, and by the distributed
KFAC factor sync.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_ef(grads, ef):
    """→ (compressed bf16 grads, new error-feedback residuals)."""
    comp = jax.tree.map(
        lambda g, e: (g.astype(jnp.float32) + e).astype(jnp.bfloat16),
        grads, ef)
    new_ef = jax.tree.map(
        lambda g, e, q: g.astype(jnp.float32) + e - q.astype(jnp.float32),
        grads, ef, comp)
    return comp, new_ef


def decompress(grads):
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)
