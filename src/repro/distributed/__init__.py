from repro.distributed.compress import (
    compress_with_ef,
    decompress,
    init_error_feedback,
)
from repro.distributed.dp_step import init_ef_sharded, make_compressed_dp_step
from repro.distributed.kfac_dist import (
    compress_factors,
    make_dist_kfac_step,
    shard_factor_inverses,
)
