"""Posterior predictives: linearized (GLM) and MC-sampled.

The GLM predictive linearizes the network at the MAP estimate, so the
function-space predictive is Gaussian with

    mean   = f(x; θ*)                      [N, C]
    var    = diag(J(x) Σ J(x)ᵀ)            [N, C]

where ``J`` is the Jacobian of the outputs w.r.t. the parameters and ``Σ``
the fitted Laplace covariance.  ``J`` is obtained the BackPACK way — the
engine's factor sweep with the **identity** over outputs in place of the
loss-Hessian factor: propagating ``S₀[c] = e_c`` backward gives, at every
Dense-shaped layer, the pair ``(A, S)`` whose contraction is that layer's
Jacobian tile ``J[c,n] = Σ_r a_{n,r} s_{c,n,r}ᵀ``.

The hot path — contracting those tiles against the posterior — is the
fused ``predictive_var`` Pallas kernel (``repro.kernels.predictive_var``),
which never materializes the per-sample Jacobian tensor ``[C, N, a, b]``:

* diagonal Σ: the kernel weights the squared tile by the covariance
  diagonal ``Sigma [a, b]``;
* Kronecker Σ = (A'⁻¹ ⊗ B'⁻¹): the inputs are half-transformed outside
  the kernel (``Ã = A L_A``, ``S̃ = S L_B`` with ``L Lᵀ`` the factor
  inverses) and the quadratic form collapses to ``‖J̃‖²_F`` — the same
  kernel without the weight.

Rank-1 layers (R == 1) skip the kernel for closed forms, mirroring
``dense_first_order_stats``; ``use_kernels=False`` keeps the naive
per-sample-Jacobian einsum as the differential/benchmark baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.module import Dense, Sequential, _nra
from repro.nn.layers import Conv2d

from .posterior import (
    DiagLaplace,
    KronLaplace,
    LaplaceStructureError,
    LastLayerLaplace,
    split_last_dense,
)


def _f32(x):
    return x.astype(jnp.float32)


def _output_factor(z):
    """Identity Jacobian seed over outputs: S₀ [C, N, C], S₀[c,n,:] = e_c."""
    if z.ndim != 2:
        raise LaplaceStructureError(
            f"glm_predictive needs [N, C] outputs (got shape {z.shape}); "
            "for sequence models slice features to one position and use the "
            "last-layer posterior's head directly")
    n, c = z.shape
    eye = jnp.eye(c, dtype=jnp.float32)
    return jnp.broadcast_to(eye[:, None, :], (c, n, c))


# ---------------------------------------------------------------------------
# per-layer variance contributions
# ---------------------------------------------------------------------------


def _diag_weight_var(cov_w, A, Sr, use_kernels):
    """Σ_{ij} J[c,n,i,j]² σ²[i,j] for J = Σ_r a sᵀ."""
    Af, Sf = _f32(A), _f32(Sr)
    if A.shape[1] == 1:
        # Rank-1 closed form: J = a sᵀ separates.
        return jnp.einsum("na,ab,cnb->cn", Af[:, 0] ** 2, cov_w,
                          Sf[:, :, 0] ** 2)
    if use_kernels:
        from repro.kernels import ops as kops

        return kops.predictive_var(Af, Sf, cov_w)
    from repro.kernels import ref

    return ref.predictive_var(Af, Sf, cov_w)


def _kron_weight_var(LA, LB, A, Sr, use_kernels):
    """‖L_Aᵀ J L_B‖²_F via half-transformed inputs (see module doc)."""
    At = _f32(A) @ LA
    St = _f32(Sr) @ LB
    if A.shape[1] == 1:
        return (jnp.sum(At[:, 0] ** 2, -1)[None]
                * jnp.sum(St[:, :, 0] ** 2, -1))
    if use_kernels:
        from repro.kernels import ops as kops

        return kops.predictive_var(At, St)
    from repro.kernels import ref

    return ref.predictive_var(At, St)


def _layer_var(post, blocks, A, Sr, bias, use_kernels):
    """Variance contribution [C, N] of one Dense-shaped layer."""
    if isinstance(post, DiagLaplace):
        var = _diag_weight_var(post.cov_diag(blocks["w"]), A, Sr, use_kernels)
        if bias:
            ssum = jnp.sum(_f32(Sr), axis=2)  # [C, N, b]
            var = var + jnp.einsum("cnb,b->cn", ssum * ssum,
                                   post.cov_diag(blocks["b"]))
        return var
    if isinstance(post, KronLaplace):
        LA, LB = post.cov_halves(blocks["w"])
        var = _kron_weight_var(LA, LB, A, Sr, use_kernels)
        if bias:
            ssum = jnp.sum(_f32(Sr), axis=2)
            cov_b = post.bias_cov(blocks["b"])
            var = var + jnp.einsum("cni,ij,cnj->cn", ssum, cov_b, ssum)
        return var
    raise LaplaceStructureError(
        f"glm_predictive: unsupported posterior {type(post).__name__}")


def _var_sweep(module, params, tape, S, blocks, post, use_kernels, var):
    """Backward Jacobian-factor sweep accumulating per-layer variance."""
    if isinstance(module, Dense):
        A = _nra(tape)
        c = S.shape[0]
        Sr = S.reshape((c,) + A.shape[:2] + (module.d_out,))
        var = var + _layer_var(post, blocks, A, Sr, module.use_bias,
                               use_kernels)
        return module.jac_t_mat(params, tape, S), var
    if isinstance(module, Conv2d):
        pat, (hh, ww) = module._unfold(tape)
        c = S.shape[0]
        Sr = S.reshape(c, S.shape[1], hh * ww, module.c_out)
        var = var + _layer_var(post, blocks, pat, Sr, module.use_bias,
                               use_kernels)
        return module.jac_t_mat(params, tape, S), var
    if not jax.tree_util.tree_leaves(params):
        # Parameter-free module: propagate the factor, no contribution.
        return module.jac_t_mat(params, tape, S), var
    if isinstance(module, Sequential):
        for m, p, t, blk in reversed(
                list(zip(module.mods, params, tape, blocks))):
            S, var = _var_sweep(m, p, t, S, blk, post, use_kernels, var)
        return S, var
    raise LaplaceStructureError(
        f"glm_predictive: unsupported parameterized module "
        f"{type(module).__name__} in a full-net sweep; fit with "
        "last_layer=True instead")


# ---------------------------------------------------------------------------
# public predictives
# ---------------------------------------------------------------------------


def _dense_glm_closed_form(head, params, post, x):
    """GLM predictive of a bare Dense head, no Jacobian seed.

    The head Jacobian w.r.t. (W, b) at sample n is rank-1 (``x_n ⊗ e_c``),
    so the variance is a bilinear form that never needs the ``[C, N, C]``
    identity seed the generic sweep propagates — the difference between
    O(N·a·C) and O(N·C²) memory, which is what makes last-layer
    uncertainty feasible at LM-vocabulary scale (diag structure; the
    Kronecker path still owns [C, C] factors by construction).
    """
    z = head.apply(params, x)
    xf = _f32(x)
    blocks = post.layer_blocks()
    if isinstance(post, DiagLaplace):
        var = (xf * xf) @ post.cov_diag(blocks["w"])        # [N, C]
        if head.use_bias:
            var = var + post.cov_diag(blocks["b"])[None]
        return z, var
    if isinstance(post, KronLaplace):
        LA, LB = post.cov_halves(blocks["w"])
        q = jnp.sum((xf @ LA) ** 2, axis=-1)                # x Acov xᵀ, [N]
        b_diag = jnp.sum(LB * LB, axis=-1)                  # diag(Bcov), [C]
        var = q[:, None] * b_diag[None]
        if head.use_bias:
            var = var + jnp.diagonal(post.bias_cov(blocks["b"]))[None]
        return z, var
    raise LaplaceStructureError(
        f"glm_predictive: unsupported posterior {type(post).__name__}")


def glm_predictive(model, params, posterior, x, *, use_kernels: bool = True):
    """Linearized (GLM) posterior predictive.

    Linearizes the network at the MAP estimate, so the function-space
    predictive is Gaussian: ``mean = f(x; θ*)``, ``var = diag(J Σ Jᵀ)``
    with ``J`` the output/parameter Jacobian and ``Σ`` the fitted Laplace
    covariance.  The Jacobian factors come from the engine's
    identity-seeded factor sweep (the Eq. 18 propagation with ``S₀ = I``)
    and contract against ``Σ`` via the fused ``predictive_var`` Pallas
    kernel — the ``[C, N, a, b]`` per-sample Jacobian tensor never
    materializes.

    Parameters
    ----------
    model, params
        The model and MAP parameters the posterior was fitted around.
        For :class:`~repro.laplace.posterior.LastLayerLaplace` the
        feature extractor runs once and the head predictive uses a
        closed form (no identity seed) — the LM-vocabulary-scale path.
    posterior
        A fitted ``DiagLaplace`` / ``KronLaplace`` / ``LastLayerLaplace``.
    x : array
        Inputs ``[N, ...]``.
    use_kernels : bool
        Route the variance contraction through the fused Pallas kernel
        (default); ``False`` keeps the naive per-sample-Jacobian einsum
        as the differential/benchmark baseline.

    Returns
    -------
    mean : array, ``[N, C]``
        MAP outputs.
    var : array, ``[N, C]``
        Function-space predictive variance ``diag(J Σ Jᵀ)``.  For
        regression add ``sigma_noise²`` for the observation predictive;
        for classification feed both through
        :func:`probit_predictive` for calibrated probabilities.
    """
    if isinstance(posterior, LastLayerLaplace):
        feats, head, f_params, h_params = split_last_dense(model, params)
        phi = feats.apply(f_params, x)
        return glm_predictive(head, h_params, posterior.inner, phi,
                              use_kernels=use_kernels)
    if isinstance(model, Dense) and x.ndim == 2:
        # Bare Dense head (the last-layer path): closed form, no seed.
        return _dense_glm_closed_form(model, params, posterior, x)
    with obs.span("laplace/predictive/glm", n=x.shape[0],
                  use_kernels=use_kernels):
        z, tape = model.forward_tape(params, x)
        S0 = _output_factor(z)
        var0 = jnp.zeros((z.shape[-1], z.shape[0]), jnp.float32)
        _, var = _var_sweep(model, params, tape, S0,
                            posterior.layer_blocks(), posterior,
                            use_kernels, var0)
        return z, var.T


def mc_predictive(model, params, posterior, x, key, n_samples: int = 30):
    """Monte-Carlo predictive over posterior weight samples:
    (mean [N, C], variance [N, C]) of the sampled outputs."""
    with obs.span("laplace/predictive/mc", n=x.shape[0],
                  n_samples=n_samples):
        thetas = posterior.sample(key, n_samples)
        zs = jax.vmap(lambda p: model.apply(p, x))(thetas)
        zs = _f32(zs)
        return jnp.mean(zs, axis=0), jnp.var(zs, axis=0)


def probit_predictive(mean, var):
    """MacKay's probit-corrected softmax: the closed-form approximation of
    E[softmax(f)] under f ~ N(mean, diag(var)) — calibrated class
    probabilities from the GLM predictive."""
    kappa = jax.lax.rsqrt(1.0 + (jnp.pi / 8.0) * _f32(var))
    return jax.nn.softmax(_f32(mean) * kappa, axis=-1)
