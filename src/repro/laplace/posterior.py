"""Laplace posteriors fitted from a ``core.engine`` run.

A Laplace approximation around the MAP estimate ``θ*`` is the Gaussian
``N(θ*, P⁻¹)`` with posterior precision

    P = H_lik + δ I,       H_lik ≈ M · G(θ*) / σ²

where ``G`` is the engine's GGN approximation of the **mean**-loss
curvature (the 1/M of the objective is folded into the propagated factors,
see ``core.loss_hessian``), ``M`` the number of sample units, ``δ`` the
prior precision and ``σ`` the observation noise (regression only).

Two structures, matching the engine's curvature families:

* :class:`DiagLaplace` — elementwise precisions from DiagGGN / DiagGGNMC;
* :class:`KronLaplace` — per-layer Kronecker blocks ``A ⊗ B`` from
  KFLR / KFAC, damped with the Martens–Grosse π split (``repro.core.kron``):
  ``P_block = (A + π√δ I) ⊗ (M·B/σ² + √δ/π I)``.  Log-determinants and
  samples stay closed-form (``logdet(A'⊗B') = b·logdet A' + a·logdet B'``,
  ``θ = θ* + A'^{-1/2} E B'^{-1/2}``), which is what makes marginal-
  likelihood tuning cheap.

:class:`LastLayerLaplace` restricts either structure to the final Dense
layer of a Sequential model (the classic last-layer Laplace), which is the
practical scope for LM-sized configs: the feature extractor stays a point
estimate and the engine sweep runs on the head alone.

Fits are validated against ``SweepPlan.posterior_structures()`` — asking a
plan for a structure its extensions cannot serve raises
:class:`LaplaceStructureError` with the plan description instead of a
downstream shape error.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, ClassVar, Optional

import jax
import jax.numpy as jnp

from repro.core import (
    CrossEntropyLoss,
    DiagGGN,
    DiagGGNMC,
    ExtensionConfig,
    KFAC,
    KFLR,
    MSELoss,
    kron as K,
)
from repro import obs
from repro.core import engine as eng
from repro.core.module import Dense, Sequential


class LaplaceStructureError(ValueError):
    """A Laplace fit/predictive was asked for a structure the sweep plan or
    model cannot serve; the message says what to change."""


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _n_units(loss, y) -> float:
    """Number of sample units M (the 1/M folded into engine factors)."""
    if isinstance(loss, CrossEntropyLoss):
        return float(max(int(jnp.sum(y >= 0)), 1))
    if isinstance(loss, MSELoss):
        return float(max(int(y.size // y.shape[-1]), 1))
    raise LaplaceStructureError(
        f"laplace: unsupported loss {type(loss).__name__} "
        "(CrossEntropyLoss or MSELoss)")


def _likelihood_of(loss) -> str:
    return "regression" if isinstance(loss, MSELoss) else "classification"


@dataclasses.dataclass(frozen=True)
class FitOptions:
    """Every Laplace-fit knob, in one place.

    The three ``fit`` classmethods and :func:`fit_posterior` had grown
    drifting keyword lists (the sweep-plumbing kwargs arrived one PR at a
    time); this dataclass is the single shared spelling::

        post = fit_posterior(model, params, x, y, loss, structure="kron",
                             options=FitOptions(mc=True, prior_prec=0.5,
                                                mesh=mesh))

    Passing the old keywords directly still works but emits a
    ``DeprecationWarning``.

    Fields
    ------
    mc : bool
        Monte-Carlo curvature (DiagGGNMC / KFAC) instead of the exact
        factorization — the LM-vocabulary path (Eq. 20).
    prior_prec : float
        Initial prior precision ``δ`` (tunable afterwards via
        ``marglik.optimize_marglik``).
    cfg, rng, extensions
        Engine sweep configuration: ``ExtensionConfig``, the MC PRNG key,
        and an explicit extension tuple overriding the structure default.
    mesh, shard_axes
        Batch-shard the fitting sweep (``SweepPlan.shard``).
    microbatch_size
        Stream it (``SweepPlan.accumulate``); composes with ``mesh``.
    ckpt_dir, resume, checkpoint_every, injector
        Preemption-safe streaming fit (``SweepStream`` snapshots);
        ``injector`` hooks a ``train.fault.FailureInjector`` in for tests.
    """

    mc: bool = False
    prior_prec: float = 1.0
    cfg: Optional[ExtensionConfig] = None
    rng: Any = None
    extensions: Any = None
    mesh: Any = None
    shard_axes: Any = ("data",)
    microbatch_size: Optional[int] = None
    ckpt_dir: Optional[str] = None
    resume: bool = False
    checkpoint_every: int = 1
    injector: Any = None

    def replace(self, **kw) -> "FitOptions":
        return dataclasses.replace(self, **kw)


_FIT_OPTION_NAMES = tuple(f.name for f in dataclasses.fields(FitOptions))


def _merge_fit_options(options, legacy, caller):
    """Resolve ``options=FitOptions(...)`` against legacy keywords.

    Legacy keywords still work — folded over ``options`` (or a default
    instance) — but emit a ``DeprecationWarning`` naming the replacement.
    Unknown keywords raise ``TypeError`` exactly like a real signature.
    """
    if not legacy:
        return options if options is not None else FitOptions()
    unknown = sorted(k for k in legacy if k not in _FIT_OPTION_NAMES)
    if unknown:
        raise TypeError(
            f"{caller}: unexpected keyword argument(s) {unknown} "
            f"(FitOptions fields: {list(_FIT_OPTION_NAMES)})")
    names = ", ".join(f"{k}=..." for k in sorted(legacy))
    warnings.warn(
        f"{caller}: passing {sorted(legacy)} as keywords is deprecated — "
        f"pass options=FitOptions({names}) instead",
        DeprecationWarning, stacklevel=3)
    return dataclasses.replace(options if options is not None else
                               FitOptions(), **legacy)


def _run_sweep(model, params, x, y, loss, extensions, cfg, rng,
               mesh, shard_axes, microbatch_size=None, ckpt_dir=None,
               resume=False, checkpoint_every=1, injector=None):
    """One engine sweep — single-device, batch-sharded over ``mesh``,
    and/or streamed over microbatches.

    With a mesh the sweep routes through ``SweepPlan.shard`` (the fused
    kernels run per shard, curvature psums per the extensions' reduce
    specs), so the same fit call serves 1..N devices and the returned
    curvature trees are placement-identical to the single-device ones.
    With ``microbatch_size`` (argument, or ``cfg.microbatch_size``) it
    additionally routes through ``SweepPlan.accumulate`` — the posterior
    curvature is folded sequentially over ``ceil(N / microbatch_size)``
    slices, so posterior fitting runs at LM-scale batches on one device.

    With ``ckpt_dir`` the accumulated sweep additionally runs
    preemption-safely (``AccumulatedSweepPlan.run_checkpointed``):
    accumulator snapshots land in ``ckpt_dir`` every
    ``checkpoint_every`` work units and ``resume=True`` restarts a
    killed fit at the interrupted slice — the refitted posterior is
    identical to an uninterrupted one.  Checkpointing requires the
    streaming lane: a monolithic or purely sharded fit has no slice
    boundaries to snapshot at, so asking for one raises
    :class:`LaplaceStructureError`.
    """
    n = jax.tree.leaves(x)[0].shape[0]
    plan = eng.plan_for_batch(extensions, cfg, n, mesh=mesh,
                              shard_axes=shard_axes,
                              microbatch_size=microbatch_size)
    with obs.span("laplace/fit_sweep", n=n,
                  extensions=",".join(sorted(e.name for e in extensions))):
        if ckpt_dir is None:
            return plan.run(model, params, x, y, loss, cfg=cfg, rng=rng)
        if not isinstance(plan, eng.AccumulatedSweepPlan):
            raise LaplaceStructureError(
                "laplace: ckpt_dir needs the streaming accumulated sweep "
                "lane — pass microbatch_size (or cfg.microbatch_size) small "
                "enough to split the fit batch into more than one slice, so "
                "the sweep has checkpointable work units "
                f"(plan: {plan.describe()})")
        from repro.train.checkpoint import SweepCheckpointer

        return plan.run_checkpointed(
            model, params, x, y, loss, cfg=cfg, rng=rng,
            checkpointer=SweepCheckpointer(ckpt_dir),
            checkpoint_every=checkpoint_every, injector=injector,
            resume=resume)


def _is_kron_block(node) -> bool:
    return (isinstance(node, dict) and "B" in node
            and set(node) <= {"A", "B", "A_diag"})


def _map_kron(fn, mean, kron, path="params"):
    """Map ``fn(mean_leaf, block)`` over param leaves zipped with their
    Kronecker blocks, preserving the mean tree's structure.  A param leaf
    without a block is a structure error (the actionable alternative to a
    shape mismatch deep inside a solve)."""
    if isinstance(mean, dict):
        k_d = kron if isinstance(kron, dict) else {}
        return {k: _map_kron(fn, v, k_d.get(k), f"{path}.{k}")
                for k, v in mean.items()}
    if isinstance(mean, (tuple, list)):
        k_t = (kron if isinstance(kron, (tuple, list))
               and len(kron) == len(mean) else (None,) * len(mean))
        return tuple(_map_kron(fn, m, c, f"{path}[{i}]")
                     for i, (m, c) in enumerate(zip(mean, k_t)))
    if mean is None or not hasattr(mean, "ndim"):
        return mean
    if not _is_kron_block(kron):
        raise LaplaceStructureError(
            f"KronLaplace: no Kronecker factors for {path} — the engine "
            "emits KFLR/KFAC blocks for Dense/Conv2d/Embedding layers only; "
            "for other models fit with last_layer=True or DiagLaplace")
    return fn(mean, kron)


def _require_structure(structure: str, extensions, cfg) -> None:
    plan = eng.plan_sweeps(extensions, cfg)
    if structure not in plan.posterior_structures():
        raise LaplaceStructureError(
            f"laplace: sweep plan cannot serve a '{structure}' posterior "
            f"(plan: {plan.describe()}); add DiagGGN/DiagGGNMC for 'diag' "
            "or KFLR/KFAC for 'kron'")


def _inv_sqrt_psd(M):
    """Symmetric inverse square root of an SPD matrix via eigh."""
    w, U = jnp.linalg.eigh(M)
    return (U * jax.lax.rsqrt(jnp.maximum(w, 1e-30))) @ U.T


def _cov_half(M):
    """L with L Lᵀ = M⁻¹ for SPD M (eigh-based)."""
    w, U = jnp.linalg.eigh(M)
    return U * jax.lax.rsqrt(jnp.maximum(w, 1e-30))


def _logdet(M):
    if M.ndim == 1:
        return jnp.sum(jnp.log(jnp.maximum(M, 1e-30)))
    return jnp.linalg.slogdet(M)[1]


# ---------------------------------------------------------------------------
# shared evidence plumbing
# ---------------------------------------------------------------------------


class _EvidenceMixin:
    """Evidence pieces common to every Gaussian posterior here.

    Subclasses are dataclasses providing ``mean`` / ``n_data`` /
    ``loss_map`` / ``likelihood`` / ``n_outputs`` / ``prior_prec`` /
    ``sigma_noise`` fields; only the structure-specific
    ``log_det_ratio`` / sampling / predictive hooks live on them.
    """

    def _curv_scale(self, sigma_noise=None):
        """Mean-loss curvature → sum-loss likelihood Hessian: M (/σ²)."""
        s = jnp.asarray(self.sigma_noise if sigma_noise is None
                        else sigma_noise, jnp.float32)
        return (jnp.float32(self.n_data) / (s * s)
                if self.likelihood == "regression"
                else jnp.float32(self.n_data))

    def n_params(self) -> int:
        return int(sum(l.size for l in jax.tree.leaves(self.mean)))

    def scatter(self, prior_prec=None):
        d = self.prior_prec if prior_prec is None else prior_prec
        sq = sum(jnp.sum(l.astype(jnp.float32) ** 2)
                 for l in jax.tree.leaves(self.mean))
        return jnp.asarray(d, jnp.float32) * sq

    def log_lik(self, sigma_noise=None):
        s = jnp.asarray(self.sigma_noise if sigma_noise is None
                        else sigma_noise, jnp.float32)
        m = jnp.float32(self.n_data)
        if self.likelihood == "regression":
            n_out = jnp.float32(self.n_data * self.n_outputs)
            return (-m * self.loss_map / (s * s) - n_out * jnp.log(s)
                    - 0.5 * n_out * jnp.log(2.0 * jnp.pi))
        return -m * self.loss_map


# ---------------------------------------------------------------------------
# diagonal posterior
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DiagLaplace(_EvidenceMixin):
    """Diagonal-precision Laplace posterior.

    ``curv`` is the engine's mean-loss GGN diagonal tree (structure aligned
    with ``mean``); the likelihood scale ``n_data/σ²`` and the prior ``δ``
    are applied lazily so prior precision and observation noise can be
    re-tuned (marglik) without re-running the sweep.
    """

    mean: Any
    curv: Any
    n_data: float
    loss_map: float
    likelihood: str = "classification"
    n_outputs: int = 1
    prior_prec: float = 1.0
    sigma_noise: float = 1.0

    structure: ClassVar[str] = "diag"

    # -- fitting -------------------------------------------------------------

    @classmethod
    def fit(cls, model, params, x, y, loss, *,
            options: Optional[FitOptions] = None, **legacy):
        o = _merge_fit_options(options, legacy, "DiagLaplace.fit")
        cfg, extensions, rng = _fit_args(
            o.cfg, o.extensions, o.rng, o.mc,
            default=(DiagGGNMC,) if o.mc else (DiagGGN,))
        _require_structure("diag", extensions, cfg)
        res = _run_sweep(model, params, x, y, loss, extensions, cfg, rng,
                         o.mesh, o.shard_axes, o.microbatch_size, o.ckpt_dir,
                         o.resume, o.checkpoint_every, o.injector)
        name = "diag_ggn_mc" if "diag_ggn_mc" in res.ext else "diag_ggn"
        curv = res.ext[name]
        try:
            curv = jax.tree.map(
                lambda p, c: c.astype(jnp.float32), params, curv)
        except ValueError as e:
            raise LaplaceStructureError(
                "DiagLaplace: curvature tree does not cover every parameter "
                f"({e}); the engine emits GGN diagonals for "
                "Dense/Conv2d/Embedding/norm layers — for other models fit "
                "with last_layer=True") from None
        return cls(mean=params, curv=curv, n_data=_n_units(loss, y),
                   loss_map=float(res.loss), likelihood=_likelihood_of(loss),
                   n_outputs=int(res.logits.shape[-1]),
                   prior_prec=float(o.prior_prec))

    # -- evidence pieces (closed form) ---------------------------------------

    def precision(self, prior_prec=None, sigma_noise=None):
        """Posterior precision tree: curv·(M/σ²) + δ."""
        d = self.prior_prec if prior_prec is None else prior_prec
        scale = self._curv_scale(sigma_noise)
        return jax.tree.map(lambda c: c * scale + d, self.curv)

    def log_det_ratio(self, prior_prec=None, sigma_noise=None):
        """log det P − P_dim · log δ  (the evidence's Occam term)."""
        d = self.prior_prec if prior_prec is None else prior_prec
        prec = self.precision(prior_prec, sigma_noise)
        ld = sum(jnp.sum(jnp.log(l)) for l in jax.tree.leaves(prec))
        return ld - self.n_params() * jnp.log(jnp.asarray(d, jnp.float32))

    # -- sampling ------------------------------------------------------------

    def sample(self, key, n_samples: int = 1):
        """Posterior samples as a params tree with leading axis K."""
        prec = self.precision()
        leaves, treedef = jax.tree_util.tree_flatten(self.mean)
        p_leaves = jax.tree.leaves(prec)
        keys = jax.random.split(key, len(leaves))
        out = []
        for m, p, k in zip(leaves, p_leaves, keys):
            eps = jax.random.normal(k, (n_samples,) + m.shape, jnp.float32)
            out.append(m.astype(jnp.float32)[None]
                       + eps * jax.lax.rsqrt(p)[None])
        return jax.tree_util.tree_unflatten(treedef, out)

    # -- predictive hooks (consumed by laplace.predictive) -------------------

    def cov_diag(self, curv_leaf):
        """Elementwise posterior variance for one parameter leaf."""
        scale = self._curv_scale(self.sigma_noise)
        return 1.0 / (curv_leaf * scale + self.prior_prec)

    def layer_blocks(self):
        return self.curv


# ---------------------------------------------------------------------------
# Kronecker posterior
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KronLaplace(_EvidenceMixin):
    """Kronecker-factored Laplace posterior (π-damped, App. C.3).

    ``kron`` is the engine's KFLR/KFAC stats tree: per layer
    ``{'w': {'A': [a,a] | 'A_diag': [a], 'B': [b,b]}, 'b': {'B': [b,b]}}``.
    ``A`` keeps the engine's per-unit normalization; ``B`` is scaled by
    ``n_data/σ²`` at use time so the block approximates the sum-loss GGN.
    """

    mean: Any
    kron: Any
    n_data: float
    loss_map: float
    likelihood: str = "classification"
    n_outputs: int = 1
    prior_prec: float = 1.0
    sigma_noise: float = 1.0

    structure: ClassVar[str] = "kron"

    @classmethod
    def fit(cls, model, params, x, y, loss, *,
            options: Optional[FitOptions] = None, **legacy):
        o = _merge_fit_options(options, legacy, "KronLaplace.fit")
        cfg, extensions, rng = _fit_args(
            o.cfg, o.extensions, o.rng, o.mc,
            default=(KFAC,) if o.mc else (KFLR,))
        _require_structure("kron", extensions, cfg)
        res = _run_sweep(model, params, x, y, loss, extensions, cfg, rng,
                         o.mesh, o.shard_axes, o.microbatch_size, o.ckpt_dir,
                         o.resume, o.checkpoint_every, o.injector)
        name = "kfac" if "kfac" in res.ext else "kflr"
        kron_tree = res.ext[name]
        # Validate coverage (and surface the actionable message now, not at
        # the first solve): every param leaf must own a Kronecker block.
        _map_kron(lambda m, b: None, params, kron_tree)
        return cls(mean=params, kron=kron_tree, n_data=_n_units(loss, y),
                   loss_map=float(res.loss), likelihood=_likelihood_of(loss),
                   n_outputs=int(res.logits.shape[-1]),
                   prior_prec=float(o.prior_prec))

    # -- damped factors ------------------------------------------------------

    def damped_factors(self, block, prior_prec=None, sigma_noise=None):
        """π-damped posterior-precision factors for one block.

        Weight blocks return ``(A', B')`` with ``P ≈ A' ⊗ B'``; bias blocks
        (no A factor) return ``(None, M·B/σ² + δ I)`` — the
        ``kron_solve_bias`` convention.
        """
        d = self.prior_prec if prior_prec is None else prior_prec
        s = self.sigma_noise if sigma_noise is None else sigma_noise
        B = block["B"].astype(jnp.float32) * self._curv_scale(s)
        if B.ndim != 2:
            raise LaplaceStructureError(
                "KronLaplace: scan-stacked Kronecker factors (B.ndim==3) "
                "are not supported — fit with last_layer=True")
        A = block.get("A", block.get("A_diag"))
        eye_b = jnp.eye(B.shape[0], dtype=jnp.float32)
        if A is None:
            return None, B + jnp.asarray(d, jnp.float32) * eye_b
        A = A.astype(jnp.float32)
        pi = K.pi_factor(A, B)
        sd = jnp.sqrt(jnp.asarray(d, jnp.float32))
        if A.ndim == 1:
            Ad = A + pi * sd
        else:
            Ad = A + pi * sd * jnp.eye(A.shape[0], dtype=jnp.float32)
        return Ad, B + (sd / pi) * eye_b

    # -- evidence pieces (closed form) ---------------------------------------

    def log_det_ratio(self, prior_prec=None, sigma_noise=None):
        """Closed form: logdet(A'⊗B') = b·logdet A' + a·logdet B'."""
        d = self.prior_prec if prior_prec is None else prior_prec
        terms = []

        def block_ld(mean_leaf, block):
            Ad, Bd = self.damped_factors(block, prior_prec, sigma_noise)
            if Ad is None:
                terms.append(_logdet(Bd))
            else:
                a_dim, b_dim = Ad.shape[0], Bd.shape[0]
                terms.append(b_dim * _logdet(Ad) + a_dim * _logdet(Bd))
            return None

        _map_kron(block_ld, self.mean, self.kron)
        return (sum(terms)
                - self.n_params() * jnp.log(jnp.asarray(d, jnp.float32)))

    # -- sampling ------------------------------------------------------------

    def sample(self, key, n_samples: int = 1):
        """θ = θ* + A'^{-1/2} E B'^{-1/2} per weight block (matrix normal);
        vec-covariance is exactly (A'⊗B')⁻¹."""
        counter = [0]

        def block_sample(mean_leaf, block):
            Ad, Bd = self.damped_factors(block)
            k = jax.random.fold_in(key, counter[0])
            counter[0] += 1
            eps = jax.random.normal(
                k, (n_samples,) + mean_leaf.shape, jnp.float32)
            m = mean_leaf.astype(jnp.float32)[None]
            SB = _inv_sqrt_psd(Bd)
            if Ad is None:
                return m + jnp.einsum("ij,kj->ki", SB, eps)
            if Ad.ndim == 1:
                half = eps * jax.lax.rsqrt(Ad)[None, :, None]
            else:
                half = jnp.einsum("ij,kjl->kil", _inv_sqrt_psd(Ad), eps)
            return m + jnp.einsum("kil,lm->kim", half, SB)

        return _map_kron(block_sample, self.mean, self.kron)

    # -- predictive hooks ----------------------------------------------------

    def cov_halves(self, block):
        """(L_A, L_B) with L Lᵀ the damped factor inverses — the GLM
        predictive's half-transforms (see kernels/predictive_var.py)."""
        Ad, Bd = self.damped_factors(block)
        if Ad is None or Ad.ndim == 1:
            raise LaplaceStructureError(
                "KronLaplace predictive needs dense A factors "
                "(Dense/Conv2d weight blocks)")
        return _cov_half(Ad), _cov_half(Bd)

    def bias_cov(self, block):
        _, Bd = self.damped_factors(block)
        return jnp.linalg.inv(Bd)

    def layer_blocks(self):
        return self.kron


# ---------------------------------------------------------------------------
# last-layer restriction
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LastLayerLaplace:
    """Laplace posterior over the final Dense layer only.

    The feature extractor (everything before the head) stays a point
    estimate; the engine sweep runs on the head alone with the extracted
    features as inputs — the practical scope for large configs, where a
    full-net sweep (or a full-net Kronecker eigendecomposition) is off the
    table.
    """

    inner: Any        # Diag/Kron posterior over the head params
    full_mean: Any    # full params tree (head included)

    structure: ClassVar[str] = "last_layer"

    @classmethod
    def fit(cls, model, params, x, y, loss, *, structure: str = "kron",
            options: Optional[FitOptions] = None, **legacy):
        o = _merge_fit_options(options, legacy, "LastLayerLaplace.fit")
        feats, head, f_params, h_params = split_last_dense(model, params)
        phi = feats.apply(f_params, x)
        inner_cls = {"diag": DiagLaplace, "kron": KronLaplace}.get(structure)
        if inner_cls is None:
            raise LaplaceStructureError(
                f"LastLayerLaplace: unknown structure '{structure}' "
                "(expected 'diag' or 'kron')")
        inner = inner_cls.fit(head, h_params, phi, y, loss, options=o)
        return cls(inner=inner, full_mean=params)

    def features(self, model, params, x):
        feats, _, f_params, _ = split_last_dense(model, params)
        return feats.apply(f_params, x)

    def sample(self, key, n_samples: int = 1):
        """Full params tree with leading axis K: head sampled, rest tiled."""
        head_samples = self.inner.sample(key, n_samples)
        base = jax.tree.map(
            lambda l: jnp.broadcast_to(l[None], (n_samples,) + l.shape),
            tuple(self.full_mean[:-1]))
        return base + (head_samples,)

    # evidence of the restricted model: delegate to the inner posterior
    def log_det_ratio(self, *a, **kw):
        return self.inner.log_det_ratio(*a, **kw)

    def scatter(self, *a, **kw):
        return self.inner.scatter(*a, **kw)

    def log_lik(self, *a, **kw):
        return self.inner.log_lik(*a, **kw)

    @property
    def likelihood(self):
        return self.inner.likelihood

    @property
    def prior_prec(self):
        return self.inner.prior_prec

    @property
    def sigma_noise(self):
        return self.inner.sigma_noise


def split_last_dense(model, params):
    """(features, head, f_params, h_params) for a Sequential ending in
    Dense — the last-layer Laplace decomposition."""
    if not isinstance(model, Sequential) or not model.mods:
        raise LaplaceStructureError(
            "LastLayerLaplace needs a Sequential model "
            f"(got {type(model).__name__})")
    if not isinstance(model.mods[-1], Dense):
        raise LaplaceStructureError(
            "LastLayerLaplace needs the final module to be Dense "
            f"(got {type(model.mods[-1]).__name__}); reorder the head or "
            "use a full-net DiagLaplace/KronLaplace fit")
    feats = Sequential(model.mods[:-1])
    return feats, model.mods[-1], tuple(params[:-1]), params[-1]


# ---------------------------------------------------------------------------
# convenience front door
# ---------------------------------------------------------------------------


def _fit_args(cfg, extensions, rng, mc, default):
    """Shared fit plumbing: default extensions + deterministic MC seeding
    (the ExtensionConfig.mc_seed path) when the caller passes no key."""
    cfg = cfg or ExtensionConfig()
    extensions = tuple(extensions) if extensions else default
    needs_mc = any(e.sweep == "ggn_mc" for e in extensions)
    if needs_mc and rng is None and cfg.mc_seed is None:
        cfg = dataclasses.replace(cfg, mc_seed=0)
    return cfg, extensions, rng


def fit_posterior(model, params, x, y, loss, *, structure: str = "diag",
                  last_layer: bool = False,
                  options: Optional[FitOptions] = None, **legacy):
    """Fit a Laplace posterior from one engine sweep.

    Parameters
    ----------
    model, params
        The trained model (``repro.core`` Module) and its MAP parameters
        ``θ*``.
    x, y
        Fitting batch: inputs ``[N, ...]`` and targets.
    loss
        ``CrossEntropyLoss`` or ``MSELoss`` — fixes the likelihood and
        the 1/M normalization folded into the curvature factors.
    structure : {'diag', 'kron'}
        Posterior precision structure: elementwise GGN diagonals
        (Eq. 19) or π-damped per-layer Kronecker blocks ``A ⊗ B``
        (Eq. 23).
    last_layer : bool
        Restrict the posterior to the final Dense layer (the LM-scale
        path): the feature extractor stays a point estimate and the
        sweep runs on the head alone.
    options : FitOptions
        Everything else — MC curvature, prior precision, the engine
        sweep's scale levers (``mesh``, ``microbatch_size``) and the
        preemption-safe streaming knobs.  See :class:`FitOptions`.
        Passing those fields as direct keywords (the pre-FitOptions
        signatures) still works but emits a ``DeprecationWarning``.

    Returns
    -------
    DiagLaplace | KronLaplace | LastLayerLaplace
        A fitted posterior exposing evidence pieces (``log_lik``,
        ``log_det_ratio``, ``scatter``), ``sample`` and the predictive
        hooks ``repro.laplace.predictive`` consumes.

    Raises
    ------
    LaplaceStructureError
        When the extension set cannot serve ``structure`` (see
        ``SweepPlan.posterior_structures``) or the model lacks the
        required layer structure — the message says what to change.
    """
    o = _merge_fit_options(options, legacy, "fit_posterior")
    with obs.span("laplace/fit", structure=structure,
                  last_layer=last_layer):
        if last_layer:
            return LastLayerLaplace.fit(model, params, x, y, loss,
                                        structure=structure, options=o)
        cls = {"diag": DiagLaplace, "kron": KronLaplace}.get(structure)
        if cls is None:
            raise LaplaceStructureError(
                f"fit_posterior: unknown structure '{structure}' "
                "(expected 'diag' or 'kron')")
        return cls.fit(model, params, x, y, loss, options=o)
