"""repro.laplace — curvature-backed uncertainty from one engine sweep.

The second consumer of BackPACK's by-products (after the §4 preconditioned
optimizer): the quantities the fused curvature kernels already emit —
DiagGGN / DiagGGNMC diagonals, KFLR / KFAC Kronecker factors — are exactly
the posterior precisions of a Laplace approximation around the trained
weights.  One ``core.engine`` run therefore buys

* a fitted Gaussian posterior (:mod:`repro.laplace.posterior` —
  :class:`DiagLaplace`, :class:`KronLaplace`, :class:`LastLayerLaplace`),
* the marginal likelihood ``log p(D | prior_prec)`` with closed-form
  log-determinants, and a jit-compiled optimizer for prior precision and
  observation noise (:mod:`repro.laplace.marglik`),
* calibrated predictions with uncertainty: the linearized GLM predictive
  (fused ``predictive_var`` Pallas kernel on the hot path) and the
  MC-sampled predictive (:mod:`repro.laplace.predictive`).

Public API::

    from repro.laplace import (
        DiagLaplace, KronLaplace, LastLayerLaplace, LaplaceStructureError,
        FitOptions, fit_posterior, glm_predictive, mc_predictive,
        probit_predictive, log_marglik, optimize_marglik,
    )
"""
from .posterior import (
    DiagLaplace,
    FitOptions,
    KronLaplace,
    LaplaceStructureError,
    LastLayerLaplace,
    fit_posterior,
)
from .marglik import (
    MatfreeEvidence,
    log_marglik,
    log_marglik_matfree,
    optimize_marglik,
)
from .predictive import glm_predictive, mc_predictive, probit_predictive
