"""Laplace evidence ``log p(D | δ, σ)`` and its jit-compiled optimizer.

The marginal likelihood of the Laplace-approximated model is closed form
once a posterior is fitted (MacKay 1992; Immer et al. 2021):

    log p(D | δ, σ) = log p(D | θ*, σ)                    (fit likelihood)
                      − ½ δ ‖θ*‖²                         (prior scatter)
                      − ½ [log det P(δ, σ) − P_dim log δ] (Occam factor)

Every piece is cheap for the diag / Kronecker posteriors in
:mod:`repro.laplace.posterior` — the log-determinants are closed form and
the sweep never re-runs — so prior precision ``δ`` (and observation noise
``σ`` for regression) can be tuned by gradient ascent on the evidence: the
Laplace answer to weight decay / noise hyperparameters, no validation set
needed.  :func:`optimize_marglik` runs an Adam loop over ``(log δ, log σ)``
under ``jax.lax.scan`` inside one jit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .posterior import LastLayerLaplace


@dataclasses.dataclass(frozen=True)
class MatfreeEvidence:
    """SLQ-estimated Laplace evidence (no factors materialized)."""

    log_marglik: float
    log_lik: float
    scatter: float
    log_det_ratio: float
    per_probe: np.ndarray  # individual SLQ quadrature estimates


def log_marglik_matfree(model, params, inputs, targets, loss, *,
                        prior_prec: float, sigma_noise: float = 1.0,
                        probes: int = 8, iters: int = 20, rng=None,
                        cfg=None, mesh=None, shard_axes=("data",)):
    """Laplace evidence with the Occam log-det estimated matrix-free.

    The closed-form posteriors need materialized factors; beyond factor
    scale the only accessible object is the GGN-vector product, so the
    Occam term

        log det P − P_dim log δ = log det( I + (M/σ²δ) · G_mean )

    is estimated by stochastic Lanczos quadrature over the ratio operator
    (``repro.curv.slq_logdet`` — eigenvalues ≥ 1, so the quadrature is
    benign), at ``probes × iters`` GGN-product cost.  The likelihood and
    scatter terms are exact (one forward pass); conventions match
    :class:`repro.laplace.posterior.DiagLaplace` so the two paths agree
    as the MC error vanishes.  ``cfg``/``mesh`` stream/shard each product
    through the usual scale machinery.
    """
    from repro.core.loss_hessian import MSELoss
    from repro.curv import GGNOperator, slq_logdet

    if rng is None:
        rng = jax.random.PRNGKey(0)
    z = model.apply(params, inputs)
    loss_map = loss.value(z, targets)
    m = jnp.float32(jnp.maximum(loss.num_units(targets), 1.0))
    regression = isinstance(loss, MSELoss)
    s = jnp.float32(sigma_noise)
    delta = jnp.float32(prior_prec)
    scale = m / (s * s) if regression else m

    op = GGNOperator(model, params, inputs, targets, loss, cfg=cfg,
                     mesh=mesh, shard_axes=tuple(shard_axes))

    def mv_ratio(v):
        gv = op.mv(v)
        return jax.tree.map(
            lambda vi, gi: vi.astype(jnp.float32)
            + (scale / delta) * gi.astype(jnp.float32), v, gv)

    with obs.span("laplace/marglik_matfree", probes=probes, iters=iters):
        slq = slq_logdet(mv_ratio, params, rng=rng, probes=probes,
                         iters=iters)
    ld_ratio = slq.logdet

    if regression:
        n_out = m * jnp.float32(z.shape[-1])
        log_lik = (-m * loss_map / (s * s) - n_out * jnp.log(s)
                   - 0.5 * n_out * jnp.log(2.0 * jnp.pi))
    else:
        log_lik = -m * loss_map
    sq = sum(jnp.sum(l.astype(jnp.float32) ** 2)
             for l in jax.tree.leaves(params))
    scatter = delta * sq
    ev = log_lik - 0.5 * (scatter + ld_ratio)
    return MatfreeEvidence(log_marglik=float(ev), log_lik=float(log_lik),
                           scatter=float(scatter),
                           log_det_ratio=float(ld_ratio),
                           per_probe=np.asarray(slq.per_probe))


def log_marglik(post, prior_prec=None, sigma_noise=None):
    """Laplace evidence of a fitted posterior at (δ, σ).

    Defaults to the posterior's stored hyperparameters; pass ``prior_prec``
    / ``sigma_noise`` (scalars or traced values) to evaluate elsewhere —
    the function is differentiable in both.
    """
    return (post.log_lik(sigma_noise)
            - 0.5 * (post.scatter(prior_prec)
                     + post.log_det_ratio(prior_prec, sigma_noise)))


@dataclasses.dataclass(frozen=True)
class MarglikResult:
    prior_prec: float
    sigma_noise: float
    history: np.ndarray  # evidence per optimizer step


def optimize_marglik(post, n_steps: int = 100, lr: float = 0.1,
                     init_prior_prec: Optional[float] = None,
                     init_sigma: Optional[float] = None,
                     tune_sigma: Optional[bool] = None):
    """Tune prior precision (and observation noise) by evidence ascent.

    Returns ``(post', MarglikResult)`` where ``post'`` carries the
    optimized hyperparameters (the curvature is reused, never re-swept).
    ``tune_sigma`` defaults to True for regression posteriors.  The whole
    Adam loop is one jitted ``lax.scan``.
    """
    if tune_sigma is None:
        tune_sigma = post.likelihood == "regression"
    inner = post.inner if isinstance(post, LastLayerLaplace) else post
    d0 = float(init_prior_prec if init_prior_prec is not None
               else inner.prior_prec)
    s0 = float(init_sigma if init_sigma is not None else inner.sigma_noise)

    def objective(theta):
        delta = jnp.exp(theta[0])
        sigma = jnp.exp(theta[1]) if tune_sigma else jnp.float32(s0)
        return -log_marglik(inner, delta, sigma)

    @jax.jit
    def run_opt(theta0):
        def step(carry, _):
            theta, m, v, t = carry
            val, g = jax.value_and_grad(objective)(theta)
            if not tune_sigma:
                g = g.at[1].set(0.0)
            t = t + 1.0
            m = 0.9 * m + 0.1 * g
            v = 0.999 * v + 0.001 * g * g
            mh = m / (1.0 - 0.9 ** t)
            vh = v / (1.0 - 0.999 ** t)
            theta = theta - lr * mh / (jnp.sqrt(vh) + 1e-8)
            return (theta, m, v, t), -val

        zeros = jnp.zeros_like(theta0)
        (theta, _, _, _), hist = jax.lax.scan(
            step, (theta0, zeros, zeros, jnp.float32(0.0)), None,
            length=n_steps)
        return theta, hist

    theta0 = jnp.log(jnp.asarray([d0, s0], jnp.float32))
    with obs.span("laplace/marglik", n_steps=n_steps,
                  tune_sigma=bool(tune_sigma)):
        theta, hist = run_opt(theta0)
    new_prior = float(jnp.exp(theta[0]))
    new_sigma = float(jnp.exp(theta[1])) if tune_sigma else s0
    new_inner = dataclasses.replace(inner, prior_prec=new_prior,
                                    sigma_noise=new_sigma)
    if isinstance(post, LastLayerLaplace):
        new_post = dataclasses.replace(post, inner=new_inner)
    else:
        new_post = new_inner
    return new_post, MarglikResult(prior_prec=new_prior,
                                   sigma_noise=new_sigma,
                                   history=np.asarray(hist))
