"""StableLM-2-1.6B [dense]: 24L d=2048 32H MHA (kv=32) d_ff=5632,
vocab=100352, LayerNorm, partial rotary 25%.  [hf:stabilityai/stablelm-2-1_6b]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="stablelm-1.6b", kind="dense", family="dense",
    n_layers=24, d_model=2048, n_heads=32, kv_heads=32, d_ff=5632,
    vocab=100352, act="silu", norm="layernorm", glu=True,
    rope_pct=0.25, qkv_bias=True,
    long_context_ok=False, source="hf:stabilityai/stablelm-2-1_6b; unverified",
)
