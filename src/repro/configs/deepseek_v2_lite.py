"""DeepSeek-V2-Lite-16B [moe+mla]: 27L d=2048 16H, MLA kv_lora=512
(qk_nope=128, qk_rope=64, v=128), 64 routed experts top-6 + 2 shared,
expert d_ff=1408, vocab=102400.  [arXiv:2405.04434; hf]

long_500k RUNS for this arch: MLA's compressed per-token cache
(kv_lora+rope = 576 floats/token/layer) is precisely its long-context
design point (~0.6 GB/layer at 524k, bf16).
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="deepseek-v2-lite-16b", kind="moe_mla", family="moe",
    n_layers=27, d_model=2048, n_heads=16, kv_heads=16, d_ff=1408,
    vocab=102400, act="silu", norm="rmsnorm",
    n_experts=64, top_k=6, d_expert=1408, n_shared_experts=2,
    kv_lora=512, qk_nope=128, qk_rope=64, v_head_dim=128,
    long_context_ok=True, source="arXiv:2405.04434; hf",
)
