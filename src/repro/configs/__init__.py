"""Architecture registry: ``get_config(arch_id)`` / ``ARCHS``."""
from repro.configs.base import (
    SHAPES,
    ModelConfig,
    Shape,
    input_specs,
    skipped_shapes,
    supported_shapes,
)

from repro.configs.internvl2_2b import ARCH as internvl2_2b
from repro.configs.granite_moe_1b import ARCH as granite_moe_1b
from repro.configs.deepseek_v2_lite import ARCH as deepseek_v2_lite
from repro.configs.stablelm_1_6b import ARCH as stablelm_1_6b
from repro.configs.gemma3_12b import ARCH as gemma3_12b
from repro.configs.h2o_danube3_4b import ARCH as h2o_danube3_4b
from repro.configs.codeqwen15_7b import ARCH as codeqwen15_7b
from repro.configs.whisper_tiny import ARCH as whisper_tiny
from repro.configs.rwkv6_3b import ARCH as rwkv6_3b
from repro.configs.hymba_1_5b import ARCH as hymba_1_5b

ARCHS = {
    c.name: c
    for c in [
        internvl2_2b, granite_moe_1b, deepseek_v2_lite, stablelm_1_6b,
        gemma3_12b, h2o_danube3_4b, codeqwen15_7b, whisper_tiny,
        rwkv6_3b, hymba_1_5b,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
