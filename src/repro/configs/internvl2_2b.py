"""InternVL2-2B [vlm]: InternViT frontend (stub) + InternLM2-1.8B backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553  [arXiv:2404.16821; hf]
Frontend is a STUB per task spec: ``input_specs`` provides precomputed patch
embeddings ([B, 256, d]) prepended to the text tokens.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="internvl2-2b", kind="dense", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, kv_heads=8, d_ff=8192,
    vocab=92553, act="silu", norm="rmsnorm", glu=True,
    rope_theta=1e6, frontend="vision", n_prefix=256,
    long_context_ok=False, source="arXiv:2404.16821; hf",
)
