"""Whisper-tiny [audio]: enc-dec, 4+4L d=384 6H d_ff=1536 vocab=51865,
conv frontend STUBBED (input_specs provides frame embeddings).
[arXiv:2212.04356; unverified]

decode shapes decode 1 text token against a seq_len-frame cross-attention
cache; long_500k skipped (full attention; 30 s audio ceiling).
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="whisper-tiny", kind="encdec", family="audio",
    n_layers=8, d_model=384, n_heads=6, kv_heads=6, d_ff=1536,
    vocab=51865, act="gelu", norm="layernorm", glu=False,
    frontend="audio", enc_layers=4, dec_layers=4, dec_len=448,
    long_context_ok=False, source="arXiv:2212.04356; unverified",
)
