"""RWKV6-3B "Finch" [ssm]: 32L d=2560 (40 heads x 64), attn-free,
data-dependent decay, channel-mix d_ff=8960, vocab=65536.
[arXiv:2404.05892; hf]

long_500k RUNS: O(1) recurrent state (no KV cache at all).
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="rwkv6-3b", kind="rwkv", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, kv_heads=40, d_ff=8960,
    vocab=65536, head_dim=64,
    long_context_ok=True, source="arXiv:2404.05892; hf",
)
