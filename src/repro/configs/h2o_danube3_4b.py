"""H2O-Danube-3-4B [dense]: 24L d=3840 32H (kv=8) d_ff=10240 vocab=32000,
llama+mistral mix with sliding-window attention (8192).
[arXiv:2401.16818; unverified]

long_500k RUNS: uniform SWA -> every layer's cache is a ring of 8192.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="h2o-danube-3-4b", kind="dense", family="dense",
    n_layers=24, d_model=3840, n_heads=32, kv_heads=8, d_ff=10240,
    vocab=32000, head_dim=120, act="silu", norm="rmsnorm", glu=True,
    window_segments=[(8192, 24)], pattern_repeat=1,
    long_context_ok=True, source="arXiv:2401.16818; unverified",
)
