"""Config dataclass, input-shape registry and spec builders.

The 40 dry-run cells are (architecture × shape); ``input_specs`` produces
``jax.ShapeDtypeStruct`` stand-ins (no allocation) for every cell, including
KV-cache trees for the decode shapes.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str       # dense | moe_gqa | moe_mla | rwkv | hymba | encdec
    family: str     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    act: str = "silu"
    norm: str = "rmsnorm"
    glu: bool = True
    rope_theta: float = 10000.0
    rope_pct: float = 1.0
    qkv_bias: bool = False
    # attention pattern: list of (window|None, count) repeated pattern_repeat×
    window_segments: Optional[List[Tuple[Optional[int], int]]] = None
    pattern_repeat: int = 1
    # moe
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # mla
    kv_lora: int = 0
    qk_nope: int = 128
    qk_rope: int = 64
    v_head_dim: int = 128
    # ssm / hybrid
    ssm_state: int = 0
    # frontend
    frontend: str = "none"  # none | vision | audio
    n_prefix: int = 0
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    dec_len: int = 448
    dtype: str = "bfloat16"
    # capability flags
    long_context_ok: bool = False
    source: str = ""

    def reduced(self):
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
            vocab=97, head_dim=16, dtype="float32",
        )
        if self.kind == "rwkv":
            kw.update(n_heads=4, head_dim=16, d_model=64)
        if self.window_segments is not None:
            kw["window_segments"] = [(8, 1), (None, 1)]
            kw["pattern_repeat"] = 1
        if self.n_experts:
            kw.update(n_experts=4, top_k=2, d_expert=32)
        if self.n_shared_experts:
            kw.update(n_shared_experts=1)
        if self.kv_lora:
            kw.update(kv_lora=32, qk_nope=16, qk_rope=8, v_head_dim=16)
        if self.ssm_state:
            kw.update(ssm_state=8)
        if self.frontend == "vision":
            kw.update(n_prefix=4)
        if self.kind == "encdec":
            kw.update(enc_layers=2, dec_layers=2, dec_len=8, n_layers=4)
        return dataclasses.replace(self, **kw)

    # ---- analytics ---------------------------------------------------------
    def param_count(self, model=None) -> int:
        from repro.nn.models import build_model

        import math

        model = model or build_model(self)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        return sum(
            math.prod(l.shape) if l.shape else 1
            for l in jax.tree.leaves(shapes)
        )

    def active_param_count(self, model=None) -> int:
        """Params touched per token (MoE: top-k of routed experts)."""
        total = self.param_count(model)
        if not self.n_experts:
            return total
        per_expert = 3 * self.d_model * self.d_expert
        routed = self.n_layers * self.n_experts * per_expert
        active = self.n_layers * self.top_k * per_expert
        return total - routed + active


def supported_shapes(cfg: ModelConfig):
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.long_context_ok:
        names.append("long_500k")
    return [SHAPES[n] for n in names]


def skipped_shapes(cfg: ModelConfig):
    return [] if cfg.long_context_ok else [SHAPES["long_500k"]]


def input_specs(cfg: ModelConfig, shape: Shape, model=None, batch=None):
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell.

    Returns (kind, specs-dict).  ``batch`` overrides the global batch (for
    per-device or reduced runs).
    """
    from repro.nn.models import build_model

    b = batch or shape.global_batch
    t = shape.seq_len
    i32 = jnp.int32
    act_dtype = jnp.dtype(cfg.dtype)

    if cfg.kind == "encdec":
        if shape.kind in ("train", "prefill"):
            return shape.kind, {
                "inputs": {
                    "frames": jax.ShapeDtypeStruct((b, t, cfg.d_model), act_dtype),
                    "tokens": jax.ShapeDtypeStruct((b, cfg.dec_len), i32),
                },
                "labels": jax.ShapeDtypeStruct((b, cfg.dec_len), i32),
            }
        model = model or build_model(cfg)
        params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        enc_spec = jax.ShapeDtypeStruct((b, t, cfg.d_model), act_dtype)
        caches = jax.eval_shape(
            lambda p, e: model.init_serve_cache(p, b, t, act_dtype, enc_out=e),
            params_spec, enc_spec,
        )
        return "decode", {
            "caches": caches,
            "tokens": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }

    if cfg.frontend == "vision":
        inputs = {
            "tokens": jax.ShapeDtypeStruct((b, t - cfg.n_prefix), i32),
            "prefix": jax.ShapeDtypeStruct((b, cfg.n_prefix, cfg.d_model), act_dtype),
        }
    else:
        inputs = jax.ShapeDtypeStruct((b, t), i32)

    if shape.kind == "train":
        return "train", {
            "inputs": inputs,
            "labels": jax.ShapeDtypeStruct((b, t), i32),
        }
    if shape.kind == "prefill":
        return "prefill", {"inputs": inputs}

    model = model or build_model(cfg)
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    caches = jax.eval_shape(
        lambda p: model.init_serve_cache(p, b, t, act_dtype), params_spec
    )
    return "decode", {
        "caches": caches,
        "tokens": jax.ShapeDtypeStruct((b,), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
    }
