"""Gemma-3-12B [dense]: 48L d=3840 16H (kv=8) d_ff=15360 vocab=262144,
5 local (window 1024) : 1 global pattern ×8, GeGLU.  [unverified]

long_500k RUNS: 40/48 layers have ring caches (1024); the 8 global layers
keep full caches — O(T) memory on 1/6 of layers, documented in DESIGN.md.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="gemma3-12b", kind="dense", family="dense",
    n_layers=48, d_model=3840, n_heads=16, kv_heads=8, d_ff=15360,
    vocab=262144, head_dim=240, act="gelu", norm="rmsnorm", glu=True,
    rope_theta=1e6, window_segments=[(1024, 5), (None, 1)], pattern_repeat=8,
    long_context_ok=True, source="hf:google/gemma-3; unverified",
)
