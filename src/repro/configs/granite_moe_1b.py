"""Granite-3.0-1B-A400M [moe]: 24L d=1024 16H (kv=8) expert d_ff=512,
32 experts top-8, vocab=49155.  [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="granite-moe-1b-a400m", kind="moe_gqa", family="moe",
    n_layers=24, d_model=1024, n_heads=16, kv_heads=8, d_ff=512,
    vocab=49155, act="silu", norm="rmsnorm",
    n_experts=32, top_k=8, d_expert=512,
    long_context_ok=False, source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
