"""The paper's own benchmark networks (DeepOBS, Table 3) as module trees.

LogReg (MNIST), 2C2D (F-MNIST), 3C3D (CIFAR-10), All-CNN-C (CIFAR-100) —
used by the Fig. 3/6/7/8/9 benchmark harnesses and trained on synthetic
image data.  Conv layers use the unfold formulation so all BackPACK
extensions apply (Grosse & Martens 2016).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.module import Activation, Dense, Sequential
from repro.nn.layers import Conv2d, Flatten, MaxPool2d


def logreg(n_classes=10, in_dim=784):
    return Sequential([Dense(in_dim, n_classes)])


def mlp(n_classes=10, in_dim=784, hidden=(128, 64), act="sigmoid"):
    mods = []
    d = in_dim
    for h in hidden:
        mods += [Dense(d, h), Activation(act)]
        d = h
    mods.append(Dense(d, n_classes))
    return Sequential(mods)


def c2d2(n_classes=10, in_ch=1, img=28):
    """2 conv + 2 dense (the paper's 2C2D, scaled by `img`)."""
    after = img // 4
    return Sequential([
        Conv2d(in_ch, 32, kernel=5, padding="SAME"), Activation("relu"),
        MaxPool2d(2),
        Conv2d(32, 64, kernel=5, padding="SAME"), Activation("relu"),
        MaxPool2d(2),
        Flatten(),
        Dense(after * after * 64, 256), Activation("relu"),
        Dense(256, n_classes),
    ])


def c3d3(n_classes=10, in_ch=3, img=32):
    """3 conv + 3 dense (the paper's 3C3D on CIFAR-10)."""
    after = img // 8
    return Sequential([
        Conv2d(in_ch, 64, kernel=5, padding="SAME"), Activation("relu"),
        MaxPool2d(2),
        Conv2d(64, 96, kernel=3, padding="SAME"), Activation("relu"),
        MaxPool2d(2),
        Conv2d(96, 128, kernel=3, padding="SAME"), Activation("relu"),
        MaxPool2d(2),
        Flatten(),
        Dense(after * after * 128, 512), Activation("relu"),
        Dense(512, 256), Activation("relu"),
        Dense(256, n_classes),
    ])


def allcnnc(n_classes=100, in_ch=3, img=32, width=96):
    """All-CNN-C (Springenberg 2015): 9 conv layers, no dense."""
    w2 = 2 * width
    return Sequential([
        Conv2d(in_ch, width, 3), Activation("relu"),
        Conv2d(width, width, 3), Activation("relu"),
        Conv2d(width, width, 3, stride=2), Activation("relu"),
        Conv2d(width, w2, 3), Activation("relu"),
        Conv2d(w2, w2, 3), Activation("relu"),
        Conv2d(w2, w2, 3, stride=2), Activation("relu"),
        Conv2d(w2, w2, 3, padding="VALID"), Activation("relu"),
        Conv2d(w2, w2, 1), Activation("relu"),
        Conv2d(w2, n_classes, 1),
        GlobalAvgPool(),
    ])


class GlobalAvgPool(Sequential):
    def __init__(self):
        super().__init__([])

    def apply(self, params, x):
        return jnp.mean(x, axis=(1, 2))

    def forward_tape(self, params, x):
        return self.apply(params, x), x

    def backward(self, params, tape, g, exts, cfg):
        import jax

        _, vjp = jax.vjp(lambda xx: self.apply((), xx), tape)
        return vjp(g)[0], (), ()

    def jac_t_mat(self, params, tape, M):
        import jax

        _, vjp = jax.vjp(lambda xx: self.apply((), xx), tape)
        return jax.vmap(lambda m: vjp(m)[0])(M)

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        return self.jac_t_mat(params, tape, S), ()
