"""Hymba-1.5B [hybrid]: 32L d=1600 25H (kv=5) d_ff=5504, parallel
attention + SSD heads (ssm_state=16), SWA everywhere except 3 global
layers (first/middle/last).  [arXiv:2411.13676; hf]

long_500k RUNS: SSM state is O(1); attention caches are rings (1024)
except the 3 global layers.
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="hymba-1.5b", kind="hymba", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, kv_heads=5, d_ff=5504,
    vocab=32001, head_dim=64, ssm_state=16,
    window_segments=[(None, 1), (1024, 15), (None, 1), (1024, 14), (None, 1)],
    pattern_repeat=1,
    long_context_ok=True, source="arXiv:2411.13676; hf",
)
