"""CodeQwen1.5-7B [dense]: 32L d=4096 32H MHA (kv=32) d_ff=13440
vocab=92416, qwen1.5 arch (qkv bias).  [hf:Qwen/CodeQwen1.5-7B]
"""
from repro.configs.base import ModelConfig

ARCH = ModelConfig(
    name="codeqwen1.5-7b", kind="dense", family="dense",
    n_layers=32, d_model=4096, n_heads=32, kv_heads=32, d_ff=13440,
    vocab=92416, act="silu", norm="rmsnorm", glu=True, qkv_bias=True,
    rope_theta=1e6,
    long_context_ok=False, source="hf:Qwen/CodeQwen1.5-7B; hf",
)
