"""Per-sequence gradient second moment, fused: M = Σ_n (A_nᵀ B_n)∘².

The [N, a, b] per-sample gradient tensor NEVER exists in HBM: one [ba×bb]
VMEM tile of sample n's gradient is formed on the MXU, squared in VREGs and
accumulated.  This is the TPU-native form of the paper's memory argument
(§2.2: "expensive in memory: O(ND) is prohibitive") — the sum over the
sequence axis inside the square is what rules out the simple (A²)ᵀ(B²)
factorization for sequence models.

Tiling: grid (a/ba, b/bb, N); per step the kernel loads A[n]: [R, ba] and
B[n]: [R, bb] (R = sequence axis, padded to a lane multiple), computes the
[ba×bb] tile, squares, accumulates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compiler import mosaic_params


def _kernel(a_ref, b_ref, o_ref):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0].astype(jnp.float32)  # [R, ba]
    b = b_ref[0].astype(jnp.float32)  # [R, bb]
    g = jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[...] += g * g


def per_sample_moment_pallas(A, B, *, block_a=128, block_b=128,
                             interpret=True):
    """A: [N, R, a], B: [N, R, b] → [a, b] float32."""
    n, r, a = A.shape
    b = B.shape[-1]
    grid = (pl.cdiv(a, block_a), pl.cdiv(b, block_b), n)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, r, block_a), lambda i, j, k: (k, 0, i)),
            pl.BlockSpec((1, r, block_b), lambda i, j, k: (k, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_a, block_b), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, b), jnp.float32),
        compiler_params=mosaic_params("parallel", "parallel", "arbitrary",
                                      interpret=interpret),
        interpret=interpret,
    )(A, B)
