"""Chunked WKV (RWKV6/SSD) forward Pallas kernel.

The chunk-parallel linear-attention recurrence with the running state
``S [dk, dv]`` held in VMEM scratch across chunk iterations — the kernel
behind the `kernelize` roofline accounting for the `wkvchunk_` scans.

Grid: (batch·heads,) with the chunk loop inside the kernel body; per chunk
the intra-chunk work is two MXU matmuls + the carry update (see
`nn/functional.wkv_chunked` for the algebra; this kernel is its fused
single-(batch,head) instantiation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, *, chunk, n_chunks):
    # refs: [1, T, dk|dv]; u_ref: [1, dk]
    dk = r_ref.shape[2]
    dv = v_ref.shape[2]
    strict = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
    u = u_ref[0].astype(jnp.float32)

    def body(c, S):
        # Leading dim must be a Slice, not an int — jax 0.4.x's
        # interpret-mode discharge rule chokes on scalar indices.
        sl = (pl.dslice(0, 1), pl.dslice(c * chunk, chunk), slice(None))
        rc = pl.load(r_ref, sl)[0].astype(jnp.float32)
        kc = pl.load(k_ref, sl)[0].astype(jnp.float32)
        vc = pl.load(v_ref, sl)[0].astype(jnp.float32)
        lwc = jnp.clip(pl.load(lw_ref, sl)[0].astype(jnp.float32),
                       -60.0, -1e-6)
        P = jnp.cumsum(lwc, axis=0)
        E = P - lwc
        r_t = rc * jnp.exp(E)
        k_t = kc * jnp.exp(-P)
        A = jax.lax.dot_general(r_t, k_t, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * strict
        y = jax.lax.dot_general(A, vc, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        diag = jnp.sum(rc * u[None] * kc, axis=-1)
        y = y + diag[:, None] * vc
        y = y + jax.lax.dot_general(r_t, S, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
        pl.store(y_ref, sl, y[None].astype(y_ref.dtype))
        decay_end = jnp.exp(P[-1])
        k_end = kc * jnp.exp(P[-1][None] - P)
        S_new = decay_end[:, None] * S + jax.lax.dot_general(
            k_end, vc, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return S_new

    S = jnp.zeros((dk, dv), jnp.float32)
    S = jax.lax.fori_loop(0, n_chunks, body, S)


def wkv_pallas(r, k, v, log_w, u, *, chunk=64, interpret=True):
    """r,k: [N,T,H,dk]; v: [N,T,H,dv]; log_w like r; u: [H,dk] → y [N,T,H,dv]."""
    n, t, h, dk = r.shape
    dv = v.shape[-1]
    while t % chunk:
        chunk //= 2
    chunk = max(chunk, 1)
    nc = t // chunk

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(n * h, t, x.shape[-1])

    lw = jnp.broadcast_to(log_w, r.shape)
    uu = jnp.broadcast_to(u, (h, dk))
    u_flat = jnp.tile(uu, (n, 1))
    kern = functools.partial(_kernel, chunk=chunk, n_chunks=nc)
    y = pl.pallas_call(
        kern,
        grid=(n * h,),
        in_specs=[
            pl.BlockSpec((1, t, dk), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, t, dk), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, t, dv), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, t, dk), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, dk), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, dv), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n * h, t, dv), r.dtype),
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(lw), u_flat)
    return jnp.moveaxis(y.reshape(n, h, t, dv), 1, 2)
