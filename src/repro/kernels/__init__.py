from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.wkv import wkv_pallas
from repro.kernels.ops import batch_l2, ggn_diag, per_sample_moment, sq_matmul
