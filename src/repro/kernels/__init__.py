from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_first_order import fused_first_order_pallas
from repro.kernels.wkv import wkv_pallas
from repro.kernels.fused_second_order import fused_second_order_pallas
from repro.kernels.predictive_var import predictive_var_pallas
from repro.kernels.ops import (
    batch_l2,
    cache_stats,
    dispatch,
    fused_first_order,
    fused_second_order,
    ggn_diag,
    per_sample_moment,
    predictive_var,
    registered,
    sq_matmul,
)
