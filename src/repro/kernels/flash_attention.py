"""Flash-attention forward Pallas kernel (causal + sliding window, GQA).

Online-softmax over k-blocks with the running (m, l, acc) state held in
VMEM scratch — the [T×S] logits/probability matrices never exist in HBM.
This is the kernel the §Perf "Pallas-fused" accounting models: per q-block
the HBM traffic is (q block in, k/v blocks streamed, out block written).

Grid: (batch, kv_head, q_blocks) with the k-loop INSIDE the kernel body
(lax.fori_loop over k blocks) so the accumulators stay resident.
Backward on TPU would recompute per-block (standard flash bwd); training
uses the jnp `sdpa_chunked` path whose checkpointed q-blocks implement the
same recompute schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, causal, window, scale,
            seq_q, seq_k):
    # q_ref: [1, bq, g, dh]; k_ref/v_ref: [1, S, dh]; o_ref: [1, bq, g, dh]
    qi = pl.program_id(2)
    bq = q_ref.shape[1]
    g = q_ref.shape[2]
    dh = q_ref.shape[3]
    q = q_ref[0].astype(jnp.float32) * scale
    q2 = q.reshape(bq * g, dh)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)[:, 0]

    n_kb = pl.cdiv(seq_k, block_k)

    def body(kb, carry):
        m, l, acc = carry
        # NB: leading dim must be a Slice, not an int — jax 0.4.x's
        # interpret-mode discharge rule chokes on scalar indices here.
        k_blk = pl.load(k_ref, (pl.dslice(0, 1),
                                pl.dslice(kb * block_k, block_k),
                                slice(None)))[0].astype(jnp.float32)
        v_blk = pl.load(v_ref, (pl.dslice(0, 1),
                                pl.dslice(kb * block_k, block_k),
                                slice(None)))[0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q2, k_blk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(bq, g, block_k)
        k_pos = kb * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, block_k), 2)
        mask = k_pos < seq_k
        if causal:
            mask &= q_pos[:, None, None] >= k_pos
        if window is not None:
            mask &= (q_pos[:, None, None] - k_pos) < window
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jax.lax.dot_general(
            p.reshape(bq * g, block_k), v_blk,
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        ).reshape(bq, g, dh)
        acc_new = acc * corr[..., None] + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((bq, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, g), jnp.float32)
    a0 = jnp.zeros((bq, g, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None,
                           block_q=128, block_k=128, interpret=True):
    """q: [N, T, H, dh]; k/v: [N, S, KV, dh] → [N, T, H, dh]."""
    n, t, h, dh = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = dh ** -0.5
    bq = min(block_q, t)
    grid = (n * kv, 1, pl.cdiv(t, bq))
    qg = q.reshape(n, t, kv, g, dh)
    qg = jnp.moveaxis(qg, 2, 1).reshape(n * kv, t, g, dh)
    kg = jnp.moveaxis(k, 2, 1).reshape(n * kv, s, dh)
    vg = jnp.moveaxis(v, 2, 1).reshape(n * kv, s, dh)
    kern = functools.partial(
        _kernel, block_k=min(block_k, s), causal=causal, window=window,
        scale=scale, seq_q=t, seq_k=s)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, g, dh), lambda b, _, i: (b, i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda b, _, i: (b, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda b, _, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, g, dh), lambda b, _, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n * kv, t, g, dh), q.dtype),
        interpret=interpret,
    )(qg, kg, vg)
    out = out.reshape(n, kv, t, g, dh)
    return jnp.moveaxis(out, 1, 2).reshape(n, t, h, dh)
