"""Kernel dispatch registry — the single entry point for reduction kernels.

Every BackPACK *reduction* kernel (the first-order/curvature statistics) is
published through one :class:`KernelSpec` table instead of ad-hoc
per-kernel wrappers.  (The sequence-mixing kernels — flash attention, WKV —
keep their own entry points in their modules: their call signatures are
layer-shaped, not reduction-shaped.)  The registry owns, in one place:

* **Padding** to block multiples.  Feature axes pad to the (shape-clamped)
  block size, sample/sequence axes to sublane multiples of 8.  Zeros are
  exact for every reduction here (they contribute nothing to a sum of
  products), so wrappers pad inputs and slice outputs.
* **Backend selection** — ``interpret=True`` on CPU (kernel bodies run under
  the Pallas interpreter: the correctness path for this container), compiled
  Mosaic on TPU.  Decided once in :func:`_interpret`, injected into every
  wrapper.
* **Jit caching** — :func:`dispatch` memoizes one jitted callable per
  ``(kernel, static options, backend)`` configuration; ``jax.jit``'s own
  shape-keyed cache then handles per-shape retracing, so hot training
  loops never re-trace and :func:`cache_stats` reports what has been set
  up.

Registered kernels (see :func:`registered`):

``sq_matmul``          (A∘A)ᵀ(B∘B) — rank-1 second moment (App. A.1)
``per_sample_moment``  Σ_n (A_nᵀB_n)∘² — sequence second moment
``batch_l2``           per-sample gradient norms via the Gram trick
``ggn_diag``           GGN diagonal from backpropagated factors (Eq. 19/22)
``fused_first_order``  ONE pass emitting {l2, moment, dot} under a static
                       extension mask — the mask maps 1:1 onto the
                       first-order extensions: ``want_l2`` ↔ BatchL2,
                       ``want_moment`` ↔ SecondMoment/Variance, ``want_dot``
                       ↔ BatchDot.  Unrequested outputs cost nothing.
                       A leading group axis batches MoE experts.
``fused_second_order`` ONE pass over (A, S) emitting {diag, kron, trace}
                       under a static mask: ``want_diag`` ↔ DiagGGN(MC),
                       ``want_kron`` ↔ KFLR/KFAC B-factor, ``want_trace`` ↔
                       per-sample GGN trace.  The class axis is folded into
                       the grid in ``class_chunk``-sized chunks (exact
                       curvature at LM-vocabulary scale with bounded VMEM).
``predictive_var``     GLM predictive variance diag(J Σ Jᵀ) [C, N] from the
                       Jacobian-factor pair (A, S) in one pass — diag Σ via
                       an elementwise ``Sigma [a, b]`` weight, Kronecker Σ
                       via caller-side half-transforms (see the kernel
                       module doc).  The Laplace serving hot path.

Adding a kernel: write the Pallas body in its own module, then register a
wrapper here with ``@register("name", ref=ref.name)``; the wrapper receives
``interpret=`` from the registry and owns only its pad/slice policy.  Public
module-level functions (``ops.batch_l2`` etc.) stay thin aliases over
:func:`dispatch`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import ref
from repro.kernels.batch_l2 import batch_l2_pallas
from repro.kernels.cross_dot import cross_dot_pallas
from repro.kernels.fused_first_order import fused_first_order_pallas
from repro.kernels.fused_second_order import fused_second_order_pallas
from repro.kernels.ggn_diag import ggn_diag_pallas
from repro.kernels.per_sample_moment import per_sample_moment_pallas
from repro.kernels.predictive_var import predictive_var_pallas
from repro.kernels.sq_matmul import sq_matmul_pallas


# ---------------------------------------------------------------------------
# registry core
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered kernel: padded wrapper + its pure-jnp oracle."""

    name: str
    wrapper: Callable  # (*arrays, interpret=..., **static) -> outputs
    ref: Optional[Callable]
    description: str


_REGISTRY: Dict[str, KernelSpec] = {}
_JIT_CACHE: Dict[Tuple, Callable] = {}

# dispatch-time telemetry (host side — nothing lands inside jitted code):
# per-kernel jit-config cache hits/misses, and the padding-waste bytes one
# call pays, measured once per (config, arg shapes) while the wrapper
# traces and replayed from _PAD_WASTE on every cached-shape dispatch.
_CACHE_HITS: Dict[str, int] = {}
_CACHE_MISSES: Dict[str, int] = {}
_PAD_WASTE: Dict[Tuple, int] = {}
_PAD_NOTE: List[List[int]] = []  # active accumulation cells (see _pad_to)


def register(name: str, *, ref: Optional[Callable] = None,
             description: str = ""):
    """Decorator adding a padded kernel wrapper to the dispatch table.

    Parameters
    ----------
    name : str
        Registry key.  :func:`dispatch` and the public aliases resolve
        kernels by this name; benchmark lanes and the differential tests
        enumerate :func:`registered` to find it.
    ref : callable, optional
        Pure-jnp oracle with the same signature — the correctness
        baseline the differential suite compares the kernel against.
    description : str, optional
        One-line summary for tooling (defaults to the wrapper's first
        docstring line).

    Returns
    -------
    callable
        The decorator.  The wrapped function receives ``interpret=``
        from the registry (CPU interpreter vs compiled Mosaic) and owns
        only its pad/slice policy; blocks it does not pin are auto-sized
        from the shapes it is *called* with — under the sharded sweep
        lane that is the shard-local batch, under the accumulated lane
        the microbatch slice, so streaming a batch automatically shrinks
        the per-launch working set (see ``_auto_class_chunk``).

    Examples
    --------
    >>> @register("my_stat", ref=ref.my_stat)
    ... def _my_stat(A, B, *, block_a=128, interpret=True):
    ...     '''stat[n] = reduce(A_n, B_n): A [N, R, a], B [N, R, b].'''
    ...     ...
    """

    def deco(fn):
        _REGISTRY[name] = KernelSpec(
            name, fn, ref, description or (fn.__doc__ or "").strip())
        return fn

    return deco


def registered() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_spec(name: str) -> KernelSpec:
    return _REGISTRY[name]


def _interpret() -> bool:
    """CPU → Pallas interpreter (correctness path); TPU → compiled Mosaic."""
    return jax.default_backend() == "cpu"


def dispatch(name: str, *args, **static) -> Any:
    """Run a registered kernel through the jit cache.

    One jitted callable per (kernel, static opts, backend) config;
    per-shape compilation caching is jax.jit's own.
    """
    spec = _REGISTRY[name]
    interpret = _interpret()
    key = (name, tuple(sorted(static.items())), interpret)
    fn = _JIT_CACHE.get(key)
    reg = obs.get()
    if fn is None:
        fn = jax.jit(partial(spec.wrapper, interpret=interpret, **static))
        _JIT_CACHE[key] = fn
        _CACHE_MISSES[name] = _CACHE_MISSES.get(name, 0) + 1
        if reg.enabled:
            reg.count(f"kernel.cache_miss.{name}")
    else:
        _CACHE_HITS[name] = _CACHE_HITS.get(name, 0) + 1
        if reg.enabled:
            reg.count(f"kernel.cache_hit.{name}")
    shapes = tuple(
        (tuple(a.shape), str(a.dtype)) for a in args if hasattr(a, "shape")
    )
    waste = _PAD_WASTE.get((key, shapes))
    if waste is None:
        # first time this config sees these shapes: the wrapper is about
        # to trace (jax.jit's shape cache is cold), so _pad_to calls run
        # now — collect their waste into a fresh accumulation cell
        _PAD_NOTE.append([0])
        try:
            out = fn(*args)
        finally:
            waste = _PAD_NOTE.pop()[0]
        _PAD_WASTE[(key, shapes)] = waste
    else:
        out = fn(*args)
    if reg.enabled:
        reg.count(f"kernel.calls.{name}")
        if waste:
            reg.count(f"kernel.padding_waste_bytes.{name}", waste)
    return out


def cache_stats() -> Dict[str, Any]:
    """Per-kernel count of cached jit configurations (plus the total),
    and per-kernel dispatch hit/miss counters under ``"hits"``/``"misses"``
    (a retrace storm shows up as misses outrunning hits)."""
    out: Dict[str, Any] = {"total": len(_JIT_CACHE)}
    for key in _JIT_CACHE:
        out[key[0]] = out.get(key[0], 0) + 1
    out["hits"] = dict(_CACHE_HITS)
    out["misses"] = dict(_CACHE_MISSES)
    return out


def clear_cache() -> None:
    _JIT_CACHE.clear()
    _CACHE_HITS.clear()
    _CACHE_MISSES.clear()
    _PAD_WASTE.clear()


# ---------------------------------------------------------------------------
# shared padding policy
# ---------------------------------------------------------------------------


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    if _PAD_NOTE:
        # dispatch is tracing this wrapper for the first time with these
        # shapes: note the zero-fill bytes this pad costs per call.  Pure
        # shape arithmetic — works identically on tracers.
        per_row = x.size // x.shape[axis] if x.shape[axis] else 0
        _PAD_NOTE[-1][0] += pad * per_row * x.dtype.itemsize
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _clamp_block(block, dim):
    """Shrink an oversized feature block to the (≥8) padded dimension."""
    return min(block, max(dim, 8))


def _auto_block(dim, cap):
    """Largest even split of ``dim`` into ≤``cap``-wide tiles, sublane-rounded.

    Plain ``min(cap, dim)`` pads dims just above a cap multiple by up to
    ~2x (e.g. 520 → 1024 with cap 512); splitting evenly first keeps the
    big-tile amortization with ≤ one sublane row of padding per tile
    (520 → 2×264).
    """
    if dim <= 8:
        return 8
    n_tiles = -(-dim // cap)
    return min(cap, -(-(-(-dim // n_tiles)) // 8) * 8)


def _auto_class_chunk(S2, ba, bb, *, mxu_intermediate, kron_view=False):
    """VMEM-budgeted class chunk, sized from the **local** batch.

    The per-class float32 working set of one grid step: the S tile, plus
    the [C'·N, ba, bb] MXU contraction intermediate when requested, plus
    the full-width second S view for the Kronecker output.  The estimate
    scales with the batch the kernel actually sees — under the
    batch-sharded sweep lane (``SweepPlan.shard``) that is the
    *shard-local* N, and under the streaming accumulated lane
    (``SweepPlan.accumulate``) the *microbatch* slice, so smaller shards
    or microbatches automatically take larger class chunks (fewer grid
    steps) inside the same ~4 MiB budget.  The two compose: the shard ×
    accumulate grid sizes chunks from the shard-local microbatch.
    """
    n2, r2 = S2.shape[1], S2.shape[2]
    per_c = n2 * r2 * bb
    if mxu_intermediate:
        per_c += n2 * ba * bb
    if kron_view:
        per_c += n2 * r2 * S2.shape[3]
    return max(1, (1 << 20) // max(per_c, 1))


def _pad_factor_pair(A, S, block_a, block_b, interpret):
    """Shared block-sizing + padding policy for the ``(A, S)`` kernels
    (``fused_second_order``, ``predictive_var``): A [N, R, a] and
    S [C, N, R, b] padded to (auto- or caller-chosen) feature blocks and
    sublane multiples.  Returns ``(A2, S2, ba, bb)``; auto ``class_chunk``
    budgets live in :func:`_auto_class_chunk` (per-kernel flags select
    which working-set terms apply)."""
    a, b = A.shape[-1], S.shape[-1]
    cap = 512 if interpret else 128
    ba = (_clamp_block(block_a, a) if block_a is not None
          else _auto_block(a, cap))
    bb = (_clamp_block(block_b, b) if block_b is not None
          else _auto_block(b, cap))
    A2 = _pad_to(_pad_to(_pad_to(A, 2, ba), 1, 8), 0, 8)
    S2 = _pad_to(_pad_to(_pad_to(S, 3, bb), 2, 8), 1, 8)
    return A2, S2, ba, bb


# ---------------------------------------------------------------------------
# registered wrappers
# ---------------------------------------------------------------------------


@register("sq_matmul", ref=ref.sq_matmul)
def _sq_matmul(A, B, *, block_a=128, block_b=128, block_n=256,
               interpret=True):
    """C = (A∘A)ᵀ(B∘B): A [N, a], B [N, b] → [a, b]."""
    a, b = A.shape[1], B.shape[1]
    ba, bb = _clamp_block(block_a, a), _clamp_block(block_b, b)
    A2 = _pad_to(_pad_to(A, 1, ba), 0, 8)
    B2 = _pad_to(_pad_to(B, 1, bb), 0, 8)
    bn = min(block_n, A2.shape[0])
    out = sq_matmul_pallas(A2, B2, block_a=ba, block_b=bb, block_n=bn,
                           interpret=interpret)
    return out[:a, :b]


@register("per_sample_moment", ref=ref.per_sample_moment)
def _per_sample_moment(A, B, *, block_a=128, block_b=128, interpret=True):
    """M = Σ_n (A_nᵀB_n)∘²: A [N, R, a], B [N, R, b] → [a, b]."""
    a, b = A.shape[-1], B.shape[-1]
    ba, bb = _clamp_block(block_a, a), _clamp_block(block_b, b)
    A2 = _pad_to(_pad_to(A, 2, ba), 1, 8)
    B2 = _pad_to(_pad_to(B, 2, bb), 1, 8)
    out = per_sample_moment_pallas(A2, B2, block_a=ba, block_b=bb,
                                   interpret=interpret)
    return out[:a, :b]


@register("batch_l2", ref=ref.batch_l2)
def _batch_l2(A, B, *, block_r=128, interpret=True):
    """l2[n] = ‖A_nᵀB_n‖²: A [N, R, a], B [N, R, b] → [N]."""
    r = A.shape[1]
    br = _clamp_block(block_r, r)
    A2 = _pad_to(A, 1, br)
    B2 = _pad_to(B, 1, br)
    return batch_l2_pallas(A2, B2, block_r=br, interpret=interpret)


@register("ggn_diag", ref=ref.ggn_diag)
def _ggn_diag(A, S, *, block_a=128, block_b=128, interpret=True):
    """GGN diag: A [N, R, a], S [C, N, R, b] → [a, b]."""
    a, b = A.shape[-1], S.shape[-1]
    ba, bb = _clamp_block(block_a, a), _clamp_block(block_b, b)
    A2 = _pad_to(_pad_to(A, 2, ba), 1, 8)
    S2 = _pad_to(_pad_to(S, 3, bb), 2, 8)
    out = ggn_diag_pallas(A2, S2, block_a=ba, block_b=bb,
                          interpret=interpret)
    return out[:a, :b]


@register("fused_first_order", ref=ref.fused_first_order)
def _fused_first_order(A, B, *, want_l2=True, want_moment=False,
                       want_dot=False, block_a=None, block_b=None,
                       interpret=True):
    """One pass over (A, B) emitting the masked first-order stats.

    A: [E, N, R, a], B: [E, N, R, b] → dict of
    l2 [E, N] / moment [E, a, b] / dot [E, N, N] (requested keys only).
    Zero-padding N and R is exact; padded l2 rows and dot rows/cols are
    sliced off, moment is unaffected.

    Default blocks are backend-aware (``None`` = auto): MXU-native 128 under
    Mosaic; 512 under the CPU interpreter, where per-grid-step overhead
    dominates and bigger tiles amortize it.
    """
    e, n, r, a = A.shape
    b = B.shape[-1]
    cap = 512 if interpret else 128
    ba = (_clamp_block(block_a, a) if block_a is not None
          else _auto_block(a, cap))
    bb = (_clamp_block(block_b, b) if block_b is not None
          else _auto_block(b, cap))
    A2 = _pad_to(_pad_to(_pad_to(A, 3, ba), 2, 8), 1, 8)
    B2 = _pad_to(_pad_to(_pad_to(B, 3, bb), 2, 8), 1, 8)
    out = fused_first_order_pallas(
        A2, B2, want_l2=want_l2, want_moment=want_moment, want_dot=want_dot,
        block_a=ba, block_b=bb, interpret=interpret)
    if "l2" in out:
        out["l2"] = out["l2"][:, :n]
    if "moment" in out:
        out["moment"] = out["moment"][:, :a, :b]
    if "dot" in out:
        out["dot"] = out["dot"][:, :n, :n]
    return out


@register("cross_dot", ref=ref.cross_dot)
def _cross_dot(A1, B1, A2, B2, *, block_a=None, block_b=None,
               interpret=True):
    """Cross-block pairwise dots: out[e,n,m] = ⟨A1ᵀB1[n], A2ᵀB2[m]⟩.

    A1/B1: [E, N1, R, a/b], A2/B2: [E, N2, R, a/b] → [E, N1, N2] float32
    — the off-diagonal Gram / empirical-NTK row-block tile.  Zero-padding
    N1, N2 and R is exact (padded per-sample gradients are zero and
    contribute nothing to any dot); padded output rows/cols are sliced
    off.
    """
    e, n1, r, a = A1.shape
    n2 = A2.shape[1]
    b = B1.shape[-1]
    cap = 512 if interpret else 128
    ba = (_clamp_block(block_a, a) if block_a is not None
          else _auto_block(a, cap))
    bb = (_clamp_block(block_b, b) if block_b is not None
          else _auto_block(b, cap))

    def prep(x, blk):
        return _pad_to(_pad_to(_pad_to(x, 3, blk), 2, 8), 1, 8)

    out = cross_dot_pallas(prep(A1, ba), prep(B1, bb),
                           prep(A2, ba), prep(B2, bb),
                           block_a=ba, block_b=bb, interpret=interpret)
    return out[:, :n1, :n2]


@register("fused_second_order", ref=ref.fused_second_order)
def _fused_second_order(A, S, *, want_diag=True, want_kron=False,
                        want_trace=False, block_a=None, block_b=None,
                        class_chunk=None, interpret=True):
    """One pass over (A, S) emitting the masked second-order stats.

    A: [N, R, a], S: [C, N, R, b] → dict of diag [a, b] / kron [b, b]
    (unscaled SᵀS) / trace [N] (requested keys only).  Zero-padding N, R
    and C is exact (padded entries contribute nothing to any sum of
    products); padded trace entries are sliced off, diag/kron rows and
    columns likewise.

    ``class_chunk`` bounds the VMEM-resident working set per grid step
    (``None`` = auto: the whole class axis when it fits a ~4 MiB float32
    budget, chunked otherwise) — the grid folds the class axis so the
    per-class contribution tensor never materializes.
    """
    c, n, r, b = S.shape
    a = A.shape[-1]
    A2, S2, ba, bb = _pad_factor_pair(A, S, block_a, block_b, interpret)
    if class_chunk is None:
        class_chunk = _auto_class_chunk(
            S2, ba, bb, mxu_intermediate=want_diag or want_trace,
            kron_view=want_kron)
    cc = max(1, min(class_chunk, c))
    S2 = _pad_to(S2, 0, cc)
    out = fused_second_order_pallas(
        A2, S2, want_diag=want_diag, want_kron=want_kron,
        want_trace=want_trace, block_a=ba, block_b=bb, class_chunk=cc,
        interpret=interpret)
    if "diag" in out:
        out["diag"] = out["diag"][:a, :b]
    if "kron" in out:
        out["kron"] = out["kron"][:b, :b]
    if "trace" in out:
        out["trace"] = out["trace"][0, :n]
    return out


@register("predictive_var", ref=ref.predictive_var)
def _predictive_var(A, S, *maybe_sigma, want_sigma=False, block_a=None,
                    block_b=None, class_chunk=None, interpret=True):
    """GLM predictive variance from Jacobian-factor tiles, in one pass.

    A: [N, R, a], S: [C, N, R, b] (+ Sigma [a, b] when ``want_sigma``) →
    var [C, N] float32.  Zero-padding N, R, C and the feature axes is
    exact: padded A/S entries zero the contraction tile, so the squared
    (optionally Sigma-weighted) contributions vanish; padded var rows and
    columns are sliced off.

    ``class_chunk`` bounds the VMEM-resident working set per grid step
    (``None`` = auto, same ~4 MiB float32 budget as ``fused_second_order``).
    """
    c, n, r, b = S.shape
    a = A.shape[-1]
    A2, S2, ba, bb = _pad_factor_pair(A, S, block_a, block_b, interpret)
    Sigma2 = None
    if want_sigma:
        (Sigma,) = maybe_sigma
        Sigma2 = _pad_to(_pad_to(Sigma, 1, bb), 0, ba)
    if class_chunk is None:
        class_chunk = _auto_class_chunk(S2, ba, bb, mxu_intermediate=True)
    cc = max(1, min(class_chunk, c))
    S2 = _pad_to(S2, 0, cc)
    out = predictive_var_pallas(
        A2, S2, Sigma2, block_a=ba, block_b=bb, class_chunk=cc,
        interpret=interpret)
    return out[:c, :n]


# ---------------------------------------------------------------------------
# public API (thin aliases over dispatch)
# ---------------------------------------------------------------------------


def sq_matmul(A, B, block_a=128, block_b=128, block_n=256):
    return dispatch("sq_matmul", A, B, block_a=block_a, block_b=block_b,
                    block_n=block_n)


def per_sample_moment(A, B, block_a=128, block_b=128):
    return dispatch("per_sample_moment", A, B, block_a=block_a,
                    block_b=block_b)


def batch_l2(A, B, block_r=128):
    return dispatch("batch_l2", A, B, block_r=block_r)


def ggn_diag(A, S, block_a=128, block_b=128):
    return dispatch("ggn_diag", A, S, block_a=block_a, block_b=block_b)


def fused_second_order(A, S, want_diag=True, want_kron=False,
                       want_trace=False, block_a=None, block_b=None,
                       class_chunk=None):
    """Fused second-order stats: A [N, R, a], S [C, N, R, b]."""
    return dispatch("fused_second_order", A, S, want_diag=want_diag,
                    want_kron=want_kron, want_trace=want_trace,
                    block_a=block_a, block_b=block_b,
                    class_chunk=class_chunk)


def predictive_var(A, S, Sigma=None, block_a=None, block_b=None,
                   class_chunk=None):
    """GLM predictive variance [C, N]: A [N, R, a], S [C, N, R, b].

    ``Sigma [a, b]`` weights the squared Jacobian elementwise (diagonal
    posterior); without it the output is ``‖J[c,n]‖²_F`` (the Kronecker
    path on half-transformed inputs — see kernels/predictive_var.py).
    """
    if Sigma is None:
        return dispatch("predictive_var", A, S, want_sigma=False,
                        block_a=block_a, block_b=block_b,
                        class_chunk=class_chunk)
    return dispatch("predictive_var", A, S, Sigma, want_sigma=True,
                    block_a=block_a, block_b=block_b,
                    class_chunk=class_chunk)


def fused_first_order(A, B, want_l2=True, want_moment=False, want_dot=False,
                      block_a=None, block_b=None):
    """Fused first-order stats; A/B may be [N, R, a] (a leading group axis
    of 1 is added and stripped) or [E, N, R, a]."""
    squeeze = A.ndim == 3
    if squeeze:
        A, B = A[None], B[None]
    out = dispatch("fused_first_order", A, B, want_l2=want_l2,
                   want_moment=want_moment, want_dot=want_dot,
                   block_a=block_a, block_b=block_b)
    if squeeze:
        out = {k: v[0] for k, v in out.items()}
    return out


def cross_dot(A1, B1, A2, B2, block_a=None, block_b=None):
    """Cross-block pairwise dots [E, N1, N2] (Gram / NTK row-block tile);
    inputs may be [N, R, a] (a leading group axis of 1 is added and the
    output squeezed to [N1, N2]) or [E, N, R, a]."""
    squeeze = A1.ndim == 3
    if squeeze:
        A1, B1, A2, B2 = A1[None], B1[None], A2[None], B2[None]
    out = dispatch("cross_dot", A1, B1, A2, B2,
                   block_a=block_a, block_b=block_b)
    return out[0] if squeeze else out
