"""Public jit'd wrappers for the Pallas kernels.

Handles zero-padding to block multiples (zeros contribute nothing to any of
the four reductions, so padding is exact) and backend selection:
``interpret=True`` on CPU (kernel body executed in Python — correctness
path for this container), compiled Mosaic on TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.batch_l2 import batch_l2_pallas
from repro.kernels.ggn_diag import ggn_diag_pallas
from repro.kernels.per_sample_moment import per_sample_moment_pallas
from repro.kernels.sq_matmul import sq_matmul_pallas


def _interpret():
    return jax.default_backend() == "cpu"


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("block_a", "block_b", "block_n"))
def sq_matmul(A, B, block_a=128, block_b=128, block_n=256):
    a, b = A.shape[1], B.shape[1]
    ba, bb = min(block_a, max(a, 8)), min(block_b, max(b, 8))
    A2 = _pad_to(_pad_to(A, 1, ba), 0, 8)
    B2 = _pad_to(_pad_to(B, 1, bb), 0, 8)
    bn = min(block_n, A2.shape[0])
    out = sq_matmul_pallas(A2, B2, block_a=ba, block_b=bb, block_n=bn,
                           interpret=_interpret())
    return out[:a, :b]


@partial(jax.jit, static_argnames=("block_a", "block_b"))
def per_sample_moment(A, B, block_a=128, block_b=128):
    a, b = A.shape[-1], B.shape[-1]
    ba, bb = min(block_a, max(a, 8)), min(block_b, max(b, 8))
    A2 = _pad_to(_pad_to(A, 2, ba), 1, 8)
    B2 = _pad_to(_pad_to(B, 2, bb), 1, 8)
    out = per_sample_moment_pallas(A2, B2, block_a=ba, block_b=bb,
                                   interpret=_interpret())
    return out[:a, :b]


@partial(jax.jit, static_argnames=("block_r",))
def batch_l2(A, B, block_r=128):
    r = A.shape[1]
    br = min(block_r, max(r, 8))
    A2 = _pad_to(A, 1, br)
    B2 = _pad_to(B, 1, br)
    return batch_l2_pallas(A2, B2, block_r=br, interpret=_interpret())


@partial(jax.jit, static_argnames=("block_a", "block_b"))
def ggn_diag(A, S, block_a=128, block_b=128):
    a, b = A.shape[-1], S.shape[-1]
    ba, bb = min(block_a, max(a, 8)), min(block_b, max(b, 8))
    A2 = _pad_to(_pad_to(A, 2, ba), 1, 8)
    S2 = _pad_to(_pad_to(S, 3, bb), 2, 8)
    out = ggn_diag_pallas(A2, S2, block_a=ba, block_b=bb,
                          interpret=_interpret())
    return out[:a, :b]
