"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import jax.numpy as jnp


def sq_matmul(A, B):
    """C[a,b] = Σ_n A²[n,a] B²[n,b] — the paper's (A∘A)ᵀ(B∘B) (App. A.1)."""
    Af, Bf = A.astype(jnp.float32), B.astype(jnp.float32)
    return (Af * Af).T @ (Bf * Bf)


def per_sample_moment(A, B):
    """M[a,b] = Σ_n (Σ_r A[n,r,a] B[n,r,b])² — sequence 2nd moment."""
    Af, Bf = A.astype(jnp.float32), B.astype(jnp.float32)
    g = jnp.einsum("nra,nrb->nab", Af, Bf)
    return jnp.sum(g * g, axis=0)


def batch_l2(A, B):
    """l2[n] = Σ_rs (A_n A_nᵀ)[r,s] (B_n B_nᵀ)[r,s] — Gram trick."""
    Af, Bf = A.astype(jnp.float32), B.astype(jnp.float32)
    ga = jnp.einsum("nra,nsa->nrs", Af, Af)
    gb = jnp.einsum("nrb,nsb->nrs", Bf, Bf)
    return jnp.sum(ga * gb, axis=(1, 2))


def ggn_diag(A, S):
    """diag[a,b] = Σ_{c,n} (Σ_r A[n,r,a] S[c,n,r,b])² (Eq. 19/22)."""
    Af, Sf = A.astype(jnp.float32), S.astype(jnp.float32)
    t = jnp.einsum("nra,cnrb->cnab", Af, Sf)
    return jnp.sum(t * t, axis=(0, 1))


def batch_dot(A, B):
    """D[n,m] = ⟨g_n, g_m⟩ for g = A_nᵀB_n — pairwise Gram trick."""
    Af, Bf = A.astype(jnp.float32), B.astype(jnp.float32)
    ga = jnp.einsum("nra,msa->nmrs", Af, Af)
    gb = jnp.einsum("nrb,msb->nmrs", Bf, Bf)
    return jnp.sum(ga * gb, axis=(2, 3))


def cross_dot(A1, B1, A2, B2):
    """out[e,n,m] = ⟨G1[e,n], G2[e,m]⟩ for G = A_nᵀB_n — cross-block Gram.

    The row-block × row-block generalization of :func:`batch_dot`: two
    different row sets (a microbatch pair's off-diagonal Gram block, or an
    NTK row block against gathered columns), a leading group axis E
    (classes for the class-diagonal empirical NTK).
    """
    g1 = jnp.einsum("enra,enrb->enab", A1.astype(jnp.float32),
                    B1.astype(jnp.float32))
    g2 = jnp.einsum("emra,emrb->emab", A2.astype(jnp.float32),
                    B2.astype(jnp.float32))
    return jnp.einsum("enab,emab->enm", g1, g2)


def fused_second_order(A, S, want_diag=True, want_kron=False,
                       want_trace=False):
    """Oracle for the fused curvature kernel: t[c,n] = A_nᵀ S_cn, reduce.

    A: [N, R, a], S: [C, N, R, b] → dict of requested float32 stats
    (diag [a, b] · kron [b, b] (unscaled SᵀS) · trace [N]).
    """
    Af, Sf = A.astype(jnp.float32), S.astype(jnp.float32)
    out = {}
    if want_diag or want_trace:
        t = jnp.einsum("nra,cnrb->cnab", Af, Sf)
        t2 = t * t
        if want_diag:
            out["diag"] = jnp.sum(t2, axis=(0, 1))
        if want_trace:
            out["trace"] = jnp.sum(t2, axis=(0, 2, 3))
    if want_kron:
        out["kron"] = jnp.einsum("cnri,cnrj->ij", Sf, Sf)
    return out


def predictive_var(A, S, Sigma=None):
    """var[c,n] = Σ_{ab} (Σ_r A[n,r,a] S[c,n,r,b])² [· Sigma[a,b]].

    The naive per-sample-Jacobian baseline for the GLM predictive
    variance: materialize J[c,n] = A_nᵀS_cn, square, (weight,) reduce.
    """
    Af, Sf = A.astype(jnp.float32), S.astype(jnp.float32)
    t = jnp.einsum("nra,cnrb->cnab", Af, Sf)
    t2 = t * t
    if Sigma is not None:
        t2 = t2 * Sigma.astype(jnp.float32)
    return jnp.sum(t2, axis=(2, 3))


def fused_first_order(A, B, want_l2=True, want_moment=False, want_dot=False):
    """Oracle for the fused kernel: materialize G[n] = A_nᵀB_n, reduce.

    A: [E, N, R, a], B: [E, N, R, b] → dict of requested stats
    (l2 [E, N] · moment [E, a, b] · dot [E, N, N]), all float32.
    """
    Af, Bf = A.astype(jnp.float32), B.astype(jnp.float32)
    g = jnp.einsum("enra,enrb->enab", Af, Bf)
    out = {}
    if want_l2:
        out["l2"] = jnp.sum(g * g, axis=(2, 3))
    if want_moment:
        out["moment"] = jnp.sum(g * g, axis=1)
    if want_dot:
        out["dot"] = jnp.einsum("enab,emab->enm", g, g)
    return out
