"""Shared Mosaic compiler-parameter plumbing for the reduction kernels.

Every ``pl.pallas_call`` in :mod:`repro.kernels` routes its TPU compiler
options through :func:`mosaic_params` so there is exactly one code path —
the non-deprecated ``pltpu.CompilerParams`` dataclass (named
``TPUCompilerParams`` on older jax) instead of the legacy
``compiler_params=dict(mosaic=...)`` nested-dict spelling, which newer
Pallas versions reject.

Under the interpreter (the CPU correctness path) no params are built at
all: Mosaic never runs, and ``pallas_call`` accepts ``None``.
"""
from __future__ import annotations


def mosaic_params(*dimension_semantics: str, interpret: bool = False):
    """Build ``CompilerParams(dimension_semantics=...)`` or ``None``.

    ``dimension_semantics`` is one ``"parallel"``/``"arbitrary"`` entry per
    grid axis; grid axes that accumulate into a revisited output block must
    be ``"arbitrary"`` (sequential) so the accumulator tile stays resident.
    """
    if interpret:
        return None
    from jax.experimental.pallas import tpu as pltpu

    params_cls = getattr(pltpu, "CompilerParams", None)
    if params_cls is None:  # pre-0.5 spelling
        params_cls = pltpu.TPUCompilerParams
    return params_cls(dimension_semantics=tuple(dimension_semantics))
