"""Fused first-order statistics Pallas kernel (one pass, K reductions).

BackPACK's economics (paper §2.2): every first-order quantity — per-sample
gradient L2 norms, the summed squared gradient (second moment / variance),
pairwise gradient dots — is a cheap reduction of the SAME ``(input,
grad_out)`` pair the batch gradient already consumes.  The seed engine still
paid one kernel launch (and one HBM read of A and B) *per statistic*; this
kernel forms each per-sample gradient tile

    G[n] = A_nᵀ B_n        (on the MXU, one [N, ba, bb] batch per tile pair)

exactly once per ``(a, b)`` feature-tile pair and emits every *requested*
reduction from the in-register tile:

    moment[a, b]  = Σ_n  G[n]∘G[n]          (second moment / variance)
    l2[n]         = Σ_ab G[n]∘G[n]          (per-sample gradient norms)
    dot[n, m]     = Σ_ab G[n]∘G[m]          (pairwise Gram / batch_dot)

The extension mask (``want_l2 / want_moment / want_dot``) is static: an
unrequested output has no ref, no VMEM footprint and no FLOPs — ``K`` stat
sweeps collapse into 1 with marginal cost per extra statistic.

A leading *group* axis ``E`` batches independent problems through one launch
(E=1 for Dense/attention projections/conv-unfold; E=n_experts for MoE
``BatchedDense``, where capacity slots are the sample units).

Shapes:  A: [E, N, R, a], B: [E, N, R, b]   (R = summed sequence/patch axis)
Outputs: l2 [E, N] · moment [E, a, b] · dot [E, N, N], all float32.

Tiling: grid (E, a/ba, b/bb) — E parallel; the (i, j) feature tiles are
``arbitrary`` because l2/dot accumulate across them (init at (0, 0)).  The
moment tile is written exactly once per (i, j), no accumulation.  G squared
is computed once and shared between the moment and l2 reductions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compiler import mosaic_params

# Output slots in kernel-ref order (static mask selects a subset).
OUTPUTS = ("l2", "moment", "dot")


def _make_kernel(want_l2, want_moment, want_dot):
    def kernel(a_ref, b_ref, *o_refs):
        i, j = pl.program_id(1), pl.program_id(2)
        refs = iter(o_refs)
        l2_ref = next(refs) if want_l2 else None
        mom_ref = next(refs) if want_moment else None
        dot_ref = next(refs) if want_dot else None

        a = a_ref[0].astype(jnp.float32)  # [N, R, ba]
        b = b_ref[0].astype(jnp.float32)  # [N, R, bb]
        # G[n] = A_nᵀ B_n for this feature-tile pair: batch over n, contract r.
        G = jax.lax.dot_general(
            a, b, (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )  # [N, ba, bb]

        if want_l2 or want_moment:
            G2 = G * G
        if want_moment:
            mom_ref[0] = jnp.sum(G2, axis=0)
        if want_l2:
            @pl.when((i == 0) & (j == 0))
            def _init_l2():
                l2_ref[...] = jnp.zeros_like(l2_ref)

            l2_ref[0] += jnp.sum(G2, axis=(1, 2))
        if want_dot:
            @pl.when((i == 0) & (j == 0))
            def _init_dot():
                dot_ref[...] = jnp.zeros_like(dot_ref)

            # dot[n, m] += ⟨G[n], G[m]⟩ — contract both feature axes.
            dot_ref[0] += jax.lax.dot_general(
                G, G, (((1, 2), (1, 2)), ((), ())),
                preferred_element_type=jnp.float32,
            )

    return kernel


def fused_first_order_pallas(A, B, *, want_l2=True, want_moment=False,
                             want_dot=False, block_a=128, block_b=128,
                             interpret=True):
    """A: [E, N, R, a], B: [E, N, R, b] → dict of requested float32 stats.

    Caller is responsible for padding (a, b) to block multiples and (N, R)
    to sublane multiples — see the ``fused_first_order`` registry entry in
    :mod:`repro.kernels.ops`, which owns that policy.
    """
    if not (want_l2 or want_moment or want_dot):
        raise ValueError("fused_first_order: empty extension mask")
    e, n, r, a = A.shape
    b = B.shape[-1]
    grid = (e, pl.cdiv(a, block_a), pl.cdiv(b, block_b))

    out_shapes, out_specs, names = [], [], []
    if want_l2:
        out_shapes.append(jax.ShapeDtypeStruct((e, n), jnp.float32))
        out_specs.append(pl.BlockSpec((1, n), lambda k, i, j: (k, 0)))
        names.append("l2")
    if want_moment:
        out_shapes.append(jax.ShapeDtypeStruct((e, a, b), jnp.float32))
        out_specs.append(
            pl.BlockSpec((1, block_a, block_b), lambda k, i, j: (k, i, j)))
        names.append("moment")
    if want_dot:
        out_shapes.append(jax.ShapeDtypeStruct((e, n, n), jnp.float32))
        out_specs.append(pl.BlockSpec((1, n, n), lambda k, i, j: (k, 0, 0)))
        names.append("dot")

    outs = pl.pallas_call(
        _make_kernel(want_l2, want_moment, want_dot),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n, r, block_a), lambda k, i, j: (k, 0, 0, i)),
            pl.BlockSpec((1, n, r, block_b), lambda k, i, j: (k, 0, 0, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        compiler_params=mosaic_params("parallel", "arbitrary", "arbitrary",
                                      interpret=interpret),
        interpret=interpret,
    )(A, B)
    if len(names) == 1:
        outs = (outs,) if not isinstance(outs, (tuple, list)) else outs
    return dict(zip(names, outs))
