"""Fused second-order sweep Pallas kernel (one pass, K curvature stats).

BackPACK's §2.3 economics, one level up from the first-order kernel: every
curvature quantity of a Dense-shaped layer — the GGN diagonal (Eq. 19/22),
the output-side Kronecker B-factor (Eq. 23, shared by KFLR and KFAC), a
per-sample GGN trace — is a cheap reduction of the SAME ``(A, S)`` pair,
where ``A`` is the layer-input tape and ``S`` the backpropagated
loss-Hessian factor.  The per-extension path re-reads ``S`` from HBM once
per statistic (and the jnp diag path even broadcasts ``A`` to ``[C·N, R,
a]`` copies); here each ``S`` tile is loaded into VMEM exactly once and
feeds every *requested* accumulator:

    t[c,n]      = A_nᵀ S_{c,n}              (MXU, [C′·N, ba, bb] per tile)
    diag[a, b]  = Σ_{c,n} t∘t               (GGN / DiagGGN-MC diagonal)
    kron[b, b]  = Σ_{c,n,r} S Sᵀ            (KFLR / KFAC B-factor, unscaled)
    trace[n]    = Σ_{c,a,b} t∘t             (per-sample GGN trace — beyond
                                             paper: curvature telemetry)

The extension mask (``want_diag / want_kron / want_trace``) is static: an
unrequested output has no ref, no VMEM footprint and no FLOPs.  The MC
sweep reuses the kernel unchanged — the Monte-Carlo sample axis stands in
for the class axis ``C``.

The class axis is folded into the grid in chunks of ``class_chunk``: at
LM-vocabulary scale the per-class contribution tensor ``[C, N, a, b]``
(and the broadcast copy of ``A``) never materializes; VMEM holds one
``[C′, N, R, bb]`` tile of ``S`` at a time.  For the Kronecker factor the
kernel takes a second, full-width view of the same ``S`` buffer so
``SᵀS`` columns span the whole output dimension — no extra HBM copy, the
two views alias one array.

Shapes:  A: [N, R, a];  S: [C, N, R, b]   (R = summed sequence/patch axis)
Outputs: diag [a, b] · kron [b, b] · trace [1, N], all float32.

Tiling: grid (b/bb, a/ba, C/C′), class chunks innermost so every
accumulator sees its revisits consecutively: diag tile (i, j) accumulates
over c; kron tile (j, ·) accumulates over (i=0, c) runs; trace accumulates
over everything.  All axes are ``arbitrary`` under Mosaic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compiler import mosaic_params

# Output slots in kernel-ref order (static mask selects a subset).
OUTPUTS = ("diag", "kron", "trace")


def _make_kernel(want_diag, want_kron, want_trace):
    need_t = want_diag or want_trace  # A only feeds the contraction tile

    def kernel(*refs):
        j, i, c = pl.program_id(0), pl.program_id(1), pl.program_id(2)
        it = iter(refs)
        a_ref = it.__next__() if need_t else None
        s_ref = it.__next__()
        sf_ref = it.__next__() if want_kron else None
        diag_ref = it.__next__() if want_diag else None
        kron_ref = it.__next__() if want_kron else None
        tr_ref = it.__next__() if want_trace else None

        s = s_ref[...].astype(jnp.float32)  # [C', N, R, bb]
        cc, n, r, bb = s.shape
        if need_t:
            a = a_ref[...].astype(jnp.float32)  # [N, R, ba]
            # Broadcast A over the class chunk in VMEM (never in HBM) and
            # batch the contraction over the fused (c, n) axis on the MXU.
            arep = jnp.broadcast_to(a[None], (cc,) + a.shape)
            t = jax.lax.dot_general(
                arep.reshape(cc * n, r, a.shape[-1]),
                s.reshape(cc * n, r, bb),
                (((1,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32,
            )  # [C'·N, ba, bb]
            t2 = t * t
        if want_diag:
            @pl.when(c == 0)
            def _init_diag():
                diag_ref[...] = jnp.zeros_like(diag_ref)

            diag_ref[...] += jnp.sum(t2, axis=0)
        if want_trace:
            @pl.when((i == 0) & (j == 0) & (c == 0))
            def _init_trace():
                tr_ref[...] = jnp.zeros_like(tr_ref)

            tr_ref[0] += jnp.sum(t2.reshape(cc, n, -1), axis=(0, 2))
        if want_kron:
            @pl.when((i == 0) & (c == 0))
            def _init_kron():
                kron_ref[...] = jnp.zeros_like(kron_ref)

            # SᵀS touches only S — accumulate once per (j, c), not per a-tile.
            @pl.when(i == 0)
            def _acc_kron():
                sf = sf_ref[...].astype(jnp.float32)  # [C', N, R, b]
                kron_ref[...] += jax.lax.dot_general(
                    s.reshape(-1, bb), sf.reshape(-1, sf.shape[-1]),
                    (((0,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )

    return kernel


def fused_second_order_pallas(A, S, *, want_diag=True, want_kron=False,
                              want_trace=False, block_a=128, block_b=128,
                              class_chunk=1, interpret=True):
    """A: [N, R, a], S: [C, N, R, b] → dict of requested float32 stats.

    Caller is responsible for padding (a, b) to block multiples, (N, R) to
    sublane multiples and C to a ``class_chunk`` multiple — see the
    ``fused_second_order`` registry entry in :mod:`repro.kernels.ops`,
    which owns that policy.
    """
    if not (want_diag or want_kron or want_trace):
        raise ValueError("fused_second_order: empty extension mask")
    c, n, r, b = S.shape
    a = A.shape[-1]
    cc = class_chunk
    # Kron-only launches never read A: drop the input and collapse the
    # a-tile grid axis so no step fetches tiles it would discard.
    need_t = want_diag or want_trace
    grid = (pl.cdiv(b, block_b), pl.cdiv(a, block_a) if need_t else 1,
            pl.cdiv(c, cc))

    in_specs, inputs = [], []
    if need_t:
        in_specs.append(
            pl.BlockSpec((n, r, block_a), lambda j, i, k: (0, 0, i)))
        inputs.append(A)
    inputs.append(S)
    in_specs.append(
        pl.BlockSpec((cc, n, r, block_b), lambda j, i, k: (k, 0, 0, j)))
    if want_kron:
        # Second view of the SAME array, full output width (see module doc).
        # Only the i == 0 lane reads it (the kron accumulator fires once per
        # (j, c), not per a-tile), so for i > 0 the index map parks on the
        # chunk the i == 0 sweep ended on: an unchanged block index lets
        # the pipeline elide the re-fetch instead of streaming the
        # full-width slab every step.
        last = pl.cdiv(c, cc) - 1
        in_specs.append(
            pl.BlockSpec((cc, n, r, b),
                         lambda j, i, k: (jnp.where(i == 0, k, last),
                                          0, 0, 0)))
        inputs.append(S)

    out_shapes, out_specs, names = [], [], []
    if want_diag:
        out_shapes.append(jax.ShapeDtypeStruct((a, b), jnp.float32))
        out_specs.append(
            pl.BlockSpec((block_a, block_b), lambda j, i, k: (i, j)))
        names.append("diag")
    if want_kron:
        out_shapes.append(jax.ShapeDtypeStruct((b, b), jnp.float32))
        out_specs.append(pl.BlockSpec((block_b, b), lambda j, i, k: (j, 0)))
        names.append("kron")
    if want_trace:
        out_shapes.append(jax.ShapeDtypeStruct((1, n), jnp.float32))
        out_specs.append(pl.BlockSpec((1, n), lambda j, i, k: (0, 0)))
        names.append("trace")

    # Grid axes are parallel unless some accumulator spans them: the class
    # axis always accumulates; the a-axis carries the kron (written once at
    # i == 0, revisited after) and trace accumulators; the b-axis only the
    # trace.  Diag-only thus keeps the (parallel, parallel, arbitrary)
    # schedule of the per-extension ggn_diag kernel it supersedes.
    sem_j = "arbitrary" if want_trace else "parallel"
    sem_i = "arbitrary" if (want_kron or want_trace) else "parallel"
    outs = pl.pallas_call(
        _make_kernel(want_diag, want_kron, want_trace),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shapes,
        compiler_params=mosaic_params(sem_j, sem_i, "arbitrary",
                                      interpret=interpret),
        interpret=interpret,
    )(*inputs)
    if len(names) == 1:
        outs = (outs,) if not isinstance(outs, (tuple, list)) else outs
    return dict(zip(names, outs))
