"""Fused cross-block pairwise-dot Pallas kernel (Gram / empirical NTK tiles).

``fused_first_order``'s ``dot`` output is the *diagonal* Gram block of one
row set against itself.  The streaming-Gram lane (``SweepPlan.accumulate``
with BatchDot / NTK) and the NTK extension family need the general
row-block × row-block tile

    out[n, m] = ⟨G1[n], G2[m]⟩,    G1[n] = A1_nᵀ B1_n,  G2[m] = A2_mᵀ B2_m

for two *different* row sets — microbatch pair (p, q) off-diagonal blocks,
or one shard's rows against the gathered columns.  Like the fused kernel,
each per-sample gradient tile is formed exactly once per feature-tile pair
on the MXU and immediately contracted; the [N, a, b] per-sample gradients
never hit HBM.

A leading group axis ``E`` batches independent problems through one launch:
E=1 for BatchDot cross blocks, E=C for the class-diagonal empirical NTK
(``ntk_classwise``), where A is broadcast over classes and B carries the
per-class output Jacobian factors.

Shapes:  A1 [E, N1, R, a], B1 [E, N1, R, b], A2 [E, N2, R, a],
         B2 [E, N2, R, b]  →  out [E, N1, N2] float32.

Tiling: grid (E, a/ba, b/bb) — E parallel; the (i, j) feature tiles are
``arbitrary`` because the output accumulates across them (init at (0, 0)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compiler import mosaic_params


def _kernel(a1_ref, b1_ref, a2_ref, b2_ref, out_ref):
    i, j = pl.program_id(1), pl.program_id(2)
    a1 = a1_ref[0].astype(jnp.float32)  # [N1, R, ba]
    b1 = b1_ref[0].astype(jnp.float32)  # [N1, R, bb]
    a2 = a2_ref[0].astype(jnp.float32)  # [N2, R, ba]
    b2 = b2_ref[0].astype(jnp.float32)  # [N2, R, bb]
    # Per-sample gradient tiles for this feature-tile pair: batch n,
    # contract the unit axis r.
    G1 = jax.lax.dot_general(
        a1, b1, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # [N1, ba, bb]
    G2 = jax.lax.dot_general(
        a2, b2, (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # [N2, ba, bb]

    @pl.when((i == 0) & (j == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # out[n, m] += ⟨G1[n], G2[m]⟩ — contract both feature axes.
    out_ref[0] += jax.lax.dot_general(
        G1, G2, (((1, 2), (1, 2)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def cross_dot_pallas(A1, B1, A2, B2, *, block_a=128, block_b=128,
                     interpret=True):
    """A1/B1: [E, N1, R, a/b], A2/B2: [E, N2, R, a/b] → [E, N1, N2] f32.

    Caller is responsible for padding the feature axes to block multiples
    and (N1, N2, R) to sublane multiples — see the ``cross_dot`` registry
    entry in :mod:`repro.kernels.ops`, which owns that policy.
    """
    e, n1, r, a = A1.shape
    n2 = A2.shape[1]
    grid = (e, pl.cdiv(a, block_a), pl.cdiv(B1.shape[-1], block_b))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n1, r, block_a), lambda k, i, j: (k, 0, 0, i)),
            pl.BlockSpec((1, n1, r, block_b), lambda k, i, j: (k, 0, 0, j)),
            pl.BlockSpec((1, n2, r, block_a), lambda k, i, j: (k, 0, 0, i)),
            pl.BlockSpec((1, n2, r, block_b), lambda k, i, j: (k, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, n1, n2), lambda k, i, j: (k, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, n1, n2), jnp.float32),
        compiler_params=mosaic_params("parallel", "arbitrary", "arbitrary",
                                      interpret=interpret),
        interpret=interpret,
    )(A1, B1, A2, B2)
