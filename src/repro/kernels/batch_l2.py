"""Per-sample gradient L2 norms via the Gram trick, fused.

l2[n] = Σ_{r,s} (A_n A_nᵀ)[r,s] · (B_n B_nᵀ)[r,s]
      = ‖Σ_r a_r b_rᵀ‖²   (Goodfellow 2015; paper App. A.1)

Cost O(N·R²·(a+b)) instead of O(N·R·a·b) — the win when R ≪ a·b/(a+b)
(short sequences / wide layers).  The two [br×bs] Gram tiles live in VMEM;
their elementwise product is reduced on the fly — neither Gram matrix is
ever materialized in HBM.

Tiling: grid (N, r/br, s/bs); output [N, 1] accumulates across (r, s) tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compiler import mosaic_params


def _kernel(a1_ref, b1_ref, a2_ref, b2_ref, o_ref):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a1 = a1_ref[0].astype(jnp.float32)  # [br, a]
    a2 = a2_ref[0].astype(jnp.float32)  # [bs, a]
    b1 = b1_ref[0].astype(jnp.float32)  # [br, b]
    b2 = b2_ref[0].astype(jnp.float32)  # [bs, b]
    ga = jax.lax.dot_general(a1, a2, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    gb = jax.lax.dot_general(b1, b2, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    o_ref[0, 0] += jnp.sum(ga * gb)


def batch_l2_pallas(A, B, *, block_r=128, interpret=True):
    """A: [N, R, a], B: [N, R, b] → [N] float32."""
    n, r, a = A.shape
    b = B.shape[-1]
    grid = (n, pl.cdiv(r, block_r), pl.cdiv(r, block_r))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_r, a), lambda k, i, j: (k, i, 0)),
            pl.BlockSpec((1, block_r, b), lambda k, i, j: (k, i, 0)),
            pl.BlockSpec((1, block_r, a), lambda k, i, j: (k, j, 0)),
            pl.BlockSpec((1, block_r, b), lambda k, i, j: (k, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda k, i, j: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        compiler_params=mosaic_params("parallel", "arbitrary", "arbitrary",
                                      interpret=interpret),
        interpret=interpret,
    )(A, B, A, B)
    return out[:, 0]
