"""Exact GGN diagonal from backpropagated factors, class-chunk fused.

diag[a,b] = Σ_{c,n} (Σ_r A[n,r,a] S[c,n,r,b])²      (paper Eq. 19)

The jnp path must broadcast A over the factor axis ([C·N, R, a] copies);
here the index map reuses the same A block for every c — zero duplication
in HBM, and the [ba×bb] contribution tile is squared/accumulated in VMEM.

Tiling: grid (a/ba, b/bb, N·C) with n = k // C, c = k % C.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compiler import mosaic_params


def _kernel(a_ref, s_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[0].astype(jnp.float32)     # [R, ba]
    s = s_ref[0, 0].astype(jnp.float32)  # [R, bb]
    t = jax.lax.dot_general(a, s, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[...] += t * t


def ggn_diag_pallas(A, S, *, block_a=128, block_b=128, interpret=True):
    """A: [N, R, a]; S: [C, N, R, b] → [a, b] float32."""
    c, n, r, b = S.shape
    a = A.shape[-1]
    grid = (pl.cdiv(a, block_a), pl.cdiv(b, block_b), n * c)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, r, block_a), lambda i, j, k: (k // c, 0, i)),
            pl.BlockSpec((1, 1, r, block_b),
                         lambda i, j, k: (k % c, k // c, 0, j)),
        ],
        out_specs=pl.BlockSpec((block_a, block_b), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, b), jnp.float32),
        compiler_params=mosaic_params("parallel", "parallel", "arbitrary",
                                      interpret=interpret),
        interpret=interpret,
    )(A, S)
