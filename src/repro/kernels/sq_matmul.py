"""Fused (A∘A)ᵀ(B∘B) Pallas kernel.

The paper's App. A.1 second-moment trick for rank-1-per-sample layers.
Fusing the elementwise squares into the matmul avoids materializing A², B²
in HBM — on TPU the squares happen in VREGs on the way into the MXU.

Tiling: grid (a/ba, b/bb, n/bn); the output tile [ba×bb] lives in VMEM and
accumulates across the (innermost) n steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compiler import mosaic_params


def _kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        a * a, b * b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def sq_matmul_pallas(A, B, *, block_a=128, block_b=128, block_n=256,
                     interpret=True):
    """A: [N, a], B: [N, b] → [a, b] float32."""
    n, a = A.shape
    b = B.shape[1]
    grid = (pl.cdiv(a, block_a), pl.cdiv(b, block_b), pl.cdiv(n, block_n))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, block_a), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_n, block_b), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_a, block_b), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, b), jnp.float32),
        compiler_params=mosaic_params("parallel", "parallel", "arbitrary",
                                      interpret=interpret),
        interpret=interpret,
    )(A, B)
