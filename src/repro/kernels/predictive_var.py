"""Fused GLM predictive-variance Pallas kernel (Laplace serving hot path).

The linearized (GLM) predictive of a Laplace posterior needs, per sample
``n`` and output class ``c``, the quadratic form ``diag(J Σ Jᵀ)`` where the
per-layer Jacobian tile w.r.t. a Dense-shaped weight block is

    J[c, n] = Σ_r a_{n,r} s_{c,n,r}ᵀ          ([a × b], never materialized)

with ``A`` the layer-input tape and ``S`` the backpropagated output-identity
factor (the same ``(A, S)`` pair the curvature kernels consume — the GGN
sweep with ``S₀ = I`` over outputs instead of the loss-Hessian factor).

Two posterior structures land on ONE kernel:

* **diag** Σ: ``var[c,n] = Σ_{ij} J[c,n,i,j]² σ²[i,j]`` — the kernel takes
  the covariance diagonal ``Sigma [a, b]`` and weights the squared
  contraction tile elementwise (``want_sigma=True``).
* **Kronecker** Σ = (A'⁻¹ ⊗ B'⁻¹): the caller half-transforms the inputs,
  ``Ã = A L_A`` and ``S̃ = S L_B`` with ``L L ᵀ`` the factor inverses, and the
  quadratic form collapses to ``‖J̃[c,n]‖²_F`` — the same kernel with
  ``want_sigma=False``.  The transform is two thin matmuls outside the
  kernel; the O(C·N·a·b) contraction stays fused.

The naive baseline materializes the per-sample Jacobian tensor
``[C, N, a, b]`` in HBM (then squares it, then reduces it — 3 full passes
of traffic); here each ``(a, b)`` tile of the contraction lives only in
VMEM/registers on its way into the ``[C, N]`` accumulator.

Shapes:  A: [N, R, a];  S: [C, N, R, b];  Sigma: [a, b] (optional)
Output:  var [C, N] float32.

Tiling: grid (C/C′, a/ba, b/bb) — class chunks outermost so each output
block ``var[c-chunk]`` stays resident across its whole (i, j) accumulation
run; the (a, b) tile axes are ``arbitrary`` under Mosaic, the class axis is
``parallel`` (distinct output blocks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compiler import mosaic_params


def _make_kernel(want_sigma):
    def kernel(*refs):
        it = iter(refs)
        a_ref = next(it)
        s_ref = next(it)
        sig_ref = next(it) if want_sigma else None
        var_ref = next(it)
        i, j = pl.program_id(1), pl.program_id(2)

        s = s_ref[...].astype(jnp.float32)      # [C', N, R, bb]
        a = a_ref[...].astype(jnp.float32)      # [N, R, ba]
        cc, n, r, bb = s.shape
        # Broadcast A over the class chunk in VMEM (never in HBM) and batch
        # the r-contraction over the fused (c, n) axis on the MXU.
        arep = jnp.broadcast_to(a[None], (cc,) + a.shape)
        t = jax.lax.dot_general(
            arep.reshape(cc * n, r, a.shape[-1]),
            s.reshape(cc * n, r, bb),
            (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )                                        # [C'·N, ba, bb]
        t2 = t * t
        if want_sigma:
            t2 = t2 * sig_ref[...].astype(jnp.float32)[None]
        contrib = jnp.sum(t2, axis=(1, 2)).reshape(cc, n)

        @pl.when((i == 0) & (j == 0))
        def _init():
            var_ref[...] = jnp.zeros_like(var_ref)

        var_ref[...] += contrib

    return kernel


def predictive_var_pallas(A, S, Sigma=None, *, block_a=128, block_b=128,
                          class_chunk=1, interpret=True):
    """A: [N, R, a], S: [C, N, R, b] (+ Sigma [a, b]) → var [C, N] float32.

    Caller is responsible for padding (a, b) to block multiples, (N, R) to
    sublane multiples and C to a ``class_chunk`` multiple — see the
    ``predictive_var`` registry entry in :mod:`repro.kernels.ops`, which
    owns that policy.  Zero padding is exact everywhere: padded A/S rows
    and columns contribute zero to the contraction tile, so their squared
    entries vanish regardless of Sigma's padding.
    """
    c, n, r, b = S.shape
    a = A.shape[-1]
    cc = class_chunk
    want_sigma = Sigma is not None
    grid = (pl.cdiv(c, cc), pl.cdiv(a, block_a), pl.cdiv(b, block_b))

    in_specs = [
        pl.BlockSpec((n, r, block_a), lambda k, i, j: (0, 0, i)),
        pl.BlockSpec((cc, n, r, block_b), lambda k, i, j: (k, 0, 0, j)),
    ]
    inputs = [A, S]
    if want_sigma:
        in_specs.append(
            pl.BlockSpec((block_a, block_b), lambda k, i, j: (i, j)))
        inputs.append(Sigma)

    out = pl.pallas_call(
        _make_kernel(want_sigma),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((cc, n), lambda k, i, j: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((c, n), jnp.float32),
        compiler_params=mosaic_params("parallel", "arbitrary", "arbitrary",
                                      interpret=interpret),
        interpret=interpret,
    )(*inputs)
    return out
