"""Wired — arbitrary-DAG modules with per-child cotangent taps.

A ``Wired`` module owns a dict of *children* (Dense / Embedding / norms —
the parameter holders, each with BackPACK-efficient extension formulas) and a
``wire(call, params, x)`` function describing the dataflow between them
(attention mixing, MoE dispatch, SSM scans, residual adds... — arbitrary
jnp code).

Backward strategy: re-run the wiring with a zero "tap" added to every child
output and take a ``jax.vjp`` w.r.t. ``(x, taps)``.  The tap cotangents are
exactly ∂L/∂(child output) — what each child's hand-written
``backward``/``curv_backward`` needs to produce gradients, first-order stats
(Eq. 5/9–11) and GGN-factor stats (Eq. 19/22) without any per-architecture
backward derivation.  The recomputation is remat-style; XLA CSEs the
duplicated forward work inside one jit region.

This single abstraction gives the paper's modular-backprop semantics for
every assigned architecture: GQA/MLA attention, MoE dispatch, RWKV6/SSD
scans, hybrid heads and cross-attention are each just a ``wire`` function.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.module import Module


class Wired(Module):
    """Subclasses set ``self.children_map`` and implement ``wire``."""

    children_map: Dict[str, Module]

    def wire(self, call, params, x):
        raise NotImplementedError

    # optional decode-time wiring; ``call_step(name, x)`` applies a child
    def wire_step(self, call_step, params, x, cache):
        raise NotImplementedError(f"{type(self).__name__} has no decode path")

    def init(self, key):
        names = sorted(self.children_map)
        keys = jax.random.split(key, max(len(names), 2))
        return {n: self.children_map[n].init(k) for n, k in zip(names, keys)}

    def param_axes(self):
        return {n: c.param_axes() for n, c in self.children_map.items()}

    def apply(self, params, x):
        def call(name, xin):
            return self.children_map[name].apply(params[name], xin)

        return self.wire(call, params, x)

    def forward_tape(self, params, x):
        tapes = {}

        def call(name, xin):
            y, t = self.children_map[name].forward_tape(params[name], xin)
            tapes[name] = t
            return y

        y = self.wire(call, params, x)
        for n in self.children_map:
            tapes.setdefault(n, ())
        return y, (x, tapes)

    # -- shared vjp machinery --------------------------------------------------
    def _tap_vjp(self, params, x):
        """vjp of the wiring w.r.t. (x, per-child output taps)."""
        outs = {}

        def rec_call(name, xin):
            y = self.children_map[name].apply(params[name], xin)
            outs[name] = y
            return y

        self.wire(rec_call, params, x)
        taps0 = {n: jax.tree.map(jnp.zeros_like, o) for n, o in outs.items()}

        def f(x_, taps):
            def call(name, xin):
                y = self.children_map[name].apply(params[name], xin)
                return jax.tree.map(jnp.add, y, taps[name])

            return self.wire(call, params, x_)

        _, vjp = jax.vjp(f, x, taps0)
        return vjp

    def backward(self, params, tape, g, exts, cfg):
        x, tapes = tape
        vjp = self._tap_vjp(params, x)
        g_x, g_outs = vjp(g)
        grads, stats = {}, {}
        for name, child in self.children_map.items():
            if name in g_outs:
                _, grads[name], st = child.backward(
                    params[name], tapes[name], g_outs[name], exts, cfg
                )
            else:  # child not reached by this wiring (static config branch)
                grads[name] = jax.tree.map(jnp.zeros_like, params[name])
                st = {}
            for k, v in st.items():
                stats.setdefault(k, {})[name] = v
        # keep per-ext stat trees structurally aligned with the params dict
        for k in stats:
            for name in self.children_map:
                stats[k].setdefault(name, ())
        return g_x, grads, stats

    def jac_t_mat(self, params, tape, M):
        x, _ = tape
        vjp = self._tap_vjp(params, x)
        return jax.vmap(lambda m: vjp(m)[0])(M)

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        x, tapes = tape
        vjp = self._tap_vjp(params, x)
        S_x, S_outs = jax.vmap(vjp)(S)
        curv = {}
        for name, child in self.children_map.items():
            if name in S_outs:
                _, cv = child.curv_backward(
                    params[name], tapes[name], S_outs[name], exts, cfg, ext_prefix
                )
            else:
                cv = {}
            for k, v in cv.items():
                curv.setdefault(k, {})[name] = v
        for k in curv:
            for name in self.children_map:
                curv[k].setdefault(name, ())
        return S_x, curv

    # -- serving ----------------------------------------------------------------
    def decode_step(self, params, x, cache):
        def call_step(name, xin):
            return self.children_map[name].apply(params[name], xin)

        return self.wire_step(call_step, params, x, cache)
