"""Model assemblies: CausalLM (all LM archs), Whisper enc-dec, VLM frontends.

``build_model(cfg)`` returns the root ``Module``; the same module tree
serves training (``engine.run`` / ``jax.grad``), the BackPACK extensions,
and decode (``serve_step`` with per-block caches).
"""
from __future__ import annotations

import math
from typing import List

import jax
import jax.numpy as jnp

from repro.core.module import (
    Dense,
    Embedding,
    LayerNorm,
    Module,
    RMSNorm,
    ScanStack,
    Sequential,
)
from repro.nn.blocks import (
    AttnBlock,
    AttnMoEBlock,
    DecBlock,
    EncBlock,
    HymbaBlock,
    MLAMoEBlock,
    RWKV6Block,
)
from repro.nn.layers import Param
from repro.nn.wired import Wired


def sinusoid_pos(t, d, dtype=jnp.float32):
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((t, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


class PrefixEmbed(Wired):
    """VLM/audio frontend stub: concat precomputed prefix embeddings with
    token embeddings.  x: {'tokens': [N,Tt] int, 'prefix': [N,P,d] float}."""

    def __init__(self, vocab, d, dtype=jnp.float32):
        self.d = d
        self.children_map = {"emb": Embedding(vocab, d, dtype=dtype)}

    def wire(self, call, params, x):
        toks = call("emb", x["tokens"])
        return jnp.concatenate([x["prefix"].astype(toks.dtype), toks], axis=1)

    def embed_tokens(self, params, tokens):
        return self.children_map["emb"].apply(params["emb"], tokens)


class TokenEmbed(Wired):
    def __init__(self, vocab, d, dtype=jnp.float32):
        self.d = d
        self.children_map = {"emb": Embedding(vocab, d, dtype=dtype)}

    def wire(self, call, params, x):
        return call("emb", x)

    def embed_tokens(self, params, tokens):
        return self.children_map["emb"].apply(params["emb"], tokens)


class CausalLM(Sequential):
    """[embed, *stacks, norm, head] with a single-token decode path."""

    def __init__(self, embed, stacks: List[Module], norm, head):
        super().__init__([embed] + stacks + [norm, head])
        self.n_stacks = len(stacks)

    @property
    def stacks(self):
        return self.mods[1: 1 + self.n_stacks]

    def init_serve_cache(self, params, batch, max_len, dtype):
        return tuple(
            s.init_cache(p, batch, max_len, dtype)
            for s, p in zip(self.stacks, params[1: 1 + self.n_stacks])
        )

    def cache_axes(self):
        return tuple(s.cache_axes() for s in self.stacks)

    def serve_step(self, params, caches, tokens, pos):
        """tokens: [N] int32; pos: scalar int32 → (logits [N,V], caches)."""
        emb = self.mods[0]
        h = emb.embed_tokens(params[0], tokens[:, None])
        x = (h, pos)
        new_caches = []
        for i, stack in enumerate(self.stacks):
            x, c = stack.decode_step(params[1 + i], x, caches[i])
            new_caches.append(c)
        h = self.mods[-2].apply(params[-2], x[0])
        logits = self.mods[-1].apply(params[-1], h)
        return logits[:, 0], tuple(new_caches)


class WhisperModel(Wired):
    """Encoder-decoder; frontend stub feeds precomputed frame embeddings.

    x: {'frames': [N, S, d], 'tokens': [N, Td] int} → logits [N, Td, V].
    """

    def __init__(self, vocab, d, n_heads, d_ff, enc_layers, dec_layers,
                 max_dec=448, dtype=jnp.float32):
        self.d, self.max_dec = d, max_dec
        self.dtype = dtype
        self.children_map = {
            "emb": Embedding(vocab, d, dtype=dtype),
            "pos_dec": Param((max_dec, d), init=lambda k, s: 0.01 * jax.random.normal(k, s), dtype=dtype),
            "enc": ScanStack(EncBlock(d, n_heads, d_ff, dtype=dtype), enc_layers),
            "ln_post": LayerNorm(d, dtype=dtype),
            "dec": ScanStack(DecBlock(d, n_heads, d_ff, dtype=dtype), dec_layers),
            "ln_f": LayerNorm(d, dtype=dtype),
            "head": Dense(d, vocab, use_bias=False, dtype=dtype,
                          axes=("embed", "vocab")),
        }

    def wire(self, call, params, x):
        frames, tokens = x["frames"], x["tokens"]
        s, td = frames.shape[1], tokens.shape[1]
        e = frames + sinusoid_pos(s, self.d, frames.dtype)[None]
        e = call("enc", e)
        e = call("ln_post", e)
        t = call("emb", tokens) + call("pos_dec", None)[:td][None]
        y, _ = call("dec", (t, e))
        y = call("ln_f", y)
        return call("head", y)

    # -- serving -----------------------------------------------------------------
    def encode(self, params, frames):
        e = frames + sinusoid_pos(frames.shape[1], self.d, frames.dtype)[None]
        e = self.children_map["enc"].apply(params["enc"], e)
        return self.children_map["ln_post"].apply(params["ln_post"], e)

    def init_serve_cache(self, params, batch, max_len, dtype, enc_out=None):
        dec_stack = self.children_map["dec"]
        caches = dec_stack.init_cache(params["dec"], batch, self.max_dec, dtype)
        if enc_out is not None:
            # fill per-layer cross K/V from the encoder output
            def fill(p, c):
                blk = dec_stack.block
                n, s = enc_out.shape[:2]
                ck = blk.children_map["ck"].apply(p["ck"], enc_out)
                cv = blk.children_map["cv"].apply(p["cv"], enc_out)
                c = dict(c)
                c["ck"] = ck.reshape(n, s, blk.h, blk.dh)
                c["cv"] = cv.reshape(n, s, blk.h, blk.dh)
                return c

            caches = jax.vmap(fill)(params["dec"], caches)
        return caches

    def cache_axes(self):
        return self.children_map["dec"].cache_axes()

    def serve_step(self, params, caches, tokens, pos):
        h = self.children_map["emb"].apply(params["emb"], tokens[:, None])
        p_dec = params["pos_dec"]["v"]
        h = h + jax.lax.dynamic_slice_in_dim(
            p_dec, jnp.minimum(pos, self.max_dec - 1), 1, axis=0
        )[None]
        x = (h, pos)
        x, caches = self.children_map["dec"].decode_step(params["dec"], x, caches)
        y = self.children_map["ln_f"].apply(params["ln_f"], x[0])
        logits = self.children_map["head"].apply(params["head"], y)
        return logits[:, 0], caches


def _expand_segments(cfg):
    """cfg.window_segments: list[(window_or_None, count)], cfg.pattern_repeat."""
    segs = cfg.window_segments or [(None, cfg.n_layers)]
    repeat = cfg.pattern_repeat or 1
    total = sum(c for _, c in segs) * repeat
    assert total == cfg.n_layers, (total, cfg.n_layers)
    return segs, repeat


def make_stacks(mk_block, segments, repeat, remat=False, seq_constraint=None):
    segs = [
        ScanStack(mk_block(w), c, remat=remat, seq_constraint=seq_constraint)
        if c > 1 else mk_block(w)
        for (w, c) in segments
    ]
    unit = Sequential(segs) if len(segs) > 1 else segs[0]
    if repeat > 1:
        return [ScanStack(unit, repeat, remat=remat and len(segs) == 1,
                          seq_constraint=seq_constraint)]
    return [unit]


def build_model(cfg, remat=False, seq_constraint=None, attn_impl="naive",
                wkv_chunk=16):
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model

    if cfg.kind == "encdec":
        return WhisperModel(cfg.vocab, d, cfg.n_heads, cfg.d_ff,
                            cfg.enc_layers, cfg.dec_layers, dtype=dtype)

    if cfg.kind == "rwkv":
        mk = lambda w: RWKV6Block(d, cfg.d_ff, head_dim=cfg.head_dim or 64,
                                  wkv_chunk=wkv_chunk, dtype=dtype)
    elif cfg.kind == "hymba":
        mk = lambda w: HymbaBlock(d, cfg.n_heads, cfg.kv_heads, cfg.d_ff,
                                  head_dim=cfg.head_dim, ssm_state=cfg.ssm_state,
                                  window=w, act=cfg.act, attn_impl=attn_impl,
                                  rope_theta=cfg.rope_theta, dtype=dtype)
    elif cfg.kind == "moe_mla":
        mk = lambda w: MLAMoEBlock(
            d, cfg.n_heads, cfg.d_expert, cfg.n_experts, cfg.top_k,
            kv_lora=cfg.kv_lora, qk_nope=cfg.qk_nope, qk_rope=cfg.qk_rope,
            v_dim=cfg.v_head_dim, n_shared=cfg.n_shared_experts,
            capacity_factor=cfg.capacity_factor, rope_theta=cfg.rope_theta,
            act=cfg.act, dtype=dtype)
    elif cfg.kind == "moe_gqa":
        mk = lambda w: AttnMoEBlock(
            d, cfg.n_heads, cfg.kv_heads, cfg.d_expert, cfg.n_experts,
            cfg.top_k, capacity_factor=cfg.capacity_factor, act=cfg.act,
            rope_theta=cfg.rope_theta, dtype=dtype, head_dim=cfg.head_dim)
    else:  # dense
        mk = lambda w: AttnBlock(
            d, cfg.n_heads, cfg.kv_heads, cfg.d_ff, head_dim=cfg.head_dim,
            window=w, norm=cfg.norm, act=cfg.act, glu=cfg.glu,
            rope_theta=cfg.rope_theta, rope_pct=cfg.rope_pct,
            qkv_bias=cfg.qkv_bias, attn_impl=attn_impl, dtype=dtype)

    segments, repeat = _expand_segments(cfg)
    stacks = make_stacks(mk, segments, repeat, remat=remat,
                         seq_constraint=seq_constraint)
    if cfg.frontend == "vision":
        embed = PrefixEmbed(cfg.vocab, d, dtype=dtype)
    else:
        embed = TokenEmbed(cfg.vocab, d, dtype=dtype)
    norm = (RMSNorm(d, dtype=dtype) if cfg.norm == "rmsnorm"
            else LayerNorm(d, dtype=dtype))
    head = Dense(d, cfg.vocab, use_bias=False, dtype=dtype,
                 axes=("embed", "vocab"))
    return CausalLM(embed, stacks, norm, head)
