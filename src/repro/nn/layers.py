"""Extra parameter-holding modules.

* ``Conv2d`` — the paper benchmarks conv nets (3C3D, All-CNN-C, 2C2D); we
  implement convolution via im2col/unfold so every BackPACK formula reduces
  to the sequence-Dense case: per-sample gradients are sums of rank-1 terms
  over patch positions, exactly like tokens (Grosse & Martens 2016).
* ``BatchedDense`` — per-expert weights ``[E, a, b]`` for MoE; statistics are
  *token-level* (each routed token is a sample unit — per-sequence moments
  are undefined once tokens of one sequence route to different experts).
* ``Param`` — a raw learnable tensor (RWKV bonus ``u``, token-shift mixers).
  Gradients flow through Wired taps; per-sample stats are not extracted
  (documented: ≲0.01% of parameters).
* ``Buffer`` — non-trainable per-layer scalar (sliding-window sizes); kept
  in the params tree so ``lax.scan`` can vary it per layer, masked out of
  optimizer updates by the ``*_buf`` name convention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.module import (
    Axes,
    Module,
    _f32,
    dense_curv_stats,
    dense_first_order_stats,
)


class Param(Module):
    """Raw learnable tensor; ``apply`` ignores x and returns the tensor."""

    def __init__(self, shape, init=0.0, dtype=jnp.float32, axes=None):
        self.shape = tuple(shape)
        self.init_val = init
        self.dtype = dtype
        self.axes = axes or Axes((None,) * len(self.shape))

    def init(self, key):
        if callable(self.init_val):
            return {"v": self.init_val(key, self.shape).astype(self.dtype)}
        return {"v": jnp.full(self.shape, self.init_val, self.dtype)}

    def param_axes(self):
        return {"v": self.axes}

    def apply(self, params, x):
        return params["v"]

    def backward(self, params, tape, g, exts, cfg):
        return None, {"v": g.astype(params["v"].dtype)}, {}

    def jac_t_mat(self, params, tape, M):
        return None

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        return None, {}


class Buffer(Module):
    """Non-trainable scalar/array riding in the params tree (name it *_buf)."""

    def __init__(self, value, dtype=jnp.int32):
        self.value = value
        self.dtype = dtype

    def init(self, key):
        return {"v": jnp.asarray(self.value, self.dtype)}

    def param_axes(self):
        return {"v": Axes(())}

    def apply(self, params, x):
        return params["v"]

    def backward(self, params, tape, g, exts, cfg):
        return None, {"v": jnp.zeros_like(params["v"])}, {}

    def jac_t_mat(self, params, tape, M):
        return None

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        return None, {}


class BatchedDense(Module):
    """Per-expert weights: x [E, cap, a] → [E, cap, b] via W [E, a, b]."""

    def __init__(self, n_experts, d_in, d_out, dtype=jnp.float32,
                 axes=("expert", "embed", "mlp"), init_scale=None):
        self.E, self.d_in, self.d_out = n_experts, d_in, d_out
        self.dtype = dtype
        self.axes = axes
        self.init_scale = init_scale if init_scale is not None else d_in ** -0.5

    def init(self, key):
        w = jax.random.normal(key, (self.E, self.d_in, self.d_out), jnp.float32)
        return {"w": (w * self.init_scale).astype(self.dtype)}

    def param_axes(self):
        return {"w": Axes(tuple(self.axes))}

    def apply(self, params, x):
        return jnp.einsum("eca,eab->ecb", x, params["w"])

    def backward(self, params, tape, g, exts, cfg):
        x = tape
        Af, Bf = _f32(x), _f32(g)
        gw = jnp.einsum("eca,ecb->eab", Af, Bf).astype(params["w"].dtype)
        g_in = jnp.einsum("ecb,eab->eca", g, params["w"])
        stats = {}
        names = {e.name for e in exts}
        if "second_moment" in names or "variance" in names:
            # token-level (capacity slots are the sample units for experts)
            if cfg.use_kernels and cfg.use_fused:
                # Fused kernel with experts as the group axis ([E, cap, 1, d]):
                # unlike the einsum below, the squares happen in-register on
                # the way out of the MXU — A², B² are never materialized in
                # HBM — and all E experts ride one launch.  (Deliberate even
                # though the synthetic R=1 axis means no multi-stat fusion:
                # there is no batched sq_matmul kernel.)
                from repro.kernels import ops as kops

                stats["_sum_grad2"] = {"w": kops.fused_first_order(
                    Af[:, :, None, :], Bf[:, :, None, :],
                    want_l2=False, want_moment=True)["moment"]}
            else:
                stats["_sum_grad2"] = {
                    "w": jnp.einsum("eca,ecb->eab", Af ** 2, Bf ** 2)}
        if "kfac" in names or "kflr" in names:
            cap = x.shape[1]
            stats["_kron_a"] = {
                "w": jnp.einsum("eca,ecd->ead", Af, Af) / float(cap)
            }
        return g_in, {"w": gw}, stats

    def jac_t_mat(self, params, tape, M):
        return jnp.einsum("xecb,eab->xeca", M, params["w"])

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        x = tape
        names = {e.name for e in exts}
        stats = {}
        Sf = _f32(S)
        diag_name = "diag_ggn_mc" if ext_prefix == "mc" else "diag_ggn"
        kron_name = "kfac" if ext_prefix == "mc" else "kflr"
        if diag_name in names:
            stats[diag_name] = {
                "w": jnp.einsum("eca,xecb->eab", _f32(x) ** 2, Sf ** 2)
            }
        if kron_name in names:
            b_fac = jnp.einsum("xeci,xecj->eij", Sf, Sf)
            stats[kron_name] = {"w": {"B": b_fac}}
        return self.jac_t_mat(params, tape, S), stats


class Conv2d(Module):
    """NHWC conv via unfold → Dense-shaped BackPACK formulas.

    x: [N, H, W, C_in] → [N, H', W', C_out].
    """

    def __init__(self, c_in, c_out, kernel=3, stride=1, padding="SAME",
                 use_bias=True, dtype=jnp.float32):
        self.c_in, self.c_out = c_in, c_out
        self.kernel = (kernel, kernel) if isinstance(kernel, int) else kernel
        self.stride = (stride, stride) if isinstance(stride, int) else stride
        self.padding = padding
        self.use_bias = use_bias
        self.dtype = dtype

    def init(self, key):
        kh, kw = self.kernel
        fan_in = kh * kw * self.c_in
        w = jax.random.normal(key, (fan_in, self.c_out), jnp.float32) * fan_in ** -0.5
        p = {"w": w.astype(self.dtype)}
        if self.use_bias:
            p["b"] = jnp.zeros((self.c_out,), self.dtype)
        return p

    def param_axes(self):
        p = {"w": Axes((None, None))}
        if self.use_bias:
            p["b"] = Axes((None,))
        return p

    def _unfold(self, x):
        # lax patches util expects NCHW; returns [N, C*kh*kw, H', W']
        xt = jnp.moveaxis(x, -1, 1)
        pat = jax.lax.conv_general_dilated_patches(
            xt, self.kernel, self.stride, self.padding
        )
        n, k, hh, ww = pat.shape
        pat = pat.reshape(n, k, hh * ww)
        return jnp.moveaxis(pat, 1, 2), (hh, ww)  # [N, P, K]

    def apply(self, params, x):
        pat, (hh, ww) = self._unfold(x)
        y = pat @ params["w"]
        if self.use_bias:
            y = y + params["b"]
        return y.reshape(x.shape[0], hh, ww, self.c_out)

    def forward_tape(self, params, x):
        return self.apply(params, x), x

    def backward(self, params, tape, g, exts, cfg):
        x = tape
        pat, (hh, ww) = self._unfold(x)
        B = g.reshape(g.shape[0], hh * ww, self.c_out)
        gw = jnp.einsum("npk,npc->kc", _f32(pat), _f32(B)).astype(params["w"].dtype)
        grads = {"w": gw}
        if self.use_bias:
            grads["b"] = jnp.sum(_f32(B), axis=(0, 1)).astype(params["w"].dtype)
        # input cotangent via vjp of unfold+matmul (XLA fuses to conv-transpose)
        _, vjp = jax.vjp(lambda xx: self.apply(params, xx), x)
        g_in = vjp(g)[0]
        stats = dense_first_order_stats(pat, B, exts, cfg, self.use_bias) if exts else {}
        return g_in, grads, stats

    def jac_t_mat(self, params, tape, M):
        x = tape
        _, vjp = jax.vjp(lambda xx: self.apply(params, xx), x)
        return jax.vmap(lambda m: vjp(m)[0])(M)

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        x = tape
        pat, (hh, ww) = self._unfold(x)
        c = S.shape[0]
        Sr = S.reshape(c, S.shape[1], hh * ww, self.c_out)
        stats = dense_curv_stats(pat, Sr, exts, cfg, self.use_bias, ext_prefix)
        return self.jac_t_mat(params, tape, S), stats


class MaxPool2d(Module):
    def __init__(self, size=2, stride=None):
        self.size = size
        self.stride = stride or size

    def apply(self, params, x):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max,
            (1, self.size, self.size, 1), (1, self.stride, self.stride, 1),
            "VALID",
        )


class Flatten(Module):
    def apply(self, params, x):
        return x.reshape(x.shape[0], -1)
