from repro.nn import functional
from repro.nn.blocks import (
    AttnBlock,
    AttnMoEBlock,
    DecBlock,
    EncBlock,
    HymbaBlock,
    MLAMoEBlock,
    RWKV6Block,
)
from repro.nn.layers import (
    BatchedDense,
    Buffer,
    Conv2d,
    Flatten,
    MaxPool2d,
    Param,
)
from repro.nn.models import (
    CausalLM,
    PrefixEmbed,
    TokenEmbed,
    WhisperModel,
    build_model,
)
from repro.nn.wired import Wired
