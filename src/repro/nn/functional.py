"""Parameter-free mixing primitives used inside ``Wired.wire`` functions.

All functions are pure jnp/lax — differentiable by the Wired VJP taps, and
TPU-idiomatic: mixing is phrased as batched matmuls (MXU) and the recurrent
scans are *chunked* so the inner work is matmul-shaped rather than a
length-T elementwise loop (the TPU-native adaptation of RWKV/SSD GPU
kernels, see DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30
GLOBAL_WINDOW = 1 << 30  # "window" value meaning full/global attention


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(dh, theta=10000.0):
    return theta ** (-jnp.arange(0, dh, 2, dtype=jnp.float32) / dh)


def apply_rope(x, positions, theta=10000.0):
    """x: [N, T, H, dh]; positions: [T] array or traced scalar."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # [dh/2]
    pos = jnp.asarray(positions, jnp.float32)
    ang = pos[..., None] * freqs
    if ang.ndim == 1:        # scalar position (decode)
        ang = ang[None, None, None]      # [1, 1, 1, dh/2]
    else:                    # [T, dh/2]
        ang = ang[None, :, None]         # [1, T, 1, dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : dh // 2].astype(jnp.float32), x[..., dh // 2:].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    return jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# scaled dot-product attention (GQA, causal, dynamic sliding window)
# ---------------------------------------------------------------------------


def sdpa(q, k, v, *, causal=True, window=None, q_positions=None,
         k_positions=None, scale=None):
    """q: [N, T, H, dh], k/v: [N, S, KV, dh(v)] → [N, T, H, dhv].

    ``window`` may be a *traced* scalar — the 5:1 local:global pattern is a
    per-layer runtime buffer so layer stacks stay scan-homogeneous.
    ``*_positions``: absolute positions (default arange), used for masking
    with KV caches / rings.
    """
    n, t, h, dh = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    scale = scale if scale is not None else dh ** -0.5
    qp = q_positions if q_positions is not None else jnp.arange(t)
    kp = k_positions if k_positions is not None else jnp.arange(s)
    qg = q.reshape(n, t, kv, g, dh)
    logits = jnp.einsum("ntkgd,nskd->nkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= qp[:, None] >= kp[None, :]
    if window is not None:
        mask &= (qp[:, None] - kp[None, :]) < window
    mask &= kp[None, :] >= 0  # ring-buffer slots not yet written
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    dv = v.shape[-1]
    out = jnp.einsum("nkgts,nskd->ntkgd", p, v.astype(jnp.float32))
    return out.reshape(n, t, h, dv).astype(q.dtype)


def sdpa_chunked(q, k, v, *, causal=True, window=None, q_positions=None,
                 k_positions=None, scale=None, q_chunk=512, k_chunk=1024):
    """Flash-attention-style chunked attention (TPU adaptation).

    Online-softmax over k-blocks inside a scan over q-blocks; each q-block
    is wrapped in ``jax.checkpoint`` so the backward pass recomputes block
    internals instead of saving [T×S] probability matrices — activation
    memory drops from O(T²) to O(T·chunk) at ≤2× attention FLOPs.  This is
    the memory-roofline lever for the train/prefill shapes (see §Perf).
    """
    n, t, h, dh = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    dv = v.shape[-1]
    scale = scale if scale is not None else dh ** -0.5
    qp = q_positions if q_positions is not None else jnp.arange(t)
    kp = k_positions if k_positions is not None else jnp.arange(s)

    cq = min(q_chunk, t)
    while t % cq:
        cq -= 1
    ck = min(k_chunk, s)
    while s % ck:
        ck -= 1
    nq, nk = t // cq, s // ck

    qf = q.reshape(n, nq, cq, kv, g, dh)
    qpb = qp.reshape(nq, cq)
    kb = k.reshape(n, nk, ck, kv, dh)
    vb = v.reshape(n, nk, ck, kv, dv)
    kpb = kp.reshape(nk, ck)

    def q_block(qi, qpos):
        # qi: [n, cq, kv, g, dh]; qpos: [cq]
        def kv_step(carry, inputs):
            m, l, acc = carry
            kbi, vbi, kpos = inputs  # [n, ck, kv, dh], [n, ck, kv, dv], [ck]
            logits = jnp.einsum("ntkgd,nskd->nkgts",
                                qi.astype(jnp.float32),
                                kbi.astype(jnp.float32)) * scale
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            mask &= kpos[None, :] >= 0
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("nkgts,nskd->nkgtd", p, vbi.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((n, kv, g, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((n, kv, g, cq), jnp.float32)
        a0 = jnp.zeros((n, kv, g, cq, dv), jnp.float32)
        with jax.named_scope(f"flashk_T{nk}"):
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0),
                 kpb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1)  # [n, cq, kv, g, dv]

    blk = jax.checkpoint(q_block)

    def scan_q(_, inp):
        qi, qpos = inp
        return None, blk(qi, qpos)

    with jax.named_scope(f"flashq_T{nq}"):
        _, outs = jax.lax.scan(scan_q, None,
                               (jnp.moveaxis(qf, 1, 0), qpb))
    out = jnp.moveaxis(outs, 0, 1).reshape(n, t, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked linear-attention scans (RWKV6 "Finch" / Mamba-2 SSD)
# ---------------------------------------------------------------------------


def wkv_chunked(r, k, v, log_w, u=None, state0=None, chunk=16):
    """RWKV6 recurrence, chunk-parallel (TPU adaptation: matmul-shaped).

        S_t = diag(w_t) S_{t-1} + k_t v_tᵀ ;   y_t = r_tᵀ S_{t-1} + (r·u·k)_t v_t

    r, k: [N, T, H, dk];  v: [N, T, H, dv];  log_w: [N, T, H, dk] (≤ 0);
    u: [H, dk] bonus or None;  state0: [N, H, dk, dv] or None.
    Returns (y [N, T, H, dv], state [N, H, dk, dv]).

    SSD/Mamba-2 is the special case of scalar per-head decay (broadcast
    log_w over dk) with u=None.
    """
    n, t, h, dk = r.shape
    dv = v.shape[-1]
    if t % chunk != 0:
        chunk = 1 if t < chunk else [c for c in range(chunk, 0, -1) if t % c == 0][0]
    nc = t // chunk
    rs = r.reshape(n, nc, chunk, h, dk).astype(jnp.float32)
    ks = k.reshape(n, nc, chunk, h, dk).astype(jnp.float32)
    vs = v.reshape(n, nc, chunk, h, dv).astype(jnp.float32)
    lw = jnp.clip(log_w.reshape(n, nc, chunk, h, -1).astype(jnp.float32),
                  -60.0, -1e-6)
    lw = jnp.broadcast_to(lw, (n, nc, chunk, h, dk))
    if state0 is None:
        state0 = jnp.zeros((n, h, dk, dv), jnp.float32)

    strict = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)

    def per_chunk(S, xs):
        rc, kc, vc, lwc = xs  # [n, chunk, h, ...]
        P = jnp.cumsum(lwc, axis=1)              # inclusive log-decay
        E = P - lwc                               # exclusive
        r_t = rc * jnp.exp(E)                     # r̃
        k_t = kc * jnp.exp(-P)                    # k̃  (bounded: chunk small)
        A = jnp.einsum("nthd,nshd->nhts", r_t, k_t) * strict[None, None]
        y = jnp.einsum("nhts,nshd->nthd", A, vc)
        if u is not None:
            diag = jnp.einsum("nthd,hd,nthd->nth", rc, u.astype(jnp.float32), kc)
            y = y + diag[..., None] * vc
        y = y + jnp.einsum("nthd,nhde->nthe", r_t, S)
        decay_end = jnp.exp(P[:, -1])             # [n, h, dk]
        k_end = kc * jnp.exp(P[:, -1][:, None] - P)
        S_new = decay_end[..., None] * S + jnp.einsum(
            "nthd,nthe->nhde", k_end, vc
        )
        return S_new, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rs, ks, vs, lw))
    with jax.named_scope(f"wkvchunk_T{nc}"):
        state, ys = jax.lax.scan(per_chunk, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(n, t, h, dv)
    return y.astype(r.dtype), state


def wkv_step(r, k, v, log_w, u, state):
    """Single-token WKV step (decode). r,k: [N,H,dk]; v: [N,H,dv]."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(jnp.clip(log_w.astype(jnp.float32), -60.0, -1e-6))
    w = jnp.broadcast_to(w, kf.shape)
    y = jnp.einsum("nhd,nhde->nhe", rf, state)
    if u is not None:
        y = y + jnp.einsum("nhd,hd,nhd->nh", rf, u.astype(jnp.float32), kf)[..., None] * vf
    state = w[..., None] * state + kf[..., None] * vf[..., None, :]
    return y.astype(r.dtype), state


# ---------------------------------------------------------------------------
# token shift (RWKV)
# ---------------------------------------------------------------------------


def token_shift(x, last=None):
    """x_{t-1} (zeros / `last` for t=0).  x: [N, T, D]."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    else:
        last = last[:, None] if last.ndim == 2 else last
    return jnp.concatenate([last, x[:, :-1]], axis=1)


# ---------------------------------------------------------------------------
# KV-cache helpers (decode)
# ---------------------------------------------------------------------------


def cache_update(cache_k, cache_v, pos_buf, k_new, v_new, pos, ring):
    """Insert one position into a (possibly ring) KV cache.

    cache_k/v: [N, S, KV, dh]; pos_buf: [S] absolute positions (-1 = empty);
    k/v_new: [N, 1, KV, dh]; pos: traced scalar.
    """
    S = cache_k.shape[1]
    slot = jnp.where(ring, pos % S, jnp.minimum(pos, S - 1))
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new.astype(cache_v.dtype), slot, axis=1)
    pos_buf = jax.lax.dynamic_update_slice_in_dim(
        pos_buf, pos[None].astype(pos_buf.dtype), slot, axis=0
    )
    return cache_k, cache_v, pos_buf
