"""Capacity-based top-k MoE dispatch (GShard-style), XLA/SPMD-friendly.

Tokens are routed to ``[E, capacity]`` slots by scatter (no [M, E, C]
one-hots); expert FFNs are ``BatchedDense`` einsums sharded over the
``expert`` axis (expert parallelism).  Gradients flow to the router through
the combine weights; overflowed tokens are dropped (standard capacity
semantics).  Per-expert BackPACK statistics (token-level moments, per-expert
KFAC factors) come from ``BatchedDense``'s hand-written formulas via the
Wired taps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def capacity(n_tokens, n_experts, top_k, factor):
    return max(int(n_tokens * top_k * factor / n_experts + 0.999), 4)


def route(logits, top_k):
    """logits: [M, E] → (gates [M,k], idx [M,k], pos [M,k], probs [M,E])."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, top_k)
    gates = vals / (jnp.sum(vals, -1, keepdims=True) + 1e-9)
    M, E = probs.shape
    oh = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # [M, k, E]
    ohf = oh.reshape(M * top_k, E)
    cum = jnp.cumsum(ohf, axis=0) - ohf
    pos = jnp.sum(cum * ohf, axis=-1).reshape(M, top_k).astype(jnp.int32)
    return gates, idx, pos, probs


def moe_apply(call, h, logits, E, top_k, cap_factor, act):
    """h: [N, T, d]; logits: [N, T, E] → [N, T, d].

    ``call`` applies the Wired children 'e_gate'/'e_up'/'e_down'.
    """
    n, t, d = h.shape
    M = n * t
    cap = capacity(M, E, top_k, cap_factor)
    hf = h.reshape(M, d)
    gates, idx, pos, _ = route(logits.reshape(M, E), top_k)
    keep = pos < cap
    pos_safe = jnp.where(keep, pos, cap)  # OOB rows dropped by scatter
    idx_f = idx.reshape(-1)
    pos_f = pos_safe.reshape(-1)
    src = jnp.repeat(jnp.arange(M), top_k)
    xe = jnp.zeros((E, cap, d), h.dtype).at[idx_f, pos_f].add(
        hf[src], mode="drop"
    )
    ye = call("e_down", act(call("e_gate", xe)) * call("e_up", xe))
    # combine: gather each token's k expert outputs, weight by gates
    got = ye[idx_f, jnp.minimum(pos_f, cap - 1)]  # [M*k, d]
    got = got * (keep.reshape(-1)[:, None]).astype(got.dtype)
    y = jnp.sum(
        got.reshape(M, top_k, d) * gates[..., None].astype(got.dtype), axis=1
    )
    return y.reshape(n, t, d)
