"""Transformer-family blocks as ``Wired`` modules.

One block = one decoder layer (attention/mixer + FFN + norms), so a layer
stack is a single homogeneous ``ScanStack``.  Heterogeneous attention
patterns (gemma3's 5 local : 1 global, hymba's 3 global layers) are built as
*nested* stacks of homogeneous segments — windows stay static per block
instance, caches get static shapes, and compile time stays O(#distinct
block types), not O(L).

Every parameter lives in a Dense / BatchedDense / Embedding / norm / Param
child, so BackPACK extension statistics come from the hand-written child
formulas; the mixing dataflow in ``wire`` is differentiated by the Wired
VJP taps.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.module import Dense, GroupRMSNorm, LayerNorm, Module, RMSNorm
from repro.nn import functional as F
from repro.nn.layers import BatchedDense, Param
from repro.nn.wired import Wired


def _norm(kind, d, dtype):
    return RMSNorm(d, dtype=dtype) if kind == "rmsnorm" else LayerNorm(d, dtype=dtype)


def _act(name):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
    }[name]


# ---------------------------------------------------------------------------
# dense attention + (G)LU FFN decoder layer
# ---------------------------------------------------------------------------


class AttnBlock(Wired):
    def __init__(self, d, n_heads, kv_heads, d_ff, *, head_dim=None,
                 causal=True, window=None, norm="rmsnorm", act="silu",
                 glu=True, rope_theta=10000.0, rope_pct=1.0, qkv_bias=False,
                 attn_impl="naive", dtype=jnp.float32):
        self.d, self.h, self.kv = d, n_heads, kv_heads
        self.dh = head_dim or d // n_heads
        self.causal, self.window = causal, window
        self.attn_impl = attn_impl
        self.act = _act(act)
        self.glu = glu
        self.rope_theta, self.rope_pct = rope_theta, rope_pct
        self.dtype = dtype
        dh = self.dh
        ch = {
            "ln1": _norm(norm, d, dtype),
            "wq": Dense(d, n_heads * dh, use_bias=qkv_bias, dtype=dtype,
                        axes=("embed", "heads")),
            "wk": Dense(d, kv_heads * dh, use_bias=qkv_bias, dtype=dtype,
                        axes=("embed", "kv")),
            "wv": Dense(d, kv_heads * dh, use_bias=qkv_bias, dtype=dtype,
                        axes=("embed", "kv")),
            "wo": Dense(n_heads * dh, d, use_bias=False, dtype=dtype,
                        axes=("heads", "embed")),
            "ln2": _norm(norm, d, dtype),
        }
        if glu:
            ch["w_gate"] = Dense(d, d_ff, use_bias=False, dtype=dtype,
                                 axes=("embed", "mlp"))
            ch["w_up"] = Dense(d, d_ff, use_bias=False, dtype=dtype,
                               axes=("embed", "mlp"))
            ch["w_down"] = Dense(d_ff, d, use_bias=False, dtype=dtype,
                                 axes=("mlp", "embed"))
        else:
            ch["w_up"] = Dense(d, d_ff, use_bias=True, dtype=dtype,
                               axes=("embed", "mlp"))
            ch["w_down"] = Dense(d_ff, d, use_bias=True, dtype=dtype,
                                 axes=("mlp", "embed"))
        self.children_map = ch

    def _rope(self, x, positions):
        if self.rope_pct >= 1.0:
            return F.apply_rope(x, positions, self.rope_theta)
        rot = int(self.dh * self.rope_pct)
        rot -= rot % 2
        return jnp.concatenate(
            [F.apply_rope(x[..., :rot], positions, self.rope_theta),
             x[..., rot:]], axis=-1)

    def _attend(self, call, x, positions, k_positions=None, kc=None, vc=None):
        n, t = x.shape[:2]
        q = call("wq", x).reshape(n, t, self.h, self.dh)
        k = call("wk", x).reshape(n, t, self.kv, self.dh)
        v = call("wv", x).reshape(n, t, self.kv, self.dh)
        q = self._rope(q, positions)
        k = self._rope(k, positions)
        return q, k, v

    def _ffn(self, call, x):
        h = call("ln2", x)
        if self.glu:
            y = self.act(call("w_gate", h)) * call("w_up", h)
        else:
            y = self.act(call("w_up", h))
        return x + call("w_down", y)

    def _sdpa(self, q, k, v):
        fn = F.sdpa_chunked if self.attn_impl == "chunked" else F.sdpa
        return fn(q, k, v, causal=self.causal, window=self.window)

    def wire(self, call, params, x):
        n, t = x.shape[:2]
        h = call("ln1", x)
        q, k, v = self._attend(call, h, jnp.arange(t))
        a = self._sdpa(q, k, v)
        x = x + call("wo", a.reshape(n, t, self.h * self.dh))
        return self._ffn(call, x)

    # -- decode -----------------------------------------------------------------
    def init_cache(self, params, batch, max_len, dtype):
        S = max_len if self.window is None else min(self.window, max_len)
        return {
            "k": jnp.zeros((batch, S, self.kv, self.dh), dtype),
            "v": jnp.zeros((batch, S, self.kv, self.dh), dtype),
            "pos": -jnp.ones((S,), jnp.int32),
        }

    def cache_axes(self):
        from repro.core.module import Axes
        return {"k": Axes(("batch", "kv_seq", "kv", "head")),
                "v": Axes(("batch", "kv_seq", "kv", "head")),
                "pos": Axes(("kv_seq",))}

    def wire_step(self, call, params, xp, cache):
        x, pos = xp  # x: [N, 1, d], pos: traced scalar
        n = x.shape[0]
        h = call("ln1", x)
        q, k, v = self._attend(call, h, pos)
        ring = jnp.asarray(self.window is not None)
        ck, cv, pbuf = F.cache_update(
            cache["k"], cache["v"], cache["pos"], k, v, pos,
            ring=ring,
        )
        a = F.sdpa(q, ck, cv, causal=True, window=self.window,
                   q_positions=pos[None], k_positions=pbuf)
        x = x + call("wo", a.reshape(n, 1, self.h * self.dh))
        x = self._ffn(call, x)
        return (x, pos), {"k": ck, "v": cv, "pos": pbuf}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) attention + MoE FFN decoder layer
# ---------------------------------------------------------------------------


class MLAMoEBlock(Wired):
    def __init__(self, d, n_heads, d_expert, n_experts, top_k, *,
                 kv_lora=512, qk_nope=128, qk_rope=64, v_dim=128,
                 n_shared=2, capacity_factor=1.25, rope_theta=10000.0,
                 act="silu", dtype=jnp.float32):
        self.d, self.h = d, n_heads
        self.kv_lora, self.nope, self.rh, self.dv = kv_lora, qk_nope, qk_rope, v_dim
        self.E, self.k_top, self.cf = n_experts, top_k, capacity_factor
        self.n_shared = n_shared
        self.rope_theta = rope_theta
        self.act = _act(act)
        self.dtype = dtype
        ch = {
            "ln1": RMSNorm(d, dtype=dtype),
            "dq": Dense(d, n_heads * (qk_nope + qk_rope), use_bias=False,
                        dtype=dtype, axes=("embed", "heads")),
            "dkv": Dense(d, kv_lora + qk_rope, use_bias=False, dtype=dtype,
                         axes=("embed", None)),
            "uk": Dense(kv_lora, n_heads * qk_nope, use_bias=False,
                        dtype=dtype, axes=(None, "heads")),
            "uv": Dense(kv_lora, n_heads * v_dim, use_bias=False,
                        dtype=dtype, axes=(None, "heads")),
            "wo": Dense(n_heads * v_dim, d, use_bias=False, dtype=dtype,
                        axes=("heads", "embed")),
            "ln2": RMSNorm(d, dtype=dtype),
            "router": Dense(d, n_experts, use_bias=False, dtype=dtype,
                            axes=("embed", None)),
            "e_gate": BatchedDense(n_experts, d, d_expert, dtype=dtype),
            "e_up": BatchedDense(n_experts, d, d_expert, dtype=dtype),
            "e_down": BatchedDense(n_experts, d_expert, d, dtype=dtype,
                                   axes=("expert", "mlp", "embed")),
        }
        if n_shared:
            sd = d_expert * n_shared
            ch["s_gate"] = Dense(d, sd, use_bias=False, dtype=dtype,
                                 axes=("embed", "mlp"))
            ch["s_up"] = Dense(d, sd, use_bias=False, dtype=dtype,
                               axes=("embed", "mlp"))
            ch["s_down"] = Dense(sd, d, use_bias=False, dtype=dtype,
                                 axes=("mlp", "embed"))
        self.children_map = ch

    def _mla_qkv(self, call, h, positions):
        n, t = h.shape[:2]
        q = call("dq", h).reshape(n, t, self.h, self.nope + self.rh)
        q_nope, q_pe = q[..., : self.nope], q[..., self.nope:]
        q_pe = F.apply_rope(q_pe, positions, self.rope_theta)
        ckv_full = call("dkv", h)
        c_kv, k_pe = ckv_full[..., : self.kv_lora], ckv_full[..., self.kv_lora:]
        k_pe = F.apply_rope(k_pe[:, :, None, :], positions, self.rope_theta)
        return q_nope, q_pe, c_kv, k_pe  # k_pe: [N, T, 1, rh]

    def _mla_attend(self, call, q_nope, q_pe, c_kv, k_pe):
        n, t = q_nope.shape[:2]
        k_nope = call("uk", c_kv).reshape(n, -1, self.h, self.nope)
        v = call("uv", c_kv).reshape(n, -1, self.h, self.dv)
        k_pe_b = jnp.broadcast_to(k_pe, k_pe.shape[:2] + (self.h, self.rh))
        q_full = jnp.concatenate([q_nope, q_pe], -1)
        k_full = jnp.concatenate([k_nope, k_pe_b], -1)
        a = F.sdpa(q_full, k_full, v, causal=True,
                   scale=(self.nope + self.rh) ** -0.5)
        return call("wo", a.reshape(n, t, self.h * self.dv))

    def _moe_ffn(self, call, x):
        from repro.nn.moe import moe_apply

        h = call("ln2", x)
        logits = call("router", h)
        y = moe_apply(call, h, logits, self.E, self.k_top, self.cf, self.act)
        if self.n_shared:
            y = y + call("s_down", self.act(call("s_gate", h)) * call("s_up", h))
        return x + y

    def wire(self, call, params, x):
        n, t = x.shape[:2]
        h = call("ln1", x)
        q_nope, q_pe, c_kv, k_pe = self._mla_qkv(call, h, jnp.arange(t))
        x = x + self._mla_attend(call, q_nope, q_pe, c_kv, k_pe)
        return self._moe_ffn(call, x)

    # -- decode: absorbed MLA over the *compressed* cache ------------------------
    def init_cache(self, params, batch, max_len, dtype):
        return {
            "ckv": jnp.zeros((batch, max_len, self.kv_lora), dtype),
            "kpe": jnp.zeros((batch, max_len, self.rh), dtype),
            "pos": -jnp.ones((max_len,), jnp.int32),
        }

    def cache_axes(self):
        from repro.core.module import Axes
        return {"ckv": Axes(("batch", "kv_seq", None)),
                "kpe": Axes(("batch", "kv_seq", None)),
                "pos": Axes(("kv_seq",))}

    def wire_step(self, call, params, xp, cache):
        x, pos = xp
        n = x.shape[0]
        h = call("ln1", x)
        q_nope, q_pe, c_kv, k_pe = self._mla_qkv(call, h, pos)
        S = cache["ckv"].shape[1]
        slot = jnp.minimum(pos, S - 1)
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], c_kv.astype(cache["ckv"].dtype), slot, axis=1)
        kpe = jax.lax.dynamic_update_slice_in_dim(
            cache["kpe"], k_pe[:, :, 0].astype(cache["kpe"].dtype), slot, axis=1)
        pbuf = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], pos[None].astype(jnp.int32), slot, axis=0)
        # absorb W_UK into the query:  score = q_nopeᵀ W_UK c_kv + q_peᵀ k_pe
        wuk = params["uk"]["w"].reshape(self.kv_lora, self.h, self.nope)
        q_lat = jnp.einsum("nthd,lhd->nthl", q_nope.astype(jnp.float32),
                           wuk.astype(jnp.float32))  # [N,1,H,kv_lora]
        scale = (self.nope + self.rh) ** -0.5
        logits = (jnp.einsum("nthl,nsl->nhts", q_lat, ckv.astype(jnp.float32))
                  + jnp.einsum("nthr,nsr->nhts", q_pe.astype(jnp.float32),
                               kpe.astype(jnp.float32))) * scale
        mask = (pbuf >= 0) & (pbuf <= pos)  # [S]
        logits = jnp.where(mask[None, None, None, :], logits, F.NEG_INF)
        p = jax.nn.softmax(logits, axis=-1)
        ctx = jnp.einsum("nhts,nsl->nthl", p, ckv.astype(jnp.float32))
        wuv = params["uv"]["w"].reshape(self.kv_lora, self.h, self.dv)
        a = jnp.einsum("nthl,lhv->nthv", ctx, wuv.astype(jnp.float32))
        x = x + call("wo", a.reshape(n, 1, self.h * self.dv).astype(x.dtype))
        x = self._moe_ffn(call, x)
        return (x, pos), {"ckv": ckv, "kpe": kpe, "pos": pbuf}


# ---------------------------------------------------------------------------
# GQA attention + MoE FFN (granite)
# ---------------------------------------------------------------------------


class AttnMoEBlock(AttnBlock):
    def __init__(self, d, n_heads, kv_heads, d_expert, n_experts, top_k, *,
                 capacity_factor=1.25, act="silu", rope_theta=10000.0,
                 dtype=jnp.float32, head_dim=None):
        super().__init__(d, n_heads, kv_heads, 4 * d, head_dim=head_dim,
                         act=act, rope_theta=rope_theta, dtype=dtype)
        # replace the dense FFN with a routed MoE
        for k in ("w_gate", "w_up", "w_down"):
            self.children_map.pop(k, None)
        self.E, self.k_top, self.cf = n_experts, top_k, capacity_factor
        self.children_map.update({
            "router": Dense(d, n_experts, use_bias=False, dtype=dtype,
                            axes=("embed", None)),
            "e_gate": BatchedDense(n_experts, d, d_expert, dtype=dtype),
            "e_up": BatchedDense(n_experts, d, d_expert, dtype=dtype),
            "e_down": BatchedDense(n_experts, d_expert, d, dtype=dtype,
                                   axes=("expert", "mlp", "embed")),
        })

    def _ffn(self, call, x):
        from repro.nn.moe import moe_apply

        h = call("ln2", x)
        logits = call("router", h)
        y = moe_apply(call, h, logits, self.E, self.k_top, self.cf, self.act)
        return x + y


# ---------------------------------------------------------------------------
# RWKV6 ("Finch") block: time-mix (WKV, data-dependent decay) + channel-mix
# ---------------------------------------------------------------------------


class RWKV6Block(Wired):
    def __init__(self, d, d_ff, *, head_dim=64, decay_lora=64,
                 wkv_chunk=16, dtype=jnp.float32):
        self.d, self.dh = d, head_dim
        self.h = d // head_dim
        self.wkv_chunk = wkv_chunk
        self.dtype = dtype
        mk = lambda: Param((d,), init=0.5, dtype=dtype)
        self.children_map = {
            "ln1": RMSNorm(d, dtype=dtype),
            "ln2": RMSNorm(d, dtype=dtype),
            "mu_r": mk(), "mu_k": mk(), "mu_v": mk(), "mu_g": mk(), "mu_w": mk(),
            "w1": Dense(d, decay_lora, use_bias=False, dtype=dtype,
                        axes=("embed", None)),
            "w2": Dense(decay_lora, d, use_bias=False, dtype=dtype,
                        axes=(None, "embed")),
            "w0": Param((d,), init=-4.0, dtype=dtype),
            "u": Param((self.h, head_dim), init=0.0, dtype=dtype),
            "wr": Dense(d, d, use_bias=False, dtype=dtype, axes=("embed", "heads")),
            "wk": Dense(d, d, use_bias=False, dtype=dtype, axes=("embed", "heads")),
            "wv": Dense(d, d, use_bias=False, dtype=dtype, axes=("embed", "heads")),
            "wg": Dense(d, d, use_bias=False, dtype=dtype, axes=("embed", "heads")),
            # per-head GroupNorm (RWKV6): shard-local under head TP
            "ln_x": GroupRMSNorm(d, self.h, dtype=dtype),
            "wo": Dense(d, d, use_bias=False, dtype=dtype, axes=("heads", "embed")),
            "cmu_r": mk(), "cmu_k": mk(),
            "cwr": Dense(d, d, use_bias=False, dtype=dtype, axes=("embed", "mlp")),
            "cwk": Dense(d, d_ff, use_bias=False, dtype=dtype, axes=("embed", "mlp")),
            "cwv": Dense(d_ff, d, use_bias=False, dtype=dtype, axes=("mlp", "embed")),
        }

    def _time_mix(self, call, h, shifted, state0=None):
        n, t, d = h.shape
        lerp = lambda mu: h + (shifted - h) * call(mu, None)
        r = call("wr", lerp("mu_r")).reshape(n, t, self.h, self.dh)
        k = call("wk", lerp("mu_k")).reshape(n, t, self.h, self.dh)
        v = call("wv", lerp("mu_v")).reshape(n, t, self.h, self.dh)
        g = jax.nn.silu(call("wg", lerp("mu_g")))
        raw = call("w0", None) + call("w2", jnp.tanh(call("w1", lerp("mu_w"))))
        log_w = -jnp.exp(raw.astype(jnp.float32)).reshape(n, t, self.h, self.dh)
        u = call("u", None)
        y, state = F.wkv_chunked(r, k, v, log_w, u=u, state0=state0,
                                 chunk=self.wkv_chunk)
        y = call("ln_x", y.reshape(n, t, d)) * g
        return call("wo", y), state

    def _chan_mix(self, call, h, shifted):
        lerp = lambda mu: h + (shifted - h) * call(mu, None)
        rc = jax.nn.sigmoid(call("cwr", lerp("cmu_r")))
        kc = jnp.square(jax.nn.relu(call("cwk", lerp("cmu_k"))))
        return rc * call("cwv", kc)

    def wire(self, call, params, x):
        h = call("ln1", x)
        y, _ = self._time_mix(call, h, F.token_shift(h))
        x = x + y
        h2 = call("ln2", x)
        return x + self._chan_mix(call, h2, F.token_shift(h2))

    def init_cache(self, params, batch, max_len, dtype):
        return {
            "x_time": jnp.zeros((batch, 1, self.d), dtype),
            "x_chan": jnp.zeros((batch, 1, self.d), dtype),
            "state": jnp.zeros((batch, self.h, self.dh, self.dh), jnp.float32),
        }

    def cache_axes(self):
        from repro.core.module import Axes
        return {"x_time": Axes(("batch", None, "embed")),
                "x_chan": Axes(("batch", None, "embed")),
                "state": Axes(("batch", "heads", None, None))}

    def wire_step(self, call, params, xp, cache):
        x, pos = xp  # [N, 1, d]
        h = call("ln1", x)
        y, state = self._time_mix(call, h, cache["x_time"].astype(h.dtype),
                                  state0=cache["state"])
        x = x + y
        h2 = call("ln2", x)
        x = x + self._chan_mix(call, h2, cache["x_chan"].astype(h2.dtype))
        return (x, pos), {"x_time": h.astype(cache["x_time"].dtype),
                          "x_chan": h2.astype(cache["x_chan"].dtype),
                          "state": state}


# ---------------------------------------------------------------------------
# Hymba: parallel attention + SSD heads sharing one block
# ---------------------------------------------------------------------------


class HymbaBlock(AttnBlock):
    def __init__(self, d, n_heads, kv_heads, d_ff, *, head_dim=None,
                 ssm_state=16, window=None, act="silu", rope_theta=10000.0,
                 attn_impl="naive", dtype=jnp.float32):
        super().__init__(d, n_heads, kv_heads, d_ff, head_dim=head_dim,
                         window=window, act=act, rope_theta=rope_theta,
                         attn_impl=attn_impl, dtype=dtype)
        self.ds = ssm_state
        self.children_map.update({
            "w_xs": Dense(d, self.h * self.dh, use_bias=False, dtype=dtype,
                          axes=("embed", "heads")),
            "w_B": Dense(d, self.h * self.ds, use_bias=False, dtype=dtype,
                         axes=("embed", "heads")),
            "w_C": Dense(d, self.h * self.ds, use_bias=False, dtype=dtype,
                         axes=("embed", "heads")),
            "w_dt": Dense(d, self.h, use_bias=True, dtype=dtype,
                          axes=("embed", "heads")),
            "a_log": Param((self.h,), init=0.0, dtype=jnp.float32),
            "norm_attn": RMSNorm(self.h * self.dh, dtype=dtype),
            "norm_ssm": RMSNorm(self.h * self.dh, dtype=dtype),
        })

    def _ssd(self, call, h, state0=None):
        n, t = h.shape[:2]
        xs = call("w_xs", h).reshape(n, t, self.h, self.dh)
        B = call("w_B", h).reshape(n, t, self.h, self.ds)
        C = call("w_C", h).reshape(n, t, self.h, self.ds)
        dt = jax.nn.softplus(call("w_dt", h).astype(jnp.float32))
        log_a = (-dt * jnp.exp(call("a_log", None)))[..., None]  # [N,T,H,1]
        y, state = F.wkv_chunked(C, B, xs, log_a, u=None, state0=state0)
        return y.reshape(n, t, self.h * self.dh), state

    def wire(self, call, params, x):
        n, t = x.shape[:2]
        h = call("ln1", x)
        q, k, v = self._attend(call, h, jnp.arange(t))
        ao = self._sdpa(q, k, v)
        ao = ao.reshape(n, t, self.h * self.dh)
        so, _ = self._ssd(call, h)
        y = 0.5 * (call("norm_attn", ao) + call("norm_ssm", so))
        x = x + call("wo", y)
        return self._ffn(call, x)

    def init_cache(self, params, batch, max_len, dtype):
        c = super().init_cache(params, batch, max_len, dtype)
        c["ssm"] = jnp.zeros((batch, self.h, self.ds, self.dh), jnp.float32)
        return c

    def cache_axes(self):
        c = super().cache_axes()
        from repro.core.module import Axes
        c["ssm"] = Axes(("batch", "heads", None, None))
        return c

    def wire_step(self, call, params, xp, cache):
        x, pos = xp
        n = x.shape[0]
        h = call("ln1", x)
        q, k, v = self._attend(call, h, pos)
        ck, cv, pbuf = F.cache_update(
            cache["k"], cache["v"], cache["pos"], k, v, pos,
            ring=jnp.asarray(self.window is not None))
        ao = F.sdpa(q, ck, cv, causal=True, window=self.window,
                    q_positions=pos[None], k_positions=pbuf)
        ao = ao.reshape(n, 1, self.h * self.dh)
        so, sstate = self._ssd(call, h, state0=cache["ssm"])
        y = 0.5 * (call("norm_attn", ao) + call("norm_ssm", so))
        x = x + call("wo", y)
        x = self._ffn(call, x)
        return (x, pos), {"k": ck, "v": cv, "pos": pbuf, "ssm": sstate}


# ---------------------------------------------------------------------------
# Whisper encoder / decoder blocks
# ---------------------------------------------------------------------------


class EncBlock(AttnBlock):
    def __init__(self, d, n_heads, d_ff, dtype=jnp.float32):
        super().__init__(d, n_heads, n_heads, d_ff, causal=False,
                         norm="layernorm", act="gelu", glu=False,
                         qkv_bias=True, dtype=dtype)


class DecBlock(Wired):
    """Input/output: tuple (y [N,Td,d], enc [N,S,d]) — enc passes through."""

    def __init__(self, d, n_heads, d_ff, dtype=jnp.float32):
        self.d, self.h = d, n_heads
        self.dh = d // n_heads
        self.dtype = dtype
        dh = self.dh
        mkd = lambda a, b, bias=True, ax=("embed", "heads"): Dense(
            a, b, use_bias=bias, dtype=dtype, axes=ax)
        self.children_map = {
            "ln1": LayerNorm(d, dtype=dtype),
            "wq": mkd(d, d), "wk": mkd(d, d, bias=False), "wv": mkd(d, d),
            "wo": mkd(d, d, ax=("heads", "embed")),
            "lnx": LayerNorm(d, dtype=dtype),
            "cq": mkd(d, d), "ck": mkd(d, d, bias=False), "cv": mkd(d, d),
            "co": mkd(d, d, ax=("heads", "embed")),
            "ln2": LayerNorm(d, dtype=dtype),
            "w1": Dense(d, d_ff, use_bias=True, dtype=dtype, axes=("embed", "mlp")),
            "w2": Dense(d_ff, d, use_bias=True, dtype=dtype, axes=("mlp", "embed")),
        }

    def _heads(self, x):
        n, t = x.shape[:2]
        return x.reshape(n, t, self.h, self.dh)

    def wire(self, call, params, x):
        y, enc = x
        n, t = y.shape[:2]
        h = call("ln1", y)
        a = F.sdpa(self._heads(call("wq", h)), self._heads(call("wk", h)),
                   self._heads(call("wv", h)), causal=True)
        y = y + call("wo", a.reshape(n, t, self.d))
        h = call("lnx", y)
        c = F.sdpa(self._heads(call("cq", h)), self._heads(call("ck", enc)),
                   self._heads(call("cv", enc)), causal=False)
        y = y + call("co", c.reshape(n, t, self.d))
        h = call("ln2", y)
        y = y + call("w2", jax.nn.gelu(call("w1", h)))
        return (y, enc)

    def init_cache(self, params, batch, max_len, dtype):
        return {
            "k": jnp.zeros((batch, max_len, self.h, self.dh), dtype),
            "v": jnp.zeros((batch, max_len, self.h, self.dh), dtype),
            "pos": -jnp.ones((max_len,), jnp.int32),
            # cross K/V filled at prefill from the encoder output
            "ck": None,
            "cv": None,
        }

    def cache_axes(self):
        from repro.core.module import Axes
        return {"k": Axes(("batch", "kv_seq", "kv", "head")),
                "v": Axes(("batch", "kv_seq", "kv", "head")),
                "pos": Axes(("kv_seq",)),
                "ck": Axes(("batch", "kv_seq", "kv", "head")),
                "cv": Axes(("batch", "kv_seq", "kv", "head"))}

    def wire_step(self, call, params, xp, cache):
        y, pos = xp
        n = y.shape[0]
        h = call("ln1", y)
        k = self._heads(call("wk", h))
        v = self._heads(call("wv", h))
        ck_, cv_, pbuf = F.cache_update(cache["k"], cache["v"], cache["pos"],
                                        k, v, pos, ring=jnp.asarray(False))
        a = F.sdpa(self._heads(call("wq", h)), ck_, cv_, causal=True,
                   q_positions=pos[None], k_positions=pbuf)
        y = y + call("wo", a.reshape(n, 1, self.d))
        h = call("lnx", y)
        c = F.sdpa(self._heads(call("cq", h)), cache["ck"], cache["cv"],
                   causal=False)
        y = y + call("co", c.reshape(n, 1, self.d))
        h = call("ln2", y)
        y = y + call("w2", jax.nn.gelu(call("w1", h)))
        return (y, pos), {"k": ck_, "v": cv_, "pos": pbuf,
                          "ck": cache["ck"], "cv": cache["cv"]}
