"""The paper's §4 optimizer: damped curvature-preconditioned updates.

    θ ← θ − α (G(θ) + (λ+η) I)⁻¹ (∇L + η θ)          (Eq. 7 / 27)

with G from any BackPACK curvature backend:

  * ``diag_ggn`` / ``diag_ggn_mc`` / ``diag_hessian`` — elementwise inverse;
  * ``kfac`` / ``kflr`` / ``kfra`` — Kronecker factors inverted with the
    Martens–Grosse π-damping (Eq. 28/29, repro.core.kron).

Parameters without a curvature entry (mixer scalars, buffers) fall back to
a plain damped-SGD step — they are a vanishing fraction of the model.

EMA smoothing over steps (``stat_decay``) follows standard K-FAC practice.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import kron as K
from repro.optim.optimizers import Optimizer, _mask_buffers

_DIAG = {"diag_ggn", "diag_ggn_mc", "diag_hessian"}
_KRON = {"kfac", "kflr", "kfra"}


def _is_kron_leaf(node):
    return isinstance(node, dict) and "B" in node and set(node) <= {"A", "B", "A_diag"}


def _ema(old, new, decay):
    if old is None:
        return new
    return jax.tree.map(lambda o, n: decay * o + (1 - decay) * n, old, new)


def _precond_tree(grads, curv, damping, eta, params, lr):
    """Recurse (grads, curv, params) producing updates."""

    def rec(g, c, p):
        if isinstance(g, dict):
            return {k: rec(g[k],
                           c.get(k) if isinstance(c, dict) else None,
                           p[k]) for k in g}
        if isinstance(g, (tuple, list)):
            c_t = c if isinstance(c, (tuple, list)) else (None,) * len(g)
            return tuple(rec(gi, ci, pi) for gi, ci, pi in zip(g, c_t, p))
        # leaf gradient
        gf = g.astype(jnp.float32) + eta * p.astype(jnp.float32)
        if c is None or (isinstance(c, tuple) and len(c) == 0):
            return -lr * gf / (damping + eta)
        if _is_kron_leaf(c):
            A = c.get("A", c.get("A_diag"))
            B = c["B"]
            if A is None:
                solve = lambda b_, g_: K.kron_solve_bias(b_, g_, damping + eta)
                if B.ndim == 3:
                    return -lr * jax.vmap(solve)(B, gf)
                return -lr * solve(B, gf)
            solve = lambda a_, b_, g_: K.kron_solve(a_, b_, g_, damping + eta)
            if B.ndim == 3:  # scan-stacked layers (or per-expert factors)
                return -lr * jax.vmap(solve)(A, B, gf)
            return -lr * solve(A, B, gf)
        # diagonal curvature leaf
        return -lr * gf / (c.astype(jnp.float32) + damping + eta)

    def walk_curv(g, c, p):
        return rec(g, c, p)

    return walk_curv(grads, curv, params)


def curvature_optimizer(lr, damping=1e-2, curvature="diag_ggn_mc",
                        weight_decay=0.0, stat_decay=0.0):
    """Returns an Optimizer whose ``update`` takes ``curv=`` (engine output)."""
    assert curvature in _DIAG | _KRON, curvature

    def init(params):
        return {"stats": None, "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, curv=None, **kw):
        if curv is None:
            raise ValueError("curvature_optimizer.update needs curv=")
        if stat_decay > 0.0 and state["stats"] is not None:
            curv = _ema(state["stats"], curv, stat_decay)
        ups = _precond_tree(grads, curv, damping, weight_decay, params, lr)
        new_state = {"stats": curv if stat_decay > 0.0 else None,
                     "t": state["t"] + 1}
        return _mask_buffers(ups, params), new_state

    return Optimizer(init, update)
