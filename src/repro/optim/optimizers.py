"""Self-contained first-order optimizers (optax-style (init, update) pairs).

Buffers (non-trainable leaves living in the params tree so ``lax.scan`` can
vary them per layer) are frozen: any leaf whose path contains a key ending
in ``_buf`` keeps a zero update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)


def _is_buffer_path(path):
    for p in path:
        key = getattr(p, "key", None) or getattr(p, "name", None)
        if isinstance(key, str) and key.endswith("_buf"):
            return True
    return False


def _mask_buffers(updates, params):
    def fix(path, u, p):
        if _is_buffer_path(path) or not jnp.issubdtype(p.dtype, jnp.floating):
            return jnp.zeros_like(p)
        return u.astype(p.dtype)

    return jax.tree_util.tree_map_with_path(fix, updates, params)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def sgd(lr):
    def init(params):
        return ()

    def update(grads, state, params, **kw):
        ups = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        return _mask_buffers(ups, params), state

    return Optimizer(init, update)


def momentum_sgd(lr, rho=0.9):
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, **kw):
        new_m = jax.tree.map(
            lambda m, g: rho * m + g.astype(jnp.float32), state, grads
        )
        ups = jax.tree.map(lambda m: -lr * m, new_m)
        return _mask_buffers(ups, params), new_m

    return Optimizer(init, update)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr_scale=1.0, **kw):
        t = state["t"] + 1
        b1t = 1 - b1 ** t.astype(jnp.float32)
        b2t = 1 - b2 ** t.astype(jnp.float32)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)

        def upd(m_, v_, p):
            mhat = m_ / b1t
            vhat = v_ / b2t
            return -lr * lr_scale * (mhat / (jnp.sqrt(vhat) + eps)
                                     + weight_decay * p.astype(jnp.float32))

        ups = jax.tree.map(upd, m, v, params)
        return _mask_buffers(ups, params), {"m": m, "v": v, "t": t}

    return Optimizer(init, update)
