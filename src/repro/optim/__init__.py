from repro.optim.matfree import make_cg_ngd_step
from repro.optim.optimizers import Optimizer, adamw, momentum_sgd, sgd
from repro.optim.precond import curvature_optimizer
from repro.optim.schedule import constant, cosine, linear_warmup
