"""Matrix-free natural-gradient step: CG (or Gram-space) implicit solve.

The §4 preconditioned update (Eq. 7) without ever materializing the
preconditioner:

    θ ← θ − α (G(θ) + δI)⁻¹ ∇L(θ)

* ``solver='cg'`` — conjugate gradients against the matrix-free
  :class:`~repro.curv.products.GGNOperator` (~2 gradient sweeps per
  iteration).  Works on *any* architecture, including LM heads whose
  explicit Kronecker factors exceed device memory — the beyond-factor
  lane.
* ``solver='kernel'`` — asdfghjkl-style kernel-space solve
  (:func:`repro.curv.ngd.kernel_ngd_direction`): exact ``(G + δI)⁻¹ g``
  for the Dense-visible parameters through one dense ``[N·C̃]`` Gram
  solve when ``N·C̃ ≪ P``.  Flat-output models only.

``make_cg_ngd_step`` returns ``(opt, step)`` — a state-holding
:class:`~repro.optim.optimizers.Optimizer` (its ``init`` builds the step
state; ``update`` is unused) and an extended-signature step function
``step(params, opt_state, batch, step_idx, rng)``, pluggable into
``train.loop.fit(..., step_fn=...)`` and built by the launcher via
``--optimizer cg_ngd``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import ExtensionConfig
from repro.core import engine as eng
from repro.optim.optimizers import Optimizer, _mask_buffers, apply_updates


def make_cg_ngd_step(model, loss, *, lr: float, damping: float = 1e-3,
                     solver: str = "cg", cg_iters: int = 10,
                     cg_tol: float = 1e-5, weight_decay: float = 0.0,
                     ext_cfg: Optional[ExtensionConfig] = None,
                     mesh=None, shard_axes: Sequence[str] = ("data",)):
    """Build the matrix-free natural-gradient training step.

    ``ext_cfg.microbatch_size`` streams both the gradient sweep and every
    curvature product; ``mesh`` shards them over ``shard_axes`` — the
    same scale levers as the engine lanes, applied to the implicit solve.
    Returns ``(opt, step)``; see the module docstring.
    """
    if solver not in ("cg", "kernel"):
        raise ValueError(f"solver must be 'cg' or 'kernel', got {solver!r}")
    cfg = ext_cfg or ExtensionConfig()
    axes = tuple(shard_axes)

    from repro.curv import GGNOperator, cg_solve, kernel_ngd_direction
    from repro.core.extensions import GGNGram

    def init(params):
        return {"t": jnp.zeros((), jnp.int32)}

    def _sweep(params, batch, rng, extensions):
        n = jax.tree.leaves(batch["inputs"])[0].shape[0]
        plan = eng.plan_for_batch(extensions, cfg, n, mesh=mesh,
                                  shard_axes=axes)
        return plan.run(model, params, batch["inputs"], batch["labels"],
                        loss, cfg=cfg, rng=rng)

    def step(params, opt_state, batch, step_idx, rng):
        metrics = {}
        if solver == "kernel":
            res = _sweep(params, batch, rng, (GGNGram,))
            d, _ = kernel_ngd_direction(
                model, params, batch["inputs"], batch["labels"], loss,
                damping=damping, cfg=cfg, results=res)
        else:
            res = _sweep(params, batch, rng, ())
            op = GGNOperator(model, params, batch["inputs"],
                             batch["labels"], loss, damping=damping,
                             cfg=cfg, mesh=mesh, shard_axes=axes)
            sol = cg_solve(op.mv, res.grads, tol=cg_tol, maxiter=cg_iters)
            d = sol.x
            metrics["cg_iters"] = sol.iters
            metrics["cg_resid"] = sol.resid
        if weight_decay:
            d = jax.tree.map(
                lambda di, p: di + jnp.float32(weight_decay)
                * p.astype(jnp.float32), d, params)
        ups = _mask_buffers(
            jax.tree.map(lambda di: -lr * di, d), params)
        params = apply_updates(params, ups)
        opt_state = {"t": opt_state["t"] + 1}
        metrics.update({"loss": res.loss, "step": step_idx + 1})
        return params, opt_state, metrics

    def update(grads, state, params, **kw):
        raise NotImplementedError(
            "cg_ngd is a whole-step optimizer (the solve needs the batch, "
            "not just the gradient) — drive it via the returned step "
            "function / train.loop.fit(step_fn=...)")

    return Optimizer(init, update), step
