"""LR schedules as step -> multiplier callables (jit-traceable)."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.float32(1.0)


def linear_warmup(warmup_steps):
    def f(step):
        return jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1)).astype(jnp.float32)

    return f


def cosine(total_steps, warmup_steps=0, final=0.1):
    def f(step):
        warm = jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
        frac = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
        cos = final + (1 - final) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return (warm * cos).astype(jnp.float32)

    return f
