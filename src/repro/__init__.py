"""repro — BackPACK (ICLR 2020) as a multi-pod JAX training framework."""
__version__ = "1.0.0"
