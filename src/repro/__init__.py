"""repro — BackPACK (ICLR 2020) as a multi-pod JAX training framework.

The curated public surface.  Everything here is importable from the top
level — consumers should not need deep module paths for the common
workflow:

    import repro

    # one generalized backprop, many quantities (paper §3)
    res = repro.run(model, params, x, y, repro.CrossEntropyLoss(),
                    extensions=(repro.DiagGGN, repro.Variance))

    # scale it out: plan → shard(mesh) / accumulate(k)
    plan = repro.plan_sweeps((repro.KFAC,), repro.ExtensionConfig())

    # matrix-free curvature beyond factor scale (repro.curv)
    gv = repro.ggn_vp(model, params, x, y, loss, v)
    sol = repro.cg_solve(op.mv, res.grads)

    # curvature-backed uncertainty (repro.laplace)
    post = repro.fit_posterior(model, params, x, y, loss)

    # NTK consumers (repro.ntk_apps): GP regression, influence, selection
    gp = repro.gp_predict(model, params, x, y, x_test, loss)
    scores = repro.influence_scores(model, params, x, y, x_t, y_t, loss)
    sel = repro.select_subset(model, params, x, y, loss, k=16)

Deeper entry points stay in their subsystems: :mod:`repro.core`
(modules, reducers, engine lanes), :mod:`repro.curv` (operators, the
kernel-space NGD, SLQ log-det, Lanczos top-k), :mod:`repro.ntk_apps`
(kernel solvers, self-influence, the selection strategies),
:mod:`repro.laplace` (posteriors, predictives, evidence),
:mod:`repro.optim`, :mod:`repro.train`, :mod:`repro.kernels`,
:mod:`repro.obs`.
"""
from repro import obs
from repro.core import (
    # engine: the generalized backprop + its scale-out planner
    ExtensionConfig,
    Results,
    SweepPlan,
    plan_sweeps,
    run,
    # losses (factored Hessians: the √H and H·v closed forms)
    CrossEntropyLoss,
    MSELoss,
    # extension classes (paper §3 quantities + beyond-paper family)
    BatchDot,
    BatchGrad,
    BatchL2,
    DiagGGN,
    DiagGGNMC,
    DiagHessian,
    Extension,
    GGNGram,
    GGNTrace,
    KFAC,
    KFLR,
    KFRA,
    NTK,
    NTKClasswise,
    SecondMoment,
    Variance,
    # reducer protocol (how every statistic shards/streams)
    Reducer,
    register_reducer,
)
from repro.curv import (
    GGNOperator,
    HessianOperator,
    cg_solve,
    ggn_vp,
    hvp,
    lanczos_topk,
    slq_logdet,
)
from repro.laplace import fit_posterior
from repro.ntk_apps import (
    gp_predict,
    influence_scores,
    ntk_kernel,
    select_subset,
    self_influence,
)

__version__ = "1.1.0"

__all__ = [
    # engine
    "ExtensionConfig",
    "Results",
    "SweepPlan",
    "plan_sweeps",
    "run",
    # losses
    "CrossEntropyLoss",
    "MSELoss",
    # extensions
    "BatchDot",
    "BatchGrad",
    "BatchL2",
    "DiagGGN",
    "DiagGGNMC",
    "DiagHessian",
    "Extension",
    "GGNGram",
    "GGNTrace",
    "KFAC",
    "KFLR",
    "KFRA",
    "NTK",
    "NTKClasswise",
    "SecondMoment",
    "Variance",
    # reducers
    "Reducer",
    "register_reducer",
    # matrix-free curvature
    "GGNOperator",
    "HessianOperator",
    "cg_solve",
    "ggn_vp",
    "hvp",
    "lanczos_topk",
    "slq_logdet",
    # NTK consumers
    "gp_predict",
    "influence_scores",
    "ntk_kernel",
    "select_subset",
    "self_influence",
    # uncertainty
    "fit_posterior",
    # observability
    "obs",
]
