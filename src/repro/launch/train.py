"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --steps 200 --seq 64 --batch 8 --optimizer kfac --ckpt /tmp/ckpt

Runs the reduced config on CPU; on a real pod the same entry point runs the
full config with the production mesh (--full --mesh single|multi).
"""
import argparse
import dataclasses

from repro import obs
from repro.configs import SHAPES, get_config
from repro.core import DiagGGNMC, ExtensionConfig, KFAC, Variance
from repro.nn.models import build_model
from repro.optim import (adamw, curvature_optimizer, make_cg_ngd_step,
                         momentum_sgd)
from repro.train.loop import LoopConfig, fit, fit_with_restarts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "momentum", "diag_ggn_mc", "kfac",
                             "cg_ngd"])
    ap.add_argument("--damping", type=float, default=1e-1)
    ap.add_argument("--cg-iters", type=int, default=10,
                    help="cg_ngd: CG iterations per step (each costs ~2 "
                         "gradient sweeps; the implicit solve never "
                         "materializes a factor, so LM heads whose KFAC "
                         "factors exceed device memory still train)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-keep", type=int, default=3,
                    help="newest checkpoints retained in --ckpt (>= 1)")
    ap.add_argument("--max-restarts", type=int, default=0,
                    help="run under the restart driver: any fault restores "
                         "the latest checkpoint and retries, up to this "
                         "many times (needs --ckpt)")
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a failure at this step (exercises the "
                         "checkpoint/restart path end-to-end; pair with "
                         "--max-restarts)")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (pod-scale; not for CPU)")
    ap.add_argument("--track-variance", action="store_true")
    ap.add_argument("--shard-sweep", action="store_true",
                    help="run extension sweeps batch-sharded over all "
                         "local devices (SweepPlan.shard lane; batch must "
                         "divide the device count)")
    ap.add_argument("--microbatch-size", type=int, default=None,
                    help="stream each batch through the accumulated sweep "
                         "lane (SweepPlan.accumulate) in slices of at most "
                         "this many samples — identical numbers, activation "
                         "memory bounded by the microbatch; composes with "
                         "--shard-sweep (the shard x accumulate grid)")
    ap.add_argument("--trace-jsonl", default=None,
                    help="record an observability trace (spans / counters / "
                         "gauges, one JSON object per line) to this file; "
                         "render it with tools/obs_report.py")
    ap.add_argument("--metrics-report", action="store_true",
                    help="print the measured span tree + counters after "
                         "training (obs.report())")
    ap.add_argument("--profile-dir", default=None,
                    help="capture a jax.profiler device trace of the run "
                         "into this directory (view with TensorBoard / "
                         "Perfetto)")
    args = ap.parse_args()

    if args.trace_jsonl or args.metrics_report or args.profile_dir:
        obs.enable(trace_jsonl=args.trace_jsonl)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=args.seq,
                                global_batch=args.batch)

    extensions, ext_cfg, track = (), None, ()
    if args.optimizer == "adamw":
        opt = adamw(args.lr or 1e-3)
    elif args.optimizer == "momentum":
        opt = momentum_sgd(args.lr or 1e-2)
    elif args.optimizer == "diag_ggn_mc":
        opt = curvature_optimizer(args.lr or 0.2, args.damping, "diag_ggn_mc")
        extensions, ext_cfg = (DiagGGNMC,), ExtensionConfig(mc_samples=1)
    elif args.optimizer == "cg_ngd":
        opt = None  # built below, once mesh/microbatch are resolved
    else:
        opt = curvature_optimizer(args.lr or 0.3, args.damping, "kfac",
                                  stat_decay=0.9)
        extensions, ext_cfg = (KFAC,), ExtensionConfig(mc_samples=1)
    if args.track_variance:
        extensions = tuple(extensions) + (Variance,)
        track = ("variance",)
    if args.microbatch_size:
        ext_cfg = dataclasses.replace(ext_cfg or ExtensionConfig(),
                                      microbatch_size=args.microbatch_size)
        print(f"[accumulate] microbatch_size={args.microbatch_size} "
              f"({-(-args.batch // args.microbatch_size)} microbatches "
              f"per step)")

    mesh = None
    if args.shard_sweep and (extensions or args.optimizer == "cg_ngd"):
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()
        print(f"[shard-sweep] data mesh over {mesh.shape['data']} device(s)")

    step_fn = None
    if args.optimizer == "cg_ngd":
        from repro.core import CrossEntropyLoss

        opt, step_fn = make_cg_ngd_step(
            model, CrossEntropyLoss(), lr=args.lr or 0.3,
            damping=args.damping, cg_iters=args.cg_iters,
            ext_cfg=ext_cfg, mesh=mesh)
        print(f"[cg_ngd] matrix-free natural gradient: {args.cg_iters} CG "
              f"iterations/step, damping {args.damping:g} — no explicit "
              f"curvature factors")

    loop = LoopConfig(steps=args.steps, ckpt_dir=args.ckpt, log_every=10,
                      ckpt_keep=args.ckpt_keep)
    injector = None
    if args.fail_at_step is not None:
        from repro.train.fault import FailureInjector

        injector = FailureInjector(fail_at_step=args.fail_at_step)
        print(f"[fault] injecting failure at step {args.fail_at_step}")
    with obs.profile(args.profile_dir):
        if args.max_restarts > 0:
            (_, _, hist, wd), restarts = fit_with_restarts(
                model, cfg, shape, opt, loop,
                max_restarts=args.max_restarts,
                on_restart=lambda i, e: print(f"[restart {i}] after: {e}"),
                extensions=extensions, ext_cfg=ext_cfg, track=track,
                mesh=mesh, injector=injector, step_fn=step_fn)
            print(f"[fault] completed with {restarts} restart(s)")
        else:
            _, _, hist, wd = fit(model, cfg, shape, opt, loop,
                                 extensions=extensions, ext_cfg=ext_cfg,
                                 resume=args.resume, track=track, mesh=mesh,
                                 injector=injector, step_fn=step_fn)
    print(f"final loss {hist[-1]['loss']:.4f} "
          f"(stragglers flagged: {len(wd.straggler_steps)})")
    if args.profile_dir:
        print(f"[obs] device trace in {args.profile_dir}")
    if args.metrics_report:
        print(obs.report())
    if args.trace_jsonl:
        obs.disable()  # close the sink so the trace file is complete
        print(f"[obs] trace written to {args.trace_jsonl} — render with "
              f"'python tools/obs_report.py {args.trace_jsonl}'")


if __name__ == "__main__":
    main()
