"""Serving launcher: batched generation with KV caches, plus an
uncertainty-aware endpoint backed by a last-layer Laplace posterior.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
        --batch 4 --prompt-len 8 --max-len 64

    # next-token mean + predictive variance instead of sampled tokens:
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
        --batch 4 --prompt-len 8 --uncertainty
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.nn.models import build_model
from repro.serve.engine import ServeConfig, generate, generate_whisper


def serve_uncertainty(cfg, model, params, prompts, *,
                      marglik_steps=25, seed=0, top_k=5, log_fn=print):
    """Uncertainty-aware endpoint: next-token logit mean + variance.

    Fits a last-layer **diagonal** Laplace posterior on one deterministic
    calibration batch — the only structure that scales to LM heads: its
    state is O(d·V) where the Kronecker B factor would be a dense [V, V]
    (plus an O(V³) eigendecomposition), and the MC sweep (DiagGGNMC) keeps
    the curvature pass at one gradient-like sweep where the exact factor's
    leading axis is T·V.  Prior precision is tuned by evidence ascent;
    predictions use the rank-1 closed-form GLM for the final prompt
    position (no Jacobian seed materialized — see
    ``laplace.predictive._dense_glm_closed_form``).
    """
    from repro import laplace
    from repro.core import CrossEntropyLoss, ExtensionConfig
    from repro.data.synthetic import DataConfig, lm_batch
    from repro.laplace.posterior import split_last_dense

    loss = CrossEntropyLoss()
    dc = DataConfig(vocab=cfg.vocab, seq_len=prompts.shape[1],
                    global_batch=prompts.shape[0], seed=seed)
    calib = lm_batch(dc, 0)
    post = laplace.fit_posterior(
        model, params, calib["inputs"], calib["labels"], loss,
        structure="diag", last_layer=True,
        options=laplace.FitOptions(mc=True,
                                   cfg=ExtensionConfig(mc_seed=seed)))
    post, res = laplace.optimize_marglik(post, n_steps=marglik_steps)
    log_fn(f"[laplace] log-evidence {float(laplace.log_marglik(post)):.1f} "
           f"prior_prec {res.prior_prec:.3g}")

    feats, head, f_params, h_params = split_last_dense(model, params)
    phi = feats.apply(f_params, prompts)          # [N, T, d]
    mean, var = laplace.glm_predictive(
        head, h_params, post.inner, phi[:, -1])   # final position: [N, V]
    probs = laplace.probit_predictive(mean, var)
    for n in range(min(2, mean.shape[0])):
        order = jnp.argsort(-mean[n])[:top_k]
        row = " ".join(
            f"tok{int(t)}:{float(mean[n, t]):.2f}±"
            f"{float(jnp.sqrt(var[n, t])):.2f}"
            for t in order)
        log_fn(f"  prompt {n}: {row}")
    return mean, var, probs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--uncertainty", action="store_true",
                    help="next-token mean + Laplace predictive variance "
                         "instead of sampled tokens")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sc = ServeConfig(max_len=args.max_len, temperature=args.temperature)

    if args.uncertainty:
        if cfg.kind == "encdec":
            raise SystemExit("--uncertainty supports decoder-only archs")
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab)
        mean, var, _ = serve_uncertainty(cfg, model, params, prompts)
        print(f"served mean+variance for {mean.shape} next-token logits "
              f"(mean var {float(var.mean()):.4f})")
        return

    if cfg.kind == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(1),
                                   (args.batch, 64, cfg.d_model))
        toks = generate_whisper(model, params, frames, sc)
    else:
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab)
        toks = generate(model, params, prompts, sc)
    print(f"generated {toks.shape} tokens")
    for row in toks[: min(2, args.batch)]:
        print(" ", " ".join(str(int(t)) for t in row[:24]), "...")


if __name__ == "__main__":
    main()
