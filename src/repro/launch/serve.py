"""Serving launcher: batched generation with KV caches.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
        --batch 4 --prompt-len 8 --max-len 64
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.nn.models import build_model
from repro.serve.engine import ServeConfig, generate, generate_whisper


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sc = ServeConfig(max_len=args.max_len, temperature=args.temperature)

    if cfg.kind == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(1),
                                   (args.batch, 64, cfg.d_model))
        toks = generate_whisper(model, params, frames, sc)
    else:
        prompts = jax.random.randint(jax.random.PRNGKey(1),
                                     (args.batch, args.prompt_len), 0,
                                     cfg.vocab)
        toks = generate(model, params, prompts, sc)
    print(f"generated {toks.shape} tokens")
    for row in toks[: min(2, args.batch)]:
        print(" ", " ".join(str(int(t)) for t in row[:24]), "...")


if __name__ == "__main__":
    main()
