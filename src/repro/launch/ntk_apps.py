"""NTK-consumer launcher: GP regression, influence, subset selection.

    PYTHONPATH=src python -m repro.launch.ntk_apps --gp --n-train 64
    PYTHONPATH=src python -m repro.launch.ntk_apps --influence --top 10
    PYTHONPATH=src python -m repro.launch.ntk_apps --select-subset 16 \
        --method bait --microbatches 4 --shard-sweep

Runs the requested consumer on a papernets model over synthetic data —
the CPU-scale driver for the same entry points a real pod points at a
dataset.  ``--shard-sweep`` assembles the kernel on the sharded lane
('master' mode: factorization on shard 0), ``--microbatches`` streams
the Jacobian sweep row-blockwise.
"""
import argparse

import jax
import jax.numpy as jnp

from repro import obs
from repro.configs import papernets
from repro.core import CrossEntropyLoss, ExtensionConfig


def _data(key, n, dim, n_classes):
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (n, dim), jnp.float32)
    y = jax.random.randint(ky, (n,), 0, n_classes)
    return x, y


def main():
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--gp", action="store_true",
                      help="NTK-GP predictive mean/variance on a test split")
    mode.add_argument("--influence", action="store_true",
                      help="train→test influence scores + self-influence")
    mode.add_argument("--select-subset", type=int, metavar="K", default=None,
                      help="pick K pool points (see --method)")
    ap.add_argument("--model", default="mlp",
                    choices=["logreg", "mlp", "c2d2"])
    ap.add_argument("--n-train", type=int, default=64)
    ap.add_argument("--n-test", type=int, default=16)
    ap.add_argument("--dim", type=int, default=32)
    ap.add_argument("--classes", type=int, default=4)
    ap.add_argument("--ridge", type=float, default=1e-2)
    ap.add_argument("--damping", type=float, default=1e-2)
    ap.add_argument("--solver", default="cholesky",
                    choices=["cholesky", "eigh", "lanczos"])
    ap.add_argument("--rank", type=int, default=None,
                    help="eigh truncation / lanczos preconditioner rank")
    ap.add_argument("--method", default="diversity",
                    choices=["diversity", "bait"],
                    help="--select-subset strategy")
    ap.add_argument("--top", type=int, default=5,
                    help="rows to print per result table")
    ap.add_argument("--microbatches", type=int, default=None,
                    help="stream sweeps in this many row blocks "
                         "(accumulate lane)")
    ap.add_argument("--shard-sweep", action="store_true",
                    help="assemble kernels on the sharded sweep lane "
                         "(gram_assembly='master')")
    ap.add_argument("--trace-jsonl", default=None,
                    help="record the obs span trace to this JSONL file")
    args = ap.parse_args()

    if args.trace_jsonl:
        obs.enable(trace_jsonl=args.trace_jsonl)

    if args.model == "logreg":
        model = papernets.logreg(args.classes, args.dim)
    elif args.model == "mlp":
        model = papernets.mlp(args.classes, args.dim, hidden=(64, 32))
    else:
        img = 8
        args.dim = img * img
        model = papernets.c2d2(args.classes, in_ch=1, img=img)
    params = model.init(jax.random.PRNGKey(0))
    loss = CrossEntropyLoss()
    cfg = ExtensionConfig()

    x_tr, y_tr = _data(jax.random.PRNGKey(1), args.n_train, args.dim,
                       args.classes)
    x_te, y_te = _data(jax.random.PRNGKey(2), args.n_test, args.dim,
                       args.classes)
    if args.model == "c2d2":
        x_tr = x_tr.reshape(-1, 8, 8, 1)
        x_te = x_te.reshape(-1, 8, 8, 1)

    mesh = None
    if args.shard_sweep:
        from repro.launch.mesh import make_data_mesh

        mesh = make_data_mesh()
        print(f"[shard-sweep] data mesh over {mesh.shape['data']} device(s)")

    from repro import ntk_apps

    if args.gp:
        gp = ntk_apps.gp_predict(
            model, params, x_tr, y_tr, x_te, loss, ridge=args.ridge,
            solver=args.solver, rank=args.rank, cfg=cfg, mesh=mesh,
            microbatches=args.microbatches)
        print(f"[gp] solver={gp.info.method} rank={gp.info.rank} "
              f"iters={gp.info.iters} resid={float(gp.info.resid):.2e}")
        pred = jnp.argmax(gp.mean, axis=-1)
        for j in range(min(args.top, args.n_test)):
            print(f"  test[{j:3d}]  pred={int(pred[j])}  "
                  f"var={float(gp.var[j]):.4f}  "
                  f"mean={[round(float(v), 3) for v in gp.mean[j]]}")
    elif args.influence:
        inf = ntk_apps.influence_scores(
            model, params, x_tr, y_tr, x_te, y_te, loss,
            damping=args.damping, cfg=cfg, mesh=mesh,
            microbatches=args.microbatches)
        si = ntk_apps.self_influence(
            model, params, x_tr, y_tr, loss, damping=args.damping,
            cfg=cfg, mesh=mesh, microbatches=args.microbatches)
        total = inf.scores.sum(axis=1)
        order = jnp.argsort(total)[::-1]
        print(f"[influence] cg iters={int(inf.iters)} "
              f"max resid={float(inf.resid.max()):.2e} — top train points "
              f"by summed influence on the test split:")
        for i in map(int, order[:args.top]):
            print(f"  train[{i:3d}]  influence={float(total[i]):+.4f}  "
                  f"self={float(si.scores[i]):.4f}")
    else:
        sel = ntk_apps.select_subset(
            model, params, x_tr, y_tr, loss, args.select_subset,
            method=args.method, lam=args.damping, cfg=cfg, mesh=mesh,
            microbatches=args.microbatches)
        print(f"[select] method={args.method} k={args.select_subset} "
              f"picks (objective per step):")
        for t, (i, s) in enumerate(zip(sel.indices, sel.scores)):
            print(f"  step {t:3d}: pool[{int(i):3d}]  score={float(s):.4f}")

    if args.trace_jsonl:
        print(f"[obs] trace written to {args.trace_jsonl}")


if __name__ == "__main__":
    main()
