"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts a ``while`` body ONCE (verified: a 10×
scan of a matmul reports 1× the FLOPs), so for scan-stacked transformers it
underreports FLOPs/bytes/collectives by ~L×.  This module parses the
post-SPMD HLO text and produces execution-weighted totals.

Trip counts: every ``lax.scan`` we emit is wrapped in
``jax.named_scope(f"..._T{trips}")``; the while op's metadata
(``op_name=".../xxx_T24/while[...]"``) carries the count.  The call graph
(while bodies/conds, fusions, to_apply) propagates multipliers from ENTRY.

Per-computation symbol tables (name → shape) resolve operand shapes, since
post-optimization HLO only prints shapes at definitions.

  * flops — dot/convolution ops everywhere (2·|out|·K), weighted;
  * bytes — Σ (operand + output bytes) over ops in non-fusion computations
            (fusion internals never touch HBM), weighted;
  * collectives — per-kind output bytes, weighted.

All quantities are per-device (post-partitioning shapes).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\w*)\[([\d,]*)\](?:\{[^}]*\})?")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_REF_RE = re.compile(r"%([\w\.\-]+)")
# scope tags survive autodiff as e.g. "transpose(jvp(scanstack_T24))/while"
_TRIP_RE = re.compile(r"_T(\d+)[^/]*/while")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_NO_TRAFFIC = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
               "bitcast(", "after-all(", "partition-id(", "replica-id(",
               "-done(")


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _out_bytes(rhs_head: str) -> int:
    return sum(_elems(dims) * _DTYPE_BYTES.get(dt, 0)
               for dt, dims in _SHAPE_RE.findall(rhs_head))


class Computation:
    def __init__(self, name: str, is_entry: bool):
        self.name = name
        self.is_entry = is_entry
        self.lines: List[str] = []
        self.shapes: Dict[str, List[Tuple[str, str]]] = {}  # sym -> shapes

    def index(self):
        for line in self.lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            sym, rhs = m.group(1), m.group(2)
            head = rhs.split("(", 1)[0]
            self.shapes[sym] = _SHAPE_RE.findall(head)


def split_computations(hlo: str):
    comps: Dict[str, Computation] = {}
    entry = None
    cur = None
    depth = 0
    for raw in hlo.splitlines():
        st = raw.strip()
        if cur is None:
            if st.endswith("{") and "->" in st and ("(" in st):
                is_entry = st.startswith("ENTRY")
                name_part = st.split("(", 1)[0].replace("ENTRY", "").strip()
                name = name_part.lstrip("%").strip()
                if not name:
                    continue
                cur = Computation(name, is_entry)
                if is_entry:
                    entry = name
        else:
            if st.startswith("}"):
                comps[cur.name] = cur
                cur = None
            else:
                cur.lines.append(st)
    for c in comps.values():
        c.index()
    return comps, entry


def analyze(hlo: str, fused_scopes=frozenset()) -> dict:
    """fused_scopes: scope-name prefixes (e.g. {"flashk", "flashq",
    "wkvchunk"}) whose while-loop bodies are modeled as living inside a
    Pallas kernel: their intermediates stay in VMEM, so only block
    loads/stores (dynamic-slice / dynamic-update-slice fusions) and
    collectives are charged to HBM.  FLOPs are always counted."""
    comps, entry = split_computations(hlo)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0,
                "collectives": {k: 0 for k in _COLL_OPS}, "n_computations": 0}

    # --- call graph with loop multipliers --------------------------------
    children: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    fusion_bodies = set()
    fused_loop_comps = set()
    for name, comp in comps.items():
        for line in comp.lines:
            if " while(" in line or line.startswith("while("):
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                trips = 1
                mt = _TRIP_RE.findall(line)
                if mt:
                    trips = int(mt[-1])
                scopes = re.findall(r"(\w+?)_T\d+[^/]*/while", line)
                if scopes and scopes[-1] in fused_scopes:
                    if mb:
                        fused_loop_comps.add(mb.group(1))
                if mb:
                    children[name].append((mb.group(1), float(trips)))
                if mc:
                    children[name].append((mc.group(1), float(trips)))
                continue
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)", line):
                children[name].append((m.group(1), 1.0))
                if "fusion(" in line:
                    fusion_bodies.add(m.group(1))

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    for _ in range(128):
        changed = False
        for parent in list(children):
            pm = mult.get(parent, 0.0)
            if pm == 0.0:
                continue
            acc: Dict[str, float] = defaultdict(float)
            for kid, f in children[parent]:
                acc[kid] += pm * f
            for kid, m in acc.items():
                if abs(mult.get(kid, 0.0) - m) > 1e-9 * max(m, 1.0):
                    mult[kid] = m
                    changed = True
        if not changed:
            break

    # --- in-place fusion analysis -------------------------------------------
    # A fusion whose root is dynamic-update-slice updates its buffer operand
    # in place: traffic is the update slice (r+w), not the buffer.  Same for
    # dynamic-slice roots reading a slice of a big buffer.  This mirrors
    # XLA's HloCostAnalysis special-casing; without it, scan tape writes
    # appear to move the whole stacked [L, ...] buffer every layer.
    fusion_info = {}
    for name, comp in comps.items():
        root = next((l for l in comp.lines if l.lstrip().startswith("ROOT")), None)
        if root is None:
            continue
        dm = _DEF_RE.match(root)
        if not dm:
            continue
        rhs = dm.group(2)
        head, _, call = rhs.partition("(")
        refs = _REF_RE.findall(call.split(" metadata", 1)[0])

        def _param_idx(sym):
            for l in comp.lines:
                dm2 = _DEF_RE.match(l)
                if dm2 and dm2.group(1) == sym and "parameter(" in dm2.group(2):
                    mm = re.search(r"parameter\((\d+)\)", dm2.group(2))
                    return int(mm.group(1)) if mm else None
            return None

        def _def_rhs(sym):
            for l in comp.lines:
                dm2 = _DEF_RE.match(l)
                if dm2 and dm2.group(1) == sym:
                    return dm2.group(2)
            return ""

        if "dynamic-update-slice(" in rhs and len(refs) >= 2:
            upd = comp.shapes.get(refs[1], [])
            upd_b = sum(_elems(d) * _DTYPE_BYTES.get(dt, 0) for dt, d in upd)
            fusion_info[name] = ("dus", upd_b, {_param_idx(refs[0])})
        elif "dynamic-slice(" in rhs and refs:
            out_b = _out_bytes(head)
            fusion_info[name] = ("ds", out_b, {_param_idx(refs[0])})
        elif re.match(r"\(.*\)\s*tuple\(", rhs) or " tuple(" in rhs:
            # multi-output fusion: scan-tape writers root in a tuple of
            # dynamic-update-slices — charge each update slice, exclude the
            # in-place buffers from operand reads
            upd_total = 0
            buf_idxs = set()
            any_dus = False
            for ref in refs:
                drhs = _def_rhs(ref)
                if "dynamic-update-slice(" in drhs:
                    any_dus = True
                    drefs = _REF_RE.findall(drhs.partition("(")[2]
                                            .split(" metadata", 1)[0])
                    if len(drefs) >= 2:
                        upd = comp.shapes.get(drefs[1], [])
                        upd_total += 2 * sum(
                            _elems(d) * _DTYPE_BYTES.get(dt, 0) for dt, d in upd)
                        buf_idxs.add(_param_idx(drefs[0]))
                else:
                    shp = comp.shapes.get(ref, [])
                    upd_total += sum(
                        _elems(d) * _DTYPE_BYTES.get(dt, 0) for dt, d in shp)
            if any_dus:
                fusion_info[name] = ("mdus", upd_total, buf_idxs)

    # params of a fusion consumed ONLY via internal dynamic-slice: the
    # fusion reads a slice of a (stacked) buffer, not the whole buffer
    fusion_sliced: Dict[str, Dict[int, int]] = {}
    for name, comp in comps.items():
        param_syms = {}
        for l in comp.lines:
            dm2 = _DEF_RE.match(l)
            if dm2 and "parameter(" in dm2.group(2):
                mm = re.search(r"parameter\((\d+)\)", dm2.group(2))
                if mm:
                    param_syms[dm2.group(1)] = int(mm.group(1))
        if not param_syms:
            continue
        sliced = {}
        for sym, idx in param_syms.items():
            pat = re.compile(rf"%{re.escape(sym)}\b")
            use_lines = [l for l in comp.lines
                         if pat.search(l) and not
                         (_DEF_RE.match(l) and _DEF_RE.match(l).group(1) == sym)]
            if not use_lines:
                continue
            ok = True
            slice_b = 0
            for u in use_lines:
                dmu = _DEF_RE.match(u)
                if not dmu or "dynamic-slice(" not in dmu.group(2):
                    ok = False
                    break
                urefs = _REF_RE.findall(dmu.group(2).partition("(")[2]
                                        .split(" metadata", 1)[0])
                if not urefs or urefs[0] != sym:
                    ok = False
                    break
                slice_b += _out_bytes(dmu.group(2).partition("(")[0])
            if ok and slice_b:
                sliced[idx] = slice_b
        if sliced:
            fusion_sliced[name] = sliced

    # --- weighted op walk --------------------------------------------------
    flops = 0.0
    bytes_ = 0.0
    coll = {k: 0.0 for k in _COLL_OPS}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        in_fusion = name in fusion_bodies
        in_fused_kernel = name in fused_loop_comps
        for line in comp.lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            head, _, call = rhs.partition("(")
            opm = re.search(r"\b([\w\-]+)$", head.strip())
            # head looks like 'bf16[2048,2048]{1,0} dot'
            opname = opm.group(1) if opm else ""
            if opname == "dot":
                out_e = sum(_elems(d) for _, d in _SHAPE_RE.findall(head))
                ops = _REF_RE.findall(call.split(")", 1)[0])
                k = 1
                mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
                if mc and ops:
                    lhs_shapes = comp.shapes.get(ops[0], [])
                    if lhs_shapes:
                        lhs_dims = [int(x) for x in lhs_shapes[0][1].split(",") if x]
                        for ci in mc.group(1).split(","):
                            if ci and int(ci) < len(lhs_dims):
                                k *= lhs_dims[int(ci)]
                flops += m * 2.0 * out_e * k
            elif opname == "convolution":
                out_e = sum(_elems(d) for _, d in _SHAPE_RE.findall(head))
                ops = _REF_RE.findall(call.split(")", 1)[0])
                ker = 1
                och = 1
                if len(ops) >= 2:
                    ksh = comp.shapes.get(ops[1], [])
                    if ksh:
                        kd = [int(x) for x in ksh[0][1].split(",") if x]
                        for x in kd:
                            ker *= x
                        och = kd[-1] if kd else 1
                flops += m * 2.0 * out_e * max(ker // max(och, 1), 1)

            if in_fusion:
                continue
            if any(t in rhs for t in _NO_TRAFFIC):
                continue
            # control-flow ops: carries/branches are not HBM traffic — the
            # body ops are counted (trip-weighted) on their own
            if re.search(r"\b(while|conditional|call)\(", rhs):
                continue
            is_coll = None
            for op in _COLL_OPS:
                if re.search(rf"\b{op}(-start)?\(", rhs):
                    is_coll = op
                    break
            ob = _out_bytes(head)
            if is_coll:
                coll[is_coll] += m * ob
            # dynamic (update-)slice: only the slice moves, not the buffer
            # (scan tape writes are in-place updates of the stacked buffer)
            if "dynamic-update-slice(" in rhs:
                ops = _REF_RE.findall(call.split(" metadata", 1)[0])
                upd_b = 0
                if len(ops) >= 2:
                    shp = comp.shapes.get(ops[1])
                    if shp:
                        upd_b = sum(_elems(d) * _DTYPE_BYTES.get(dt, 0)
                                    for dt, d in shp)
                bytes_ += m * 2 * upd_b  # read update + write slice
                continue
            if "dynamic-slice(" in rhs:
                bytes_ += m * 2 * ob  # read slice + write result
                continue
            # fused-kernel model: only block io + collectives touch HBM
            if in_fused_kernel and not is_coll:
                if "fusion(" in rhs:
                    mcf = re.search(r"calls=%?([\w\.\-]+)", line)
                    inf = fusion_info.get(mcf.group(1)) if mcf else None
                    if inf is not None:
                        bytes_ += m * (2 if inf[0] != "mdus" else 1) * inf[1]
                continue
            # fusion ops: in-place roots charge slices; params consumed via
            # internal dynamic-slice charge the slice, not the buffer
            if "fusion(" in rhs:
                mc2 = re.search(r"calls=%?([\w\.\-]+)", line)
                callee = mc2.group(1) if mc2 else None
                info = fusion_info.get(callee)
                sliced = fusion_sliced.get(callee, {})
                refs = _REF_RE.findall(call.split(", kind", 1)[0])
                kind_f, slice_b, buf_idxs = info if info else (None, 0, set())
                total = 0
                for i, ref in enumerate(refs):
                    if i in buf_idxs:
                        continue
                    if i in sliced:
                        total += sliced[i]
                        continue
                    shp = comp.shapes.get(ref)
                    if shp:
                        total += sum(_elems(d) * _DTYPE_BYTES.get(dt, 0)
                                     for dt, d in shp)
                if kind_f in ("dus", "ds"):
                    total += 2 * slice_b
                elif kind_f == "mdus":
                    total += slice_b
                else:
                    total += _out_bytes(head)
                bytes_ += m * total
                continue
            # operand bytes via symbol table
            operand_b = 0
            for ref in _REF_RE.findall(call.split(" metadata", 1)[0]):
                shp = comp.shapes.get(ref)
                if shp:
                    operand_b += sum(
                        _elems(d) * _DTYPE_BYTES.get(dt, 0) for dt, d in shp
                    )
            bytes_ += m * (ob + operand_b)
    return {
        "flops": flops,
        "bytes": bytes_,
        "collectives": {k: int(v) for k, v in coll.items()},
        "n_computations": len(comps),
        "n_fused_loop_comps": len(fused_loop_comps),
    }
