import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds the real step function (train / prefill / decode)
with full parameter, optimizer-state, batch and KV-cache shardings, runs
``jax.jit(...).lower(...).compile()`` against the production mesh, and
records ``memory_analysis`` / ``cost_analysis`` / collective-bytes into a
JSON artifact consumed by the §Roofline table.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single,multi [--opts remat,zero1,seqshard] [--curvature mc]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as _np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, input_specs, supported_shapes
from repro.core import CrossEntropyLoss
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.roofline import model_flops_per_device, roofline
from repro.nn.models import build_model
from repro.optim import adamw
from repro.sharding import input_shardings, partition_specs, rules_for
from repro.train.step import make_decode_step, make_prefill_step, make_train_step

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "benchmarks", "artifacts")


def _mem_dict(ma):
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    return {k: int(getattr(ma, k, 0)) for k in keys}


def opt_shardings(p_shards, mesh, zero1=False):
    """AdamW state: m/v mirror params; ZeRO-1 additionally shards them on
    the data axis (first shardable dim not already data-sharded)."""
    def z1(ns):
        if not zero1:
            return ns
        spec = list(ns.spec) if ns.spec else []
        # find a replicated dim to shard over data
        for i, s in enumerate(spec):
            if s is None:
                spec[i] = "data"
                return NamedSharding(mesh, P(*spec))
        return ns

    mv = jax.tree.map(z1, p_shards)
    return {"m": mv, "v": mv, "t": NamedSharding(mesh, P())}


def run_cell(cfg, shape, mesh, multi_pod, opts, curvature=None):
    # perf_counter, not time.time: wall-clock adjustment (NTP) mid-compile
    # used to yield negative compile_s
    t0 = time.perf_counter()
    use_remat = "remat" in opts
    seq_shard = "seqshard" in opts
    mode = "long" if shape.name == "long_500k" else "std"
    rules = rules_for(mode, multi_pod)
    seq_sh = None
    if seq_shard:
        seq_sh = NamedSharding(mesh, P(rules.get("batch"), "model"))
    wkv_chunk = 16
    for o in opts:
        if o.startswith("wkv"):
            wkv_chunk = int(o[3:])
    model = build_model(cfg, remat=use_remat, seq_constraint=seq_sh,
                        attn_impl="chunked" if "chunkattn" in opts else "naive",
                        wkv_chunk=wkv_chunk)
    loss = CrossEntropyLoss()
    kind, specs = input_specs(cfg, shape, model=model)
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shards = partition_specs(model.param_axes(), params_spec, rules, mesh)
    in_sh = input_shardings(kind, specs, rules, mesh)

    if kind == "train":
        opt = adamw(3e-4, weight_decay=0.1)
        opt_spec = jax.eval_shape(opt.init, params_spec)
        o_shards = opt_shardings(p_shards, mesh, zero1="zero1" in opts)
        if curvature:
            from repro.core import DiagGGNMC, ExtensionConfig, KFAC
            from repro.optim import curvature_optimizer
            from repro.train.step import make_extended_train_step

            exts = (KFAC,) if curvature == "kfac" else (DiagGGNMC,)
            copt = curvature_optimizer(1e-3, curvature=exts[0].name)
            copt_spec = jax.eval_shape(copt.init, params_spec)
            step = make_extended_train_step(model, loss, copt, exts,
                                            ExtensionConfig(mc_samples=1))
            args = (params_spec, copt_spec, specs,
                    jax.ShapeDtypeStruct((), jnp.int32),
                    jax.ShapeDtypeStruct((2,), jnp.uint32))
            shardings = (p_shards, NamedSharding(mesh, P()), in_sh,
                         NamedSharding(mesh, P()), NamedSharding(mesh, P()))
            fn = jax.jit(step, in_shardings=shardings)
            lowered = fn.lower(*args)
        else:
            mb = 1
            for o in opts:
                if o.startswith("mb") and o[2:].isdigit():
                    mb = int(o[2:])
            step = make_train_step(
                model, loss, opt, microbatch=mb,
                grad_dtype=jnp.bfloat16 if "gbf16" in opts else None)
            args = (params_spec, opt_spec, specs,
                    jax.ShapeDtypeStruct((), jnp.int32))
            shardings = (p_shards, o_shards, in_sh, NamedSharding(mesh, P()))
            fn = jax.jit(step, in_shardings=shardings,
                         donate_argnums=(0, 1))
            lowered = fn.lower(*args)
    elif kind == "prefill":
        step = make_prefill_step(model)
        fn = jax.jit(step, in_shardings=(p_shards, in_sh["inputs"]))
        lowered = fn.lower(params_spec, specs["inputs"])
    else:  # decode
        step = make_decode_step(model)
        cache_sh = partition_specs(model.cache_axes(), specs["caches"],
                                   rules, mesh)
        fn = jax.jit(
            step,
            in_shardings=(p_shards, cache_sh, in_sh["tokens"], in_sh["pos"]),
            donate_argnums=(1,),
        )
        lowered = fn.lower(params_spec, specs["caches"], specs["tokens"],
                           specs["pos"])

    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    fused = frozenset({"flashk", "flashq", "wkvchunk"}) \
        if "kernelize" in opts else frozenset()
    weighted = hlo_analyze(hlo, fused_scopes=fused)
    n_chips = int(mesh.devices.size)
    n_params = sum(
        int(_np.prod(l.shape)) if l.shape else 1
        for l in jax.tree.leaves(params_spec)
    )
    active = cfg.active_param_count(model) if cfg.n_experts else n_params
    mflops = model_flops_per_device(cfg, shape, n_chips, n_params, active)
    terms = roofline(weighted, n_chips, mflops)
    return {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": kind,
        "mesh": "multi" if multi_pod else "single",
        "opts": sorted(opts),
        "curvature": curvature,
        "n_chips": n_chips,
        "n_params": n_params,
        "n_params_active": active,
        "cost_raw": {k: float(v) for k, v in cost.items()
                     if k in ("flops", "bytes accessed", "transcendentals")},
        "memory": _mem_dict(ma),
        "collectives": weighted["collectives"],
        "roofline": terms,
        "compile_s": round(time.perf_counter() - t0, 1),
        "hlo_bytes": len(hlo),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--opts", default="")
    ap.add_argument("--curvature", default=None)
    ap.add_argument("--out", default=None)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else args.arch.split(",")
    opts = set(o for o in args.opts.split(",") if o)
    out_path = args.out or os.path.abspath(
        os.path.join(ARTIFACT, f"dryrun_{args.tag}.json"))
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    results = {}
    if os.path.exists(out_path):
        with open(out_path) as f:
            results = json.load(f)

    for mesh_name in args.mesh.split(","):
        multi = mesh_name == "multi"
        mesh = make_production_mesh(multi_pod=multi)
        for arch in archs:
            cfg = get_config(arch)
            shapes = (supported_shapes(cfg) if args.shape == "all"
                      else [SHAPES[s] for s in args.shape.split(",")
                            if SHAPES[s] in supported_shapes(cfg)])
            for shape in shapes:
                key = f"{arch}|{shape.name}|{mesh_name}"
                if key in results and not args.force:
                    print(f"[skip] {key}")
                    continue
                print(f"[cell] {key} ...", flush=True)
                try:
                    rec = run_cell(cfg, shape, mesh, multi, opts,
                                   args.curvature)
                    results[key] = rec
                    r = rec["roofline"]
                    print(f"  ok compile={rec['compile_s']}s "
                          f"comp={r['compute_s']:.3e}s mem={r['memory_s']:.3e}s "
                          f"coll={r['collective_s']:.3e}s dom={r['dominant']}",
                          flush=True)
                except Exception as e:
                    results[key] = {"error": f"{type(e).__name__}: {e}"}
                    print(f"  FAIL {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1, sort_keys=True)
    n_ok = sum(1 for v in results.values() if "error" not in v)
    print(f"done: {n_ok}/{len(results)} cells ok -> {out_path}")


if __name__ == "__main__":
    main()
