"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state.  Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) — DP across the
pod axis rides DCN; model parallelism stays inside the pod's ICI domain.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    """Version-compat mesh constructor.

    ``jax.sharding.AxisType`` landed after jax 0.4.x; on older versions
    (e.g. the pinned 0.4.37 CI environment) every axis is implicitly Auto,
    so dropping the kwarg is behavior-preserving.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    return _mesh(tuple(shape), tuple(axes))


def make_data_mesh(n_devices=None):
    """1-D ('data',) mesh over ``n_devices`` (default: every local device).

    The mesh the batch-sharded sweep lane (``SweepPlan.shard``) and the
    multi-device CI lane run on — pure DP, no model axis.
    """
    if n_devices is None:
        n_devices = jax.device_count()
    return _mesh((n_devices,), ("data",))


# v5e-class hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW_PER_LINK = 50e9       # B/s, ~4 links/chip in a 2D torus
ICI_LINKS = 4
HBM_BYTES = 16 * 2 ** 30     # v5e HBM capacity
