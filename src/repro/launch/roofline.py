"""Roofline-term computation from compiled dry-run artifacts.

All inputs are PER-DEVICE quantities from the trip-count-weighted HLO walk
(`hlo_cost.analyze`, post-SPMD shapes):

  compute term    = flops_per_dev / 197e12        (bf16 peak, v5e class)
  memory term     = bytes_per_dev / 819e9         (HBM bandwidth)
  collective term = coll_bytes_per_dev / (4 × 50e9)  (ICI links)

The raw ``compiled.cost_analysis()`` numbers are recorded alongside but NOT
used for the terms: XLA's analysis counts while-loop bodies once
(verified), so it underreports scan-stacked models by ~L×.

MODEL_FLOPS (6·N·D, or 6·N_active·D for MoE) is attached per cell so the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs is visible — it catches
remat/recompute waste and padding overhead.
"""
from __future__ import annotations

from repro.launch import mesh as hw


def roofline(weighted: dict, n_chips: int, model_flops_per_dev: float = 0.0):
    flops = float(weighted.get("flops", 0.0))
    bytes_ = float(weighted.get("bytes", 0.0))
    coll_bytes = float(sum(weighted.get("collectives", {}).values()))
    t_comp = flops / hw.PEAK_FLOPS_BF16
    t_mem = bytes_ / hw.HBM_BW
    t_coll = coll_bytes / (hw.ICI_LINKS * hw.ICI_BW_PER_LINK)
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    total = max(t_comp, t_mem, t_coll)
    out = dict(terms)
    out["dominant"] = dom
    out["flops_per_dev"] = flops
    out["bytes_per_dev"] = bytes_
    out["coll_bytes_per_dev"] = coll_bytes
    if model_flops_per_dev:
        out["model_flops_per_dev"] = model_flops_per_dev
        out["useful_compute_ratio"] = (
            model_flops_per_dev / flops if flops else 0.0
        )
        # fraction of the compute roofline actually achieved if the step ran
        # at the modeled time 'total'
        out["roofline_fraction"] = (
            (model_flops_per_dev / hw.PEAK_FLOPS_BF16) / total if total else 0.0
        )
    return out


def model_flops_per_device(cfg, shape, n_chips, params, active_params):
    """6·N·D rule: training does fwd+bwd (6), prefill 2, decode 2 per token."""
    if shape.kind == "train":
        mult = 6.0
        tokens = shape.global_batch * shape.seq_len
        if cfg.kind == "encdec":
            tokens = shape.global_batch * (shape.seq_len + cfg.dec_len)
    elif shape.kind == "prefill":
        mult = 2.0
        tokens = shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence per step
        mult = 2.0
        tokens = shape.global_batch
    n = active_params if active_params else params
    return mult * n * tokens / n_chips
