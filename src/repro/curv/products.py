"""Forward-over-reverse curvature-vector products.

The GGN-vector product is the half-sandwich contraction

    G v = Jᵀ H (J v)

evaluated matrix-free: one ``jax.linearize`` through the network gives
``J v`` (forward mode), the exact loss Hessian applies in logit space via
``loss.hessian_vec`` (closed form, :mod:`repro.core.loss_hessian`), and
the transposed linearization carries it back to parameter space.  Cost is
~2 gradient evaluations per product, memory is O(P) — no factor is ever
materialized, so every architecture the explicit lanes can't touch
(LM heads with 10⁵-class vocabularies, full transformers) is in scope.

The Hessian-vector product is plain forward-over-reverse through the
scalar objective: ``H v = ∂/∂ε ∇L(θ + εv)|₀``.

Scale composition mirrors the engine's sweep lanes: ``microbatch_size``
streams the product over batch slices and ``mesh`` shards the batch rows,
each partial batch corrected from 1/M_local to 1/M_global by the
mask-aware ``_ScaledLoss`` adapter — products are *linear* in the loss,
so the corrected contributions sum to the monolithic value exactly, even
with padding masks leaving unit counts uneven across slices or shards.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.engine import _ScaledLoss, _shard_map
from repro.core.extensions import ExtensionConfig


def _slice_bounds(n: int, microbatch: Optional[int]):
    """Static (offset, rows) schedule over ``n`` samples — uneven final
    slice allowed (the streamed lanes' schedule, in miniature)."""
    if not microbatch or microbatch >= n:
        return [(0, n)]
    return [(o, min(microbatch, n - o)) for o in range(0, n, microbatch)]


def _take_rows(tree, off, rows):
    return jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(a, off, rows,
                                                               0), tree)


def _ggn_vp_block(model, params, inputs, targets, loss, v):
    """Single-block product: linearize once, transpose the linearization."""
    def f(p):
        return model.apply(p, inputs)

    z, jvp_fn = jax.linearize(f, params)
    Jv = jvp_fn(v)
    Hv = loss.hessian_vec(z, targets, Jv)
    vjp_fn = jax.linear_transpose(jvp_fn, params)
    (out,) = vjp_fn(Hv)
    return out


def _hvp_block(model, params, inputs, targets, loss, v):
    def obj(p):
        return loss.value(model.apply(p, inputs), targets)

    return jax.jvp(jax.grad(obj), (params,), (v,))[1]


def _streamed(block_fn, model, params, inputs, targets, loss, v,
              microbatch, total_units=None):
    """Sum the per-slice contributions under the 1/M_global correction.

    ``total_units`` overrides the global unit count (the sharded body
    passes the psum'd global count so the shard × accumulate composition
    applies exactly one correction).
    """
    n = jax.tree.leaves(inputs)[0].shape[0]
    bounds = _slice_bounds(n, microbatch)
    if len(bounds) == 1 and total_units is None:
        return block_fn(model, params, inputs, targets, loss, v)
    # raw mask-aware unit count over this lane's full batch
    mg = total_units if total_units is not None else loss.num_units(targets)
    out = None
    for off, rows in bounds:
        sloss = _ScaledLoss(loss, total_units=mg)
        o = block_fn(model, params, _take_rows(inputs, off, rows),
                     _take_rows(targets, off, rows), sloss, v)
        out = o if out is None else jax.tree.map(jnp.add, out, o)
    return out


def _product(block_fn, model, params, inputs, targets, loss, v, *,
             cfg: Optional[ExtensionConfig] = None, mesh=None,
             shard_axes: Sequence[str] = ("data",)):
    cfg = cfg or ExtensionConfig()
    microbatch = cfg.microbatch_size
    if mesh is None:
        return _streamed(block_fn, model, params, inputs, targets, loss, v,
                         microbatch)
    axes = tuple(shard_axes)
    batch = P(axes)

    def body(params, inputs, targets, v):
        # Global unit count first (a psum sees every shard's rows), then
        # stream this shard's rows against it — the shard × accumulate
        # composition applies exactly one 1/M_global correction.
        raw = loss.num_units(targets)
        mg = jnp.maximum(jax.lax.psum(raw, axes), 1.0)
        out = _streamed(block_fn, model, params, inputs, targets, loss, v,
                        microbatch, total_units=mg)
        return jax.lax.psum(out, axes)

    fn = _shard_map(body, mesh=mesh, in_specs=(P(), batch, batch, P()),
                    out_specs=P())
    return fn(params, inputs, targets, v)


def ggn_vp(model, params, inputs, targets, loss, v, *, cfg=None, mesh=None,
           shard_axes=("data",)):
    """Matrix-free GGN-vector product ``(Jᵀ H J) v`` of the mean loss.

    ``v`` is a params-like tangent pytree; the result has the same
    structure.  ``cfg=ExtensionConfig(microbatch_size=k)`` streams the
    contraction over batch slices; ``mesh`` runs it batch-sharded over
    ``shard_axes`` — both exact, per the ``_ScaledLoss`` correction.
    """
    return _product(_ggn_vp_block, model, params, inputs, targets, loss, v,
                    cfg=cfg, mesh=mesh, shard_axes=shard_axes)


def hvp(model, params, inputs, targets, loss, v, *, cfg=None, mesh=None,
        shard_axes=("data",)):
    """Matrix-free Hessian-vector product ``∇²L(θ) v`` of the mean loss
    (forward-over-reverse: jvp of the gradient).  Same composition knobs
    as :func:`ggn_vp`."""
    return _product(_hvp_block, model, params, inputs, targets, loss, v,
                    cfg=cfg, mesh=mesh, shard_axes=shard_axes)


class _CurvOperator:
    """A curvature matrix as a linear operator on params-like pytrees.

    ``mv`` applies ``(C + damping·I) v``; ``mv_stacked`` maps it over a
    leading probe/RHS axis on every leaf (the batched-CG and SLQ
    callers).  Instances close over one batch — build a new operator per
    batch, reuse it across products (CG iterations re-trace nothing
    under jit).
    """

    _block = None  # subclass hook

    def __init__(self, model, params, inputs, targets, loss, *,
                 damping: float = 0.0, cfg: Optional[ExtensionConfig] = None,
                 mesh=None, shard_axes: Sequence[str] = ("data",)):
        self.model = model
        self.params = params
        self.inputs = inputs
        self.targets = targets
        self.loss = loss
        self.damping = damping
        self.cfg = cfg
        self.mesh = mesh
        self.shard_axes = tuple(shard_axes)

    def mv(self, v):
        out = _product(type(self)._block, self.model, self.params,
                       self.inputs, self.targets, self.loss, v,
                       cfg=self.cfg, mesh=self.mesh,
                       shard_axes=self.shard_axes)
        if self.damping:
            d = jnp.float32(self.damping)
            out = jax.tree.map(
                lambda o, t: o + d * t.astype(o.dtype), out, v)
        return out

    def mv_stacked(self, V):
        return jax.vmap(self.mv)(V)

    @property
    def dim(self) -> int:
        """Number of parameters the operator acts on."""
        return sum(l.size for l in jax.tree.leaves(self.params))


class GGNOperator(_CurvOperator):
    """``(G + damping·I)`` with ``G`` the GGN of the mean loss."""

    _block = staticmethod(_ggn_vp_block)


class HessianOperator(_CurvOperator):
    """``(H + damping·I)`` with ``H`` the full Hessian of the mean loss."""

    _block = staticmethod(_hvp_block)
