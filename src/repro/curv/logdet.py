"""Lanczos tridiagonalization and its two consumers: SLQ log-det, top-k.

    log det A = tr log A ≈ (1/K) Σ_k  dim · Σ_j τ²_{kj} log λ_{kj}

with Hutchinson (Rademacher) probes ``v_k`` and ``(λ, τ)`` the Ritz
values/first-component weights of an m-step Lanczos tridiagonalization of
``A`` started at ``v_k`` (Ubaru–Chen–Saad 2017).  ``A`` is touched only
through ``mv`` — m matrix-vector products per probe — so the estimator
scales to any operator the matrix-free lane can apply: the log-det of a
damped GGN whose explicit factors would never fit, estimated at
``K·m`` gradient-sweep cost and O(m·P) memory.

The same m-step scan, kept with its stored basis, yields extremal Ritz
pairs: :func:`lanczos_topk` returns the top-k eigenvalue/eigenvector
estimates of the operator, which the NTK-apps regression lane uses as a
spectral preconditioner for Gram-space CG solves.

Lanczos runs on the raveled vector with full reorthogonalization against
the stored basis (m is small; without it the classic loss-of-orthogonality
bias wrecks the quadrature weights and duplicates Ritz pairs).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class SLQResult(NamedTuple):
    logdet: jnp.ndarray       # the MC estimate
    per_probe: jnp.ndarray    # [probes] individual quadrature estimates


class TopKResult(NamedTuple):
    eigvals: jnp.ndarray      # [k] Ritz values, descending
    eigvecs: jnp.ndarray      # [k, dim] matching Ritz vectors (rows)


def lanczos_tridiag(mv_flat: Callable, v0: jnp.ndarray, m: int):
    """m-step Lanczos on the flat SPD operator ``mv_flat`` from unit ``v0``.

    Returns ``(alphas [m], betas [m], V [m, dim])`` — the tridiagonal
    coefficients and the stored orthonormal basis (row i is the i-th
    Lanczos vector).  ``betas[-1]`` is the residual norm of the last
    step.  Full reorthogonalization against V every step.
    """
    dim = v0.shape[0]
    V0 = jnp.zeros((m, dim), jnp.float32)

    def step(carry, i):
        V, v, v_prev, beta_prev = carry
        V = V.at[i].set(v)
        w = mv_flat(v) - beta_prev * v_prev
        alpha = jnp.vdot(w, v)
        w = w - alpha * v
        # full reorthogonalization (unfilled rows are zero)
        w = w - V.T @ (V @ w)
        beta = jnp.linalg.norm(w)
        v_next = w / jnp.maximum(beta, 1e-30)
        return (V, v_next, v, beta), (alpha, beta)

    (V, _, _, _), (alphas, betas) = jax.lax.scan(
        step, (V0, v0, jnp.zeros_like(v0), jnp.float32(0.0)),
        jnp.arange(m))
    return alphas, betas, V


def _flat_operator(mv: Callable, template):
    """Ravel a pytree operator to a float32 flat-vector operator."""
    flat0, unravel = ravel_pytree(template)

    def mv_flat(x):
        return ravel_pytree(mv(unravel(x.astype(flat0.dtype))))[0].astype(
            jnp.float32)

    return mv_flat, flat0.size


def slq_logdet(mv: Callable, template, *, rng, probes: int = 8,
               iters: int = 20) -> SLQResult:
    """Estimate ``log det A`` of the SPD operator ``mv``.

    ``template`` is any pytree with the operator's domain structure (the
    params tree); probe vectors are drawn to match it.  ``probes``
    controls MC variance (√-rate), ``iters`` the quadrature accuracy
    (exponential in the condition number's √).  Returns the estimate and
    the per-probe values (their spread is the error bar).
    """
    mv_flat, dim = _flat_operator(mv, template)
    m = min(iters, dim)

    def one_probe(key):
        s = jax.random.rademacher(key, (dim,), jnp.float32)
        v0 = s / jnp.sqrt(jnp.float32(dim))
        alphas, betas, _ = lanczos_tridiag(mv_flat, v0, m)
        T = (jnp.diag(alphas) + jnp.diag(betas[:-1], 1)
             + jnp.diag(betas[:-1], -1))
        lam, U = jnp.linalg.eigh(T)
        # Breakdown (β→0: Krylov space exhausted) pads T with decoupled
        # zero modes; their Ritz weight on e₁ is ~0, but clamp λ anyway.
        lam = jnp.maximum(lam, 1e-30)
        tau2 = U[0, :] ** 2
        return jnp.float32(dim) * jnp.sum(tau2 * jnp.log(lam))

    keys = jax.random.split(rng, probes)
    per = jnp.stack([one_probe(k) for k in keys])
    return SLQResult(logdet=jnp.mean(per), per_probe=per)


def lanczos_topk(mv: Callable, template, *, rng, k: int,
                 iters: int | None = None) -> TopKResult:
    """Top-k Ritz (eigenvalue, eigenvector) pairs of the SPD operator.

    Runs one m-step Lanczos sweep (``m = iters``, default ``2k + 10``
    clamped to the dimension) from a random unit start, diagonalizes the
    tridiagonal T, and lifts the m-space eigenvectors back through the
    stored basis: ``y_j = Vᵀ u_j``.  Extremal Ritz values converge first,
    so modest ``iters`` already gives the dominant spectrum — the piece a
    truncated / preconditioned Gram-space solve needs.  ``template`` is
    any pytree with the operator's domain structure; eigenvectors are
    returned raveled ([k, dim] rows).
    """
    mv_flat, dim = _flat_operator(mv, template)
    if k > dim:
        raise ValueError(f"lanczos_topk: k={k} exceeds operator dim={dim}")
    m = min(dim, iters if iters is not None else 2 * k + 10)
    if m < k:
        raise ValueError(f"lanczos_topk: iters={m} < k={k}")

    v0 = jax.random.normal(rng, (dim,), jnp.float32)
    v0 = v0 / jnp.linalg.norm(v0)
    alphas, betas, V = lanczos_tridiag(mv_flat, v0, m)
    T = (jnp.diag(alphas) + jnp.diag(betas[:-1], 1)
         + jnp.diag(betas[:-1], -1))
    lam, U = jnp.linalg.eigh(T)       # ascending
    top = jnp.argsort(lam)[::-1][:k]
    eigvals = lam[top]
    eigvecs = (V.T @ U[:, top]).T     # [k, dim]
    # Ritz vectors inherit V's orthonormality up to the reorthogonalization
    # tolerance; renormalize so downstream projectors are clean.
    eigvecs = eigvecs / jnp.maximum(
        jnp.linalg.norm(eigvecs, axis=1, keepdims=True), 1e-30)
    return TopKResult(eigvals=eigvals, eigvecs=eigvecs)
