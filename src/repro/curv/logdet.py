"""Stochastic Lanczos quadrature (SLQ) log-determinant estimation.

    log det A = tr log A ≈ (1/K) Σ_k  dim · Σ_j τ²_{kj} log λ_{kj}

with Hutchinson (Rademacher) probes ``v_k`` and ``(λ, τ)`` the Ritz
values/first-component weights of an m-step Lanczos tridiagonalization of
``A`` started at ``v_k`` (Ubaru–Chen–Saad 2017).  ``A`` is touched only
through ``mv`` — m matrix-vector products per probe — so the estimator
scales to any operator the matrix-free lane can apply: the log-det of a
damped GGN whose explicit factors would never fit, estimated at
``K·m`` gradient-sweep cost and O(m·P) memory.

Lanczos runs on the raveled parameter vector with full
reorthogonalization against the stored basis (m is small; without it the
classic loss-of-orthogonality bias wrecks the quadrature weights).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


class SLQResult(NamedTuple):
    logdet: jnp.ndarray       # the MC estimate
    per_probe: jnp.ndarray    # [probes] individual quadrature estimates


def slq_logdet(mv: Callable, template, *, rng, probes: int = 8,
               iters: int = 20) -> SLQResult:
    """Estimate ``log det A`` of the SPD operator ``mv``.

    ``template`` is any pytree with the operator's domain structure (the
    params tree); probe vectors are drawn to match it.  ``probes``
    controls MC variance (√-rate), ``iters`` the quadrature accuracy
    (exponential in the condition number's √).  Returns the estimate and
    the per-probe values (their spread is the error bar).
    """
    flat0, unravel = ravel_pytree(template)
    dim = flat0.size
    m = min(iters, dim)

    def mv_flat(x):
        return ravel_pytree(mv(unravel(x.astype(flat0.dtype))))[0].astype(
            jnp.float32)

    def lanczos(v0):
        V0 = jnp.zeros((m, dim), jnp.float32)

        def step(carry, i):
            V, v, v_prev, beta_prev = carry
            V = V.at[i].set(v)
            w = mv_flat(v) - beta_prev * v_prev
            alpha = jnp.vdot(w, v)
            w = w - alpha * v
            # full reorthogonalization (unfilled rows are zero)
            w = w - V.T @ (V @ w)
            beta = jnp.linalg.norm(w)
            v_next = w / jnp.maximum(beta, 1e-30)
            return (V, v_next, v, beta), (alpha, beta)

        (_, _, _, _), (alphas, betas) = jax.lax.scan(
            step, (V0, v0, jnp.zeros_like(v0), jnp.float32(0.0)),
            jnp.arange(m))
        return alphas, betas

    def one_probe(key):
        s = jax.random.rademacher(key, (dim,), jnp.float32)
        v0 = s / jnp.sqrt(jnp.float32(dim))
        alphas, betas = lanczos(v0)
        T = (jnp.diag(alphas) + jnp.diag(betas[:-1], 1)
             + jnp.diag(betas[:-1], -1))
        lam, U = jnp.linalg.eigh(T)
        # Breakdown (β→0: Krylov space exhausted) pads T with decoupled
        # zero modes; their Ritz weight on e₁ is ~0, but clamp λ anyway.
        lam = jnp.maximum(lam, 1e-30)
        tau2 = U[0, :] ** 2
        return jnp.float32(dim) * jnp.sum(tau2 * jnp.log(lam))

    keys = jax.random.split(rng, probes)
    per = jnp.stack([one_probe(k) for k in keys])
    return SLQResult(logdet=jnp.mean(per), per_probe=per)
