"""repro.curv — matrix-free curvature: products, solvers, estimators.

BackPACK's explicit diagonals and Kronecker factors (PAPER.md §3) stop
scaling once materialization is infeasible; curvature-*vector* products
do not.  This subsystem provides the beyond-factor lane:

* :func:`ggn_vp` / :func:`hvp` — forward-over-reverse GGN- and
  Hessian-vector products (``jvp`` through the network, the exact loss
  Hessian from :mod:`repro.core.loss_hessian` in the middle, ``vjp``
  back), composing with the engine's scale machinery: ``microbatch_size``
  streams the contraction, ``mesh`` shards the batch — both via the
  mask-aware ``_ScaledLoss`` correction, so the product matches its
  monolithic single-device value exactly.
* :class:`GGNOperator` / :class:`HessianOperator` — the same products as
  reusable linear operators (``.mv`` / batched ``.mv_stacked``).
* :func:`cg_solve` — batched preconditioned conjugate gradients against
  any such operator (the implicit solve behind natural-gradient steps).
* :func:`kernel_ngd_direction` — kernel-space natural gradient: the
  Woodbury identity moves the solve into the ``[N·C̃]`` logit-Gram space
  when ``N·C̃ ≪ P``, with the Gram assembled by the engine's ``ggn_gram``
  extension through the fused ``cross_dot`` kernel.
* :func:`slq_logdet` — stochastic Lanczos quadrature log-determinant
  (Hutchinson probes), the beyond-factor evidence path for
  :mod:`repro.laplace.marglik`.
* :func:`lanczos_topk` — top-k Ritz pairs from the same Lanczos scan
  (full reorthogonalization, stored basis), the spectral preconditioner
  behind the NTK-apps truncated / preconditioned Gram solves.
"""
from .products import GGNOperator, HessianOperator, ggn_vp, hvp
from .cg import cg_solve
from .ngd import kernel_ngd_direction
from .logdet import lanczos_topk, lanczos_tridiag, slq_logdet

__all__ = [
    "GGNOperator",
    "HessianOperator",
    "cg_solve",
    "ggn_vp",
    "hvp",
    "kernel_ngd_direction",
    "lanczos_topk",
    "lanczos_tridiag",
    "slq_logdet",
]
