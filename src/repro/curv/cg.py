"""Batched preconditioned conjugate gradients on parameter pytrees.

The implicit solve behind matrix-free natural gradients: CG only ever
touches the curvature through ``mv`` (one GGN/Hessian-vector product per
iteration), so ``(G + δI)⁻¹ g`` costs ``iters × ~2`` gradient sweeps and
O(P) memory — no factor inversion, no materialization.

Batched RHS ride a leading axis on every leaf: inner products reduce
over the trailing axes, so each RHS runs its own CG recurrence in
lockstep under one ``lax.while_loop`` (convergence when *every* RHS's
relative residual passes ``tol``).  A preconditioner is any linear
callable ``r → M⁻¹r`` on the same pytrees — e.g. the inverse DiagGGN,
turning an explicit cheap factor into a convergence accelerator for the
implicit expensive one.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class CGResult(NamedTuple):
    x: object          # solution pytree (leading RHS axis if batched)
    iters: jnp.ndarray  # iterations executed
    resid: jnp.ndarray  # final relative residual (per RHS if batched)


def _vdot(a, b, batch_ndim: int):
    """Pytree inner product, reduced to a scalar per leading-RHS index."""
    def leaf(x, y):
        x32, y32 = x.astype(jnp.float32), y.astype(jnp.float32)
        axes = tuple(range(batch_ndim, x.ndim))
        return jnp.sum(x32 * y32, axis=axes)

    leaves = [leaf(x, y) for x, y in zip(jax.tree.leaves(a),
                                         jax.tree.leaves(b))]
    return sum(leaves[1:], leaves[0])


def cg_solve(mv: Callable, b, *, tol: float = 1e-6, maxiter: int = 50,
             precond: Optional[Callable] = None, x0=None,
             batched: bool = False) -> CGResult:
    """Solve ``A x = b`` with ``A`` given only through ``mv``.

    ``mv`` must be symmetric positive (semi-)definite — damp it
    (``GGNOperator(damping=δ)``) for the semi-definite GGN.  With
    ``batched=True`` every leaf of ``b`` carries a leading RHS axis and
    ``mv`` must map it (``operator.mv_stacked``); the recurrences run per
    RHS with a joint stopping rule.  ``precond`` applies ``M⁻¹`` (same
    calling convention as ``mv``).

    Returns :class:`CGResult` — ``x``, iterations executed, and the final
    relative residual ``‖b − Ax‖ / ‖b‖`` (per RHS when batched).
    """
    batch_ndim = 1 if batched else 0
    apply_m = precond if precond is not None else (lambda r: r)

    def expand(s):
        # scalar-per-RHS → broadcastable against a leaf
        def to(leaf):
            return s.reshape(s.shape + (1,) * (leaf.ndim - batch_ndim))
        return to

    x = x0 if x0 is not None else jax.tree.map(jnp.zeros_like, b)
    r = jax.tree.map(lambda bi, ax: bi.astype(jnp.float32)
                     - ax.astype(jnp.float32), b, mv(x))
    z = apply_m(r)
    p = z
    rz = _vdot(r, z, batch_ndim)
    b_norm = jnp.sqrt(jnp.maximum(_vdot(b, b, batch_ndim), 1e-30))

    def resid_of(rr):
        return jnp.sqrt(jnp.maximum(_vdot(rr, rr, batch_ndim), 0.0)) / b_norm

    def cond(state):
        x, r, p, rz, it = state
        return jnp.logical_and(it < maxiter,
                               jnp.any(resid_of(r) > tol))

    def step(state):
        x, r, p, rz, it = state
        ap = mv(p)
        pap = _vdot(p, ap, batch_ndim)
        alpha = rz / jnp.where(pap > 0, pap, 1.0)
        # a fully converged (or degenerate) RHS freezes in place
        alpha = jnp.where(pap > 0, alpha, 0.0)
        ea = expand(alpha)
        x = jax.tree.map(lambda xi, pi: xi + ea(pi) * pi.astype(jnp.float32),
                         x, p)
        r = jax.tree.map(lambda ri, api: ri - ea(api)
                         * api.astype(jnp.float32), r, ap)
        z = apply_m(r)
        rz_new = _vdot(r, z, batch_ndim)
        beta = rz_new / jnp.where(rz > 0, rz, 1.0)
        beta = jnp.where(rz > 0, beta, 0.0)
        eb = expand(beta)
        p = jax.tree.map(lambda zi, pi: zi.astype(jnp.float32)
                         + eb(pi) * pi.astype(jnp.float32), z, p)
        return x, r, p, rz_new, it + 1

    x = jax.tree.map(lambda a: a.astype(jnp.float32), x)
    state = (x, r, p, rz, jnp.int32(0))
    x, r, _, _, it = jax.lax.while_loop(cond, step, state)
    return CGResult(x=x, iters=it, resid=resid_of(r))
