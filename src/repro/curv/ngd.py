"""Kernel-space natural gradient: solve in [N·C̃] Gram space, not [P].

For the damped GGN ``F = J'ᵀJ' + δI`` (``J' = √Hᵀ J``, the loss-scaled
half-sandwich Jacobian of the Dense-visible parameters), the Woodbury
identity moves the solve into sample space:

    F⁻¹ g = (1/δ) [ g − J'ᵀ (K + δI)⁻¹ J' g ],    K = J' J'ᵀ  [N·C̃, N·C̃]

— asdfghjkl's ``kernel_free_cross_entropy`` trick: when ``N·C̃ ≪ P`` the
only dense object is the Gram matrix ``K``, assembled by the engine's
``ggn_gram`` extension (one extra backward sweep; the inner J·Jᵀ routed
through the fused ``cross_dot`` kernel under ``cfg.use_kernels``), and
the parameter-space work is one jvp + one vjp.  Parameters outside the
Gram's coverage (embeddings, norms — layers without a Dense curvature
hook) see ``F = δI`` exactly, so their direction is the damped-SGD
``g/δ`` — the same fallback convention as ``optim.precond``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.engine import gram_total, run
from repro.core.extensions import ExtensionConfig, GGNGram


def _covered(params, gram_tree):
    """Params-shaped pytree of bools: does this leaf have a Gram block?"""
    def rec(p, s):
        if isinstance(p, dict):
            return {k: rec(p[k], s.get(k) if isinstance(s, dict) else None)
                    for k in p}
        if isinstance(p, (tuple, list)):
            s_t = s if isinstance(s, (tuple, list)) else (None,) * len(p)
            return tuple(rec(pi, si) for pi, si in zip(p, s_t))
        return s is not None and not (isinstance(s, tuple) and not s)

    return rec(params, gram_tree)


def _mask_to(tree, mask):
    return jax.tree.map(
        lambda t, m: t if m else jnp.zeros_like(t), tree, mask)


def kernel_ngd_direction(model, params, inputs, targets, loss, *,
                         damping: float,
                         cfg: Optional[ExtensionConfig] = None,
                         rng=None, grads=None, results=None):
    """Natural-gradient direction ``(G + δI)⁻¹ ∇L`` via the Gram-space
    solve.

    Runs one engine sweep with the ``ggn_gram`` extension (skipped when a
    ``results`` from such a sweep is passed in), solves the dense
    ``[N·C̃, N·C̃]`` system, and maps back with one jvp + one vjp.  Flat
    ``[N, C]`` model outputs only — sequence models should reach for the
    CG lane (:func:`repro.curv.cg.cg_solve` over a
    :class:`~repro.curv.products.GGNOperator`), whose cost never sees
    ``N·C̃``.  Returns ``(direction, aux)`` with the loss/grads-bearing
    engine results in ``aux``.
    """
    cfg = cfg or ExtensionConfig()
    res = results
    if res is None:
        res = run(model, params, inputs, targets, loss,
                  extensions=(GGNGram,), cfg=cfg, rng=rng)
    z = res.logits
    if z.ndim != 2:
        raise ValueError(
            "kernel-space NGD needs flat [N, C] model outputs, got logits "
            f"of shape {z.shape} — use the CG lane for sequence models")
    g = grads if grads is not None else res.grads
    delta = jnp.float32(damping)

    K = gram_total(res.ext["ggn_gram"])          # [N, N, C̃, C̃]
    n, _, c, _ = K.shape
    K2 = K.transpose(0, 2, 1, 3).reshape(n * c, n * c)

    mask = _covered(params, res.ext["ggn_gram"])
    g_cov = _mask_to(g, mask)

    def f(p):
        return model.apply(p, inputs)

    zz, jvp_fn = jax.linearize(f, params)
    S = loss.sqrt_hessian(zz, targets).astype(jnp.float32)  # [C̃, N, C]
    Jg = jvp_fn(g_cov).astype(jnp.float32)                  # [N, C]
    w = jnp.einsum("cnz,nz->nc", S, Jg).reshape(n * c)      # J' g

    q = jnp.linalg.solve(
        K2 + delta * jnp.eye(n * c, dtype=K2.dtype), w).reshape(n, c)

    v_z = jnp.einsum("cnz,nc->nz", S, q)                    # √H (·)
    vjp_fn = jax.linear_transpose(jvp_fn, params)
    (t,) = vjp_fn(v_z.astype(zz.dtype))
    t_cov = _mask_to(t, mask)

    d = jax.tree.map(
        lambda gi, ti: (gi.astype(jnp.float32)
                        - ti.astype(jnp.float32)) / delta, g, t_cov)
    return d, res
