"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step, host slice): resuming after
a failure (or on a different host layout) regenerates the exact stream with
no iterator state to checkpoint — the data-side half of fault tolerance.

The token stream is a structured Markov-ish mixture (not uniform noise) so
losses move visibly and curvature statistics are non-degenerate:
  next ~ (shift by a step-dependent offset) mixed with noise tokens.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _fold(seed, step, salt):
    k = jax.random.PRNGKey(seed)
    k = jax.random.fold_in(k, step)
    return jax.random.fold_in(k, salt)


def lm_batch(dc: DataConfig, step: int):
    """→ {'inputs': tokens [B_host, T], 'labels': [B_host, T]}."""
    b_host = dc.global_batch // dc.n_hosts
    k1 = _fold(dc.seed, step, dc.host_id * 3 + 1)
    k2 = _fold(dc.seed, step, dc.host_id * 3 + 2)
    base = jax.random.randint(k1, (b_host, dc.seq_len + 1), 0, dc.vocab)
    # structured component: token_{t+1} = token_t + offset (mod V) w.p. 0.7
    offset = (step % 17) + 1
    shifted = (base[:, :-1] + offset) % dc.vocab
    gate = jax.random.bernoulli(k2, 0.7, shifted.shape)
    seq = jnp.where(gate, shifted, base[:, 1:])
    tokens = jnp.concatenate([base[:, :1], seq], axis=1)
    return {"inputs": tokens[:, :-1], "labels": tokens[:, 1:]}


def vlm_batch(dc: DataConfig, step: int, n_prefix: int, d_model: int,
              dtype=jnp.float32):
    b_host = dc.global_batch // dc.n_hosts
    lm = lm_batch(
        dataclasses.replace(dc, seq_len=dc.seq_len - n_prefix), step)
    kp = _fold(dc.seed, step, dc.host_id * 3 + 3)
    prefix = 0.02 * jax.random.normal(
        kp, (b_host, n_prefix, d_model), jnp.float32).astype(dtype)
    labels = jnp.concatenate(
        [-jnp.ones((b_host, n_prefix), jnp.int32), lm["labels"]], axis=1)
    return {
        "inputs": {"tokens": lm["inputs"], "prefix": prefix},
        "labels": labels,
    }


def audio_batch(dc: DataConfig, step: int, dec_len: int, d_model: int,
                dtype=jnp.float32):
    b_host = dc.global_batch // dc.n_hosts
    kf = _fold(dc.seed, step, dc.host_id * 3 + 4)
    frames = 0.02 * jax.random.normal(
        kf, (b_host, dc.seq_len, d_model), jnp.float32).astype(dtype)
    lm = lm_batch(dataclasses.replace(dc, seq_len=dec_len), step)
    return {
        "inputs": {"frames": frames, "tokens": lm["inputs"]},
        "labels": lm["labels"],
    }


def batch_for(cfg, shape_or_dc, step, seed=0, batch=None):
    """Arch-aware batch builder from a ModelConfig + Shape."""
    seq = shape_or_dc.seq_len
    b = batch or shape_or_dc.global_batch
    dc = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=b, seed=seed)
    dt = jnp.dtype(cfg.dtype)
    if cfg.kind == "encdec":
        return audio_batch(dc, step, cfg.dec_len, cfg.d_model, dt)
    if cfg.frontend == "vision":
        return vlm_batch(dc, step, cfg.n_prefix, cfg.d_model, dt)
    return lm_batch(dc, step)
