from repro.data.synthetic import DataConfig, audio_batch, batch_for, lm_batch, vlm_batch
