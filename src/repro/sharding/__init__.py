from repro.sharding.rules import (
    GRAM_ASSEMBLY_MODES,
    RULES,
    Rules,
    batch_axes,
    gram_assembly_spec,
    input_shardings,
    partition_specs,
    rules_for,
    sweep_shard_axes,
)
