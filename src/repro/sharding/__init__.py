from repro.sharding.rules import (
    RULES,
    Rules,
    batch_axes,
    input_shardings,
    partition_specs,
    rules_for,
    sweep_shard_axes,
)
