"""Logical-axis → mesh-axis rules (MaxText-style), with divisibility
fallbacks.

Every parameter/cache leaf carries logical axis names (``Axes``); a
``Rules`` table maps those to mesh axes per execution mode.  A mesh axis is
only applied when the dimension is divisible by the axis size and the mesh
axis is not already used by an earlier dimension of the same leaf —
otherwise the dimension is replicated.  This keeps the same rule table valid
across all 10 architectures (e.g. hymba's 25-head projections simply fall
back to replication on the 'model' axis where 25∤16).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.module import Axes, is_axes


@dataclasses.dataclass(frozen=True)
class Rules:
    table: Dict[str, object]  # logical name -> mesh axis (str/tuple/None)

    def get(self, name):
        return self.table.get(name)


def rules_for(mode: str, multi_pod: bool) -> Rules:
    data = ("pod", "data") if multi_pod else ("data",)
    base = {
        "vocab": "model",
        "heads": "model",
        "kv": "model",
        "mlp": "model",
        "expert": "model",
        "embed": None,
        "layers": None,
        "head": None,
        "batch": data,
        "seq": None,
        "kv_seq": None,
    }
    if mode == "long":  # batch=1 long-context decode: context parallelism
        base["batch"] = None
        base["kv_seq"] = data
    return Rules(base)


RULES = rules_for  # alias


def batch_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


# --- distributed Gram/NTK assembly modes (asdfghjkl-style gather modes) -----

# How the sharded sweep lanes assemble pairwise (Gram-reduced) statistics —
# [N, N] gradient/NTK row blocks — across the data shards:
#
#   'split'   each shard keeps its row block; the sharded out-specs
#             concatenate them, so the logical [N, N] result is physically
#             row-sharded over the data axes (the default: no extra
#             traffic, kernel-regression solvers shard rows anyway).
#   'all'     every shard all-gathers the row blocks in-body; the result
#             is the full [N, N] matrix, replicated.
#   'master'  one full copy on the first shard only (torch.distributed's
#             gather-to-rank-0): the body emits a leading device axis —
#             result [S, N, N] sharded over it, ``[0]`` is the master
#             copy, the other slots are zeros.
GRAM_ASSEMBLY_MODES = ("split", "all", "master")


def gram_assembly_spec(mode: str, axes):
    """``(out PartitionSpec, placement description)`` for a pairwise
    statistic under assembly ``mode`` over mesh ``axes`` — the one table
    both sharded sweep lanes (plain and shard × accumulate) derive their
    Gram out-specs from."""
    if mode not in GRAM_ASSEMBLY_MODES:
        raise ValueError(f"unknown gram assembly mode {mode!r}: "
                         f"expected one of {GRAM_ASSEMBLY_MODES}")
    axes = tuple(axes)
    if mode == "split":
        return P(axes), "sharded(axis0)"
    if mode == "all":
        return P(), "replicated(all-gathered)"
    return P(axes), "master(shard0 of leading device axis)"


def sweep_shard_axes(mesh):
    """Mesh axes the batch-sharded sweep lane (``SweepPlan.shard``) splits
    over — the canonical batch axes from this rules table that actually
    exist in ``mesh``.  One table drives both the implicit-SPMD input
    shardings and the explicit sharded-sweep lane, so the two paths can
    never disagree about which axes carry data parallelism."""
    return tuple(ax for ax in batch_axes("pod" in mesh.axis_names)
                 if ax in mesh.axis_names)


def _axis_size(mesh, name):
    if name is None:
        return 1
    if isinstance(name, tuple):
        s = 1
        for n in name:
            s *= mesh.shape[n]
        return s
    return mesh.shape[name]


def _spec_for_leaf(axes: Axes, shape, rules: Rules, mesh):
    spec = []
    used = set()
    names = tuple(axes.names)
    # leaves may have more dims than names if stacked; left-pad with 'layers'
    if len(names) < len(shape):
        names = ("layers",) * (len(shape) - len(names)) + names
    for dim, logical in zip(shape, names[: len(shape)]):
        mesh_axis = rules.get(logical) if logical else None
        if mesh_axis is None:
            spec.append(None)
            continue
        key = tuple(mesh_axis) if isinstance(mesh_axis, tuple) else (mesh_axis,)
        if used & set(key):
            spec.append(None)
            continue
        size = _axis_size(mesh, mesh_axis)
        if size > 1 and dim % size == 0:
            spec.append(mesh_axis)
            used |= set(key)
        else:
            spec.append(None)
    return NamedSharding(mesh, P(*spec))


def partition_specs(axes_tree, shape_tree, rules: Rules, mesh):
    """axes_tree: pytree of Axes; shape_tree: matching pytree of
    ShapeDtypeStruct/arrays → pytree of NamedSharding."""
    flat_shapes, treedef = jax.tree_util.tree_flatten(shape_tree)
    flat_axes = jax.tree_util.tree_leaves(axes_tree, is_leaf=is_axes)
    if len(flat_axes) != len(flat_shapes):
        raise ValueError(
            f"axes/shape tree mismatch: {len(flat_axes)} vs {len(flat_shapes)}"
        )
    specs = [
        _spec_for_leaf(a, s.shape, rules, mesh)
        for a, s in zip(flat_axes, flat_shapes)
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def input_shardings(kind, specs, rules: Rules, mesh):
    """Shardings for the input-spec dict produced by configs.input_specs."""
    data = rules.get("batch")

    def shard_batched(leaf, extra=()):
        spec = [data] + [None] * (len(leaf.shape) - 1)
        if data is None:
            spec[0] = None
        # divisibility check
        size = _axis_size(mesh, data) if data else 1
        if size > 1 and leaf.shape and leaf.shape[0] % size != 0:
            spec[0] = None
        return NamedSharding(mesh, P(*spec))

    if kind == "train":
        return {
            "inputs": jax.tree.map(shard_batched, specs["inputs"]),
            "labels": jax.tree.map(shard_batched, specs["labels"]),
        }
    if kind == "prefill":
        return {"inputs": jax.tree.map(shard_batched, specs["inputs"])}
    # decode: tokens [B], pos scalar; caches handled by partition_specs
    return {
        "tokens": shard_batched(specs["tokens"]),
        "pos": NamedSharding(mesh, P()),
    }
