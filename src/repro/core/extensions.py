"""Extension declarations — the quantities BackPACK extracts (paper Table 1/5).

An :class:`Extension` is a pure declaration; the engine inspects the set of
requested extensions to decide which backward sweeps to run:

  * ``first``  — the standard cotangent sweep (always runs: it also produces
                 the batch gradient).  BatchGrad / BatchL2 / SecondMoment /
                 Variance / KFAC-A-factor hook in here.
  * ``ggn``    — a symmetric-factor sweep propagating ``S`` (paper Eq. 18),
                 either with the exact loss-Hessian factorization (DiagGGN,
                 KFLR) or a Monte-Carlo one (DiagGGNMC, KFAC).
  * ``kfra``   — the batch-averaged ``Ḡ`` recursion (paper Eq. 24); chain
                 (Sequential-of-Dense/activation) models only.
  * ``hess``   — exact Hessian diagonal via residual ± factors (Eq. 25/26);
                 chain models only.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

from .reducers import (
    CONCAT,
    GRAM,
    GRAM_PAIR,
    KRON,
    MOMENT_MERGE,
    PMEAN,
    PSUM,
    Reducer,
    resolve_reducer,
)


@dataclasses.dataclass(frozen=True)
class Extension:
    """One extractable quantity (a row of the paper's Table 1/5).

    An extension is a *pure declaration* the engine plans sweeps from.
    The declaration is also what the scale-out lanes act on: ``reduce``
    is the :class:`~repro.core.reducers.Reducer` protocol object saying
    how partial results combine across the batch axis, whether that axis
    is split over devices (:meth:`~repro.core.engine.SweepPlan.shard`)
    or over time (:meth:`~repro.core.engine.SweepPlan.accumulate`) —
    every lane drives the same object.

    Parameters
    ----------
    name : str
        Key of the statistic in ``Results.ext``.
    sweep : {'first', 'ggn_exact', 'ggn_mc', 'jac', 'kfra', 'hess'}
        Which backward sweep produces it.
    reduce : Reducer
        How partial results over a split batch combine — one of the
        registered protocol instances (``PSUM``, ``CONCAT``, ``GRAM``,
        ``KRON``, ``PMEAN``, ``MOMENT_MERGE`` from
        :mod:`repro.core.reducers`) or a custom :class:`Reducer`.  The
        pre-protocol string names (``reduce='gram'`` etc.) still resolve,
        with a ``DeprecationWarning`` naming the replacement instance.
    """

    name: str
    sweep: str
    reduce: Union[Reducer, str] = PSUM

    def __post_init__(self):
        # Deprecated string aliases resolve to protocol instances at
        # declaration time (resolve_reducer warns), so the engine only
        # ever sees Reducer objects.
        if not isinstance(self.reduce, Reducer):
            object.__setattr__(self, "reduce", resolve_reducer(self.reduce))


# --- first-order extensions (paper §2.2, App. A.1) -------------------------
BatchGrad = Extension("batch_grad", "first", reduce=CONCAT)
"""Per-sample gradients ``[N, *param]`` of the mean loss (paper Eq. 5)."""

BatchL2 = Extension("batch_l2", "first", reduce=CONCAT)
"""Per-sample squared gradient norms ``[N]`` via the Gram trick (Eq. 9)."""

BatchDot = Extension("batch_dot", "first", reduce=GRAM)
"""Pairwise per-sample gradient dots ``[N, N]`` — beyond-paper
(BackPACK-2.x-style) gradient-similarity / conflict telemetry."""

SecondMoment = Extension("second_moment", "first", reduce=PSUM)
"""Batch-scaled second moment ``N·Σ_n g_n²`` per parameter (Eq. 10)."""

Variance = Extension("variance", "first", reduce=MOMENT_MERGE)
"""Per-parameter gradient variance ``N·Σg² − (Σg)²`` (Eq. 11)."""

# --- second-order extensions (paper §2.3, App. A.2) -------------------------
DiagGGN = Extension("diag_ggn", "ggn_exact", reduce=PSUM)
"""Exact generalized-Gauss-Newton diagonal per parameter (Eq. 19)."""

DiagGGNMC = Extension("diag_ggn_mc", "ggn_mc", reduce=PSUM)
"""Monte-Carlo GGN diagonal (the Eq. 20 factorization of Eq. 19)."""

KFLR = Extension("kflr", "ggn_exact", reduce=KRON)
"""Kronecker-factored low-rank GGN blocks ``A ⊗ B`` with the exact
loss-Hessian factor in ``B`` (Eq. 23)."""

KFAC = Extension("kfac", "ggn_mc", reduce=KRON)
"""KFAC blocks — the Eq. 23 Kronecker pair with the MC factor in ``B``."""

KFRA = Extension("kfra", "kfra", reduce=PMEAN)
"""Kronecker factors from the batch-averaged Ḡ recursion (Eq. 24);
chain (Sequential-of-Dense/activation) models only."""

DiagHessian = Extension("diag_hessian", "hess", reduce=PSUM)
"""Exact Hessian diagonal via signed residual factors (Eq. 25/26);
chain models only."""

GGNTrace = Extension("ggn_trace", "ggn_exact", reduce=CONCAT)
"""Per-sample GGN trace ``[N]`` — beyond-paper curvature-concentration
telemetry (which samples dominate the loss curvature); a marginal-cost
output of the fused second-order kernel.  Dense-shaped layers only."""

# --- empirical NTK family (beyond-paper; Gram blocks of the Jacobian) -------
NTK = Extension("ntk", "jac", reduce=GRAM)
"""Empirical NTK row blocks ``[N, N]`` per layer parameter:
``Θ[n, m] = Σ_c ⟨J_c(x_n), J_c(x_m)⟩`` from *raw* output Jacobians
(identity cotangents — no loss weighting), summed over the class axis.
Vector-output (``z [N, C]``) models; Dense-shaped layers contribute
(like GGNTrace).  Sum the leaves for the total kernel
(:func:`repro.core.engine.ntk_total`)."""

NTKClasswise = Extension("ntk_classwise", "jac", reduce=GRAM)
"""Class-diagonal empirical NTK ``[N, N, C]`` per layer parameter:
``Θ[n, m, c] = ⟨J_c(x_n), J_c(x_m)⟩`` (asdfghjkl's class-wise kernel,
sample axes leading so the Gram reducer's row-block layout applies)."""

GGNGram = Extension("ggn_gram", "ggn_exact", reduce=GRAM_PAIR)
"""Loss-scaled logit-space GGN Gram blocks ``[N, N, C̃, C̃]`` per layer
parameter: ``K[n, m, c, c'] = ⟨Jᵀ√H-col c of x_n, Jᵀ√H-col c' of x_m⟩``
with the exact sqrt loss-Hessian factor (C̃ = U·C columns).  Summing the
leaves (:func:`repro.core.engine.gram_total`) gives the full kernel
matrix ``J' J'ᵀ`` of the half-sandwich ``J' = √Hᵀ J`` — the ``[N·C̃]``
Gram operator that kernel-space natural gradients (``repro.curv.ngd``)
solve against when ``N·C̃ ≪ P``.  Sample axes lead, so the Gram
reducer's row-block shard/stream layouts apply unchanged."""

ALL_EXTENSIONS = (
    BatchGrad,
    BatchL2,
    BatchDot,
    SecondMoment,
    Variance,
    DiagGGN,
    DiagGGNMC,
    KFLR,
    KFAC,
    KFRA,
    DiagHessian,
    GGNTrace,
    NTK,
    NTKClasswise,
    GGNGram,
)
_BY_NAME = {e.name: e for e in ALL_EXTENSIONS}


def by_name(name: str) -> Extension:
    return _BY_NAME[name]


def sweeps_needed(extensions) -> set:
    return {e.sweep for e in extensions}


def reduce_spec(extensions) -> dict:
    """``{extension name: Reducer}`` for a set of extensions.

    The protocol-object table every scale-out lane drives — see
    :mod:`repro.core.reducers` for the protocol and
    ``engine.ShardedSweepPlan`` / ``engine.AccumulatedSweepPlan`` for the
    drivers.  (Pre-protocol callers compared the values against strings;
    compare ``reduce_spec(...)[name].name`` instead.)
    """
    return {e.name: e.reduce for e in extensions}


@dataclasses.dataclass(frozen=True)
class FusedMask:
    """Static extension mask for the fused first-order kernel.

    Maps 1:1 onto the fused kernel's outputs: ``l2`` ↔ BatchL2, ``moment`` ↔
    SecondMoment/Variance (both reduce the summed squared gradient), ``dot``
    ↔ BatchDot.  An unset flag means that output is never allocated or
    computed inside the kernel.
    """

    l2: bool = False
    moment: bool = False
    dot: bool = False

    def any(self) -> bool:
        return self.l2 or self.moment or self.dot

    def wants(self):
        """Kwargs for ``kernels.ops.fused_first_order``."""
        return dict(want_l2=self.l2, want_moment=self.moment,
                    want_dot=self.dot)


def first_order_mask(exts_or_names) -> FusedMask:
    """Fused-kernel mask for a set of extensions (or extension names)."""
    names = {e if isinstance(e, str) else e.name for e in exts_or_names}
    return FusedMask(
        l2="batch_l2" in names,
        moment=bool(names & {"second_moment", "variance"}),
        dot="batch_dot" in names,
    )


@dataclasses.dataclass(frozen=True)
class FusedSecondMask:
    """Static extension mask for the fused second-order (curvature) kernel.

    Maps 1:1 onto the fused kernel's outputs: ``diag`` ↔ DiagGGN/DiagGGNMC,
    ``kron`` ↔ the KFLR/KFAC output-side B-factor, ``trace`` ↔ GGNTrace.
    An unset flag means that output is never allocated or computed inside
    the kernel.
    """

    diag: bool = False
    kron: bool = False
    trace: bool = False

    def any(self) -> bool:
        return self.diag or self.kron or self.trace

    def wants(self):
        """Kwargs for ``kernels.ops.fused_second_order``."""
        return dict(want_diag=self.diag, want_kron=self.kron,
                    want_trace=self.trace)


def second_order_mask(exts_or_names) -> FusedSecondMask:
    """Fused-curvature-kernel mask for a set of extensions (or names).

    Pure, like :func:`first_order_mask`: the engine's plan and the layer
    stat hooks derive the same mask independently.  Works per sweep — the
    exact sweep's names ({diag_ggn, kflr, ggn_trace}) and the MC sweep's
    ({diag_ggn_mc, kfac}) both land on the same kernel outputs.
    """
    names = {e if isinstance(e, str) else e.name for e in exts_or_names}
    return FusedSecondMask(
        diag=bool(names & {"diag_ggn", "diag_ggn_mc"}),
        kron=bool(names & {"kflr", "kfac"}),
        trace="ggn_trace" in names,
    )


@dataclasses.dataclass(frozen=True)
class ExtensionConfig:
    """Knobs shared by the engine's sweeps.

    Parameters
    ----------
    mc_samples : int
        Number of Monte-Carlo columns C̃ for the MC loss-Hessian
        factorization (paper Eq. 20).  Cost is ~1 gradient-like sweep per
        sample; variance of DiagGGNMC/KFAC shrinks as 1/C̃.
    mc_seed : int, optional
        Deterministic PRNG seed for the MC sweep when no explicit ``rng``
        is passed to :func:`repro.core.run`.
    class_chunk : int, optional
        Chunk size over the exact factor's leading U·C axis — exact
        curvature at LM-vocabulary scale with bounded memory.
    use_kernels : bool
        Route moment formulas through the Pallas kernels in
        ``repro.kernels`` (interpret mode on CPU); pure-jnp einsums
        otherwise.
    use_fused : bool
        With ``use_kernels``: one fused kernel launch per layer per sweep
        (the default) vs the per-extension legacy path (the benchmark
        baseline).
    microbatch_size : int, optional
        Stream the sweep over microbatches of at most this many samples
        *per device* (the accumulated lane, ``SweepPlan.accumulate``):
        consumers — ``make_extended_train_step``, ``train.loop.fit``,
        the Laplace ``fit`` methods — compose lanes via
        ``engine.plan_for_batch``, which folds each extension's
        ``reduce`` spec sequentially over ``ceil(N_device /
        microbatch_size)`` slices, serving effective batches far beyond
        device memory.  Under a mesh the bound applies to the
        shard-local rows (the grid already splits the batch spatially).
    shard_axes : tuple of str, optional
        Mesh axis names the batch is sharded over — set by the sharded
        sweep lane for the body it runs under ``shard_map``; never set
        this by hand.
    """

    mc_samples: int = 1          # C̃ for the MC factorization (paper Eq. 20)
    # Explicit PRNG seed for the MC sweep (DiagGGNMC / KFAC).  When the
    # caller passes no ``rng`` to ``engine.run``, the sweep derives its key
    # from this seed — repeated runs with the same config are then
    # deterministic (required by the marglik tests; previously every MC
    # caller had to thread its own key or the run failed).  An explicit
    # ``rng`` argument still takes precedence.
    mc_seed: Optional[int] = None
    class_chunk: Optional[int] = None  # chunk size over C for exact factors
    # When True, first-order moment formulas route through the Pallas kernels
    # in repro.kernels (interpret=True on CPU); pure-jnp einsums otherwise.
    use_kernels: bool = False
    # With use_kernels=True: route all requested reductions — first-order
    # stats AND the curvature-sweep stats (GGN diag, Kronecker B-factors,
    # GGN trace) — through ONE fused kernel launch per layer per sweep (the
    # default).  False falls back to the seed's per-extension path (a
    # separate kernel or einsum per statistic) — kept as the baseline the
    # fused paths are benchmarked against.
    use_fused: bool = True
    # Stream the sweep over microbatches of at most this many samples (the
    # accumulated lane).  Consumed by make_extended_train_step /
    # train.loop.fit / the Laplace fits, which route through
    # ``SweepPlan.accumulate(ceil(N / microbatch_size))``.
    microbatch_size: Optional[int] = None
    # Mesh axis names the batch is sharded over, set by the sharded sweep
    # lane (``SweepPlan.shard``) for the body it runs under
    # ``jax.shard_map``.  When set, the engine corrects the loss's 1/M
    # normalization from shard-local to global, layer hooks compute
    # cross-shard statistics (pairwise dots, KFRA expectations) against
    # all-gathered factors, and the per-extension ``reduce`` specs are
    # applied before results leave the shard body.  None = single-device
    # semantics (the default; never set this by hand outside shard_map).
    shard_axes: Optional[tuple] = None
    # --- accumulation-driver fields -----------------------------------------
    # Set by ``AccumulatedSweepPlan.run`` for the microbatch bodies it
    # drives; never set these by hand.  ``total_units`` is the mask-aware
    # global unit count M over the WHOLE accumulated batch (the engine's
    # loss adapter rescales microbatch-local factors to the global 1/M
    # normalization), ``total_batch`` the global raw sample count N (the
    # batch-size scale of SecondMoment/Variance), ``sample_offset`` the
    # global index of this microbatch's first sample (per-sample MC PRNG
    # streams), and ``accum_stats`` makes the engine emit mergeable raw
    # accumulators (Chan (count, mean, M2) triples for Variance) instead
    # of finalized statistics.
    total_units: Optional[Any] = None
    total_batch: Optional[int] = None
    sample_offset: Any = 0
    accum_stats: bool = False
    # Streaming-Gram pair passes (single-device): the batch the hooks see
    # is the concatenation of two microbatch slices, and pairwise stats
    # (batch_dot / ntk*) should emit ONLY the cross block rows[:cross_split]
    # × rows[cross_split:] — computed through the fused cross-block kernel
    # (``kernels.ops.cross_dot``) when kernels are on.  Ignored under
    # ``shard_axes`` (sharded pairwise stats compute full gathered-column
    # rows; the driver slices the blocks).  Set by the accumulated
    # driver's pair passes; never set this by hand.
    cross_split: Optional[int] = None
