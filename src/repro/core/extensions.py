"""Extension declarations — the quantities BackPACK extracts (paper Table 1/5).

An :class:`Extension` is a pure declaration; the engine inspects the set of
requested extensions to decide which backward sweeps to run:

  * ``first``  — the standard cotangent sweep (always runs: it also produces
                 the batch gradient).  BatchGrad / BatchL2 / SecondMoment /
                 Variance / KFAC-A-factor hook in here.
  * ``ggn``    — a symmetric-factor sweep propagating ``S`` (paper Eq. 18),
                 either with the exact loss-Hessian factorization (DiagGGN,
                 KFLR) or a Monte-Carlo one (DiagGGNMC, KFAC).
  * ``kfra``   — the batch-averaged ``Ḡ`` recursion (paper Eq. 24); chain
                 (Sequential-of-Dense/activation) models only.
  * ``hess``   — exact Hessian diagonal via residual ± factors (Eq. 25/26);
                 chain models only.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class Extension:
    name: str
    sweep: str  # 'first' | 'ggn_exact' | 'ggn_mc' | 'kfra' | 'hess'
    # How shard-local results combine across a data-parallel mesh axis
    # (the batch-sharded sweep lane, ``SweepPlan.shard``):
    #   'psum'         sum the per-shard partial reductions (batch-summed
    #                  statistics: GGN/Hessian diagonals, second moment)
    #   'concat'       per-sample stats — each shard owns its samples'
    #                  rows; the sharded out-spec concatenates them
    #   'gram'         pairwise per-sample stats ([N, N] Gram matrices):
    #                  each shard computes its row block against the
    #                  all-gathered factors, rows concatenate
    #   'kron'         Kronecker factor pairs: A factors are batch *means*
    #                  (pmean), B factors batch sums (psum)
    #   'pmean'        batch-averaged statistics (KFRA's Ḡ recursion)
    #   'moment_merge' mean/variance pairs via the numerically stable
    #                  pairwise (Chan) moment merge across shards
    reduce: str = "psum"


# --- first-order extensions (paper §2.2, App. A.1) -------------------------
BatchGrad = Extension("batch_grad", "first", reduce="concat")
BatchL2 = Extension("batch_l2", "first", reduce="concat")
# beyond-paper (BackPACK-2.x-style): pairwise per-sample gradient dots —
# gradient-similarity / conflict telemetry, Gram-trick computed
BatchDot = Extension("batch_dot", "first", reduce="gram")
SecondMoment = Extension("second_moment", "first", reduce="psum")
Variance = Extension("variance", "first", reduce="moment_merge")

# --- second-order extensions (paper §2.3, App. A.2) -------------------------
DiagGGN = Extension("diag_ggn", "ggn_exact", reduce="psum")
DiagGGNMC = Extension("diag_ggn_mc", "ggn_mc", reduce="psum")
KFLR = Extension("kflr", "ggn_exact", reduce="kron")
KFAC = Extension("kfac", "ggn_mc", reduce="kron")
KFRA = Extension("kfra", "kfra", reduce="pmean")
DiagHessian = Extension("diag_hessian", "hess", reduce="psum")
# beyond-paper: per-sample GGN trace [N] — curvature-concentration telemetry
# (which samples dominate the loss curvature); a marginal-cost output of the
# fused second-order kernel.  Dense-shaped layers (Dense / Conv2d) only.
GGNTrace = Extension("ggn_trace", "ggn_exact", reduce="concat")

ALL_EXTENSIONS = (
    BatchGrad,
    BatchL2,
    BatchDot,
    SecondMoment,
    Variance,
    DiagGGN,
    DiagGGNMC,
    KFLR,
    KFAC,
    KFRA,
    DiagHessian,
    GGNTrace,
)
_BY_NAME = {e.name: e for e in ALL_EXTENSIONS}


def by_name(name: str) -> Extension:
    return _BY_NAME[name]


def sweeps_needed(extensions) -> set:
    return {e.sweep for e in extensions}


def reduce_spec(extensions) -> dict:
    """``{extension name: cross-shard reducer}`` for a set of extensions.

    The table the batch-sharded sweep lane acts on — see
    :class:`Extension` for the reducer vocabulary and
    ``engine.ShardedSweepPlan`` for the implementation.
    """
    return {e.name: e.reduce for e in extensions}


@dataclasses.dataclass(frozen=True)
class FusedMask:
    """Static extension mask for the fused first-order kernel.

    Maps 1:1 onto the fused kernel's outputs: ``l2`` ↔ BatchL2, ``moment`` ↔
    SecondMoment/Variance (both reduce the summed squared gradient), ``dot``
    ↔ BatchDot.  An unset flag means that output is never allocated or
    computed inside the kernel.
    """

    l2: bool = False
    moment: bool = False
    dot: bool = False

    def any(self) -> bool:
        return self.l2 or self.moment or self.dot

    def wants(self):
        """Kwargs for ``kernels.ops.fused_first_order``."""
        return dict(want_l2=self.l2, want_moment=self.moment,
                    want_dot=self.dot)


def first_order_mask(exts_or_names) -> FusedMask:
    """Fused-kernel mask for a set of extensions (or extension names)."""
    names = {e if isinstance(e, str) else e.name for e in exts_or_names}
    return FusedMask(
        l2="batch_l2" in names,
        moment=bool(names & {"second_moment", "variance"}),
        dot="batch_dot" in names,
    )


@dataclasses.dataclass(frozen=True)
class FusedSecondMask:
    """Static extension mask for the fused second-order (curvature) kernel.

    Maps 1:1 onto the fused kernel's outputs: ``diag`` ↔ DiagGGN/DiagGGNMC,
    ``kron`` ↔ the KFLR/KFAC output-side B-factor, ``trace`` ↔ GGNTrace.
    An unset flag means that output is never allocated or computed inside
    the kernel.
    """

    diag: bool = False
    kron: bool = False
    trace: bool = False

    def any(self) -> bool:
        return self.diag or self.kron or self.trace

    def wants(self):
        """Kwargs for ``kernels.ops.fused_second_order``."""
        return dict(want_diag=self.diag, want_kron=self.kron,
                    want_trace=self.trace)


def second_order_mask(exts_or_names) -> FusedSecondMask:
    """Fused-curvature-kernel mask for a set of extensions (or names).

    Pure, like :func:`first_order_mask`: the engine's plan and the layer
    stat hooks derive the same mask independently.  Works per sweep — the
    exact sweep's names ({diag_ggn, kflr, ggn_trace}) and the MC sweep's
    ({diag_ggn_mc, kfac}) both land on the same kernel outputs.
    """
    names = {e if isinstance(e, str) else e.name for e in exts_or_names}
    return FusedSecondMask(
        diag=bool(names & {"diag_ggn", "diag_ggn_mc"}),
        kron=bool(names & {"kflr", "kfac"}),
        trace="ggn_trace" in names,
    )


@dataclasses.dataclass(frozen=True)
class ExtensionConfig:
    """Knobs shared by the engine's sweeps."""

    mc_samples: int = 1          # C̃ for the MC factorization (paper Eq. 20)
    # Explicit PRNG seed for the MC sweep (DiagGGNMC / KFAC).  When the
    # caller passes no ``rng`` to ``engine.run``, the sweep derives its key
    # from this seed — repeated runs with the same config are then
    # deterministic (required by the marglik tests; previously every MC
    # caller had to thread its own key or the run failed).  An explicit
    # ``rng`` argument still takes precedence.
    mc_seed: Optional[int] = None
    class_chunk: Optional[int] = None  # chunk size over C for exact factors
    # When True, first-order moment formulas route through the Pallas kernels
    # in repro.kernels (interpret=True on CPU); pure-jnp einsums otherwise.
    use_kernels: bool = False
    # With use_kernels=True: route all requested reductions — first-order
    # stats AND the curvature-sweep stats (GGN diag, Kronecker B-factors,
    # GGN trace) — through ONE fused kernel launch per layer per sweep (the
    # default).  False falls back to the seed's per-extension path (a
    # separate kernel or einsum per statistic) — kept as the baseline the
    # fused paths are benchmarked against.
    use_fused: bool = True
    # Mesh axis names the batch is sharded over, set by the sharded sweep
    # lane (``SweepPlan.shard``) for the body it runs under
    # ``jax.shard_map``.  When set, the engine corrects the loss's 1/M
    # normalization from shard-local to global, layer hooks compute
    # cross-shard statistics (pairwise dots, KFRA expectations) against
    # all-gathered factors, and the per-extension ``reduce`` specs are
    # applied before results leave the shard body.  None = single-device
    # semantics (the default; never set this by hand outside shard_map).
    shard_axes: Optional[tuple] = None
