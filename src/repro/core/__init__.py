"""repro.core — BackPACK's extended backpropagation, in JAX.

Public API::

    from repro.core import (
        Dense, Embedding, RMSNorm, LayerNorm, Activation, Lambda,
        Sequential, Parallel, Residual, ScanStack, Module,
        CrossEntropyLoss, MSELoss,
        BatchGrad, BatchL2, SecondMoment, Variance,
        DiagGGN, DiagGGNMC, DiagHessian, KFAC, KFLR, KFRA,
        NTK, NTKClasswise, ntk_total,
        Reducer, register_reducer, resolve_reducer,
        ExtensionConfig, run,
    )
"""
from .extensions import (
    ALL_EXTENSIONS,
    NTK,
    BatchDot,
    BatchGrad,
    BatchL2,
    DiagGGN,
    DiagGGNMC,
    DiagHessian,
    Extension,
    ExtensionConfig,
    FusedMask,
    FusedSecondMask,
    GGNGram,
    GGNTrace,
    KFAC,
    KFLR,
    KFRA,
    NTKClasswise,
    SecondMoment,
    Variance,
    by_name,
    first_order_mask,
    reduce_spec,
    second_order_mask,
)
from . import reducers
from .reducers import (
    CONCAT,
    GRAM,
    GRAM_PAIR,
    KRON,
    MOMENT_MERGE,
    PMEAN,
    PSUM,
    REDUCERS,
    Reducer,
    register_reducer,
    resolve_reducer,
)
from .loss_hessian import CrossEntropyLoss, MSELoss
from .module import (
    Activation,
    Axes,
    Dense,
    Embedding,
    GroupRMSNorm,
    Lambda,
    LayerNorm,
    Module,
    Parallel,
    Residual,
    RMSNorm,
    ScanStack,
    Sequential,
    UnsupportedSweep,
    is_axes,
    per_sample_l2,
    per_sample_sq_sum,
)
from .engine import (
    AccumulatedSweepPlan,
    Results,
    ShardedSweepPlan,
    SweepPlan,
    SweepStream,
    gram_total,
    loss_and_grad,
    ntk_total,
    plan_for_batch,
    plan_sweeps,
    run,
)
from . import kron, oracle
