"""Kronecker-factor algebra: π-damped inverses (paper App. C.3, Eq. 28/29).

A Kronecker-factored curvature block is ``G ≈ A ⊗ B`` with ``A`` an
input-side ``[a×a]`` factor (possibly diagonal, stored as a vector — the
embedding case) and ``B`` an output-side ``[b×b]`` factor.

``(A ⊗ B + (λ+η) I)⁻¹`` is approximated per Martens & Grosse (2015):

    (A + π √(λ+η) I)⁻¹ ⊗ (B + (1/π) √(λ+η) I)⁻¹,
    π = sqrt( (tr A / dim A) / (tr B / dim B) ).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pi_factor(A, B):
    """Trace-norm π (Eq. 29). A may be a vector (diagonal factor)."""
    tr_a = jnp.sum(A) if A.ndim == 1 else jnp.trace(A)
    dim_a = A.shape[0]
    tr_b = jnp.trace(B)
    dim_b = B.shape[0]
    num = tr_a * dim_b
    den = dim_a * tr_b
    return jnp.sqrt(jnp.maximum(num, 1e-30) / jnp.maximum(den, 1e-30))


def damped_inverses(A, B, damping):
    """Return callables' data: inverted damped factors (Eq. 28)."""
    pi = pi_factor(A, B)
    sd = jnp.sqrt(damping)
    if A.ndim == 1:
        A_inv = 1.0 / (A + pi * sd)
    else:
        A_inv = jnp.linalg.inv(A + pi * sd * jnp.eye(A.shape[0], dtype=A.dtype))
    B_inv = jnp.linalg.inv(B + (sd / pi) * jnp.eye(B.shape[0], dtype=B.dtype))
    return A_inv, B_inv


def kron_solve(A, B, g, damping):
    """(A⊗B + λI)⁻¹ vec(g) for g of shape [a, b] (weight-matrix layout)."""
    A_inv, B_inv = damped_inverses(A, B, damping)
    g32 = g.astype(jnp.float32)
    if A.ndim == 1:
        return (A_inv[:, None] * g32) @ B_inv.T
    return A_inv @ g32 @ B_inv.T


def kron_solve_bias(B, g, damping):
    """Bias blocks carry only the B factor (paper footnote 7/8)."""
    B_inv = jnp.linalg.inv(
        B + damping * jnp.eye(B.shape[0], dtype=B.dtype)
    )
    return B_inv @ g.astype(jnp.float32)


def kron_mat_vec(A, B, g):
    """(A ⊗ B) vec(g) in weight-matrix layout."""
    g32 = g.astype(jnp.float32)
    if A.ndim == 1:
        return (A[:, None] * g32) @ B.T
    return A @ g32 @ B.T


def kron_dense(A, B):
    """Materialize A ⊗ B (tests only)."""
    if A.ndim == 1:
        A = jnp.diag(A)
    return jnp.kron(A, B)
