"""First-class Reducer protocol — how partial extension results combine.

Historically ``Extension.reduce`` was a closed string vocabulary
(``'psum' / 'concat' / 'gram' / 'kron' / 'pmean' / 'moment_merge'``)
interpreted independently by three engine drivers (the shard_map reducer,
the lax.scan sequential accumulator, and the shard × accumulate grid).
This module replaces the strings with protocol *objects*: one
:class:`Reducer` instance per combination rule, driven uniformly by every
lane.  String names keep working as deprecated aliases (resolved — with a
``DeprecationWarning`` — by :func:`resolve_reducer`).

The protocol
------------

Spatial (cross-shard) reduction::

    shard_reduce(tree, axes)   # inside shard_map; collectives or identity

Sequential (cross-microbatch) accumulation — a weighted left fold::

    acc = reducer.init(zero_tree)
    acc = reducer.update(acc, partial, meta)   # meta = {'weight': n_mb, ...}
    out = reducer.finalize(acc, meta)          # meta carries total counts

``merge(a, b)`` combines two *accumulated* partials; it must be
associative (and, unless ``commutative`` is False, order-invariant) —
tests/test_reducers.py asserts both properties for every registered
reducer with hypothesis.

Capability flags (what the drivers dispatch on, instead of string
switches):

``supports_streaming``
    The accumulated lane can fold this reducer sequentially.  Third-party
    reducers that genuinely need the whole batch resident set this False
    and get the capability error from ``AccumulatedSweepPlan`` for free.
``local_rows``
    Sharded outputs keep shard-local sample rows (axis 0); the sharded
    out-specs concatenate them (``'concat'`` rows, ``'gram'`` row blocks).
``streams_rows``
    The accumulated lane appends this reducer's rows microbatch by
    microbatch (the ``'concat'`` fast path) instead of carrying a
    running accumulator.
``pairwise``
    Gram-family: entries pair samples *across* microbatches, so the
    streaming driver runs extra row-block pair passes and scatters the
    emitted blocks (see ``engine._run_accumulated``).  The streaming
    algebra is block-scatter-into-zeros + elementwise add — associative
    and commutative because blocks are disjoint.
``supports_checkpoint``
    The accumulator state round-trips through ``serialize`` /
    ``deserialize`` as a pytree of arrays, so a checkpointable streaming
    sweep (``engine.SweepStream``) can snapshot it mid-run and restore
    it — possibly in a different process, on a different device mesh —
    and continue folding with ``update``/``merge``.  Third-party
    reducers whose accumulator holds non-array state (open files,
    device-pinned buffers) set this False and are rejected by the
    checkpointed driver with an actionable error.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# shared tree helpers (also used by the engine drivers)
# ---------------------------------------------------------------------------


def merge_stat_trees(model_stats, key):
    """Extract ``stats[key]`` sub-tree from the nested per-module stats."""

    def rec(node):
        if isinstance(node, dict):
            # module-level stats dict keyed by extension name
            return node.get(key, ())
        if isinstance(node, (tuple, list)):
            return tuple(rec(c) for c in node)
        return ()

    return rec(model_stats)


def _tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def _tree_axpy(w, x, y):
    """y + w·x leaf-wise (the weighted running-mean accumulator step)."""
    return jax.tree.map(lambda xl, yl: yl + w * xl, x, y)


def _chan_merge(a, b):
    """Merge two (count, mean, M2) triples — Chan et al.'s pairwise update."""
    na, ma, m2a = a
    nb, mb, m2b = b
    n = na + nb
    d = mb - ma
    mean = ma + d * (nb / n)
    m2 = m2a + m2b + d * d * (na * nb / n)
    return n, mean, m2


def _is_moment_triple(x) -> bool:
    return isinstance(x, dict) and set(x) == {"n", "mean", "m2"}


def _merge_moment_triples(acc, new):
    """Fold one partial batch's (count, mean, M2) triples into the running
    ones — the sequential counterpart of the sharded binary merge tree."""

    def merge(a, b):
        n, mean, m2 = _chan_merge((a["n"], a["mean"], a["m2"]),
                                  (b["n"], b["mean"], b["m2"]))
        return {"n": n, "mean": mean, "m2": m2}

    return jax.tree.map(merge, acc, new, is_leaf=_is_moment_triple)


def _finalize_moment_triples(tree):
    """n·M2 — the engine's ``n·Σg² − (Σg)²`` variance convention."""
    return jax.tree.map(lambda t: t["n"] * t["m2"], tree,
                        is_leaf=_is_moment_triple)


def _kron_map(fn, tree, *rest):
    """Walk Kronecker stats trees applying ``fn(kind, leaf, *others)`` —
    ``kind`` is ``'A'`` for A/``A_diag`` factors, ``'B'`` for B factors,
    ``None`` for stray array leaves.  Extra trees walk in lockstep (the
    accumulator's (new, acc) pairs).  The one factor-key dispatch table
    keeps the sharded reducer, the sequential accumulator and its
    finalizer from drifting apart."""

    def rec(node, *others):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                o = tuple(d[k] for d in others)
                if k in ("A", "A_diag"):
                    out[k] = jax.tree.map(partial(fn, "A"), v, *o)
                elif k == "B":
                    out[k] = jax.tree.map(partial(fn, "B"), v, *o)
                else:
                    out[k] = rec(v, *o)
            return out
        if isinstance(node, (tuple, list)):
            return tuple(rec(*z) for z in zip(node, *others))
        if hasattr(node, "ndim"):
            return fn(None, node, *others)
        return node

    return rec(tree, *rest)


def _is_kfra_partial(x) -> bool:
    """Marker for the streaming-KFRA raw emission: the global-mean loss
    Hessian contribution plus the per-layer chain partials (see
    ``Module.kfra_partials``)."""
    return isinstance(x, dict) and set(x) == {"gbar", "partials"}


# ---------------------------------------------------------------------------
# the protocol
# ---------------------------------------------------------------------------


class Reducer:
    """How one extension's partial results combine across a split batch.

    Base class = the ``'psum'`` behaviour (sum of partial batch
    reductions); subclasses override the pieces that differ.  Instances
    are stateless singletons — declare them on :class:`Extension` and the
    engine's three drivers (shard / accumulate / grid) call the protocol
    methods instead of switching on strings.
    """

    name = "psum"
    supports_streaming = True
    supports_checkpoint = True
    local_rows = False
    streams_rows = False
    pairwise = False
    commutative = True
    streaming_form = "running sum"

    # -- spatial (cross-shard) ---------------------------------------------
    def shard_reduce(self, tree, axes):
        return jax.tree.map(lambda x: jax.lax.psum(x, axes), tree)

    @property
    def placement(self) -> str:
        """Where sharded outputs live: shard-local sample rows
        (concatenated by the out-specs) or replicated reductions."""
        return "sharded(axis0)" if self.local_rows else "replicated"

    # -- sequential (cross-microbatch) -------------------------------------
    def init(self, zero):
        """Initial accumulator from a zeros-like of one partial emission."""
        return zero

    def update(self, acc, new, meta: Dict[str, Any]):
        """Fold one microbatch's raw emission into the accumulator.
        ``meta['weight']`` is the microbatch's raw sample count."""
        return _tree_add(acc, new)

    def merge(self, a, b):
        """Combine two accumulated partials (associative; commutative
        unless ``commutative`` is False)."""
        return _tree_add(a, b)

    def finalize(self, acc, meta: Dict[str, Any]):
        """Accumulated partials → the monolithic statistic.  ``meta``
        carries ``total_batch`` / ``total_units`` (and, for reducers that
        replay model structure, driver-provided callbacks)."""
        return acc

    # -- checkpointing (preemption-safe streaming sweeps) -------------------
    def serialize(self, acc):
        """Accumulator → a pytree of arrays for a checkpoint snapshot.

        The default is the identity: every built-in reducer's
        accumulator already *is* a pytree of arrays (running sums, kron
        factor trees, Chan ``{'n','mean','m2'}`` triples, KFRA
        ``{'gbar','partials'}`` pairs).  Override when the live
        accumulator carries anything a ``save``/``restore`` round trip
        through host arrays cannot represent; the serialized form must
        have a stable tree structure and leaf shapes across the whole
        sweep (the checkpoint layer validates both on restore).
        """
        return acc

    def deserialize(self, payload):
        """Inverse of :meth:`serialize` — restored arrays → a live
        accumulator ``update``/``merge``/``finalize`` can keep folding.
        The restored state may land on a different device mesh than it
        was saved from; built-in accumulators are replicated host-side
        values, so the identity default is elastic for free."""
        return payload


class PsumReducer(Reducer):
    """Sum of partial batch reductions (GGN/Hessian diagonals, moments)."""


class ConcatReducer(Reducer):
    """Per-sample rows: each shard/microbatch owns its samples' rows,
    concatenated in sample order (hence not commutative)."""

    name = "concat"
    local_rows = True
    streams_rows = True
    commutative = False
    streaming_form = "row append"

    def shard_reduce(self, tree, axes):
        return tree  # sharded out-specs concatenate the local rows

    def update(self, acc, new, meta):
        return self.merge(acc, new)

    def merge(self, a, b):
        return jax.tree.map(lambda x, y: jnp.concatenate([x, y], 0), a, b)


class GramReducer(Reducer):
    """Pairwise per-sample statistics ([N, N] Gram row blocks).

    Sharded: each shard computes its *row block* against the all-gathered
    factors; rows stay shard-local (the out-specs concatenate them), with
    the distributed assembly modes (``'split' | 'all' | 'master'``) applied
    by the shard lane on top.

    Streamed: the main microbatch scan emits *diagonal* blocks in place;
    off-diagonal blocks come from one extra sweep per (micro)batch pair,
    and every block is scattered into a zero [N, N] accumulator — so the
    streaming algebra is an elementwise add of disjoint-block matrices
    (associative, commutative), and peak factor memory stays at two
    microbatches.
    """

    name = "gram"
    local_rows = True
    pairwise = True
    streaming_form = "row-block scatter (diag in-place, pairs streamed)"

    def shard_reduce(self, tree, axes):
        return tree

    @staticmethod
    def transpose_block(x):
        """Off-diagonal block (p, q) → its mirror (q, p): pairwise stats
        are symmetric in the sample axes (the leading two; trailing axes —
        e.g. the class axis of ``ntk_classwise`` — ride along)."""
        return jnp.swapaxes(x, 0, 1)


class GramPairReducer(GramReducer):
    """Gram row blocks whose trailing axes are a *column pair* (e.g. the
    ``ggn_gram`` ``[N, M, C̃, C̃]`` logit-space kernel blocks).

    Identical shard/stream algebra to :class:`GramReducer`, except the
    off-diagonal mirror: block (p, q) entry ``T[n, m, c, c']`` is the
    inner product of row (n, c) with row (m, c'), so the (q, p) block
    transposes the column pair *along with* the sample pair."""

    name = "gram_pair"

    @staticmethod
    def transpose_block(x):
        return jnp.swapaxes(jnp.swapaxes(x, 0, 1), 2, 3)


class KronReducer(Reducer):
    """Kronecker factor pairs: A factors are batch *means* (sharded:
    pmean; streamed: running sample-count-weighted mean), B factors batch
    sums (psum / running sum)."""

    name = "kron"
    streaming_form = "weighted A mean + B sum"

    def shard_reduce(self, tree, axes):
        def red(kind, x):
            if kind == "A":
                return jax.lax.pmean(x, axes)
            if kind == "B":
                return jax.lax.psum(x, axes)
            return x

        return _kron_map(red, tree)

    def update(self, acc, new, meta):
        w = meta["weight"]

        def step(kind, n_leaf, a_leaf):
            if kind == "A":
                return a_leaf + w * n_leaf
            return a_leaf + n_leaf

        return _kron_map(step, new, acc)

    def merge(self, a, b):
        return _kron_map(lambda kind, x, y: x + y, a, b)

    def finalize(self, acc, meta):
        n_total = meta["total_batch"]
        return _kron_map(
            lambda kind, x: x / n_total if kind == "A" else x, acc)


class MomentMergeReducer(Reducer):
    """Mean/variance via the numerically stable pairwise (Chan) moment
    merge — across shards in a binary tree (already applied inside the
    shard body, see ``engine._sharded_moment_triple``), across
    microbatches as a sequential fold of (count, mean, M2) triples."""

    name = "moment_merge"
    streaming_form = "sequential Chan merge"

    def shard_reduce(self, tree, axes):
        return tree  # triples are merged across shards in the body

    def update(self, acc, new, meta):
        return self.merge(acc, new)

    def merge(self, a, b):
        return _merge_moment_triples(a, b)

    def finalize(self, acc, meta):
        return _finalize_moment_triples(acc)


class MeanReducer(Reducer):
    """Batch-averaged statistics (``'pmean'``): sharded via
    ``lax.pmean``, streamed as a sample-count-weighted running mean.

    KFRA rides on this reducer with one extra wrinkle: its Ḡ recursion
    needs the *global* batch expectation at every layer, so the streamed
    emission is a ``{'gbar', 'partials'}`` pair — the loss-Hessian mean
    *contribution* (sums across microbatches) plus per-layer expectation
    partials (weighted means) — and ``finalize`` replays the chain
    recursion on the accumulated global expectations via the
    driver-provided ``meta['replay']`` callback (exact: every
    batch-dependent quantity in the recursion is a batch mean).
    """

    name = "pmean"
    streaming_form = "weighted partial means (+ chain replay for KFRA)"

    def shard_reduce(self, tree, axes):
        return jax.tree.map(lambda x: jax.lax.pmean(x, axes), tree)

    def update(self, acc, new, meta):
        w = meta["weight"]
        if _is_kfra_partial(new):
            return {"gbar": _tree_add(acc["gbar"], new["gbar"]),
                    "partials": _tree_axpy(w, new["partials"],
                                           acc["partials"])}
        return _tree_axpy(w, new, acc)

    def merge(self, a, b):
        return _tree_add(a, b)

    def finalize(self, acc, meta):
        n_total = meta["total_batch"]
        if _is_kfra_partial(acc):
            partials = jax.tree.map(lambda x: x / n_total, acc["partials"])
            return meta["replay"](acc["gbar"], partials)
        return jax.tree.map(lambda x: x / n_total, acc)


# ---------------------------------------------------------------------------
# registry + deprecated string aliases
# ---------------------------------------------------------------------------

PSUM = PsumReducer()
CONCAT = ConcatReducer()
GRAM = GramReducer()
GRAM_PAIR = GramPairReducer()
KRON = KronReducer()
MOMENT_MERGE = MomentMergeReducer()
PMEAN = MeanReducer()

REDUCERS: Dict[str, Reducer] = {}


def register_reducer(reducer: Reducer) -> Reducer:
    """Add a reducer to the registry (enumerated by the protocol
    conformance tests; resolved by the deprecated string alias path)."""
    REDUCERS[reducer.name] = reducer
    return reducer


for _r in (PSUM, CONCAT, GRAM, GRAM_PAIR, KRON, MOMENT_MERGE, PMEAN):
    register_reducer(_r)


_ALIAS_REPLACEMENT = {
    "psum": "repro.core.reducers.PSUM",
    "concat": "repro.core.reducers.CONCAT",
    "gram": "repro.core.reducers.GRAM",
    "kron": "repro.core.reducers.KRON",
    "moment_merge": "repro.core.reducers.MOMENT_MERGE",
    "pmean": "repro.core.reducers.PMEAN",
}


def resolve_reducer(spec) -> Reducer:
    """Reducer instance for ``spec`` — a :class:`Reducer` passes through;
    a registered string name resolves as a *deprecated* alias."""
    if isinstance(spec, Reducer):
        return spec
    if isinstance(spec, str):
        if spec not in REDUCERS:
            raise ValueError(
                f"unknown reducer {spec!r}: registered reducers are "
                f"{sorted(REDUCERS)} (register_reducer adds new ones)")
        warnings.warn(
            f"string reduce specs are deprecated: reduce={spec!r} — "
            f"declare the Reducer instance instead "
            f"({_ALIAS_REPLACEMENT.get(spec, f'repro.core.reducers.REDUCERS[{spec!r}]')})",
            DeprecationWarning, stacklevel=3)
        return REDUCERS[spec]
    raise TypeError(f"reduce spec must be a Reducer or a registered "
                    f"string name, got {type(spec).__name__}")
