"""Loss functions with the derivative structure BackPACK needs.

For a loss ``L(θ) = (1/M) Σ_m ℓ(z_m, y_m)`` over M sample-units (images for
the paper's nets; tokens for LM heads), each loss exposes

  * ``value(z, y)``            — scalar mean loss,
  * ``grad(z, y)``             — cotangents dL/dz, already carrying the 1/M,
  * ``sqrt_hessian(z, y)``     — exact symmetric factorization ``S`` with
                                 ``S Sᵀ = ∇²_z L`` (paper Eq. 15), shape
                                 ``[C, *z.shape]`` (leading factor axis),
  * ``sqrt_hessian_mc(rng, z, y, k, sample_offset)`` — Monte-Carlo factor
                                 ``S̃`` (Eq. 20), shape ``[k, *z.shape]``;
                                 draws are keyed per *global* sample index
                                 (``sample_offset + n``) so batch-sharded
                                 sweeps reproduce single-device draws,
  * ``sqrt_hessian_chunk(z, y, lo, size)`` — a contiguous slice of the exact
                                 factor's leading axis, enabling class-chunked
                                 exact curvature at LM vocabulary scale,
  * ``hessian_mean(z, y)``     — batch-averaged loss Hessian (KFRA Eq. 24b).

The 1/M of the mean reduction is folded into the factors as 1/sqrt(M) so the
propagated quantities square back to the *objective's* curvature.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _num_units(y):
    return y.size


class CrossEntropyLoss:
    """Softmax cross-entropy over the last axis of ``z``; integer targets.

    ``z``: [..., C] logits.  ``y``: [...] int targets.  Mean over all target
    positions (paper Eq. 1; tokens for LMs).  Positions where ``y < 0`` are
    masked out (padding) and excluded from the mean.
    """

    name = "cross_entropy"

    def _mask_and_m(self, y):
        mask = (y >= 0)
        m = jnp.maximum(jnp.sum(mask), 1).astype(jnp.float32)
        return mask, m

    def num_units(self, y):
        """Raw mask-aware unit count (no ≥1 clamp — a fully padded shard
        reports 0).

        The sharded sweep lane psums this over the data axes to rescale
        shard-local factors to the global 1/M normalization — exact even
        when padding masks are uneven across shards; the lane re-applies
        the divide-by-zero clamp locally and globally itself.
        """
        return jnp.sum(y >= 0).astype(jnp.float32)

    def value(self, z, y):
        mask, m = self._mask_and_m(y)
        logp = jax.nn.log_softmax(z.astype(jnp.float32), axis=-1)
        picked = jnp.take_along_axis(
            logp, jnp.maximum(y, 0)[..., None], axis=-1
        )[..., 0]
        return -jnp.sum(picked * mask) / m

    def grad(self, z, y):
        mask, m = self._mask_and_m(y)
        p = jax.nn.softmax(z.astype(jnp.float32), axis=-1)
        onehot = jax.nn.one_hot(jnp.maximum(y, 0), z.shape[-1], dtype=p.dtype)
        g = (p - onehot) * mask[..., None] / m
        return g.astype(z.dtype)

    # -- exact symmetric factorization ---------------------------------------
    #
    # The loss Hessian over z = [N, U, C] (U = token/unit axes flattened) is
    # block-diagonal over (n, u).  The exact factor therefore needs one
    # column per (unit u, class c): column (u,c) at sample n is
    #     S[(u,c), n, u', v] = δ_{u,u'} · √p_c (e_c − p)_v / √m.
    # Leading factor axis has size U·C (u-major).  For MLPs U=1 this is the
    # paper's [C×C] factor.  The U·C growth is precisely why exact-factor
    # curvature cannot scale to sequence models and the MC factor (one
    # column per unit, cross-unit terms vanish in expectation) is the
    # practical path — the paper's CIFAR-100 argument, magnified.

    def n_exact_cols(self, z):
        C = z.shape[-1]
        U = int(z.size // (z.shape[0] * C))
        return U * C

    def sqrt_hessian(self, z, y):
        return self.sqrt_hessian_chunk(z, y, 0, self.n_exact_cols(z))

    def sqrt_hessian_chunk(self, z, y, lo, size):
        """Columns [lo, lo+size) of the exact factor's leading (U·C) axis."""
        mask, m = self._mask_and_m(y)
        C = z.shape[-1]
        N = z.shape[0]
        U = int(z.size // (N * C))
        zf = z.reshape(N, U, C)
        maskf = mask.reshape(N, U)
        p = jax.nn.softmax(zf.astype(jnp.float32), axis=-1)
        sp = jnp.sqrt(p)
        cols = lo + jnp.arange(size)
        valid = (cols < U * C).astype(p.dtype)
        cols_c = jnp.minimum(cols, U * C - 1)
        u_idx = cols_c // C
        c_idx = cols_c % C
        onehot_u = jax.nn.one_hot(u_idx, U, dtype=p.dtype)       # [size, U]
        onehot_c = jax.nn.one_hot(c_idx, C, dtype=p.dtype)       # [size, C]
        # gather per-column quantities at the column's unit
        p_u = p[:, u_idx, :]                                      # [N, size, C]
        sp_uc = jnp.take_along_axis(
            sp[:, u_idx, :], c_idx[None, :, None], axis=-1, mode="clip"
        )[..., 0]                                                 # [N, size]
        col = sp_uc[..., None] * (onehot_c[None] - p_u)           # [N, size, C]
        col = col * maskf[:, u_idx][..., None]
        S = onehot_u.T[None, :, :, None] * col[:, None, :, :]     # [N, U, size, C]
        S = jnp.moveaxis(S, 2, 0)                                 # [size, N, U, C]
        S = S * valid[:, None, None, None] / jnp.sqrt(m)
        return S.reshape((size,) + z.shape).astype(z.dtype)

    def sqrt_hessian_mc(self, rng, z, y, k=1, sample_offset=0):
        """MC factor with *per-sample* PRNG streams.

        Sample ``n`` draws from ``fold_in(rng, sample_offset + n)`` — the
        draws depend only on a sample's global index, never on the batch
        shape, so a batch-sharded sweep (each shard passing its global
        offset) reproduces the single-device factorization bit-for-bit.
        """
        mask, m = self._mask_and_m(y)
        p = jax.nn.softmax(z.astype(jnp.float32), axis=-1)
        keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            rng, sample_offset + jnp.arange(z.shape[0]))

        def draw(key, zn, yn):
            return jax.random.categorical(key, zn, axis=-1,
                                          shape=(k,) + yn.shape)

        yhat = jax.vmap(draw)(keys, z.astype(jnp.float32), y)  # [N, k, ...]
        yhat = jnp.moveaxis(yhat, 1, 0)                        # [k, N, ...]
        onehot = jax.nn.one_hot(yhat, z.shape[-1], dtype=p.dtype)
        S = (p[None] - onehot) * mask[None, ..., None]
        S = S / jnp.sqrt(m * k)
        return S.astype(z.dtype)

    def hessian_mean(self, z, y):
        """(1/m) Σ ∇²ℓ — KFRA initialization (Eq. 24b). [C, C]."""
        mask, m = self._mask_and_m(y)
        p = jax.nn.softmax(z.astype(jnp.float32), axis=-1)
        p = p * mask[..., None]
        pf = p.reshape(-1, z.shape[-1])
        H = jnp.einsum("mc,cd->cd", pf, jnp.eye(z.shape[-1], dtype=pf.dtype)) \
            - pf.T @ pf
        return H / m

    def hessian_vec(self, z, y, v):
        """∇²_z L applied to v (same shape as z) — oracle/testing helper."""
        mask, m = self._mask_and_m(y)
        p = jax.nn.softmax(z.astype(jnp.float32), axis=-1)
        v32 = v.astype(jnp.float32)
        hv = p * v32 - p * jnp.sum(p * v32, axis=-1, keepdims=True)
        return (hv * mask[..., None] / m).astype(z.dtype)


class MSELoss:
    """0.5‖z − y‖² summed over the last axis, mean over the rest."""

    name = "mse"

    def num_units(self, y):
        """M of the 1/M mean normalization (see CrossEntropyLoss)."""
        return jnp.float32(max(int(jnp.size(y) // y.shape[-1]), 1))

    def value(self, z, y):
        m = max(int(jnp.size(y) // y.shape[-1]), 1)
        return 0.5 * jnp.sum((z.astype(jnp.float32) - y) ** 2) / m

    def grad(self, z, y):
        m = max(int(jnp.size(y) // y.shape[-1]), 1)
        return ((z.astype(jnp.float32) - y) / m).astype(z.dtype)

    def n_exact_cols(self, z):
        C = z.shape[-1]
        U = int(z.size // (z.shape[0] * C))
        return U * C

    def sqrt_hessian(self, z, y):
        return self.sqrt_hessian_chunk(z, y, 0, self.n_exact_cols(z))

    def sqrt_hessian_chunk(self, z, y, lo, size):
        """Column (u,c) = δ_{u,u'} e_c / √m  (u-major leading axis)."""
        m = max(int(jnp.size(y) // y.shape[-1]), 1)
        C = z.shape[-1]
        N = z.shape[0]
        U = int(z.size // (N * C))
        cols = lo + jnp.arange(size)
        valid = (cols < U * C).astype(jnp.float32)
        cols_c = jnp.minimum(cols, U * C - 1)
        onehot_u = jax.nn.one_hot(cols_c // C, U, dtype=jnp.float32)
        onehot_c = jax.nn.one_hot(cols_c % C, C, dtype=jnp.float32)
        S = onehot_u[:, None, :, None] * onehot_c[:, None, None, :]
        S = jnp.broadcast_to(S, (size, N, U, C)) * valid[:, None, None, None]
        return (S / jnp.sqrt(float(m))).reshape((size,) + z.shape).astype(z.dtype)

    def sqrt_hessian_mc(self, rng, z, y, k=1, sample_offset=0):
        m = max(int(jnp.size(y) // y.shape[-1]), 1)
        # E[s sᵀ] = I via Rademacher vectors; per-sample streams keyed by
        # the global sample index (see CrossEntropyLoss.sqrt_hessian_mc)
        # keep the draws invariant under batch sharding.
        keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            rng, sample_offset + jnp.arange(z.shape[0]))
        s = jax.vmap(
            lambda key, zn: jax.random.rademacher(
                key, (k,) + zn.shape, dtype=jnp.float32)
        )(keys, z)
        s = jnp.moveaxis(s, 1, 0)
        return (s / jnp.sqrt(float(m * k))).astype(z.dtype)

    def hessian_mean(self, z, y):
        # per-position Hessian of 0.5‖z−y‖² is I; its mean over positions is I.
        return jnp.eye(z.shape[-1], dtype=jnp.float32)

    def hessian_vec(self, z, y, v):
        m = max(int(jnp.size(y) // y.shape[-1]), 1)
        return v / m
