"""Generalized backprop engine — one forward pass, K extension sweeps.

``run(model, params, batch, loss, extensions, ...)`` returns

  ``Results(loss, grads, ext)`` with ``ext[name]`` a pytree mirroring the
  params structure (per-module stats), plus the raw per-sweep byproducts the
  optimizers consume (Kronecker factor pairs, GGN diagonals, ...).

Sweep plan (decided statically from the requested extensions):

  first      cotangent sweep — batch gradient + all first-order stats +
             KFAC/KFLR A-factors (they only need layer inputs).  Always runs.
  ggn_exact  exact loss-Hessian factor ``S`` (Eq. 15/18).  When
             ``cfg.class_chunk`` is set, the factor's leading axis is
             processed in chunks of that size under ``lax.scan`` — exact
             curvature at LM-vocabulary scale with bounded memory
             (beyond-paper: the paper stops at C=100).
  ggn_mc     Monte-Carlo factor ``S̃`` (Eq. 20) — the KFAC trick; cost is
             ~1 extra gradient-like sweep per MC sample.
  kfra       averaged ``Ḡ`` recursion (Eq. 24); chain models only.
  hess       exact Hessian diagonal with residual ± factors (Eq. 25/26);
             chain models only.

The whole engine is pure-functional and jit/pjit-compatible: the caller may
wrap ``run`` in ``jax.jit`` with sharded inputs.

Scale-out lanes (both driven by the extensions' declared ``reduce`` specs):

  ``SweepPlan.shard(mesh, axes)``      split the batch over devices
                                       (``shard_map``; cross-shard
                                       collectives per reduce spec)
  ``SweepPlan.accumulate(k)``          stream the batch over k sequential
                                       microbatches (``lax.scan``; the same
                                       reduce specs as running accumulators)
  ``plan.shard(mesh).accumulate(k)``   both: the shard × accumulate grid

The accumulated lane additionally has a preemption-safe form: the plan's
``stream(...)`` method returns a :class:`SweepStream` — the identical
slice schedule driven step by step from the host, whose accumulator state
is a checkpointable pytree of arrays.  ``run_checkpointed(...)`` /
``resume(...)`` drive it with snapshots through a checkpointer (see
``repro.train.checkpoint.SweepCheckpointer``), restart-exact and elastic
across device-mesh changes.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs

try:  # jax >= 0.5 exposes it at top level
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

from .extensions import (
    Extension,
    ExtensionConfig,
    FusedMask,
    FusedSecondMask,
    by_name,
    first_order_mask,
    reduce_spec,
    second_order_mask,
    sweeps_needed,
)
from .module import Module
from .reducers import (
    PSUM,
    Reducer,
    _chan_merge,
    merge_stat_trees as _merge_stat_trees,
)
from ..sharding.rules import GRAM_ASSEMBLY_MODES, gram_assembly_spec


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """Static per-call sweep plan, decided once from the extension set.

    ``fused_mask`` is the fused first-order kernel's extension mask and
    ``fused_second_mask`` the fused curvature kernel's — the reductions
    each kernel emits for this extension set; ``fused_active`` says whether
    the config actually routes through them (kernels on AND fused on).
    Together they make the paper's "K quantities, one backward pass" claim
    explicit and inspectable (``plan_sweeps(...)`` is public for
    tests/benchmarks).

    The plan is extension-level *intent*: layer stat hooks re-derive the
    same masks (``first_order_mask`` / ``second_order_mask`` are pure) but
    may specialize on tape shapes the plan cannot see — rank-1 (R==1)
    layers skip both fused launches for the cheaper closed forms (see
    ``dense_first_order_stats`` / ``dense_curv_stats``).
    """

    names: frozenset
    sweeps: frozenset
    first_exts: tuple
    kron_exts: tuple
    fused_mask: FusedMask
    fused_active: bool
    fused_second_mask: FusedSecondMask = FusedSecondMask()

    def describe(self) -> str:
        passes = 1 + sum(s in self.sweeps
                         for s in ("ggn_exact", "ggn_mc", "jac", "kfra",
                                   "hess"))
        fused = [k for k in ("l2", "moment", "dot")
                 if getattr(self.fused_mask, k)]
        lane = fused if self.fused_active and fused else None
        # The second-order lane reports the *planned* kernel outputs for the
        # extension set regardless of config (the curvature lane is what a
        # plan is usually inspected for); `fused_active` says whether this
        # config routes both lanes through the fused kernels.
        second = [k for k in ("diag", "kron", "trace")
                  if getattr(self.fused_second_mask, k)]
        structures = list(self.posterior_structures())
        return (f"sweeps={sorted(self.sweeps) or ['first']} "
                f"passes={passes} fused_first_order={lane} "
                f"fused_second_order={second or None} "
                f"fused_active={self.fused_active} "
                f"laplace={structures or None}")

    def posterior_structures(self) -> tuple:
        """Laplace posterior structures this sweep plan can fit.

        ``'diag'`` needs a GGN diagonal (DiagGGN / DiagGGNMC), ``'kron'``
        Kronecker factors (KFLR / KFAC); ``'last_layer'`` restricts either
        to the final Dense layer, so it is available whenever any structure
        is.  ``repro.laplace`` validates fits against this — a misconfigured
        fit fails with this list in the message instead of a shape error.
        """
        out = []
        if self.names & {"diag_ggn", "diag_ggn_mc"}:
            out.append("diag")
        if self.names & {"kflr", "kfac"}:
            out.append("kron")
        if out:
            out.append("last_layer")
        return tuple(out)


    def shard(self, mesh, axes=("data",),
              gram_assembly: str = "split") -> "ShardedSweepPlan":
        """Bind this plan to a device mesh: the batch-sharded sweep lane.

        ``axes`` names the mesh axis (or axes) the batch is split over;
        the returned :class:`ShardedSweepPlan` runs the same sweeps under
        ``shard_map`` — fused kernels on each shard's local batch, then
        the per-extension ``reduce`` specs combine the shards (see
        ``ShardedSweepPlan.describe()`` for the placement report).

        ``gram_assembly`` picks the distributed layout of pairwise (Gram /
        empirical NTK) outputs: ``'split'`` leaves each shard its row
        block (sharded axis 0, no extra communication), ``'all'``
        all-gathers the full [N, N] matrix onto every shard, ``'master'``
        materializes it on the first shard only (the others hold zeros
        under a leading device axis).
        """
        if isinstance(axes, str):
            axes = (axes,)
        gram_assembly_spec(gram_assembly, axes)  # validate the mode early
        return ShardedSweepPlan(plan=self, mesh=mesh, axes=tuple(axes),
                                gram_assembly=gram_assembly)

    def accumulate(self, num_microbatches: int) -> "AccumulatedSweepPlan":
        """Bind this plan to a microbatch schedule: the streaming lane.

        The returned :class:`AccumulatedSweepPlan` runs the identical
        sweep once per microbatch slice under a ``lax.scan`` driver,
        folding results through each extension's ``reduce`` spec
        reinterpreted as a *sequential* accumulator — effective batches
        far beyond device memory, matching the monolithic sweep.
        Composes with sharding: ``plan.shard(mesh).accumulate(k)`` is the
        shard × accumulate grid.

        Parameters
        ----------
        num_microbatches : int
            Number of sequential slices the batch is split into (each of
            ``ceil(N / num_microbatches)`` samples; the final slice may
            be smaller).
        """
        return AccumulatedSweepPlan(plan=self,
                                    num_microbatches=int(num_microbatches))

    def run(self, model, params, inputs, targets, loss,
            cfg: Optional[ExtensionConfig] = None,
            rng: Optional[jax.Array] = None) -> Results:
        """Run the monolithic sweep for this plan's extensions — the
        plan-object counterpart of :func:`run`, giving all three lanes
        (monolithic / sharded / accumulated) one calling convention."""
        extensions = tuple(by_name(n) for n in sorted(self.names))
        with obs.span("engine/sweep", lane="monolithic",
                      extensions=",".join(sorted(self.names))):
            return run(model, params, inputs, targets, loss,
                       extensions=extensions, cfg=cfg, rng=rng)


def plan_sweeps(extensions: Sequence[Extension],
                cfg: Optional[ExtensionConfig] = None) -> SweepPlan:
    """Build the static sweep plan for a set of requested extensions.

    Parameters
    ----------
    extensions : sequence of Extension
        The quantities to extract (``repro.core.BatchGrad`` etc.).
    cfg : ExtensionConfig, optional
        Only ``use_kernels`` / ``use_fused`` are consulted (they decide
        ``fused_active``); sweep structure depends on the extensions
        alone.

    Returns
    -------
    SweepPlan
        The static schedule: which backward sweeps run, which fused
        kernel outputs they request, and the scale-out entry points
        (:meth:`SweepPlan.shard`, :meth:`SweepPlan.accumulate`).
        ``plan.describe()`` renders it for inspection.
    """
    cfg = cfg or ExtensionConfig()
    first_exts = tuple(e for e in extensions if e.sweep == "first")
    return SweepPlan(
        names=frozenset(e.name for e in extensions),
        sweeps=frozenset(sweeps_needed(extensions)),
        first_exts=first_exts,
        # KFAC/KFLR A-factors are harvested during the first sweep:
        kron_exts=tuple(e for e in extensions if e.name in ("kfac", "kflr")),
        fused_mask=first_order_mask(first_exts),
        fused_active=cfg.use_kernels and cfg.use_fused,
        fused_second_mask=second_order_mask(extensions),
    )


def plan_for_batch(extensions, cfg, n, mesh=None, shard_axes=("data",),
                   microbatch_size=None):
    """Compose the right sweep lane for a batch of ``n`` samples.

    The single place consumers (the extended train step, the Laplace
    fits) derive their lane composition from: shard over ``mesh`` when
    one is given, accumulate when a microbatch size (argument, or
    ``cfg.microbatch_size``) asks for more than one slice.
    ``microbatch_size`` bounds the rows a *device* sweeps per sequential
    slice — under a mesh the grid already splits the batch over shards,
    so the count comes from the shard-local batch (a shard whose rows
    already fit the bound accumulates nothing).  Returns a plan object
    with the uniform ``.run(model, params, inputs, targets, loss, cfg=,
    rng=)`` contract — a plain :class:`SweepPlan`, a
    :class:`ShardedSweepPlan`, an :class:`AccumulatedSweepPlan`, or the
    shard × accumulate grid.
    """
    cfg = cfg or ExtensionConfig()
    plan = plan_sweeps(extensions, cfg)
    n_dev = n
    if mesh is not None:
        plan = plan.shard(mesh, shard_axes)
        n_dev = max(1, n // plan.n_shards)
    mb = microbatch_size or cfg.microbatch_size
    k = -(-n_dev // mb) if mb else 1
    if k > 1:
        plan = plan.accumulate(k)
    return plan


@dataclasses.dataclass
class Results:
    loss: jnp.ndarray
    grads: Any
    logits: Any
    ext: Dict[str, Any]

    def __getitem__(self, k):
        return self.ext[k]


def _tree_add(a, b):
    if a is None:
        return b
    return jax.tree.map(jnp.add, a, b)


def _zip_stats(fn, st, gr):
    """Map fn over (stats, grads) in parallel, tolerating () stat holes
    (buffers / raw mixer params that have gradients but no per-sample
    statistics)."""
    if st is None or (isinstance(st, tuple) and len(st) == 0):
        return ()
    if isinstance(st, dict):
        return {
            k: _zip_stats(fn, v, gr.get(k) if isinstance(gr, dict) else None)
            for k, v in st.items()
        }
    if isinstance(st, (tuple, list)):
        gr_t = gr if isinstance(gr, (tuple, list)) else (None,) * len(st)
        return tuple(_zip_stats(fn, s, g) for s, g in zip(st, gr_t))
    return fn(st, gr)


# ---------------------------------------------------------------------------
# batch-sharded sweep lane (SweepPlan.shard)
# ---------------------------------------------------------------------------


def _axis_count(axes):
    """Number of shards over the named mesh axes (inside shard_map)."""
    return jax.lax.psum(1, tuple(axes))


def _global_sample_offset(axes, n_local):
    """Global index of this shard's first sample.

    ``shard_map`` splits axis 0 major-to-minor over ``axes``; the linear
    shard index times the local batch recovers the single-device sample
    numbering (what the per-sample MC streams are keyed on).
    """
    idx = 0
    for ax in axes:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return idx * n_local


class _ScaledLoss:
    """Loss adapter correcting a partial batch's 1/M normalization.

    Every loss here normalizes by the number M of sample units; a body
    that only sees part of the batch — a shard's rows under ``shard_map``
    (the sharded lane), a microbatch slice (the accumulated lane), or
    both — gets cotangents/factors scaled by 1/M_local instead of
    1/M_global.  This adapter rescales by ``ml / mg``:

    * ``axes`` set, no ``total_units``: the sharded lane — M_global is
      the psum of the raw local counts over the data axes, and MC factors
      get the shard's global sample offset so the per-sample PRNG streams
      line up with the single-device draws.
    * ``total_units`` set: the accumulated lane — M_global over the whole
      accumulated batch is computed once by the driver from the full
      targets and passed in (a psum inside one microbatch could only see
      that microbatch's units).  The driver also supplies the complete
      ``sample_offset`` (shard base + microbatch start), so no implicit
      shard offset is added.

    ``value``/``hessian_mean`` return the partial batch's *contribution*
    (already psum'd across shards when ``axes`` is set); under the
    accumulated lane the driver sums contributions over microbatches.
    Per-sample quantities then match their monolithic single-device
    counterparts exactly, even when padding masks leave unit counts
    uneven across shards or microbatches.
    """

    def __init__(self, base, axes=(), total_units=None, sample_offset=0):
        self.base = base
        self.axes = tuple(axes or ())
        self.total_units = total_units
        self.sample_offset = sample_offset

    def __getattr__(self, name):
        return getattr(self.base, name)

    def _psum(self, x):
        return jax.lax.psum(x, self.axes) if self.axes else x

    def _m(self, y):
        # num_units is the *raw* count — a fully padded shard reports 0.
        # The local clamp must mirror the base loss's own ≥1 clamp (that
        # is what its outputs were divided by); the global clamp only
        # guards the degenerate everything-masked batch.
        raw = self.base.num_units(y)
        ml = jnp.maximum(raw, 1.0)
        if self.total_units is not None:
            mg = jnp.maximum(self.total_units, 1.0)
        else:
            mg = jnp.maximum(self._psum(raw), 1.0)
        return ml, mg

    def value(self, z, y):
        ml, mg = self._m(y)
        return self._psum(self.base.value(z, y) * ml) / mg

    def grad(self, z, y):
        ml, mg = self._m(y)
        g = self.base.grad(z, y)
        return (g.astype(jnp.float32) * (ml / mg)).astype(g.dtype)

    def n_exact_cols(self, z):
        return self.base.n_exact_cols(z)

    def _offset(self, z):
        off = self.sample_offset
        if self.axes and self.total_units is None:
            off = off + _global_sample_offset(self.axes, z.shape[0])
        return off

    def sqrt_hessian(self, z, y):
        return self.sqrt_hessian_chunk(z, y, 0, self.n_exact_cols(z))

    def sqrt_hessian_chunk(self, z, y, lo, size):
        ml, mg = self._m(y)
        S = self.base.sqrt_hessian_chunk(z, y, lo, size)
        return (S.astype(jnp.float32) * jnp.sqrt(ml / mg)).astype(S.dtype)

    def sqrt_hessian_mc(self, rng, z, y, k=1, sample_offset=0):
        ml, mg = self._m(y)
        off = sample_offset + self._offset(z)
        S = self.base.sqrt_hessian_mc(rng, z, y, k, sample_offset=off)
        return (S.astype(jnp.float32) * jnp.sqrt(ml / mg)).astype(S.dtype)

    def hessian_mean(self, z, y):
        ml, mg = self._m(y)
        return self._psum(self.base.hessian_mean(z, y) * ml) / mg

    def hessian_vec(self, z, y, v):
        # Per-sample like ``grad``: rescale this partial batch's 1/M_local
        # to 1/M_global, no psum (matrix-free products psum the final
        # parameter-space result themselves).
        ml, mg = self._m(y)
        hv = self.base.hessian_vec(z, y, v)
        return (hv.astype(jnp.float32) * (ml / mg)).astype(hv.dtype)


def _default_rng(sweeps, cfg, rng):
    """MC-sweep rng defaulting shared by every lane: an explicit key wins,
    else ``cfg.mc_seed`` (deterministic sweeps), else an error when an MC
    extension actually needs draws — and an unused placeholder key when
    none does."""
    if rng is not None:
        return rng
    if "ggn_mc" in sweeps:
        if cfg.mc_seed is None:
            raise ValueError(
                "MC extensions need an rng key: pass rng= or set "
                "ExtensionConfig(mc_seed=...) for deterministic sweeps")
        return jax.random.PRNGKey(cfg.mc_seed)
    return jax.random.PRNGKey(0)  # unused without an MC sweep


def _moment_triple(sum_g2, grad_sum, n):
    """(count, mean, M2) triple from a partial batch's (Σg², Σg)."""
    nl = jnp.float32(n)
    g1 = grad_sum.astype(jnp.float32)
    return nl, g1 / nl, sum_g2 - g1 ** 2 / nl


def _sharded_moment_triple(sum_g2, grad_local, n_local, axes):
    """Global (count, mean, M2) triple across shards, moment-merge style.

    Each shard contributes its local (Σg, Σg²) as a (count, mean, M2)
    triple; a binary tree of :func:`_chan_merge` steps combines the
    all-gathered triples without ever forming the catastrophically
    cancelling global Σg² − (Σg)²/n difference between large
    intermediates.  ``n·M2`` of the result equals the engine's
    single-device ``n·Σg² − (Σg)²`` in exact arithmetic.
    """
    g1 = jax.lax.all_gather(grad_local.astype(jnp.float32), tuple(axes))
    g2 = jax.lax.all_gather(sum_g2, tuple(axes))
    parts = [_moment_triple(g2[i], g1[i], n_local)
             for i in range(g1.shape[0])]
    while len(parts) > 1:
        merged = [_chan_merge(parts[i], parts[i + 1])
                  for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            merged.append(parts[-1])
        parts = merged
    return parts[0]


def _sharded_variance(sum_g2, grad_local, n_local, axes):
    """Global gradient variance across shards: ``n·M2`` of the merged
    triple (see :func:`_sharded_moment_triple`)."""
    n, _, m2 = _sharded_moment_triple(sum_g2, grad_local, n_local, axes)
    return n * m2


def _reduce_sharded(grads, ext, extensions, axes):
    """Apply each extension's declared cross-shard reducer (inside
    shard_map) — one :meth:`Reducer.shard_reduce` call per extension;
    gradients are always psum'd.  Local-row reducers (concat / gram) are
    identity here: the sharded out-specs concatenate their sample rows,
    and moment-merge outputs are already global (see
    :func:`_sharded_variance`)."""
    red = reduce_spec(extensions)
    out = {name: red.get(name, PSUM).shard_reduce(tree, axes)
           for name, tree in ext.items()}
    grads = jax.tree.map(lambda x: jax.lax.psum(x, axes), grads)
    return grads, out


def _assemble_gram(tree, mode, axes):
    """Distributed assembly of a pairwise row-block tree inside shard_map.

    ``'split'`` keeps each shard's row block (sharded axis 0 — the
    default, zero extra communication).  ``'all'`` all-gathers the row
    blocks so every shard holds the full [N, N, ...] matrix.
    ``'master'`` gathers too but zeros every shard except linear shard 0,
    under a fresh leading device axis: stacked by the sharded out-spec,
    ``out[0]`` is the full matrix and the other entries are zeros (the
    asdfghjkl-style master layout, without broadcasting the O(N²) result
    back to every host).
    """
    if mode == "split":
        return tree

    def asm(x):
        full = jax.lax.all_gather(x, tuple(axes), axis=0, tiled=True)
        if mode == "all":
            return full
        idx = 0
        for ax in axes:
            idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
        return jnp.where(idx == 0, full, jnp.zeros_like(full))[None]

    return jax.tree.map(asm, tree)


def _assemble_pairwise_ext(ext, red, mode, axes):
    """Apply :func:`_assemble_gram` to every pairwise extension entry."""
    if mode == "split":
        return ext
    return {nm: (_assemble_gram(t, mode, axes)
                 if red.get(nm, PSUM).pairwise else t)
            for nm, t in ext.items()}


@dataclasses.dataclass(frozen=True)
class ShardedSweepPlan:
    """A :class:`SweepPlan` bound to a device mesh — the batch-sharded lane.

    ``run`` wraps the whole engine sweep in ``shard_map`` over the data
    axes: the forward/backward (and the fused Pallas kernel launches
    inside it) run on each device's local batch shard, then the
    per-extension ``reduce`` specs combine the shards — psum for
    batch-summed curvature, pmean/psum factor pairs for KFAC/KFLR,
    all-gathered Gram rows for pairwise dots, a pairwise moment merge for
    the variance, and plain row concatenation (via the sharded out-specs)
    for per-sample statistics.  Results are numerically equivalent to the
    single-device sweep (exactly, up to accumulation order).
    """

    plan: SweepPlan
    mesh: Any
    axes: tuple
    gram_assembly: str = "split"

    @property
    def n_shards(self) -> int:
        s = 1
        for ax in self.axes:
            s *= self.mesh.shape[ax]
        return s

    def reduce_specs(self) -> dict:
        """``{extension name: cross-shard reducer}`` for this plan."""
        return reduce_spec([by_name(n) for n in sorted(self.plan.names)])

    def check_batch(self, n: int) -> None:
        """Raise unless the global batch splits evenly over the shards."""
        if n % self.n_shards:
            raise ValueError(
                f"global batch {n} is not divisible by {self.n_shards} "
                f"shards over mesh axes {self.axes}")

    def describe(self) -> str:
        red = self.reduce_specs()
        _, gram_place = gram_assembly_spec(self.gram_assembly, self.axes)
        placement = ", ".join(
            f"{n}:{r.name}->" + (gram_place if r.pairwise else r.placement)
            for n, r in sorted(red.items()))
        mesh_shape = dict(zip(self.mesh.axis_names,
                              self.mesh.devices.shape))
        return (f"{self.plan.describe()} | shard_axes={list(self.axes)} "
                f"shards={self.n_shards} mesh={mesh_shape} "
                f"reduce=[{placement}] "
                f"grads:psum->replicated logits:concat->sharded(axis0)")

    def run(self, model, params, inputs, targets, loss,
            cfg: Optional[ExtensionConfig] = None,
            rng: Optional[jax.Array] = None) -> Results:
        """The sharded analogue of :func:`run` — same signature minus
        ``extensions`` (the plan carries them), same Results contract."""
        cfg = dataclasses.replace(cfg or ExtensionConfig(),
                                  shard_axes=tuple(self.axes))
        extensions = tuple(by_name(n) for n in sorted(self.plan.names))
        self.check_batch(jax.tree.leaves(inputs)[0].shape[0])
        rng = _default_rng(self.plan.sweeps, cfg, rng)

        batch = P(tuple(self.axes))
        red = self.reduce_specs()
        gram_spec, _ = gram_assembly_spec(self.gram_assembly, self.axes)
        ext_specs = {}
        for name in self.plan.names:
            r = red[name]
            ext_specs[name] = (gram_spec if r.pairwise
                               else batch if r.local_rows else P())

        def body(p, x, y, key):
            res = run(model, p, x, y, loss, extensions=extensions, cfg=cfg,
                      rng=key)
            ext = _assemble_pairwise_ext(res.ext, red, self.gram_assembly,
                                         self.axes)
            return res.loss, res.grads, res.logits, ext

        fn = _shard_map(body, mesh=self.mesh,
                        in_specs=(P(), batch, batch, P()),
                        out_specs=(P(), P(), batch, ext_specs),
                        check_rep=False)
        with obs.span("engine/sweep", lane="sharded", shards=self.n_shards,
                      extensions=",".join(sorted(self.plan.names))):
            loss_val, grads, logits, ext = fn(params, inputs, targets, rng)
        return Results(loss=loss_val, grads=grads, logits=logits, ext=ext)

    def accumulate(self, num_microbatches: int) -> "AccumulatedSweepPlan":
        """Stack the sequential lane on top of this sharded plan: the
        shard × accumulate grid.  Each device scans over
        ``num_microbatches`` slices of its local batch rows; see
        :meth:`SweepPlan.accumulate`."""
        return AccumulatedSweepPlan(plan=self.plan,
                                    num_microbatches=int(num_microbatches),
                                    sharded=self)


# ---------------------------------------------------------------------------
# streaming accumulated sweep lane (SweepPlan.accumulate)
# ---------------------------------------------------------------------------

def _run_accumulated(model, params, inputs, targets, loss, extensions,
                     cfg, rng, num_microbatches, base_offset=0, n_shards=1):
    """Sequential microbatch driver: the identical sweep per slice, folded
    through the extensions' :class:`Reducer` protocols as sequential
    accumulators (``init`` / ``update`` per slice, ``finalize`` once).

    Runs either at top level (single-device accumulated lane) or inside a
    ``shard_map`` shard body (``cfg.shard_axes`` set — the shard ×
    accumulate grid, where ``inputs`` are this shard's local rows,
    ``base_offset`` its first global sample index and ``n_shards`` the
    grid width).  ``cfg`` must already carry ``total_units`` /
    ``total_batch`` / ``accum_stats``.

    The batch splits into ``ceil(n / k)``-row slices: every full slice
    runs under one ``lax.scan`` (bounded memory, one trace), an uneven
    final slice runs as a separate step.  Reducers dispatch by
    capability: ``streams_rows`` outputs ride the scan stack and
    concatenate in sample order; ``pairwise`` (Gram / NTK) outputs
    stream as row blocks — the main scan yields each slice's *diagonal*
    block, extra pair passes (one per slice pair, also scanned) fill the
    off-diagonal blocks, and every block is scattered into a zero
    [n, S·n, ...] accumulator, so peak factor memory stays at two
    microbatches; everything else folds through ``update``.  Returns
    ``(loss, grads, logits, ext)``.
    """
    red = reduce_spec(extensions)
    pair_names = [e.name for e in extensions if red[e.name].pairwise]
    concat_names = [e.name for e in extensions if red[e.name].streams_rows]
    carry_names = [e.name for e in extensions
                   if not (red[e.name].pairwise or red[e.name].streams_rows)]
    n = jax.tree.leaves(inputs)[0].shape[0]
    k = max(1, min(int(num_microbatches), n))
    m = -(-n // k)          # slice rows (ceil); last slice may be smaller
    k_full = n // m
    rem = n - k_full * m
    sharded = bool(cfg.shard_axes)

    def slice_run(p, key, x_i, y_i, off):
        cfg_i = dataclasses.replace(cfg, sample_offset=off)
        res = run(model, p, x_i, y_i, loss, extensions=extensions,
                  cfg=cfg_i, rng=key)
        carry_ext = {nm: res.ext[nm] for nm in carry_names}
        cat_ext = {nm: res.ext[nm] for nm in concat_names}
        pair_ext = {nm: res.ext[nm] for nm in pair_names}
        return (res.loss, res.grads, carry_ext, res.logits, cat_ext,
                pair_ext)

    def head(a):
        return a[:m]

    zshape = jax.eval_shape(slice_run, params, rng,
                            jax.tree.map(head, inputs),
                            jax.tree.map(head, targets), 0)
    zeros = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), zshape[:3])
    zero = (zeros[0], zeros[1],
            {nm: red[nm].init(zeros[2][nm]) for nm in carry_names})

    # Pairwise (Gram-family) accumulators: one [n, S, n, ...] buffer per
    # stat leaf (S = n_shards), scatter-filled block by block and reshaped
    # to the row-block layout [n, S·n, ...] at the end.  A streamed
    # block's column axis is already the shard-gathered S·rows, so the
    # middle shard axis lines the scattered columns up with the
    # shard-major global sample order.
    def pair_zero(s):
        return jnp.zeros((n, n_shards, n) + s.shape[2:], s.dtype)

    pair_acc = {nm: jax.tree.map(pair_zero, zshape[5][nm])
                for nm in pair_names}

    def split(a):
        return a[:k_full * m].reshape((k_full, m) + a.shape[1:])

    xs = (jax.tree.map(split, inputs), jax.tree.map(split, targets),
          base_offset + m * jnp.arange(k_full))

    def body(carry, xs_i):
        x_i, y_i, off = xs_i
        lv, g, cext, z, yext, pext = slice_run(params, rng, x_i, y_i, off)
        a_lv, a_g, a_ext = carry
        meta = {"weight": float(m)}
        carry = (a_lv + lv, jax.tree.map(jnp.add, a_g, g),
                 {nm: red[nm].update(a_ext[nm], cext[nm], meta)
                  for nm in carry_names})
        return carry, (z, yext, pext)

    with jax.named_scope(f"accumscan_T{k_full}"):
        (lv, grads, c_ext), (zs, ys, ps) = jax.lax.scan(body, zero, xs)

    def unstack(a):
        return a.reshape((k_full * a.shape[1],) + a.shape[2:])

    logits = jax.tree.map(unstack, zs)
    cat_ext = {nm: jax.tree.map(unstack, ys[nm]) for nm in concat_names}

    # Diagonal blocks rode the scan stack: block t is rows
    # [t·m, (t+1)·m) against its own gathered columns.
    def scatter_diag(acc, blocks):
        for t in range(k_full):
            b = blocks[t].reshape((m, n_shards, m) + blocks.shape[3:])
            acc = acc.at[t * m:(t + 1) * m, :, t * m:(t + 1) * m].set(
                b.astype(acc.dtype))
        return acc

    pair_acc = {nm: jax.tree.map(scatter_diag, pair_acc[nm], ps[nm])
                for nm in pair_names}

    if rem:
        def tail(a):
            return a[k_full * m:]

        lv_r, g_r, cext_r, z_r, yext_r, pext_r = slice_run(
            params, rng, jax.tree.map(tail, inputs),
            jax.tree.map(tail, targets), base_offset + k_full * m)
        lv = lv + lv_r
        grads = jax.tree.map(jnp.add, grads, g_r)
        meta_r = {"weight": float(rem)}
        c_ext = {nm: red[nm].update(c_ext[nm], cext_r[nm], meta_r)
                 for nm in carry_names}
        cat = partial(jax.tree.map, lambda a, b: jnp.concatenate([a, b], 0))
        logits = cat(logits, z_r)
        cat_ext = {nm: cat(cat_ext[nm], yext_r[nm]) for nm in concat_names}

        def scatter_rem(acc, blk):
            b = blk.reshape((rem, n_shards, rem) + blk.shape[2:])
            o = k_full * m
            return acc.at[o:o + rem, :, o:o + rem].set(b.astype(acc.dtype))

        pair_acc = {nm: jax.tree.map(scatter_rem, pair_acc[nm], pext_r[nm])
                    for nm in pair_names}

    # Off-diagonal row blocks: one extra 2-slice sweep per (p, q) pair,
    # scanned over the pair index.  Single-device, cfg.cross_split makes
    # the layer hooks emit only the [m, rows_q, ...] cross block (half the
    # pair-pass FLOPs); sharded, the hooks gather as usual and the cross
    # blocks are cut out of the gathered columns (the within-pair diagonal
    # sub-blocks are redundant with the main scan and discarded).
    if pair_names and (k_full > 1 or (rem and k_full)):
        pair_exts = tuple(e for e in extensions if e.name in pair_names)

        def pair_run(off_p, off_q, rows_q):
            def cut(a):
                ap = jax.lax.dynamic_slice_in_dim(a, off_p, m, 0)
                aq = jax.lax.dynamic_slice_in_dim(a, off_q, rows_q, 0)
                return jnp.concatenate([ap, aq], 0)

            cfg_p = dataclasses.replace(
                cfg, sample_offset=0,
                cross_split=None if sharded else m)
            res = run(model, params, jax.tree.map(cut, inputs),
                      jax.tree.map(cut, targets), loss,
                      extensions=pair_exts, cfg=cfg_p, rng=rng)
            return res.ext

        def scatter_pair(acc, blk, off_p, off_q, rows_q, reducer):
            if sharded:
                b = blk.reshape((m + rows_q, n_shards, m + rows_q)
                                + blk.shape[2:])
                top = b[:m, :, m:]             # [m, S, rows_q, ...]
                bot = b[m:, :, :m]             # [rows_q, S, m, ...]
            else:
                top = blk[:, None]
                bot = reducer.transpose_block(blk)[:, None]
            tail0 = (0,) * (top.ndim - 3)
            acc = jax.lax.dynamic_update_slice(
                acc, top.astype(acc.dtype), (off_p, 0, off_q) + tail0)
            return jax.lax.dynamic_update_slice(
                acc, bot.astype(acc.dtype), (off_q, 0, off_p) + tail0)

        def pair_step(rows_q):
            def step(acc_tree, offs):
                off_p, off_q = offs[0], offs[1]
                pext = pair_run(off_p, off_q, rows_q)
                acc_tree = {
                    nm: jax.tree.map(
                        lambda a, b, r=red[nm]: scatter_pair(
                            a, b, off_p, off_q, rows_q, r),
                        acc_tree[nm], pext[nm])
                    for nm in pair_names}
                return acc_tree, None

            return step

        pairs = [(p * m, q * m)
                 for p in range(k_full) for q in range(p + 1, k_full)]
        if pairs:
            with jax.named_scope(f"gramscan_T{len(pairs)}"):
                pair_acc, _ = jax.lax.scan(
                    pair_step(m), pair_acc, jnp.asarray(pairs, jnp.int32))
        if rem:
            offs = jnp.stack(
                [m * jnp.arange(k_full, dtype=jnp.int32),
                 jnp.full((k_full,), k_full * m, jnp.int32)], axis=1)
            with jax.named_scope(f"gramscan_rem_T{k_full}"):
                pair_acc, _ = jax.lax.scan(pair_step(rem), pair_acc, offs)

    ext = {}
    meta_fin = {"total_batch": float(n), "total_units": cfg.total_units}
    if "kfra" in carry_names:
        # The reducer accumulates KFRA's global batch expectations
        # ({'gbar', 'partials'}); replaying the Ḡ recursion through the
        # layer stack is model structure, so the driver provides it.
        meta_fin["replay"] = lambda gbar, parts: _merge_stat_trees(
            model.kfra_apply(params, gbar, parts, extensions, cfg)[1],
            "kfra")
    for nm in carry_names:
        # spans here record at trace time when this driver runs under jit
        # or inside a shard_map body — still useful: finalize cost is
        # dominated by tracing/lowering for the kron/KFRA replays.
        with obs.span("engine/finalize", ext=nm, reducer=red[nm].name):
            ext[nm] = red[nm].finalize(c_ext[nm], meta_fin)
    ext.update(cat_ext)
    for nm in pair_names:
        ext[nm] = jax.tree.map(
            lambda a: a.reshape((n, n_shards * n) + a.shape[3:]),
            pair_acc[nm])
    return lv, grads, logits, ext


@dataclasses.dataclass(frozen=True)
class AccumulatedSweepPlan:
    """A :class:`SweepPlan` bound to a microbatch schedule — the streaming
    accumulated lane (optionally stacked on a :class:`ShardedSweepPlan`:
    the shard × accumulate grid).

    ``run`` executes the identical fused-kernel sweep once per microbatch
    slice under a ``lax.scan`` driver and folds results through each
    extension's ``reduce`` spec reinterpreted as a *sequential*
    accumulator (``Reducer.init`` / ``update`` / ``finalize``): running
    sums for psum, running sample-count-weighted A / summed B factors for
    kron, in-order row appends for concat, the pairwise Chan moment merge
    for moment_merge, streamed row-block scatters for the pairwise Gram
    family (BatchDot / NTK — diagonal blocks from the main scan, one
    extra sweep per slice pair for the off-diagonal blocks), and weighted
    partial means plus a final chain replay for KFRA's pmean.  The loss's
    1/M normalization is corrected with the mask-aware *global* unit
    count (computed once from the full targets), and MC factor draws
    stay keyed per global sample index — so results match the monolithic
    sweep up to accumulation order while peak activation/factor memory
    scales with the microbatch, serving effective batches far beyond
    device memory.

    Third-party reducers that genuinely need the whole batch resident
    declare ``supports_streaming = False`` and are rejected with an
    actionable error.
    """

    plan: SweepPlan
    num_microbatches: int
    sharded: Optional[ShardedSweepPlan] = None

    def __post_init__(self):
        # Both construction paths (SweepPlan.accumulate and
        # ShardedSweepPlan.accumulate) land here — a bad count must raise
        # on either, not silently clamp to a monolithic sweep.
        if self.num_microbatches < 1:
            raise ValueError("num_microbatches must be >= 1 "
                             f"(got {self.num_microbatches})")

    def describe(self) -> str:
        base = (self.sharded or self.plan).describe()
        red = reduce_spec([by_name(nm) for nm in sorted(self.plan.names)])
        accs = ", ".join(f"{nm}:{r.name}({r.streaming_form})"
                         for nm, r in sorted(red.items()))
        return (f"{base} | accumulate={self.num_microbatches} microbatches "
                f"(sequential reduce: {accs})")

    def _check_extensions(self, extensions):
        red = reduce_spec(extensions)
        bad = sorted(nm for nm, r in red.items() if not r.supports_streaming)
        if bad:
            kinds = ", ".join(f"{nm} ({red[nm].name})" for nm in bad)
            raise ValueError(
                f"extensions [{kinds}] have no sequential accumulator: "
                "their reducers declare supports_streaming=False — the "
                "whole batch must be resident at once.  Run them on a "
                "monolithic or sharded sweep, implement the streaming "
                "protocol on the reducer, or drop them from the "
                "accumulated plan.")
        return red

    def run(self, model, params, inputs, targets, loss,
            cfg: Optional[ExtensionConfig] = None,
            rng: Optional[jax.Array] = None) -> Results:
        """The accumulated analogue of :func:`run` — same signature minus
        ``extensions`` (the plan carries them), same Results contract."""
        cfg = cfg or ExtensionConfig()
        extensions = tuple(by_name(nm) for nm in sorted(self.plan.names))
        red = self._check_extensions(extensions)
        n = jax.tree.leaves(inputs)[0].shape[0]
        rng = _default_rng(self.plan.sweeps, cfg, rng)
        # Mask-aware global unit count over the WHOLE batch, computed once
        # from the full targets — each microbatch body rescales its local
        # factors to this 1/M (see _ScaledLoss).
        mg = loss.num_units(targets)

        if self.sharded is None:
            cfg2 = dataclasses.replace(
                cfg, shard_axes=None, total_units=mg, total_batch=n,
                accum_stats=True, cross_split=None)
            with obs.span("engine/sweep", lane="accumulated",
                          k=self.num_microbatches, n=n,
                          extensions=",".join(sorted(self.plan.names))):
                lv, grads, logits, ext = _run_accumulated(
                    model, params, inputs, targets, loss, extensions, cfg2,
                    rng, self.num_microbatches)
            return Results(loss=lv, grads=grads, logits=logits, ext=ext)

        sp = self.sharded
        sp.check_batch(n)
        n_local = n // sp.n_shards
        batch = P(tuple(sp.axes))
        gram_spec, _ = gram_assembly_spec(sp.gram_assembly, sp.axes)
        ext_specs = {}
        for nm in self.plan.names:
            r = red[nm]
            ext_specs[nm] = (gram_spec if r.pairwise
                             else batch if r.streams_rows else P())
        cfg2 = dataclasses.replace(cfg, shard_axes=tuple(sp.axes),
                                   total_batch=n, accum_stats=True,
                                   cross_split=None)
        k = self.num_microbatches

        def body(p, x, y, key, mg_):
            cfg_b = dataclasses.replace(cfg2, total_units=mg_)
            base = _global_sample_offset(sp.axes, n_local)
            lv, grads, logits, ext = _run_accumulated(
                model, p, x, y, loss, extensions, cfg_b, key, k,
                base_offset=base, n_shards=sp.n_shards)
            ext = _assemble_pairwise_ext(ext, red, sp.gram_assembly,
                                         sp.axes)
            return lv, grads, logits, ext

        fn = _shard_map(body, mesh=sp.mesh,
                        in_specs=(P(), batch, batch, P(), P()),
                        out_specs=(P(), P(), batch, ext_specs),
                        check_rep=False)
        with obs.span("engine/sweep", lane="shard_accumulate",
                      k=k, n=n, shards=sp.n_shards,
                      extensions=",".join(sorted(self.plan.names))):
            lv, grads, logits, ext = fn(params, inputs, targets, rng,
                                        jnp.asarray(mg, jnp.float32))
        return Results(loss=lv, grads=grads, logits=logits, ext=ext)

    # -- preemption-safe streaming (SweepStream) ----------------------------

    def stream(self, model, params, inputs, targets, loss,
               cfg: Optional[ExtensionConfig] = None,
               rng: Optional[jax.Array] = None) -> "SweepStream":
        """Build the checkpointable stepwise executor for this plan.

        Returns a :class:`SweepStream` over the same microbatch schedule
        as :meth:`run`, but driven one work unit at a time from the host
        so its accumulator state can be snapshotted between units (and
        restored — possibly in a different process, on a different device
        mesh).  Most callers want :meth:`run_checkpointed` /
        :meth:`resume`, which wrap the drive loop.
        """
        return SweepStream(self, model, params, inputs, targets, loss,
                           cfg=cfg, rng=rng)

    def run_checkpointed(self, model, params, inputs, targets, loss,
                         cfg: Optional[ExtensionConfig] = None,
                         rng: Optional[jax.Array] = None, *,
                         checkpointer=None, checkpoint_every: int = 1,
                         injector=None, resume: bool = False) -> Results:
        """Run the accumulated sweep preemption-safely.

        Drives a :class:`SweepStream` work unit by work unit, saving its
        accumulator state through ``checkpointer`` every
        ``checkpoint_every`` units (plus once at completion).  A process
        killed mid-sweep restarts with ``resume=True`` (or via
        :meth:`resume`) and continues from the last snapshot, producing
        results identical to an uninterrupted run — mask-aware 1/M
        scaling and per-global-sample-index MC keying included.

        Parameters
        ----------
        checkpointer : object, optional
            Duck-typed snapshot store (``repro.train.checkpoint.
            SweepCheckpointer``): ``save(cursor, state, meta)`` and
            ``restore_latest(state_like) -> (cursor, state, meta) | None``.
            ``None`` runs the stream without snapshots.
        checkpoint_every : int
            Save cadence in work units (clamped to >= 1).
        injector : object, optional
            Fault hook called as ``injector.check(cursor)`` before each
            work unit (``repro.train.fault.FailureInjector``) — lets
            tests kill the sweep mid-stream deterministically.
        resume : bool
            When True, restore the latest snapshot from ``checkpointer``
            before driving (a missing snapshot is a cold start, not an
            error; :meth:`resume` is the strict variant).
        """
        stream = self.stream(model, params, inputs, targets, loss,
                             cfg=cfg, rng=rng)
        if resume and checkpointer is not None:
            snap = checkpointer.restore_latest(stream.state_arrays())
            if snap is not None:
                stream.load_state(*snap)
        return _drive_stream(stream, checkpointer, checkpoint_every,
                             injector)

    def resume(self, model, params, inputs, targets, loss, checkpointer,
               cfg: Optional[ExtensionConfig] = None,
               rng: Optional[jax.Array] = None, *,
               checkpoint_every: int = 1, injector=None) -> Results:
        """Restart an interrupted checkpointed sweep — strict.

        The restart counterpart of :meth:`run_checkpointed`: restores the
        latest snapshot from ``checkpointer`` and drives the remaining
        work units.  Raises ``FileNotFoundError`` when no snapshot exists
        (a restart driver that silently recomputes from scratch would
        mask a broken checkpoint path).  The caller must rebuild the
        stream inputs identically (same batch, extensions, loss, cfg and
        rng/``mc_seed``) — the snapshot's schedule metadata is validated
        against the rebuilt stream and mismatches raise with the first
        offending field.  The device mesh may differ: restored
        accumulators are replicated host-side values, so a sweep
        checkpointed on N devices resumes on M unchanged (elastic
        re-sharding).
        """
        stream = self.stream(model, params, inputs, targets, loss,
                             cfg=cfg, rng=rng)
        snap = checkpointer.restore_latest(stream.state_arrays())
        if snap is None:
            raise FileNotFoundError(
                "resume(...) found no sweep snapshot to restore — run "
                "run_checkpointed(...) first, or call it with resume=True "
                "to tolerate a cold start")
        stream.load_state(*snap)
        return _drive_stream(stream, checkpointer, checkpoint_every,
                             injector)


def _drive_stream(stream, checkpointer, checkpoint_every, injector):
    """Drive a :class:`SweepStream` to completion with periodic snapshots.

    ``injector.check(cursor)`` runs *before* each work unit, so a fault
    injected at cursor j leaves units 0..j-1 done and their last snapshot
    on disk — exactly the state a preempted process would leave behind.
    """
    every = max(1, int(checkpoint_every))
    while not stream.done:
        if injector is not None:
            injector.check(stream.cursor)
        stream.step()
        if checkpointer is not None and (stream.done
                                         or stream.cursor % every == 0):
            checkpointer.save(stream.cursor, stream.state_arrays(),
                              stream.schedule_meta())
    return stream.result()


class SweepStream:
    """Stepwise, checkpointable executor of an accumulated sweep.

    The preemption-safe form of :class:`AccumulatedSweepPlan`: the same
    microbatch schedule, but instead of folding every slice inside one
    ``lax.scan`` trace, the schedule is materialized as a host-driven
    list of *work units* — one per microbatch slice, then one per
    off-diagonal Gram/NTK slice pair — and :meth:`step` executes them one
    at a time, folding each result into ``self.state``: a pytree of
    arrays only (summed loss/grads, per-reducer accumulators, preallocated
    per-sample row buffers, monolithic ``[n, n, ...]`` pairwise blocks).

    Between any two units the pair ``(cursor, state)`` is a complete
    snapshot: :meth:`state_arrays` serializes every reducer accumulator
    (``Reducer.serialize``), :meth:`load_state` restores it, and
    :meth:`schedule_meta` carries the schedule invariants a restore is
    validated against.  Because each work unit covers a *global*
    contiguous row range ``[t·m, (t+1)·m)`` and MC factors are keyed per
    global sample index, an interrupted-and-resumed stream reproduces the
    uninterrupted run exactly — and because the folded accumulators are
    replicated host-side values combined through the reducers'
    merge algebra, a snapshot taken on an N-device mesh resumes on an
    M-device mesh unchanged (elastic re-sharding; only per-slice compute
    is re-sharded, never the accumulator state).

    When the plan is sharded, full slices whose rows split evenly over
    the mesh run under ``shard_map`` (pairwise extensions and the uneven
    remainder slice run single-device); pairwise outputs always use the
    monolithic ``[n, n, ...]`` layout regardless of the plan's
    ``gram_assembly``.

    Reducers opt out via ``supports_checkpoint = False`` (accumulator
    state that does not round-trip through ``serialize``/``deserialize``)
    and are rejected at stream construction with an actionable error.
    """

    def __init__(self, plan: "AccumulatedSweepPlan", model, params, inputs,
                 targets, loss, cfg: Optional[ExtensionConfig] = None,
                 rng: Optional[jax.Array] = None):
        cfg = cfg or ExtensionConfig()
        self.plan = plan
        self.model = model
        self.params = params
        self.inputs = inputs
        self.targets = targets
        self.loss = loss
        # Resolve through the plan-carried extension objects first so
        # custom (unregistered) first-sweep extensions stream too; the
        # registry covers the built-in curvature names.
        local = {e.name: e for e in (plan.plan.first_exts
                                     + plan.plan.kron_exts)}
        self.extensions = tuple(local[nm] if nm in local else by_name(nm)
                                for nm in sorted(plan.plan.names))
        self.red = plan._check_extensions(self.extensions)
        bad = sorted(nm for nm, r in self.red.items()
                     if not r.supports_checkpoint)
        if bad:
            kinds = ", ".join(f"{nm} ({self.red[nm].name})" for nm in bad)
            raise ValueError(
                f"extensions [{kinds}] cannot be checkpointed: their "
                "reducers declare supports_checkpoint=False — the "
                "accumulator state does not round-trip through "
                "serialize/deserialize.  Run them on an uncheckpointed "
                "sweep, implement serialize/deserialize on the reducer, "
                "or drop them from the checkpointed plan.")
        self.rng = _default_rng(plan.plan.sweeps, cfg, rng)
        self.pair_names = [e.name for e in self.extensions
                           if self.red[e.name].pairwise]
        self.concat_names = [e.name for e in self.extensions
                             if self.red[e.name].streams_rows]
        self.carry_names = [e.name for e in self.extensions
                            if not (self.red[e.name].pairwise
                                    or self.red[e.name].streams_rows)]
        self._pair_exts = tuple(e for e in self.extensions
                                if e.name in self.pair_names)

        n = jax.tree.leaves(inputs)[0].shape[0]
        k = max(1, min(int(plan.num_microbatches), n))
        self.n = n
        self.m = m = -(-n // k)   # slice rows; last slice may be smaller
        self.k_full = n // m
        self.rem = n - self.k_full * m
        self.n_slices = self.k_full + (1 if self.rem else 0)
        self.n_shards = (plan.sharded.n_shards
                         if plan.sharded is not None else 1)

        # The canonical schedule is mesh-independent: slices cover global
        # contiguous row ranges, so the global sample index of batch row
        # r is r in every lane — the invariant MC-draw exactness and
        # elastic resume both rest on.
        mg = loss.num_units(targets)
        self.cfg = dataclasses.replace(
            cfg, shard_axes=None, total_units=jnp.asarray(mg, jnp.float32),
            total_batch=n, accum_stats=True, cross_split=None)

        units = [("slice", t) for t in range(self.n_slices)]
        if self.pair_names:
            units += [("pair", p * m, q * m, m)
                      for p in range(self.k_full)
                      for q in range(p + 1, self.k_full)]
            if self.rem:
                units += [("pair", p * m, self.k_full * m, self.rem)
                          for p in range(self.k_full)]
        self.units = units
        self._cursor = 0
        self._jit_cache = {}
        self._slice_jit = jax.jit(self._slice_results)
        self.state = self._init_state()

    # -- schedule -----------------------------------------------------------

    @property
    def cursor(self) -> int:
        """Index of the next work unit to execute (== the snapshot step)."""
        return self._cursor

    @property
    def num_units(self) -> int:
        """Total work units: slices, then off-diagonal pair passes."""
        return len(self.units)

    @property
    def done(self) -> bool:
        return self._cursor >= len(self.units)

    def describe(self) -> str:
        pairs = len(self.units) - self.n_slices
        return (f"{self.plan.describe()} | stream: {self.n_slices} slice "
                f"units ({self.m} rows each) + {pairs} pair units, "
                f"cursor={self._cursor}/{len(self.units)}")

    # -- per-unit execution -------------------------------------------------

    def _slice_results(self, params, rng, x_i, y_i, off):
        cfg_i = dataclasses.replace(self.cfg, sample_offset=off)
        res = run(self.model, params, x_i, y_i, self.loss,
                  extensions=self.extensions, cfg=cfg_i, rng=rng)
        return (res.loss, res.grads,
                {nm: res.ext[nm] for nm in self.carry_names},
                res.logits,
                {nm: res.ext[nm] for nm in self.concat_names},
                {nm: res.ext[nm] for nm in self.pair_names})

    def _init_state(self):
        def head(a):
            return a[:self.m]

        shapes = jax.eval_shape(
            self._slice_results, self.params, self.rng,
            jax.tree.map(head, self.inputs),
            jax.tree.map(head, self.targets), 0)
        lv_s, g_s, carry_s, z_s, rows_s, pair_s = shapes

        def zeros(s):
            return jnp.zeros(s.shape, s.dtype)

        def rows_buf(s):
            return jnp.zeros((self.n,) + s.shape[1:], s.dtype)

        def pair_buf(s):
            return jnp.zeros((self.n, self.n) + s.shape[2:], s.dtype)

        return {
            "loss": zeros(lv_s),
            "grads": jax.tree.map(zeros, g_s),
            "carry": {nm: self.red[nm].init(
                          jax.tree.map(zeros, carry_s[nm]))
                      for nm in self.carry_names},
            "logits": jax.tree.map(rows_buf, z_s),
            "rows": {nm: jax.tree.map(rows_buf, rows_s[nm])
                     for nm in self.concat_names},
            "pair": {nm: jax.tree.map(pair_buf, pair_s[nm])
                     for nm in self.pair_names},
        }

    def step(self) -> int:
        """Execute the next work unit; returns the advanced cursor."""
        if self.done:
            raise ValueError("sweep stream already complete — result() "
                             "holds the finalized Results")
        unit = self.units[self._cursor]
        if unit[0] == "slice":
            t = unit[1]
            rows = self.m if t < self.k_full else self.rem
            with obs.span("engine/stream/slice", t=t, rows=rows):
                self._do_slice(t)
        else:
            with obs.span("engine/stream/pair", off_p=unit[1],
                          off_q=unit[2], rows_q=unit[3]):
                self._do_pair(*unit[1:])
        self._cursor += 1
        obs.gauge("engine.stream.cursor", self._cursor)
        return self._cursor

    def _use_shard_map(self, rows) -> bool:
        return (self.plan.sharded is not None and self.n_shards > 1
                and rows % self.n_shards == 0)

    def _sharded_slice(self):
        if "sharded" not in self._jit_cache:
            sp = self.plan.sharded
            axes = tuple(sp.axes)
            batch = P(axes)
            main_exts = tuple(e for e in self.extensions
                              if e.name not in self.pair_names)
            cfg_s = dataclasses.replace(self.cfg, shard_axes=axes)

            def body(p, x, y, key, t_off):
                n_local = jax.tree.leaves(x)[0].shape[0]
                off = t_off + _global_sample_offset(axes, n_local)
                cfg_i = dataclasses.replace(cfg_s, sample_offset=off)
                res = run(self.model, p, x, y, self.loss,
                          extensions=main_exts, cfg=cfg_i, rng=key)
                return (res.loss, res.grads,
                        {nm: res.ext[nm] for nm in self.carry_names},
                        res.logits,
                        {nm: res.ext[nm] for nm in self.concat_names})

            out_specs = (P(), P(), {nm: P() for nm in self.carry_names},
                         batch, {nm: batch for nm in self.concat_names})
            self._jit_cache["sharded"] = jax.jit(_shard_map(
                body, mesh=sp.mesh, in_specs=(P(), batch, batch, P(), P()),
                out_specs=out_specs, check_rep=False))
        return self._jit_cache["sharded"]

    def _pair_diag(self):
        if "pair_diag" not in self._jit_cache:
            def f(params, rng, x_i, y_i, off):
                cfg_i = dataclasses.replace(self.cfg, sample_offset=off)
                res = run(self.model, params, x_i, y_i, self.loss,
                          extensions=self._pair_exts, cfg=cfg_i, rng=rng)
                return {nm: res.ext[nm] for nm in self.pair_names}

            self._jit_cache["pair_diag"] = jax.jit(f)
        return self._jit_cache["pair_diag"]

    def _do_slice(self, t):
        lo = t * self.m
        rows = self.m if t < self.k_full else self.rem

        def cut(a):
            return a[lo:lo + rows]

        x_i = jax.tree.map(cut, self.inputs)
        y_i = jax.tree.map(cut, self.targets)
        off = jnp.int32(lo)
        if self._use_shard_map(rows):
            lv, g, carry, z, rows_ext = self._sharded_slice()(
                self.params, x_i, y_i, self.rng, off)
            pair = (self._pair_diag()(self.params, self.rng, x_i, y_i, off)
                    if self.pair_names else {})
        else:
            lv, g, carry, z, rows_ext, pair = self._slice_jit(
                self.params, self.rng, x_i, y_i, off)

        st = self.state
        # Weights are *global* slice rows against a global total batch —
        # the same w_t / N ratios as the in-scan lanes, but independent of
        # the mesh, so folds commute with elastic re-sharding.
        meta = {"weight": float(rows)}
        st["loss"] = st["loss"] + lv
        st["grads"] = jax.tree.map(jnp.add, st["grads"], g)
        st["carry"] = {nm: self.red[nm].update(st["carry"][nm], carry[nm],
                                               meta)
                       for nm in self.carry_names}

        def put(buf, v):
            return buf.at[lo:lo + rows].set(v.astype(buf.dtype))

        st["logits"] = jax.tree.map(put, st["logits"], z)
        st["rows"] = {nm: jax.tree.map(put, st["rows"][nm], rows_ext[nm])
                      for nm in self.concat_names}

        def put_diag(buf, blk):
            return buf.at[lo:lo + rows, lo:lo + rows].set(
                blk.astype(buf.dtype))

        st["pair"] = {nm: jax.tree.map(put_diag, st["pair"][nm], pair[nm])
                      for nm in self.pair_names}

    def _pair_fn(self, rows_q):
        key = ("pair", rows_q)
        if key not in self._jit_cache:
            m = self.m

            def f(params, rng, inputs, targets, off_p, off_q):
                def cut(a):
                    ap = jax.lax.dynamic_slice_in_dim(a, off_p, m, 0)
                    aq = jax.lax.dynamic_slice_in_dim(a, off_q, rows_q, 0)
                    return jnp.concatenate([ap, aq], 0)

                cfg_p = dataclasses.replace(self.cfg, sample_offset=0,
                                            cross_split=m)
                res = run(self.model, params, jax.tree.map(cut, inputs),
                          jax.tree.map(cut, targets), self.loss,
                          extensions=self._pair_exts, cfg=cfg_p, rng=rng)
                return {nm: res.ext[nm] for nm in self.pair_names}

            self._jit_cache[key] = jax.jit(f)
        return self._jit_cache[key]

    def _do_pair(self, off_p, off_q, rows_q):
        pext = self._pair_fn(rows_q)(self.params, self.rng, self.inputs,
                                     self.targets, jnp.int32(off_p),
                                     jnp.int32(off_q))
        st = self.state

        def put(buf, blk, reducer):
            tail0 = (0,) * (buf.ndim - 2)
            buf = jax.lax.dynamic_update_slice(
                buf, blk.astype(buf.dtype), (off_p, off_q) + tail0)
            bot = reducer.transpose_block(blk).astype(buf.dtype)
            return jax.lax.dynamic_update_slice(
                buf, bot, (off_q, off_p) + tail0)

        st["pair"] = {nm: jax.tree.map(
                          lambda a, b, r=self.red[nm]: put(a, b, r),
                          st["pair"][nm], pext[nm])
                      for nm in self.pair_names}

    # -- snapshots ----------------------------------------------------------

    def state_arrays(self):
        """The checkpoint payload: ``self.state`` with every reducer
        accumulator passed through :meth:`Reducer.serialize` — a pytree
        of arrays with stable structure and leaf shapes across the whole
        stream lifetime (what the checkpoint layer validates against)."""
        st = dict(self.state)
        st["carry"] = {nm: self.red[nm].serialize(self.state["carry"][nm])
                       for nm in self.carry_names}
        return st

    def schedule_meta(self) -> dict:
        """JSON-able schedule invariants saved next to each snapshot.

        Everything a resumed stream must rebuild identically — batch
        rows, slice schedule, extension set, loss, MC configuration and
        the PRNG key data.  ``n_shards`` is informational only: elastic
        resume legitimately changes it.
        """
        try:
            key_data = jax.random.key_data(self.rng)
        except (TypeError, AttributeError):
            key_data = self.rng
        return {
            "n": int(self.n),
            "num_microbatches": int(self.plan.num_microbatches),
            "slice_rows": int(self.m),
            "work_units": len(self.units),
            "extensions": sorted(self.plan.plan.names),
            "loss": type(self.loss).__name__,
            "mc_samples": int(self.cfg.mc_samples),
            "rng": [int(v) for v in
                    jax.device_get(key_data).ravel().tolist()],
            "n_shards": int(self.n_shards),
        }

    _ELASTIC_META = ("n_shards",)

    def check_meta(self, meta: dict) -> None:
        """Validate a snapshot's schedule metadata against this stream —
        raises ``ValueError`` naming the first mismatching field."""
        here = self.schedule_meta()
        for field, now in here.items():
            if field in self._ELASTIC_META or field not in meta:
                continue
            if meta[field] != now:
                raise ValueError(
                    "sweep snapshot does not match this stream: field "
                    f"{field!r} was {meta[field]!r} at save time but is "
                    f"{now!r} now — resume must rebuild the stream with "
                    "the identical batch, microbatch schedule, "
                    "extensions, loss and rng/mc_seed (only the device "
                    "mesh may change)")

    def load_state(self, cursor, arrays, meta: Optional[dict] = None):
        """Restore a snapshot: cursor + serialized state (+ validated
        schedule metadata, when the checkpointer kept it)."""
        if meta is not None:
            self.check_meta(meta)
        cursor = int(cursor)
        if not 0 <= cursor <= len(self.units):
            raise ValueError(
                f"sweep snapshot cursor {cursor} outside this stream's "
                f"schedule of {len(self.units)} work units")
        # Snapshots come back as host (numpy) arrays — re-ingest onto the
        # current backend before folding continues.
        arrays = dict(jax.tree.map(jnp.asarray, arrays))
        arrays["carry"] = {nm: self.red[nm].deserialize(
                               arrays["carry"][nm])
                           for nm in self.carry_names}
        self.state = arrays
        self._cursor = cursor

    # -- finalize -----------------------------------------------------------

    def result(self) -> Results:
        """Finalize every accumulator — only valid once ``done``."""
        if not self.done:
            raise ValueError(
                f"sweep stream incomplete ({self._cursor}/"
                f"{len(self.units)} work units) — drive step() to "
                "completion (or use run_checkpointed) before result()")
        st = self.state
        meta_fin = {"total_batch": float(self.n),
                    "total_units": self.cfg.total_units}
        if "kfra" in self.carry_names:
            meta_fin["replay"] = lambda gbar, parts: _merge_stat_trees(
                self.model.kfra_apply(self.params, gbar, parts,
                                      self.extensions, self.cfg)[1],
                "kfra")
        ext = {}
        for nm in self.carry_names:
            with obs.span("engine/finalize", ext=nm,
                          reducer=self.red[nm].name):
                ext[nm] = self.red[nm].finalize(st["carry"][nm], meta_fin)
        ext.update(st["rows"])
        for nm in self.pair_names:
            ext[nm] = st["pair"][nm]
        return Results(loss=st["loss"], grads=st["grads"],
                       logits=st["logits"], ext=ext)


def run(
    model: Module,
    params,
    inputs,
    targets,
    loss,
    extensions: Sequence[Extension] = (),
    cfg: Optional[ExtensionConfig] = None,
    rng: Optional[jax.Array] = None,
) -> Results:
    """One generalized backward pass: batch gradient + K extensions.

    The engine's front door (re-exported as ``repro.core.run``).  A
    single forward pass is followed by the sweeps the extension set
    needs — the cotangent sweep always runs (it produces the batch
    gradient and every first-order statistic), plus at most one factor
    sweep per curvature family: the exact loss-Hessian factorization
    ``S`` with ``S Sᵀ = ∇²_z L`` (Eq. 15/18), its Monte-Carlo counterpart
    (Eq. 20), the averaged Ḡ recursion (Eq. 24), or the signed residual
    factors of the exact Hessian diagonal (Eq. 25/26).

    Parameters
    ----------
    model : Module
        A ``repro.core`` module tree (e.g. ``Sequential`` of layers).
    params
        Parameter pytree, as returned by ``model.init``.
    inputs : array or pytree
        Batch inputs, leading sample axis N.
    targets : array
        Loss targets; ``CrossEntropyLoss`` masks positions with
        ``targets < 0``.
    loss
        ``CrossEntropyLoss`` or ``MSELoss`` (anything exposing the
        ``repro.core.loss_hessian`` derivative protocol).
    extensions : sequence of Extension
        Quantities to extract, e.g. ``(BatchL2, Variance, KFAC)``.
    cfg : ExtensionConfig, optional
        Kernel routing, MC sample count/seed, class chunking,
        microbatch size; see :class:`ExtensionConfig`.
    rng : jax.Array, optional
        PRNG key for the MC factor sweep.  Optional when
        ``cfg.mc_seed`` is set; required (or the seed) whenever an MC
        extension (DiagGGNMC / KFAC) is requested.

    Returns
    -------
    Results
        ``loss`` (scalar mean loss), ``grads`` (params-shaped pytree),
        ``logits`` ``[N, ..., C]``, and ``ext[name]`` — one entry per
        requested extension mirroring the params structure: per-sample
        rows ``[N, ...]`` for BatchGrad/BatchL2/GGNTrace, ``[N, N]``
        Gram matrices for BatchDot, parameter-shaped reductions for the
        moments and GGN/Hessian diagonals (Eq. 19), and per-layer
        ``{'A': [a, a], 'B': [b, b]}`` Kronecker blocks (Eq. 23) for
        KFAC/KFLR/KFRA.

    Notes
    -----
    Pure-functional and jit-compatible; wrap in ``jax.jit`` freely.  For
    batches beyond device memory or multi-device execution, bind the
    plan first: ``plan_sweeps(exts, cfg).shard(mesh).accumulate(k).run(...)``.
    """
    cfg = cfg or ExtensionConfig()
    plan = plan_sweeps(extensions, cfg)
    sweeps = plan.sweeps
    first_exts, kron_exts = plan.first_exts, plan.kron_exts
    # Inside a shard_map body (the ShardedSweepPlan lane) and/or a
    # microbatch body (the AccumulatedSweepPlan lane): correct the loss
    # normalization from partial-batch to global so every per-sample
    # quantity below matches its monolithic single-device value.
    axes = cfg.shard_axes
    if axes or cfg.total_units is not None:
        loss = _ScaledLoss(loss, axes or (), cfg.total_units,
                           cfg.sample_offset)

    # ---- forward ----------------------------------------------------------
    with jax.named_scope("fwd_tape"):
        z, tape = model.forward_tape(params, inputs)
        loss_val = loss.value(z, targets)

    # ---- first-order sweep -------------------------------------------------
    # Each layer's stat hook recomputes plan.fused_mask from `first_exts`
    # (the mapping is pure), so with cfg.use_kernels the whole sweep is one
    # fused kernel launch per parameterized layer.
    with jax.named_scope("first_order_sweep"):
        g = loss.grad(z, targets)
        g_in, grads, stats = model.backward(
            params, tape, g, first_exts + kron_exts, cfg
        )

    ext: Dict[str, Any] = {}
    names = plan.names
    if "batch_grad" in names:
        ext["batch_grad"] = _merge_stat_trees(stats, "batch_grad")
    if "batch_l2" in names:
        ext["batch_l2"] = _merge_stat_trees(stats, "batch_l2")
    if "batch_dot" in names:
        ext["batch_dot"] = _merge_stat_trees(stats, "batch_dot")
    if "second_moment" in names or "variance" in names:
        sum_g2 = _merge_stat_trees(stats, "_sum_grad2")
        n = jax.tree.leaves(inputs)[0].shape[0]
        if cfg.total_batch is not None:
            # Accumulated lane: SecondMoment/Variance scale with the raw
            # batch size of the WHOLE accumulated batch, not this
            # microbatch's slice.
            n_total = jnp.float32(cfg.total_batch)
        else:
            n_total = (jnp.float32(n) * _axis_count(axes) if axes
                       else float(n))
        if "second_moment" in names:
            ext["second_moment"] = jax.tree.map(
                lambda s: s * n_total, sum_g2
            )
        if "variance" in names:
            if cfg.accum_stats:
                # Accumulation-driver body: emit the mergeable raw
                # (count, mean, M2) triple for this partial batch — the
                # driver folds triples across microbatches with the
                # pairwise Chan merge and finalizes n·M2 at the end.
                # Under a sharded microbatch the triple is already merged
                # across shards (and replicated).
                def triple(s, gr):
                    t = (_sharded_moment_triple(s, gr, n, axes) if axes
                         else _moment_triple(s, gr, n))
                    return {"n": t[0], "mean": t[1], "m2": t[2]}

                ext["variance"] = _zip_stats(triple, sum_g2, grads)
            elif axes:
                # moment-merge reducer: local (Σg, Σg²) pairs combine
                # across shards via stable pairwise Chan merges; the
                # result is already global (reducer 'moment_merge').
                ext["variance"] = _zip_stats(
                    lambda s, gr: _sharded_variance(s, gr, n, axes),
                    sum_g2, grads)
            else:
                def var(s, gr):
                    return s * float(n) - gr.astype(jnp.float32) ** 2

                ext["variance"] = _zip_stats(var, sum_g2, grads)
    kron_a = _merge_stat_trees(stats, "_kron_a") if kron_exts else None

    # ---- GGN sweeps ---------------------------------------------------------
    if "ggn_exact" in sweeps:
        exact_exts = tuple(e for e in extensions if e.sweep == "ggn_exact")
        C = loss.n_exact_cols(z)  # U·C columns for token-factored losses
        chunk = cfg.class_chunk
        if "ggn_gram" in names and chunk is not None and chunk < C:
            # Cross-column Gram entries K[·,·,c,c'] pair columns across
            # chunks — a chunked scan only ever sees one chunk's columns.
            raise ValueError(
                "GGNGram is incompatible with class_chunk: the logit-space "
                "Gram needs all C̃ columns of the sqrt-Hessian factor at "
                "once (cross-chunk column pairs are unformable)")
        if chunk is None or chunk >= C:
            with jax.named_scope("ggn_exact_sweep"):
                S = loss.sqrt_hessian(z, targets)
                _, curv = model.curv_backward(params, tape, S, exact_exts,
                                              cfg, "exact")
        else:
            n_chunks = -(-C // chunk)

            def body(acc, i):
                Sc = loss.sqrt_hessian_chunk(z, targets, i * chunk, chunk)
                _, cv = model.curv_backward(params, tape, Sc, exact_exts, cfg, "exact")
                return _tree_add(acc, cv), None

            S0 = loss.sqrt_hessian_chunk(z, targets, 0, chunk)
            _, curv0 = model.curv_backward(params, tape, S0, exact_exts, cfg, "exact")
            zero = jax.tree.map(jnp.zeros_like, curv0)
            with jax.named_scope(f"chunkscan_T{n_chunks}"):
                curv, _ = jax.lax.scan(body, zero, jnp.arange(n_chunks))
        if "diag_ggn" in names:
            ext["diag_ggn"] = _merge_stat_trees(curv, "diag_ggn")
        if "kflr" in names:
            ext["kflr"] = _combine_kron(curv, kron_a, "kflr")
        if "ggn_trace" in names:
            ext["ggn_trace"] = _merge_stat_trees(curv, "ggn_trace")
        if "ggn_gram" in names:
            ext["ggn_gram"] = _merge_stat_trees(curv, "ggn_gram")

    if "ggn_mc" in sweeps:
        mc_exts = tuple(e for e in extensions if e.sweep == "ggn_mc")
        rng = _default_rng(sweeps, cfg, rng)
        with jax.named_scope("ggn_mc_sweep"):
            S = loss.sqrt_hessian_mc(rng, z, targets, cfg.mc_samples)
            _, curv = model.curv_backward(params, tape, S, mc_exts, cfg,
                                          "mc")
        if "diag_ggn_mc" in names:
            ext["diag_ggn_mc"] = _merge_stat_trees(curv, "diag_ggn_mc")
        if "kfac" in names:
            ext["kfac"] = _combine_kron(curv, kron_a, "kfac")

    # ---- raw-Jacobian sweep (empirical NTK family) --------------------------
    if "jac" in sweeps:
        jac_exts = tuple(e for e in extensions if e.sweep == "jac")
        if z.ndim != 2:
            raise ValueError(
                "NTK extensions need flat [N, C] model outputs, got logits "
                f"of shape {z.shape} — reduce the sequence axis before the "
                "head or restrict the NTK to a flat-output model")
        C = z.shape[-1]
        # Identity cotangents per class: S0[c, n, :] = e_c.  The transposed-
        # Jacobian sweep then yields raw per-sample Jacobian factors — no
        # loss curvature, no 1/M scaling, no MC draws.
        S0 = jnp.broadcast_to(jnp.eye(C, dtype=jnp.float32)[:, None, :],
                              (C, z.shape[0], C))
        _, jcurv = model.curv_backward(params, tape, S0, jac_exts, cfg, "ntk")
        if "ntk" in names:
            ext["ntk"] = _merge_stat_trees(jcurv, "ntk")
        if "ntk_classwise" in names:
            ext["ntk_classwise"] = _merge_stat_trees(jcurv, "ntk_classwise")

    # ---- chain-only sweeps ---------------------------------------------------
    if "kfra" in sweeps:
        Gbar = loss.hessian_mean(z, targets)
        if cfg.accum_stats:
            # Accumulation-driver body: emit the streamable halves of the
            # recursion — the global Ḡ contribution plus the per-layer
            # batch-expectation partials.  The driver's MeanReducer folds
            # both across microbatches and replays the chain recursion
            # once at the end (exact: every batch-dependent quantity in
            # Eq. 24 is a batch mean).
            ext["kfra"] = {"gbar": Gbar,
                           "partials": model.kfra_partials(params, tape,
                                                           cfg)}
        else:
            _, kstats = model.kfra_backward(params, tape, Gbar, extensions,
                                            cfg)
            ext["kfra"] = _merge_stat_trees(kstats, "kfra")

    if "hess" in sweeps:
        S = loss.sqrt_hessian(z, targets)
        g0 = loss.grad(z, targets)
        _, _, hstats = model.hess_backward(
            params, tape, g0, [(S, 1.0)], extensions, cfg
        )
        ext["diag_hessian"] = _merge_stat_trees(hstats, "diag_hessian")

    if axes:
        grads, ext = _reduce_sharded(grads, ext, extensions, axes)
    return Results(loss=loss_val, grads=grads, logits=z, ext=ext)


def _combine_kron(curv_stats, kron_a_stats, name):
    """Zip B-factors (curvature sweep) with A-factors (first sweep)."""
    b_tree = _merge_stat_trees(curv_stats, name)

    def rec(b_node, a_node):
        if b_node is None:
            return None
        if isinstance(b_node, dict) and b_node and set(b_node) <= {"w", "b", "g"}:
            # module-level stats dict ({'w': {'B': ...}, 'b': ...})
            out = {}
            for k, v in b_node.items():
                entry = dict(v) if isinstance(v, dict) else {"B": v}
                if a_node is not None and isinstance(a_node, dict) and k in a_node:
                    entry["A"] = a_node[k]
                out[k] = entry
            return out
        if isinstance(b_node, dict):
            # structural dict (Wired child names) — recurse
            return {
                k: rec(v, a_node.get(k) if isinstance(a_node, dict) else None)
                for k, v in b_node.items()
            }
        if isinstance(b_node, (tuple, list)):
            a_children = a_node if isinstance(a_node, (tuple, list)) else (None,) * len(b_node)
            return tuple(rec(bc, ac) for bc, ac in zip(b_node, a_children))
        return b_node

    return rec(b_tree, kron_a_stats)


def ntk_total(ext_tree):
    """Sum a per-parameter NTK stats tree into the total kernel.

    ``run(...).ext['ntk']`` mirrors the params structure with one
    ``[N, N]`` block per parameter leaf (``[N, N, C]`` for
    ``ntk_classwise``) — the empirical NTK Θ(x, x') = J Jᵀ is their sum.
    Works on sharded row-block layouts too (the leaves just carry the
    lane's row/assembly shape).
    """
    leaves = jax.tree.leaves(ext_tree)
    if not leaves:
        raise ValueError("empty NTK stats tree — was the extension run?")
    out = leaves[0].astype(jnp.float32)
    for leaf in leaves[1:]:
        out = out + leaf.astype(jnp.float32)
    return out


def gram_total(ext_tree):
    """Sum a per-parameter ``ggn_gram`` stats tree into the total kernel.

    ``run(...).ext['ggn_gram']`` mirrors the params structure with one
    ``[N, N, C̃, C̃]`` loss-scaled logit-Gram block per parameter leaf;
    their sum is the full half-sandwich kernel ``K = J' J'ᵀ`` with
    ``J' = √Hᵀ J`` — exactly the ``[N·C̃, N·C̃]`` operator kernel-space
    natural gradients invert.  Layout matches :func:`ntk_total` (sample
    axes leading), so sharded/streamed row-block leaves sum the same way.
    """
    leaves = jax.tree.leaves(ext_tree)
    if not leaves:
        raise ValueError("empty GGN-Gram stats tree — was the extension "
                         "run?")
    out = leaves[0].astype(jnp.float32)
    for leaf in leaves[1:]:
        out = out + leaf.astype(jnp.float32)
    return out


def loss_and_grad(model, params, inputs, targets, loss):
    """Plain training objective — the baseline backward pass."""
    res = run(model, params, inputs, targets, loss, extensions=())
    return res.loss, res.grads


def local_loss_and_grad(model, params, inputs, targets, loss, axes):
    """Inside ``shard_map``: global mean loss + this shard's *unreduced*
    gradient contribution, already carrying the global 1/M normalization.

    The seam the compressed-DP step needs — it compresses the local
    contribution (with error feedback) *before* the explicit psum, which
    the engine's own sharded lane would otherwise have performed
    internally.  ``psum(local grads) == run(...).grads`` exactly.
    """
    sloss = _ScaledLoss(loss, axes)
    z, tape = model.forward_tape(params, inputs)
    lv = sloss.value(z, targets)
    g = sloss.grad(z, targets)
    _, grads, _ = model.backward(params, tape, g, (), ExtensionConfig())
    return lv, grads
