"""Generalized backprop engine — one forward pass, K extension sweeps.

``run(model, params, batch, loss, extensions, ...)`` returns

  ``Results(loss, grads, ext)`` with ``ext[name]`` a pytree mirroring the
  params structure (per-module stats), plus the raw per-sweep byproducts the
  optimizers consume (Kronecker factor pairs, GGN diagonals, ...).

Sweep plan (decided statically from the requested extensions):

  first      cotangent sweep — batch gradient + all first-order stats +
             KFAC/KFLR A-factors (they only need layer inputs).  Always runs.
  ggn_exact  exact loss-Hessian factor ``S`` (Eq. 15/18).  When
             ``cfg.class_chunk`` is set, the factor's leading axis is
             processed in chunks of that size under ``lax.scan`` — exact
             curvature at LM-vocabulary scale with bounded memory
             (beyond-paper: the paper stops at C=100).
  ggn_mc     Monte-Carlo factor ``S̃`` (Eq. 20) — the KFAC trick; cost is
             ~1 extra gradient-like sweep per MC sample.
  kfra       averaged ``Ḡ`` recursion (Eq. 24); chain models only.
  hess       exact Hessian diagonal with residual ± factors (Eq. 25/26);
             chain models only.

The whole engine is pure-functional and jit/pjit-compatible: the caller may
wrap ``run`` in ``jax.jit`` with sharded inputs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 exposes it at top level
    from jax import shard_map as _shard_map
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

from .extensions import (
    Extension,
    ExtensionConfig,
    FusedMask,
    FusedSecondMask,
    by_name,
    first_order_mask,
    reduce_spec,
    second_order_mask,
    sweeps_needed,
)
from .module import Module


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """Static per-call sweep plan, decided once from the extension set.

    ``fused_mask`` is the fused first-order kernel's extension mask and
    ``fused_second_mask`` the fused curvature kernel's — the reductions
    each kernel emits for this extension set; ``fused_active`` says whether
    the config actually routes through them (kernels on AND fused on).
    Together they make the paper's "K quantities, one backward pass" claim
    explicit and inspectable (``plan_sweeps(...)`` is public for
    tests/benchmarks).

    The plan is extension-level *intent*: layer stat hooks re-derive the
    same masks (``first_order_mask`` / ``second_order_mask`` are pure) but
    may specialize on tape shapes the plan cannot see — rank-1 (R==1)
    layers skip both fused launches for the cheaper closed forms (see
    ``dense_first_order_stats`` / ``dense_curv_stats``).
    """

    names: frozenset
    sweeps: frozenset
    first_exts: tuple
    kron_exts: tuple
    fused_mask: FusedMask
    fused_active: bool
    fused_second_mask: FusedSecondMask = FusedSecondMask()

    def describe(self) -> str:
        passes = 1 + sum(s in self.sweeps
                         for s in ("ggn_exact", "ggn_mc", "kfra", "hess"))
        fused = [k for k in ("l2", "moment", "dot")
                 if getattr(self.fused_mask, k)]
        lane = fused if self.fused_active and fused else None
        # The second-order lane reports the *planned* kernel outputs for the
        # extension set regardless of config (the curvature lane is what a
        # plan is usually inspected for); `fused_active` says whether this
        # config routes both lanes through the fused kernels.
        second = [k for k in ("diag", "kron", "trace")
                  if getattr(self.fused_second_mask, k)]
        structures = list(self.posterior_structures())
        return (f"sweeps={sorted(self.sweeps) or ['first']} "
                f"passes={passes} fused_first_order={lane} "
                f"fused_second_order={second or None} "
                f"fused_active={self.fused_active} "
                f"laplace={structures or None}")

    def posterior_structures(self) -> tuple:
        """Laplace posterior structures this sweep plan can fit.

        ``'diag'`` needs a GGN diagonal (DiagGGN / DiagGGNMC), ``'kron'``
        Kronecker factors (KFLR / KFAC); ``'last_layer'`` restricts either
        to the final Dense layer, so it is available whenever any structure
        is.  ``repro.laplace`` validates fits against this — a misconfigured
        fit fails with this list in the message instead of a shape error.
        """
        out = []
        if self.names & {"diag_ggn", "diag_ggn_mc"}:
            out.append("diag")
        if self.names & {"kflr", "kfac"}:
            out.append("kron")
        if out:
            out.append("last_layer")
        return tuple(out)


    def shard(self, mesh, axes=("data",)) -> "ShardedSweepPlan":
        """Bind this plan to a device mesh: the batch-sharded sweep lane.

        ``axes`` names the mesh axis (or axes) the batch is split over;
        the returned :class:`ShardedSweepPlan` runs the same sweeps under
        ``shard_map`` — fused kernels on each shard's local batch, then
        the per-extension ``reduce`` specs combine the shards (see
        ``ShardedSweepPlan.describe()`` for the placement report).
        """
        if isinstance(axes, str):
            axes = (axes,)
        return ShardedSweepPlan(plan=self, mesh=mesh, axes=tuple(axes))


def plan_sweeps(extensions: Sequence[Extension],
                cfg: Optional[ExtensionConfig] = None) -> SweepPlan:
    """Build the static sweep plan for a set of requested extensions."""
    cfg = cfg or ExtensionConfig()
    first_exts = tuple(e for e in extensions if e.sweep == "first")
    return SweepPlan(
        names=frozenset(e.name for e in extensions),
        sweeps=frozenset(sweeps_needed(extensions)),
        first_exts=first_exts,
        # KFAC/KFLR A-factors are harvested during the first sweep:
        kron_exts=tuple(e for e in extensions if e.name in ("kfac", "kflr")),
        fused_mask=first_order_mask(first_exts),
        fused_active=cfg.use_kernels and cfg.use_fused,
        fused_second_mask=second_order_mask(extensions),
    )


@dataclasses.dataclass
class Results:
    loss: jnp.ndarray
    grads: Any
    logits: Any
    ext: Dict[str, Any]

    def __getitem__(self, k):
        return self.ext[k]


def _merge_stat_trees(model_stats, key):
    """Extract ``stats[key]`` sub-tree from the nested per-module stats."""

    def rec(node):
        if isinstance(node, dict):
            # module-level stats dict keyed by extension name
            return node.get(key, ())
        if isinstance(node, (tuple, list)):
            return tuple(rec(c) for c in node)
        return ()

    return rec(model_stats)


def _tree_add(a, b):
    if a is None:
        return b
    return jax.tree.map(jnp.add, a, b)


def _zip_stats(fn, st, gr):
    """Map fn over (stats, grads) in parallel, tolerating () stat holes
    (buffers / raw mixer params that have gradients but no per-sample
    statistics)."""
    if st is None or (isinstance(st, tuple) and len(st) == 0):
        return ()
    if isinstance(st, dict):
        return {
            k: _zip_stats(fn, v, gr.get(k) if isinstance(gr, dict) else None)
            for k, v in st.items()
        }
    if isinstance(st, (tuple, list)):
        gr_t = gr if isinstance(gr, (tuple, list)) else (None,) * len(st)
        return tuple(_zip_stats(fn, s, g) for s, g in zip(st, gr_t))
    return fn(st, gr)


# ---------------------------------------------------------------------------
# batch-sharded sweep lane (SweepPlan.shard)
# ---------------------------------------------------------------------------


def _axis_count(axes):
    """Number of shards over the named mesh axes (inside shard_map)."""
    return jax.lax.psum(1, tuple(axes))


def _global_sample_offset(axes, n_local):
    """Global index of this shard's first sample.

    ``shard_map`` splits axis 0 major-to-minor over ``axes``; the linear
    shard index times the local batch recovers the single-device sample
    numbering (what the per-sample MC streams are keyed on).
    """
    idx = 0
    for ax in axes:
        idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
    return idx * n_local


class _ShardScaledLoss:
    """Loss adapter for the sharded sweep body (inside ``shard_map``).

    Every loss here normalizes by the number M of sample units; a shard
    only sees its local units, so its cotangents/factors come out scaled
    by 1/M_local instead of 1/M_global.  This adapter psums M over the
    data axes and rescales — per-sample quantities then match their
    single-device counterparts exactly, even when padding masks leave the
    unit counts uneven across shards.  MC factors additionally get the
    shard's global sample offset so the per-sample PRNG streams line up
    with the single-device draws.
    """

    def __init__(self, base, axes):
        self.base = base
        self.axes = tuple(axes)

    def __getattr__(self, name):
        return getattr(self.base, name)

    def _m(self, y):
        # num_units is the *raw* count — a fully padded shard reports 0.
        # The local clamp must mirror the base loss's own ≥1 clamp (that
        # is what its outputs were divided by); the global clamp only
        # guards the degenerate everything-masked batch.
        raw = self.base.num_units(y)
        ml = jnp.maximum(raw, 1.0)
        mg = jnp.maximum(jax.lax.psum(raw, self.axes), 1.0)
        return ml, mg

    def value(self, z, y):
        ml, mg = self._m(y)
        return jax.lax.psum(self.base.value(z, y) * ml, self.axes) / mg

    def grad(self, z, y):
        ml, mg = self._m(y)
        g = self.base.grad(z, y)
        return (g.astype(jnp.float32) * (ml / mg)).astype(g.dtype)

    def n_exact_cols(self, z):
        return self.base.n_exact_cols(z)

    def sqrt_hessian(self, z, y):
        return self.sqrt_hessian_chunk(z, y, 0, self.n_exact_cols(z))

    def sqrt_hessian_chunk(self, z, y, lo, size):
        ml, mg = self._m(y)
        S = self.base.sqrt_hessian_chunk(z, y, lo, size)
        return (S.astype(jnp.float32) * jnp.sqrt(ml / mg)).astype(S.dtype)

    def sqrt_hessian_mc(self, rng, z, y, k=1, sample_offset=0):
        ml, mg = self._m(y)
        off = sample_offset + _global_sample_offset(self.axes, z.shape[0])
        S = self.base.sqrt_hessian_mc(rng, z, y, k, sample_offset=off)
        return (S.astype(jnp.float32) * jnp.sqrt(ml / mg)).astype(S.dtype)

    def hessian_mean(self, z, y):
        ml, mg = self._m(y)
        return jax.lax.psum(self.base.hessian_mean(z, y) * ml, self.axes) / mg


def _chan_merge(a, b):
    """Merge two (count, mean, M2) triples — Chan et al.'s pairwise update."""
    na, ma, m2a = a
    nb, mb, m2b = b
    n = na + nb
    d = mb - ma
    mean = ma + d * (nb / n)
    m2 = m2a + m2b + d * d * (na * nb / n)
    return n, mean, m2


def _sharded_variance(sum_g2, grad_local, n_local, axes):
    """Global gradient variance across shards, moment-merge style.

    Each shard contributes its local (Σg, Σg²) as a (count, mean, M2)
    triple; a binary tree of :func:`_chan_merge` steps combines the
    all-gathered triples without ever forming the catastrophically
    cancelling global Σg² − (Σg)²/n difference between large
    intermediates.  The result ``n·M2`` equals the engine's single-device
    ``n·Σg² − (Σg)²`` in exact arithmetic.
    """
    g1 = jax.lax.all_gather(grad_local.astype(jnp.float32), tuple(axes))
    g2 = jax.lax.all_gather(sum_g2, tuple(axes))
    nl = jnp.float32(n_local)
    parts = [(nl, g1[i] / nl, g2[i] - g1[i] ** 2 / nl)
             for i in range(g1.shape[0])]
    while len(parts) > 1:
        merged = [_chan_merge(parts[i], parts[i + 1])
                  for i in range(0, len(parts) - 1, 2)]
        if len(parts) % 2:
            merged.append(parts[-1])
        parts = merged
    n, _, m2 = parts[0]
    return n * m2


def _kron_reduce(tree, axes):
    """Kronecker-factor reducer: A factors are batch *means* (pmean), B
    factors batch sums (psum); Embedding's diagonal ``A_diag`` reduces
    like ``A``."""

    def rec(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in ("A", "A_diag"):
                    out[k] = jax.tree.map(
                        lambda x: jax.lax.pmean(x, axes), v)
                elif k == "B":
                    out[k] = jax.tree.map(lambda x: jax.lax.psum(x, axes), v)
                else:
                    out[k] = rec(v)
            return out
        if isinstance(node, (tuple, list)):
            return tuple(rec(c) for c in node)
        return node

    return rec(tree)


def _reduce_sharded(grads, ext, extensions, axes):
    """Apply each extension's declared cross-shard reducer (inside
    shard_map).  'concat'/'gram' stats stay shard-local — the sharded
    out-specs concatenate their sample rows — and 'moment_merge' outputs
    are already global (see :func:`_sharded_variance`)."""
    red = reduce_spec(extensions)
    out = {}
    for name, tree in ext.items():
        kind = red.get(name, "psum")
        if kind == "psum":
            out[name] = jax.tree.map(lambda x: jax.lax.psum(x, axes), tree)
        elif kind == "pmean":
            out[name] = jax.tree.map(lambda x: jax.lax.pmean(x, axes), tree)
        elif kind == "kron":
            out[name] = _kron_reduce(tree, axes)
        else:
            out[name] = tree
    grads = jax.tree.map(lambda x: jax.lax.psum(x, axes), grads)
    return grads, out


@dataclasses.dataclass(frozen=True)
class ShardedSweepPlan:
    """A :class:`SweepPlan` bound to a device mesh — the batch-sharded lane.

    ``run`` wraps the whole engine sweep in ``shard_map`` over the data
    axes: the forward/backward (and the fused Pallas kernel launches
    inside it) run on each device's local batch shard, then the
    per-extension ``reduce`` specs combine the shards — psum for
    batch-summed curvature, pmean/psum factor pairs for KFAC/KFLR,
    all-gathered Gram rows for pairwise dots, a pairwise moment merge for
    the variance, and plain row concatenation (via the sharded out-specs)
    for per-sample statistics.  Results are numerically equivalent to the
    single-device sweep (exactly, up to accumulation order).
    """

    plan: SweepPlan
    mesh: Any
    axes: tuple

    # reducers whose outputs keep shard-local sample rows (sharded axis 0)
    _LOCAL_ROWS = ("concat", "gram")

    @property
    def n_shards(self) -> int:
        s = 1
        for ax in self.axes:
            s *= self.mesh.shape[ax]
        return s

    def reduce_specs(self) -> dict:
        """``{extension name: cross-shard reducer}`` for this plan."""
        return reduce_spec([by_name(n) for n in sorted(self.plan.names)])

    def describe(self) -> str:
        red = self.reduce_specs()
        placement = ", ".join(
            f"{n}:{k}->" +
            ("sharded(axis0)" if k in self._LOCAL_ROWS else "replicated")
            for n, k in sorted(red.items()))
        mesh_shape = dict(zip(self.mesh.axis_names,
                              self.mesh.devices.shape))
        return (f"{self.plan.describe()} | shard_axes={list(self.axes)} "
                f"shards={self.n_shards} mesh={mesh_shape} "
                f"reduce=[{placement}] "
                f"grads:psum->replicated logits:concat->sharded(axis0)")

    def run(self, model, params, inputs, targets, loss,
            cfg: Optional[ExtensionConfig] = None,
            rng: Optional[jax.Array] = None) -> Results:
        """The sharded analogue of :func:`run` — same signature minus
        ``extensions`` (the plan carries them), same Results contract."""
        cfg = dataclasses.replace(cfg or ExtensionConfig(),
                                  shard_axes=tuple(self.axes))
        extensions = tuple(by_name(n) for n in sorted(self.plan.names))
        n = jax.tree.leaves(inputs)[0].shape[0]
        if n % self.n_shards:
            raise ValueError(
                f"global batch {n} is not divisible by {self.n_shards} "
                f"shards over mesh axes {self.axes}")
        if rng is None:
            if "ggn_mc" in self.plan.sweeps:
                if cfg.mc_seed is None:
                    raise ValueError(
                        "MC extensions need an rng key: pass rng= or set "
                        "ExtensionConfig(mc_seed=...) for deterministic "
                        "sweeps")
                rng = jax.random.PRNGKey(cfg.mc_seed)
            else:
                rng = jax.random.PRNGKey(0)  # unused without an MC sweep

        batch = P(tuple(self.axes))
        red = self.reduce_specs()
        ext_specs = {name: (batch if red[name] in self._LOCAL_ROWS else P())
                     for name in self.plan.names}

        def body(p, x, y, key):
            res = run(model, p, x, y, loss, extensions=extensions, cfg=cfg,
                      rng=key)
            return res.loss, res.grads, res.logits, res.ext

        fn = _shard_map(body, mesh=self.mesh,
                        in_specs=(P(), batch, batch, P()),
                        out_specs=(P(), P(), batch, ext_specs),
                        check_rep=False)
        loss_val, grads, logits, ext = fn(params, inputs, targets, rng)
        return Results(loss=loss_val, grads=grads, logits=logits, ext=ext)


def run(
    model: Module,
    params,
    inputs,
    targets,
    loss,
    extensions: Sequence[Extension] = (),
    cfg: Optional[ExtensionConfig] = None,
    rng: Optional[jax.Array] = None,
) -> Results:
    cfg = cfg or ExtensionConfig()
    plan = plan_sweeps(extensions, cfg)
    sweeps = plan.sweeps
    first_exts, kron_exts = plan.first_exts, plan.kron_exts
    # Inside a shard_map body (the ShardedSweepPlan lane): correct the
    # loss normalization from shard-local to global so every per-sample
    # quantity below matches its single-device value.
    axes = cfg.shard_axes
    if axes:
        loss = _ShardScaledLoss(loss, axes)

    # ---- forward ----------------------------------------------------------
    z, tape = model.forward_tape(params, inputs)
    loss_val = loss.value(z, targets)

    # ---- first-order sweep -------------------------------------------------
    # Each layer's stat hook recomputes plan.fused_mask from `first_exts`
    # (the mapping is pure), so with cfg.use_kernels the whole sweep is one
    # fused kernel launch per parameterized layer.
    g = loss.grad(z, targets)
    g_in, grads, stats = model.backward(
        params, tape, g, first_exts + kron_exts, cfg
    )

    ext: Dict[str, Any] = {}
    names = plan.names
    if "batch_grad" in names:
        ext["batch_grad"] = _merge_stat_trees(stats, "batch_grad")
    if "batch_l2" in names:
        ext["batch_l2"] = _merge_stat_trees(stats, "batch_l2")
    if "batch_dot" in names:
        ext["batch_dot"] = _merge_stat_trees(stats, "batch_dot")
    if "second_moment" in names or "variance" in names:
        sum_g2 = _merge_stat_trees(stats, "_sum_grad2")
        n = jax.tree.leaves(inputs)[0].shape[0]
        n_total = (jnp.float32(n) * _axis_count(axes) if axes
                   else float(n))
        if "second_moment" in names:
            ext["second_moment"] = jax.tree.map(
                lambda s: s * n_total, sum_g2
            )
        if "variance" in names:
            if axes:
                # moment-merge reducer: local (Σg, Σg²) pairs combine
                # across shards via stable pairwise Chan merges; the
                # result is already global (reducer 'moment_merge').
                ext["variance"] = _zip_stats(
                    lambda s, gr: _sharded_variance(s, gr, n, axes),
                    sum_g2, grads)
            else:
                def var(s, gr):
                    return s * float(n) - gr.astype(jnp.float32) ** 2

                ext["variance"] = _zip_stats(var, sum_g2, grads)
    kron_a = _merge_stat_trees(stats, "_kron_a") if kron_exts else None

    # ---- GGN sweeps ---------------------------------------------------------
    if "ggn_exact" in sweeps:
        exact_exts = tuple(e for e in extensions if e.sweep == "ggn_exact")
        C = loss.n_exact_cols(z)  # U·C columns for token-factored losses
        chunk = cfg.class_chunk
        if chunk is None or chunk >= C:
            S = loss.sqrt_hessian(z, targets)
            _, curv = model.curv_backward(params, tape, S, exact_exts, cfg, "exact")
        else:
            n_chunks = -(-C // chunk)

            def body(acc, i):
                Sc = loss.sqrt_hessian_chunk(z, targets, i * chunk, chunk)
                _, cv = model.curv_backward(params, tape, Sc, exact_exts, cfg, "exact")
                return _tree_add(acc, cv), None

            S0 = loss.sqrt_hessian_chunk(z, targets, 0, chunk)
            _, curv0 = model.curv_backward(params, tape, S0, exact_exts, cfg, "exact")
            zero = jax.tree.map(jnp.zeros_like, curv0)
            with jax.named_scope(f"chunkscan_T{n_chunks}"):
                curv, _ = jax.lax.scan(body, zero, jnp.arange(n_chunks))
        if "diag_ggn" in names:
            ext["diag_ggn"] = _merge_stat_trees(curv, "diag_ggn")
        if "kflr" in names:
            ext["kflr"] = _combine_kron(curv, kron_a, "kflr")
        if "ggn_trace" in names:
            ext["ggn_trace"] = _merge_stat_trees(curv, "ggn_trace")

    if "ggn_mc" in sweeps:
        mc_exts = tuple(e for e in extensions if e.sweep == "ggn_mc")
        if rng is None:
            if cfg.mc_seed is None:
                raise ValueError(
                    "MC extensions need an rng key: pass rng= or set "
                    "ExtensionConfig(mc_seed=...) for deterministic sweeps")
            rng = jax.random.PRNGKey(cfg.mc_seed)
        S = loss.sqrt_hessian_mc(rng, z, targets, cfg.mc_samples)
        _, curv = model.curv_backward(params, tape, S, mc_exts, cfg, "mc")
        if "diag_ggn_mc" in names:
            ext["diag_ggn_mc"] = _merge_stat_trees(curv, "diag_ggn_mc")
        if "kfac" in names:
            ext["kfac"] = _combine_kron(curv, kron_a, "kfac")

    # ---- chain-only sweeps ---------------------------------------------------
    if "kfra" in sweeps:
        Gbar = loss.hessian_mean(z, targets)
        _, kstats = model.kfra_backward(params, tape, Gbar, extensions, cfg)
        ext["kfra"] = _merge_stat_trees(kstats, "kfra")

    if "hess" in sweeps:
        S = loss.sqrt_hessian(z, targets)
        g0 = loss.grad(z, targets)
        _, _, hstats = model.hess_backward(
            params, tape, g0, [(S, 1.0)], extensions, cfg
        )
        ext["diag_hessian"] = _merge_stat_trees(hstats, "diag_hessian")

    if axes:
        grads, ext = _reduce_sharded(grads, ext, extensions, axes)
    return Results(loss=loss_val, grads=grads, logits=z, ext=ext)


def _combine_kron(curv_stats, kron_a_stats, name):
    """Zip B-factors (curvature sweep) with A-factors (first sweep)."""
    b_tree = _merge_stat_trees(curv_stats, name)

    def rec(b_node, a_node):
        if b_node is None:
            return None
        if isinstance(b_node, dict) and b_node and set(b_node) <= {"w", "b", "g"}:
            # module-level stats dict ({'w': {'B': ...}, 'b': ...})
            out = {}
            for k, v in b_node.items():
                entry = dict(v) if isinstance(v, dict) else {"B": v}
                if a_node is not None and isinstance(a_node, dict) and k in a_node:
                    entry["A"] = a_node[k]
                out[k] = entry
            return out
        if isinstance(b_node, dict):
            # structural dict (Wired child names) — recurse
            return {
                k: rec(v, a_node.get(k) if isinstance(a_node, dict) else None)
                for k, v in b_node.items()
            }
        if isinstance(b_node, (tuple, list)):
            a_children = a_node if isinstance(a_node, (tuple, list)) else (None,) * len(b_node)
            return tuple(rec(bc, ac) for bc, ac in zip(b_node, a_children))
        return b_node

    return rec(b_tree, kron_a_stats)


def loss_and_grad(model, params, inputs, targets, loss):
    """Plain training objective — the baseline backward pass."""
    res = run(model, params, inputs, targets, loss, extensions=())
    return res.loss, res.grads


def local_loss_and_grad(model, params, inputs, targets, loss, axes):
    """Inside ``shard_map``: global mean loss + this shard's *unreduced*
    gradient contribution, already carrying the global 1/M normalization.

    The seam the compressed-DP step needs — it compresses the local
    contribution (with error feedback) *before* the explicit psum, which
    the engine's own sharded lane would otherwise have performed
    internally.  ``psum(local grads) == run(...).grads`` exactly.
    """
    sloss = _ShardScaledLoss(loss, axes)
    z, tape = model.forward_tape(params, inputs)
    lv = sloss.value(z, targets)
    g = sloss.grad(z, targets)
    _, grads, _ = model.backward(params, tape, g, (), ExtensionConfig())
    return lv, grads
