"""Module protocol + generalized-backprop combinators (paper §2.1, Fig. 2).

BackPACK's central abstraction: *a module only needs to know how to multiply
by its Jacobians*.  Every module exposes

  ``apply(params, x)``                       forward
  ``forward_tape(params, x)``                forward + tape (default: input)
  ``backward(params, tape, g, exts, cfg)``   one cotangent sweep step:
      returns ``(g_in, param_grads, stats)`` where ``stats[ext]`` mirrors the
      params pytree (first-order extensions, Eq. 5/9–11 + KFAC A-factors)
  ``jac_t_mat(params, tape, M)``             transposed-Jacobian applied to a
      stack of cotangents ``M``: leading factor axis ``[C̃, *out]→[C̃, *in]``
      (the matrix-Jacobian product the paper §2.1 calls out as missing from
      AD frameworks)
  ``curv_backward(params, tape, S, exts, cfg)``  GGN-factor sweep step
      (Eq. 18): returns ``(S_in, curv_stats)``
  ``kfra_backward(params, tape, Gbar, exts, cfg)``  averaged-curvature sweep
      (Eq. 24); chain models only
  ``hess_backward(params, tape, g, factors, exts, cfg)``  Hessian-diagonal
      sweep with signed residual factors (Eq. 25/26); chain models only

Parameter-free modules fall back to ``jax.vjp`` (re-linearization = remat);
parameterized modules (Dense / Embedding / norms) carry hand-derived
formulas that never materialize per-sample gradients (App. A.1).

Axis convention: activations are ``[N, *reduce_axes, feature]``; axis 0 is
the sample axis.  Per-sample gradients sum over the middle axes *inside* the
square — the sequence/conv generalization of the paper's rank-1 trick.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from .extensions import (
    ExtensionConfig,
    FusedMask,
    FusedSecondMask,
    first_order_mask,
    second_order_mask,
)


def _f32(x):
    return x.astype(jnp.float32)


def _nra(x):
    """Reshape [N, *R, d] -> [N, R, d] (R = prod of middle axes)."""
    n, d = x.shape[0], x.shape[-1]
    return x.reshape(n, -1, d)


class UnsupportedSweep(Exception):
    """Raised when a sweep (KFRA / DiagHessian) hits a non-chain module."""


@dataclasses.dataclass(frozen=True)
class Axes:
    """Logical sharding axis names for one parameter leaf."""

    names: tuple

    def prepend(self, name):
        return Axes((name,) + tuple(self.names))


def is_axes(x):
    return isinstance(x, Axes)


# ---------------------------------------------------------------------------
# shared moment helpers (the paper's App. A.1 formulas, sequence-generalized)
# ---------------------------------------------------------------------------


def per_sample_sq_sum(A, B, chunk=8, use_kernels=False):
    """Σ_n (A_nᵀ B_n)∘² without keeping all N [a×b] matrices.

    A: [N, R, a], B: [N, R, b]  →  [a, b] float32.
    R == 1 reduces to the paper's ``(A∘A)ᵀ(B∘B)`` (App. A.1).
    """
    A, B = _f32(A), _f32(B)
    n, r, a = A.shape
    b = B.shape[-1]
    if r == 1:
        if use_kernels:
            from repro.kernels import ops as kops

            return kops.sq_matmul(A[:, 0, :], B[:, 0, :])
        return jnp.einsum("na,nb->ab", A[:, 0, :] ** 2, B[:, 0, :] ** 2)
    if use_kernels:
        from repro.kernels import ops as kops

        return kops.per_sample_moment(A, B)

    chunk = max(1, min(chunk, n))
    pad = (-n) % chunk
    if pad:
        A = jnp.concatenate([A, jnp.zeros((pad, r, a), A.dtype)], 0)
        B = jnp.concatenate([B, jnp.zeros((pad, r, b), B.dtype)], 0)
    Ac = A.reshape(-1, chunk, r, a)
    Bc = B.reshape(-1, chunk, r, b)

    def body(carry, ab):
        Ai, Bi = ab
        g = jnp.einsum("nra,nrb->nab", Ai, Bi)
        return carry + jnp.sum(g * g, axis=0), None

    with jax.named_scope(f"chunkscan_T{Ac.shape[0]}"):
        out, _ = jax.lax.scan(body, jnp.zeros((a, b), jnp.float32), (Ac, Bc))
    return out


def _pairwise_rows(ps, shard_axes=None, cross_split=None):
    """Gram rows G Gᵀ for per-sample rows ``ps`` [N, ...] → [rows, M] f32.

    Single device: rows == M == N (the full pairwise matrix).  Under a
    batch-sharded sweep (``shard_axes`` set, inside ``shard_map``) each
    shard computes its *row block* against the all-gathered rows
    (M == global N); the sharded out-spec concatenates the blocks back
    into the exact full matrix — pairwise stats are the one statistic a
    shard cannot finish from local samples alone.  With ``cross_split``
    (the streaming-Gram pair passes; mutually exclusive with
    ``shard_axes``) the batch is a concatenated microbatch pair and only
    the cross block ``rows[:cs] @ rows[cs:].T`` is emitted.
    """
    f = _f32(ps).reshape(ps.shape[0], -1)
    if cross_split is not None:
        return f[:cross_split] @ f[cross_split:].T
    cols = (jax.lax.all_gather(f, shard_axes, axis=0, tiled=True)
            if shard_axes else f)
    return f @ cols.T


def per_sample_dots(A, B, shard_axes=None, cross_split=None):
    """D[n,m] = ⟨g_n, g_m⟩ for g = A_nᵀB_n — pairwise Gram trick.

    A: [N, R, a], B: [N, R, b] → [rows, M] float32; rows == M == N
    single-device, global N columns under a sharded sweep (row block vs
    the all-gathered factors — gathering (A, B) costs activation-sized
    traffic instead of the [N, a, b] per-sample gradients), and the
    ``[cs, N - cs]`` cross block under ``cross_split`` (the streaming
    pair passes).  diag of the assembled matrix == batch_l2.
    """
    A, B = _f32(A), _f32(B)
    if cross_split is not None:
        ga = jnp.einsum("nra,msa->nmrs", A[:cross_split], A[cross_split:])
        gb = jnp.einsum("nrb,msb->nmrs", B[:cross_split], B[cross_split:])
        return jnp.sum(ga * gb, axis=(2, 3))
    Am, Bm = A, B
    if shard_axes:
        Am = jax.lax.all_gather(A, shard_axes, axis=0, tiled=True)
        Bm = jax.lax.all_gather(B, shard_axes, axis=0, tiled=True)
    ga = jnp.einsum("nra,msa->nmrs", A, Am)
    gb = jnp.einsum("nrb,msb->nmrs", B, Bm)
    return jnp.sum(ga * gb, axis=(2, 3))


def _pair_split(cfg):
    """(shard_axes, cross_split) a pairwise stat hook should honour:
    cross blocks are a single-device streaming construct — under a
    sharded sweep the gathered-column row block already carries every
    pair and the driver slices it (see ``engine._run_accumulated``)."""
    axes = getattr(cfg, "shard_axes", None)
    cs = None if axes else getattr(cfg, "cross_split", None)
    return axes, cs


def per_sample_l2(A, B, use_kernels=False):
    """‖g_n‖² for g_n = A_nᵀ B_n — Gram trick (Goodfellow 2015 / App. A.1).

    A: [N, R, a], B: [N, R, b]  →  [N] float32.
    """
    A, B = _f32(A), _f32(B)
    r = A.shape[1]
    if r == 1:
        return jnp.sum(A[:, 0, :] ** 2, -1) * jnp.sum(B[:, 0, :] ** 2, -1)
    if use_kernels:
        from repro.kernels import ops as kops

        return kops.batch_l2(A, B)
    ga = jnp.einsum("nra,nsa->nrs", A, A)
    gb = jnp.einsum("nrb,nsb->nrs", B, B)
    return jnp.sum(ga * gb, axis=(1, 2))


def dense_first_order_stats(A, B, exts, cfg: ExtensionConfig, bias: bool):
    """First-order extension stats for y = x @ W (+ b).

    A: [N, R, a] inputs, B: [N, R, b] output cotangents (already / m).
    Returns ``{ext_name: {'w': ..., 'b': ...}}``.

    With ``cfg.use_kernels`` (and ``cfg.use_fused``, the default) every
    requested weight reduction — batch_l2, summed squared gradient, pairwise
    dots — comes out of ONE fused Pallas launch over (A, B); the static
    :class:`~repro.core.extensions.FusedMask` selects the outputs.  With
    ``use_fused=False`` each statistic runs its own legacy kernel (the
    benchmark baseline).  Bias stats are cheap row-sums and stay in jnp.
    """
    names = {e.name for e in exts}
    mask = first_order_mask(names)
    out = {}
    Af, Bf = _f32(A), _f32(B)
    axes, cross = _pair_split(cfg)
    # For R==1 every statistic has a cheaper rank-1 specialization than a
    # fused launch that materializes G[n]=a_n b_nᵀ: l2 is Σa²·Σb²
    # (O(N(a+b))), dot is (AAᵀ)∘(BBᵀ) (O(N²(a+b))), and the moment is the
    # single (A∘A)ᵀ(B∘B) matmul — per_sample_sq_sum routes it to the
    # dedicated sq_matmul kernel below.  Skip the fused kernel entirely.
    # Under a sharded sweep the pairwise dot needs the *cross-shard* Gram
    # blocks, which the shard-local fused kernel cannot see — dot drops
    # out of the launch mask and runs through the gathered Gram einsum
    # (l2/moment stay fused: they are per-sample/batch-sum local).  The
    # streaming pair passes (``cross`` set) likewise bypass the fused
    # launch: only the off-diagonal block is wanted, which the dedicated
    # cross_dot kernel computes without the two diagonal blocks.
    rank1 = A.shape[1] == 1
    kmask = FusedMask() if rank1 else (
        dataclasses.replace(mask, dot=False) if (axes or cross) else mask)
    fused = None
    if cfg.use_kernels and cfg.use_fused and kmask.any():
        from repro.kernels import ops as kops

        fused = kops.fused_first_order(Af, Bf, **kmask.wants())
    if "batch_grad" in names:
        d = {"w": jnp.einsum("nra,nrb->nab", Af, Bf)}
        if bias:
            d["b"] = jnp.sum(Bf, axis=1)
        out["batch_grad"] = d
    if mask.moment:
        w = (fused["moment"] if fused is not None and kmask.moment
             else per_sample_sq_sum(A, B, use_kernels=cfg.use_kernels))
        d = {"w": w}
        if bias:
            bsum = jnp.sum(Bf, axis=1)
            d["b"] = jnp.sum(bsum * bsum, axis=0)
        out["_sum_grad2"] = d
    if mask.l2:
        # per_sample_l2 short-circuits to the rank-1 closed form when R==1.
        l2w = (fused["l2"] if fused is not None and kmask.l2
               else per_sample_l2(A, B, use_kernels=cfg.use_kernels))
        if bias:
            bsum = jnp.sum(Bf, axis=1)
            out["batch_l2"] = {"w": l2w, "b": jnp.sum(bsum * bsum, -1)}
        else:
            out["batch_l2"] = {"w": l2w}
    if mask.dot:
        if fused is not None and kmask.dot:
            dw = fused["dot"]
        elif cross is not None and rank1:
            # Rank-1 cross block: (A1 A2ᵀ) ∘ (B1 B2ᵀ), O(m²(a+b)).
            dw = ((Af[:cross, 0] @ Af[cross:, 0].T)
                  * (Bf[:cross, 0] @ Bf[cross:, 0].T))
        elif cross is not None and cfg.use_kernels:
            from repro.kernels import ops as kops

            dw = kops.cross_dot(Af[:cross], Bf[:cross],
                                Af[cross:], Bf[cross:])
        else:
            # Non-fused fallback is the pure-jnp Gram einsum: no standalone
            # dot kernel ever existed, so that IS the per-extension baseline
            # (and for R==1 it reduces to the cheap (AAᵀ)∘(BBᵀ) form).
            dw = per_sample_dots(A, B, shard_axes=axes, cross_split=cross)
        if bias:
            bsum = jnp.sum(Bf, axis=1)
            out["batch_dot"] = {"w": dw,
                                "b": _pairwise_rows(bsum, axes, cross)}
        else:
            out["batch_dot"] = {"w": dw}
    if "kfac" in names or "kflr" in names:
        n, r, _ = A.shape
        a_fac = jnp.einsum("nra,nrc->ac", Af, Af) / float(n * r)
        out["_kron_a"] = {"w": a_fac}
    return out


def _dense_ntk_stats(A, S, names, cfg: ExtensionConfig, bias: bool):
    """Empirical-NTK row blocks for y = x @ W (+ b) from raw-Jacobian
    factors.

    A: [N, R, a] inputs, S: [C, N, R, b] identity-cotangent factors (the
    raw output Jacobian backpropagated to this layer — no loss weighting).
    The per-class per-sample weight Jacobian is G[c,n] = A_nᵀ S[c,n]; the
    class-diagonal kernel block

        T[c, n, m] = ⟨G[c,n], G[c,m]⟩ = Σ_{r,s} (A_n·A_m)(S_cn·S_cm)

    is emitted as [N, M, C] (``ntk_classwise``; sample axes leading so the
    Gram reducer's row-block algebra applies) or class-summed [N, M]
    (``ntk``).  Column semantics mirror :func:`per_sample_dots`: M == N
    single-device, global N under a sharded sweep (row block vs the
    all-gathered factors), the ``[cs, N - cs]`` cross block under
    ``cross_split`` (the streaming pair passes).  The fused path batches
    the class axis through one ``cross_dot`` launch (E = C); rank-1
    layers take the closed form (A₁A₂ᵀ) ∘ per-class (S₁S₂ᵀ).
    """
    out = {}
    Af, Sf = _f32(A), _f32(S)
    axes, cross = _pair_split(cfg)
    rank1 = A.shape[1] == 1
    A1 = A2 = Af
    S1 = S2 = Sf
    if axes:
        A2 = jax.lax.all_gather(Af, axes, axis=0, tiled=True)
        S2 = jax.lax.all_gather(Sf, axes, axis=1, tiled=True)
    elif cross is not None:
        A1, A2 = Af[:cross], Af[cross:]
        S1, S2 = Sf[:, :cross], Sf[:, cross:]
    if rank1:
        KA = A1[:, 0] @ A2[:, 0].T                            # [N, M]
        KS = jnp.einsum("cnb,cmb->cnm", S1[:, :, 0], S2[:, :, 0])
        T = KA[None] * KS                                     # [C, N, M]
    elif cfg.use_kernels and cfg.use_fused:
        from repro.kernels import ops as kops

        c = S1.shape[0]
        T = kops.cross_dot(jnp.broadcast_to(A1[None], (c,) + A1.shape), S1,
                           jnp.broadcast_to(A2[None], (c,) + A2.shape), S2)
    else:
        ga = jnp.einsum("nra,msa->nmrs", A1, A2)
        gs = jnp.einsum("cnrb,cmsb->cnmrs", S1, S2)
        T = jnp.einsum("nmrs,cnmrs->cnm", ga, gs)
    if bias:
        Sb1 = jnp.sum(S1, axis=2)                             # [C, N, b]
        Sb2 = jnp.sum(S2, axis=2)
    if "ntk" in names:
        d = {"w": jnp.sum(T, axis=0)}
        if bias:
            d["b"] = jnp.einsum("cnb,cmb->nm", Sb1, Sb2)
        out["ntk"] = d
    if "ntk_classwise" in names:
        d = {"w": jnp.moveaxis(T, 0, -1)}
        if bias:
            d["b"] = jnp.einsum("cnb,cmb->nmc", Sb1, Sb2)
        out["ntk_classwise"] = d
    return out


def _dense_ggn_gram_stats(A, S, cfg: ExtensionConfig, bias: bool):
    """Loss-scaled logit-space Gram blocks for y = x @ W (+ b).

    A: [N, R, a] inputs, S: [C̃, N, R, b] *loss-scaled* sqrt-Hessian
    factors (the exact sweep's cotangents, carrying 1/√m).  The
    half-sandwich row J'[(n,c)] = A_nᵀ S[c,n] gives the full cross-column
    kernel block

        T[n, m, c, c'] = ⟨J'[(n,c)], J'[(m,c')]⟩
                       = Σ_{r,s} (A_n,r·A_m,s)(S[c,n,r]·S[c',m,s])

    emitted as [N, M, C̃, C̃] — sample axes leading so the Gram reducer's
    row-block algebra (shard assembly, streaming pair passes) applies
    unchanged.  Column semantics mirror :func:`_dense_ntk_stats`.  The
    fused path flattens the (c, n) row pairs through one ``cross_dot``
    launch (E = 1, N₁ = C̃·N); rank-1 layers take the closed form
    (A₁A₂ᵀ) ⊗-broadcast over the per-column-pair (S₁S₂ᵀ).
    """
    Af, Sf = _f32(A), _f32(S)
    axes, cross = _pair_split(cfg)
    rank1 = A.shape[1] == 1
    A1 = A2 = Af
    S1 = S2 = Sf
    if axes:
        A2 = jax.lax.all_gather(Af, axes, axis=0, tiled=True)
        S2 = jax.lax.all_gather(Sf, axes, axis=1, tiled=True)
    elif cross is not None:
        A1, A2 = Af[:cross], Af[cross:]
        S1, S2 = Sf[:, :cross], Sf[:, cross:]
    c1, n1 = S1.shape[0], S1.shape[1]
    c2, n2 = S2.shape[0], S2.shape[1]
    if rank1:
        KA = A1[:, 0] @ A2[:, 0].T                            # [N, M]
        KS = jnp.einsum("cnb,dmb->nmcd", S1[:, :, 0], S2[:, :, 0])
        T = KA[:, :, None, None] * KS
    elif cfg.use_kernels and cfg.use_fused:
        from repro.kernels import ops as kops

        r = A1.shape[1]
        A1r = jnp.broadcast_to(A1[None], (c1,) + A1.shape)
        A2r = jnp.broadcast_to(A2[None], (c2,) + A2.shape)
        flat = kops.cross_dot(
            A1r.reshape(1, c1 * n1, r, -1), S1.reshape(1, c1 * n1, r, -1),
            A2r.reshape(1, c2 * n2, r, -1), S2.reshape(1, c2 * n2, r, -1))
        # [(c,n), (d,m)] → [n, m, c, d]
        T = flat.reshape(c1, n1, c2, n2).transpose(1, 3, 0, 2)
    else:
        ga = jnp.einsum("nra,msa->nmrs", A1, A2)
        T = jnp.einsum("nmrs,cnrb,dmsb->nmcd", ga, S1, S2)
    d = {"w": T}
    if bias:
        Sb1 = jnp.sum(S1, axis=2)                             # [C, N, b]
        Sb2 = jnp.sum(S2, axis=2)
        d["b"] = jnp.einsum("cnb,dmb->nmcd", Sb1, Sb2)
    return {"ggn_gram": d}


def dense_curv_stats(A, S, exts, cfg: ExtensionConfig, bias: bool, ext_prefix):
    """Second-order stats for a Dense layer from backpropagated factor ``S``.

    A: [N, R, a], S: [C̃, N, R, b] (leading factor axis, carries 1/√m).
    diag contribution: Σ_{c,n} (Σ_r A[n,r,i] S[c,n,r,j])∘²  (Eq. 19/22).
    Kron B factor: R · Σ_{c,n,r} S Sᵀ (Grosse–Martens spatial scaling; exact
    for R=1 where it reduces to App. A.2's B_KFLR/B_KFAC).
    Per-sample GGN trace: Σ_{c,a,b} of the squared contribution per n.

    With ``cfg.use_kernels`` (and ``cfg.use_fused``, the default) every
    requested weight-block curvature statistic comes out of ONE fused
    Pallas launch over (A, S) — the static
    :class:`~repro.core.extensions.FusedSecondMask` selects the outputs,
    and the ``S`` tile is read once for all of them.  Rank-1 (R==1) layers
    skip the launch for cheaper closed forms, as in
    :func:`dense_first_order_stats`.  With
    ``use_fused=False`` each statistic runs its own legacy path (the
    broadcast ``per_sample_sq_sum`` for the diagonal, a jnp einsum for the
    B-factor/trace) — the benchmark baseline.  Bias stats are cheap
    row-sums and stay in jnp.  The MC sweep lands here too: its sample
    axis C̃ simply stands in for the class axis.
    """
    names = {e.name for e in exts}
    if ext_prefix == "ntk":
        # The raw-Jacobian ('jac') sweep lands here with identity
        # cotangents: pairwise kernel blocks instead of curvature sums.
        return _dense_ntk_stats(A, S, names, cfg, bias)
    out = {}
    c, n, r, b = S.shape
    Af, Sf = _f32(A), _f32(S)
    diag_name = "diag_ggn_mc" if ext_prefix == "mc" else "diag_ggn"
    kron_name = "kfac" if ext_prefix == "mc" else "kflr"
    mask = second_order_mask(names)
    # Rank-1 (R==1) layers skip the fused launch, mirroring the first-order
    # path: every statistic separates over the unit sequence axis (diag via
    # the rank-1 branch of per_sample_sq_sum, kron is already the plain
    # SᵀS einsum, trace factors into a product of row norms), which beats a
    # kernel launch that pads R from 1 to a full sublane.
    rank1 = A.shape[1] == 1
    kmask = FusedSecondMask() if rank1 else mask
    fused = None
    if cfg.use_kernels and cfg.use_fused and kmask.any():
        from repro.kernels import ops as kops

        fused = kops.fused_second_order(Af, Sf, **kmask.wants())
    if diag_name in names:
        if fused is not None:
            w = fused["diag"]
        else:
            Arep = jnp.broadcast_to(A[None], (c,) + A.shape).reshape(c * n, r, -1)
            Srep = Sf.reshape(c * n, r, b)
            w = per_sample_sq_sum(Arep, Srep, use_kernels=cfg.use_kernels)
        d = {"w": w}
        if bias:
            ssum = jnp.sum(Sf, axis=2)
            d["b"] = jnp.sum(ssum * ssum, axis=(0, 1))
        out[diag_name] = d
    if kron_name in names:
        ssq = (fused["kron"] if fused is not None
               else jnp.einsum("cnri,cnrj->ij", Sf, Sf))
        b_fac = ssq * float(r)
        out[kron_name] = {"w": {"B": b_fac}}
        if bias:
            out[kron_name]["b"] = {"B": b_fac}
    if "ggn_trace" in names:
        if fused is not None:
            tr = fused["trace"]
        elif rank1:
            # t² = A²[n,a]·S²[c,n,b] separates: trace_n = ‖A_n‖²·Σ_cb S².
            tr = (jnp.sum(Af[:, 0] ** 2, -1)
                  * jnp.sum(Sf[:, :, 0] ** 2, axis=(0, 2)))
        else:
            t = jnp.einsum("nra,cnrb->cnab", Af, Sf)
            tr = jnp.sum(t * t, axis=(0, 2, 3))
        d = {"w": tr}
        if bias:
            ssum = jnp.sum(Sf, axis=2)  # [C, N, b]
            d["b"] = jnp.sum(ssum * ssum, axis=(0, 2))
        out["ggn_trace"] = d
    if "ggn_gram" in names:
        out.update(_dense_ggn_gram_stats(A, S, cfg, bias))
    return out


# ---------------------------------------------------------------------------
# base Module
# ---------------------------------------------------------------------------


class Module:
    """Base module: parameter-free, vjp-backed fallbacks."""

    def init(self, key):
        return ()

    def param_axes(self):
        """Logical sharding axis names, mirroring the params pytree."""
        return ()

    def apply(self, params, x):
        raise NotImplementedError

    def forward_tape(self, params, x):
        return self.apply(params, x), x

    # -- first-order sweep ---------------------------------------------------
    def backward(self, params, tape, g, exts, cfg):
        x = tape
        _, vjp = jax.vjp(self.apply, params, x)
        gp, gx = vjp(g)
        stats = self.generic_stats(params, tape, g, exts, cfg)
        return gx, gp, stats

    def generic_stats(self, params, tape, g, exts, cfg):
        """Per-sample stats for small mixer params via vmapped VJP.

        Only used for parameter-bearing modules without hand-written
        formulas; cost is one extra per-sample VJP of this module alone.
        """
        if not jax.tree_util.tree_leaves(params):
            return {}
        names = {e.name for e in exts}
        wanted = names & {"batch_grad", "batch_l2", "second_moment",
                          "variance", "batch_dot"}
        if not wanted:
            return {}
        x = tape

        def per_sample(gx, xx):
            _, vjp = jax.vjp(lambda p: self.apply(p, jax.tree.map(lambda a: a[None], xx)), params)
            return vjp(jax.tree.map(lambda a: a[None], gx))[0]

        pg = jax.vmap(per_sample)(g, x)  # params-tree with leading N
        out = {}
        if "batch_grad" in names:
            out["batch_grad"] = pg
        if "second_moment" in names or "variance" in names:
            out["_sum_grad2"] = jax.tree.map(lambda a: jnp.sum(_f32(a) ** 2, 0), pg)
        if "batch_l2" in names:
            out["batch_l2"] = jax.tree.map(
                lambda a: jnp.sum(_f32(a).reshape(a.shape[0], -1) ** 2, -1), pg
            )
        if "batch_dot" in names:
            axes, cross = _pair_split(cfg)
            out["batch_dot"] = jax.tree.map(
                lambda a: _pairwise_rows(a, axes, cross), pg
            )
        return out

    # -- matrix-Jacobian products (paper §2.1's missing primitive) -----------
    def jac_t_mat(self, params, tape, M):
        x = tape
        _, vjp = jax.vjp(lambda xx: self.apply(params, xx), x)
        return jax.vmap(lambda m: vjp(m)[0])(M)

    # -- GGN-factor sweep ------------------------------------------------------
    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        return self.jac_t_mat(params, tape, S), {}

    # -- chain-only sweeps ----------------------------------------------------
    def kfra_backward(self, params, tape, Gbar, exts, cfg):
        raise UnsupportedSweep(f"KFRA unsupported for {type(self).__name__}")

    def kfra_partials(self, params, tape, cfg):
        """Batch-mean chain partials of the Ḡ recursion (streaming KFRA).

        Everything batch-dependent in Eq. 24 is a batch expectation — the
        Dense A factor, the activation's E_n[f'f'ᵀ] mask outer.  The
        accumulated lane streams these raw means microbatch by microbatch
        (sample-count-weighted, see ``reducers.MeanReducer``) and replays
        the batch-independent chain on the accumulated *global* means via
        :meth:`kfra_apply` — exact, because the recursion is linear in
        each partial.
        """
        raise UnsupportedSweep(f"KFRA unsupported for {type(self).__name__}")

    def kfra_apply(self, params, Gbar, partials, exts, cfg):
        """Replay one Ḡ recursion step from accumulated chain partials.

        Returns ``(Gbar_in, stats)`` exactly like :meth:`kfra_backward`,
        but every batch expectation is read from ``partials`` (a
        :meth:`kfra_partials` tree, already globally averaged) instead of
        the tape — ``kfra_backward(tape) ==
        kfra_apply(kfra_partials(tape))`` by construction.
        """
        raise UnsupportedSweep(f"KFRA unsupported for {type(self).__name__}")

    def hess_backward(self, params, tape, g, factors, exts, cfg):
        raise UnsupportedSweep(
            f"DiagHessian unsupported for {type(self).__name__}"
        )

    # -- serving --------------------------------------------------------------
    def decode_step(self, params, x, cache):
        """Single-token decode. Stateless modules apply as-is."""
        return self.apply(params, x), cache

    def init_cache(self, params, batch, max_len, dtype):
        return ()

    def cache_axes(self):
        """Logical axis names for the decode cache, mirroring init_cache."""
        return ()


class Lambda(Module):
    """Wrap a parameter-free function (reshapes, rotations, masking...)."""

    def __init__(self, fn: Callable, step_fn: Optional[Callable] = None):
        self.fn = fn
        self.step_fn = step_fn

    def apply(self, params, x):
        return self.fn(x)

    def decode_step(self, params, x, cache):
        if self.step_fn is not None:
            return self.step_fn(x), cache
        return self.fn(x), cache


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------


class Dense(Module):
    """y = x @ W (+ b), x: [N, ..., d_in]."""

    def __init__(self, d_in, d_out, use_bias=True, dtype=jnp.float32,
                 init_scale=None, axes=("embed", "mlp")):
        self.d_in, self.d_out, self.use_bias = d_in, d_out, use_bias
        self.dtype = dtype
        self.init_scale = init_scale
        self.axes = axes

    def init(self, key):
        scale = self.init_scale
        if scale is None:
            scale = self.d_in ** -0.5
        w = (jax.random.normal(key, (self.d_in, self.d_out), jnp.float32)
             * scale).astype(self.dtype)
        p = {"w": w}
        if self.use_bias:
            p["b"] = jnp.zeros((self.d_out,), self.dtype)
        return p

    def param_axes(self):
        p = {"w": Axes(tuple(self.axes))}
        if self.use_bias:
            p["b"] = Axes((self.axes[1],))
        return p

    def apply(self, params, x):
        y = x @ params["w"]
        if self.use_bias:
            y = y + params["b"]
        return y

    def backward(self, params, tape, g, exts, cfg):
        x = tape
        A, B = _nra(x), _nra(g)
        gw = jnp.einsum("nra,nrb->ab", _f32(A), _f32(B)).astype(params["w"].dtype)
        grads = {"w": gw}
        if self.use_bias:
            grads["b"] = jnp.sum(_f32(B), axis=(0, 1)).astype(params["w"].dtype)
        g_in = (g @ params["w"].T).reshape(x.shape)
        stats = dense_first_order_stats(A, B, exts, cfg, self.use_bias) if exts else {}
        return g_in, grads, stats

    def jac_t_mat(self, params, tape, M):
        return M @ params["w"].T

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        x = tape
        A = _nra(x)
        c = S.shape[0]
        Sr = S.reshape((c,) + A.shape[:2] + (self.d_out,))
        stats = dense_curv_stats(A, Sr, exts, cfg, self.use_bias, ext_prefix)
        return self.jac_t_mat(params, tape, S), stats

    def kfra_backward(self, params, tape, Gbar, exts, cfg):
        return self.kfra_apply(params, Gbar,
                               self.kfra_partials(params, tape, cfg),
                               exts, cfg)

    def kfra_partials(self, params, tape, cfg):
        A = _nra(tape)
        n, r, _ = A.shape
        return {"a": jnp.einsum("nra,nrc->ac", _f32(A), _f32(A))
                / float(n * r)}

    def kfra_apply(self, params, Gbar, partials, exts, cfg):
        stats = {}
        if "kfra" in {e.name for e in exts}:
            d = {"w": {"A": partials["a"], "B": Gbar}}
            if self.use_bias:
                d["b"] = {"B": Gbar}
            stats["kfra"] = d
        w = _f32(params["w"])
        return w @ Gbar @ w.T, stats

    def hess_backward(self, params, tape, g, factors, exts, cfg):
        x = tape
        A, B = _nra(x), _nra(g)
        diag_w = jnp.zeros((self.d_in, self.d_out), jnp.float32)
        diag_b = jnp.zeros((self.d_out,), jnp.float32)
        new_factors = []
        for S, sign in factors:
            c = S.shape[0]
            Sr = S.reshape((c,) + A.shape[:2] + (self.d_out,))
            Arep = jnp.broadcast_to(A[None], (c,) + A.shape).reshape(c * A.shape[0], A.shape[1], -1)
            Srep = _f32(Sr).reshape(c * A.shape[0], A.shape[1], self.d_out)
            diag_w = diag_w + sign * per_sample_sq_sum(Arep, Srep)
            ssum = jnp.sum(_f32(Sr), axis=2)
            diag_b = diag_b + sign * jnp.sum(ssum * ssum, axis=(0, 1))
            new_factors.append((self.jac_t_mat(params, tape, S), sign))
        g_in, grads, _ = self.backward(params, tape, g, (), cfg)
        stats = {"diag_hessian": {"w": diag_w}}
        if self.use_bias:
            stats["diag_hessian"]["b"] = diag_b
        return g_in, new_factors, stats

    def decode_step(self, params, x, cache):
        return self.apply(params, x), cache


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


class Embedding(Module):
    """Token embedding lookup; input int tokens [N, T] -> [N, T, d]."""

    def __init__(self, vocab, d, dtype=jnp.float32, scale=None,
                 axes=("vocab", "embed")):
        self.vocab, self.d = vocab, d
        self.dtype = dtype
        self.scale = scale if scale is not None else d ** -0.5
        self.axes = axes

    def init(self, key):
        w = (jax.random.normal(key, (self.vocab, self.d), jnp.float32)
             * self.scale).astype(self.dtype)
        return {"w": w}

    def param_axes(self):
        return {"w": Axes(tuple(self.axes))}

    def apply(self, params, x):
        return jnp.take(params["w"], x, axis=0)

    def backward(self, params, tape, g, exts, cfg):
        tok = tape
        gw = jnp.zeros((self.vocab, self.d), jnp.float32).at[tok.reshape(-1)].add(
            _f32(g).reshape(-1, self.d)
        )
        grads = {"w": gw.astype(params["w"].dtype)}
        stats = {}
        names = {e.name for e in exts}
        if names & {"batch_grad", "batch_l2", "second_moment", "variance"}:
            def scatter_n(tok_n, g_n):
                return jnp.zeros((self.vocab, self.d), jnp.float32).at[
                    tok_n.reshape(-1)
                ].add(_f32(g_n).reshape(-1, self.d))

            pg = jax.vmap(scatter_n)(tok, g)  # [N, V, d] — small-vocab path
            if "batch_grad" in names:
                stats["batch_grad"] = {"w": pg}
            if "second_moment" in names or "variance" in names:
                stats["_sum_grad2"] = {"w": jnp.sum(pg * pg, 0)}
            if "batch_l2" in names:
                stats["batch_l2"] = {"w": jnp.sum(pg * pg, axis=(1, 2))}
            if "batch_dot" in names:
                stats["batch_dot"] = {"w": _pairwise_rows(pg, *_pair_split(cfg))}
        if "kfac" in names or "kflr" in names:
            counts = jnp.zeros((self.vocab,), jnp.float32).at[tok.reshape(-1)].add(1.0)
            stats["_kron_a"] = {"w": counts / float(tok.size)}  # diagonal A
        return None, grads, stats

    def jac_t_mat(self, params, tape, M):
        return None

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        tok = tape
        names = {e.name for e in exts}
        stats = {}
        diag_name = "diag_ggn_mc" if ext_prefix == "mc" else "diag_ggn"
        kron_name = "kfac" if ext_prefix == "mc" else "kflr"
        if diag_name in names:
            def scatter_cn(tok_n, S_n):  # tok_n: [T], S_n: [T, d]
                return jnp.zeros((self.vocab, self.d), jnp.float32).at[
                    tok_n.reshape(-1)
                ].add(_f32(S_n).reshape(-1, self.d))

            pg = jax.vmap(lambda Sc: jax.vmap(scatter_cn)(tok, Sc))(S)  # [C,N,V,d]
            stats[diag_name] = {"w": jnp.sum(pg * pg, axis=(0, 1))}
        if kron_name in names:
            Sf = _f32(S)
            b_fac = jnp.einsum("cnti,cntj->ij", Sf, Sf) * float(S.shape[2])
            counts = jnp.zeros((self.vocab,), jnp.float32).at[tok.reshape(-1)].add(1.0)
            stats[kron_name] = {"w": {"A_diag": counts / float(tok.size), "B": b_fac}}
        return None, stats


# ---------------------------------------------------------------------------
# Norms and activations
# ---------------------------------------------------------------------------


class RMSNorm(Module):
    def __init__(self, d, eps=1e-6, dtype=jnp.float32):
        self.d, self.eps, self.dtype = d, eps, dtype

    def init(self, key):
        return {"g": jnp.ones((self.d,), self.dtype)}

    def param_axes(self):
        return {"g": Axes(("embed",))}

    def _norm(self, x):
        mu = jnp.mean(_f32(x) ** 2, axis=-1, keepdims=True)
        r = jax.lax.rsqrt(mu + self.eps)
        return (_f32(x) * r).astype(x.dtype), r

    def apply(self, params, x):
        xh, _ = self._norm(x)
        return xh * params["g"]

    def forward_tape(self, params, x):
        xh, r = self._norm(x)
        return xh * params["g"], (xh, r)

    def backward(self, params, tape, g, exts, cfg):
        xh, r = tape
        u = _f32(g) * _f32(params["g"])
        xhf = _f32(xh)
        g_in = (r * (u - xhf * jnp.mean(xhf * u, axis=-1, keepdims=True))).astype(g.dtype)
        per_sample = jnp.sum(
            _f32(xh).reshape(xh.shape[0], -1, self.d)
            * _f32(g).reshape(g.shape[0], -1, self.d),
            axis=1,
        )  # [N, d]
        grads = {"g": jnp.sum(per_sample, 0).astype(params["g"].dtype)}
        stats = {}
        names = {e.name for e in exts}
        if "batch_grad" in names:
            stats["batch_grad"] = {"g": per_sample}
        if "second_moment" in names or "variance" in names:
            stats["_sum_grad2"] = {"g": jnp.sum(per_sample ** 2, 0)}
        if "batch_l2" in names:
            stats["batch_l2"] = {"g": jnp.sum(per_sample ** 2, -1)}
        if "batch_dot" in names:
            stats["batch_dot"] = {"g": _pairwise_rows(
                per_sample, *_pair_split(cfg))}
        return g_in, grads, stats

    def jac_t_mat(self, params, tape, M):
        xh, r = tape
        u = _f32(M) * _f32(params["g"])
        xhf = _f32(xh)[None]
        return (r[None] * (u - xhf * jnp.mean(xhf * u, axis=-1, keepdims=True))).astype(M.dtype)

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        xh, r = tape
        names = {e.name for e in exts}
        stats = {}
        diag_name = "diag_ggn_mc" if ext_prefix == "mc" else "diag_ggn"
        if diag_name in names:
            t = jnp.einsum(
                "nrd,cnrd->cnd",
                _f32(xh).reshape(xh.shape[0], -1, self.d),
                _f32(S).reshape(S.shape[:2] + (-1, self.d)),
            )
            stats[diag_name] = {"g": jnp.sum(t * t, axis=(0, 1))}
        return self.jac_t_mat(params, tape, S), stats


class GroupRMSNorm(RMSNorm):
    """RMS-normalize within G groups of the last axis (per-head GroupNorm
    à la RWKV); scale is per-channel.  Shard-local when heads are TP-sharded
    — replaces a full-width norm that would all-gather every layer."""

    def __init__(self, d, groups, eps=1e-6, dtype=jnp.float32):
        super().__init__(d, eps=eps, dtype=dtype)
        self.groups = groups

    def _norm(self, x):
        g = self.groups
        xg = _f32(x).reshape(x.shape[:-1] + (g, self.d // g))
        mu = jnp.mean(xg ** 2, axis=-1, keepdims=True)
        r = jax.lax.rsqrt(mu + self.eps)
        xh = (xg * r).reshape(x.shape)
        return xh.astype(x.dtype), r

    def backward(self, params, tape, g, exts, cfg):
        xh, r = tape
        gr = self.groups
        u = (_f32(g) * _f32(params["g"])).reshape(g.shape[:-1] + (gr, -1))
        xhf = _f32(xh).reshape(u.shape)
        g_in = (r * (u - xhf * jnp.mean(xhf * u, axis=-1, keepdims=True)))
        g_in = g_in.reshape(g.shape).astype(g.dtype)
        per_sample = jnp.sum(
            _f32(xh).reshape(xh.shape[0], -1, self.d)
            * _f32(g).reshape(g.shape[0], -1, self.d),
            axis=1,
        )
        grads = {"g": jnp.sum(per_sample, 0).astype(params["g"].dtype)}
        stats = {}
        names = {e.name for e in exts}
        if "batch_grad" in names:
            stats["batch_grad"] = {"g": per_sample}
        if "second_moment" in names or "variance" in names:
            stats["_sum_grad2"] = {"g": jnp.sum(per_sample ** 2, 0)}
        if "batch_l2" in names:
            stats["batch_l2"] = {"g": jnp.sum(per_sample ** 2, -1)}
        if "batch_dot" in names:
            stats["batch_dot"] = {"g": _pairwise_rows(
                per_sample, *_pair_split(cfg))}
        return g_in, grads, stats

    def jac_t_mat(self, params, tape, M):
        xh, r = tape
        gr = self.groups
        shp = M.shape[:-1] + (gr, self.d // gr)
        u = (_f32(M) * _f32(params["g"])).reshape(shp)
        xhf = _f32(xh).reshape((1,) + xh.shape[:-1] + (gr, self.d // gr))
        out = r[None] * (u - xhf * jnp.mean(xhf * u, axis=-1, keepdims=True))
        return out.reshape(M.shape).astype(M.dtype)


class LayerNorm(Module):
    def __init__(self, d, eps=1e-5, dtype=jnp.float32):
        self.d, self.eps, self.dtype = d, eps, dtype

    def init(self, key):
        return {"g": jnp.ones((self.d,), self.dtype),
                "b": jnp.zeros((self.d,), self.dtype)}

    def param_axes(self):
        return {"g": Axes(("embed",)), "b": Axes(("embed",))}

    def _norm(self, x):
        xf = _f32(x)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        return ((xf - mu) * jax.lax.rsqrt(var + self.eps)).astype(x.dtype)

    def apply(self, params, x):
        return self._norm(x) * params["g"] + params["b"]

    def forward_tape(self, params, x):
        return self.apply(params, x), x

    def backward(self, params, tape, g, exts, cfg):
        x = tape
        _, vjp = jax.vjp(self.apply, params, x)
        gp, gx = vjp(g)
        xh = self._norm(x)
        per_g = jnp.sum(
            _f32(xh).reshape(x.shape[0], -1, self.d)
            * _f32(g).reshape(g.shape[0], -1, self.d),
            axis=1,
        )
        per_b = jnp.sum(_f32(g).reshape(g.shape[0], -1, self.d), axis=1)
        stats = {}
        names = {e.name for e in exts}
        if "batch_grad" in names:
            stats["batch_grad"] = {"g": per_g, "b": per_b}
        if "second_moment" in names or "variance" in names:
            stats["_sum_grad2"] = {"g": jnp.sum(per_g ** 2, 0), "b": jnp.sum(per_b ** 2, 0)}
        if "batch_l2" in names:
            stats["batch_l2"] = {"g": jnp.sum(per_g ** 2, -1), "b": jnp.sum(per_b ** 2, -1)}
        if "batch_dot" in names:
            axes, cross = _pair_split(cfg)
            stats["batch_dot"] = {"g": _pairwise_rows(per_g, axes, cross),
                                  "b": _pairwise_rows(per_b, axes, cross)}
        return gx, gp, stats

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        x = tape
        names = {e.name for e in exts}
        stats = {}
        diag_name = "diag_ggn_mc" if ext_prefix == "mc" else "diag_ggn"
        if diag_name in names:
            xh = self._norm(x)
            t = jnp.einsum(
                "nrd,cnrd->cnd",
                _f32(xh).reshape(x.shape[0], -1, self.d),
                _f32(S).reshape(S.shape[:2] + (-1, self.d)),
            )
            sb = jnp.sum(_f32(S).reshape(S.shape[:2] + (-1, self.d)), axis=2)
            stats[diag_name] = {
                "g": jnp.sum(t * t, axis=(0, 1)),
                "b": jnp.sum(sb * sb, axis=(0, 1)),
            }
        return self.jac_t_mat(params, x, S), stats

    def jac_t_mat(self, params, tape, M):
        x = tape if not isinstance(tape, tuple) else tape[0]
        _, vjp = jax.vjp(lambda xx: self.apply(params, xx), x)
        return jax.vmap(lambda m: vjp(m)[0])(M)


_ACTS = {
    "relu": (jax.nn.relu, lambda x: (x > 0).astype(jnp.float32),
             lambda x: jnp.zeros_like(x, jnp.float32)),
    "gelu": (jax.nn.gelu,
             lambda x: jax.vmap(jax.grad(lambda v: jax.nn.gelu(v)))(x.reshape(-1)).reshape(x.shape),
             lambda x: jax.vmap(jax.grad(jax.grad(lambda v: jax.nn.gelu(v))))(x.reshape(-1)).reshape(x.shape)),
    "silu": (jax.nn.silu,
             lambda x: jax.vmap(jax.grad(lambda v: jax.nn.silu(v)))(x.reshape(-1)).reshape(x.shape),
             lambda x: jax.vmap(jax.grad(jax.grad(lambda v: jax.nn.silu(v))))(x.reshape(-1)).reshape(x.shape)),
    "sigmoid": (jax.nn.sigmoid,
                lambda x: jax.nn.sigmoid(x) * (1 - jax.nn.sigmoid(x)),
                lambda x: jax.nn.sigmoid(x) * (1 - jax.nn.sigmoid(x)) * (1 - 2 * jax.nn.sigmoid(x))),
    "tanh": (jnp.tanh,
             lambda x: 1 - jnp.tanh(x) ** 2,
             lambda x: -2 * jnp.tanh(x) * (1 - jnp.tanh(x) ** 2)),
    "identity": (lambda x: x,
                 lambda x: jnp.ones_like(x, jnp.float32),
                 lambda x: jnp.zeros_like(x, jnp.float32)),
}


class Activation(Module):
    """Elementwise activation with first & second derivative (Eq. 25/26)."""

    def __init__(self, name):
        self.name = name
        self.fn, self.d1, self.d2 = _ACTS[name]

    def apply(self, params, x):
        return self.fn(x)

    def backward(self, params, tape, g, exts, cfg):
        return (self.d1(_f32(tape)) * _f32(g)).astype(g.dtype), (), {}

    def jac_t_mat(self, params, tape, M):
        return (self.d1(_f32(tape))[None] * _f32(M)).astype(M.dtype)

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        return self.jac_t_mat(params, tape, S), {}

    def kfra_backward(self, params, tape, Gbar, exts, cfg):
        return self.kfra_apply(params, Gbar,
                               self.kfra_partials(params, tape, cfg),
                               exts, cfg)

    def kfra_partials(self, params, tape, cfg):
        d1 = self.d1(_f32(tape)).reshape(tape.shape[0], -1, tape.shape[-1])
        n, r, h = d1.shape
        # E_n[f'_n f'_nᵀ] (diagonal per-sample Jacobians).  The Ḡ
        # recursion needs the expectation over the *global* batch at every
        # step — a local mean would compound shard bias layer by layer, so
        # under a sharded sweep the expectation is pmean'd here, in-line,
        # not post-hoc.
        outer = jnp.einsum("nri,nrj->ij", d1, d1) / float(n * r)
        axes = getattr(cfg, "shard_axes", None)
        if axes:
            outer = jax.lax.pmean(outer, axes)
        return {"m": outer}

    def kfra_apply(self, params, Gbar, partials, exts, cfg):
        # Ḡ_in = Ḡ ∘ E_n[f'_n f'_nᵀ]
        return Gbar * partials["m"], {}

    def hess_backward(self, params, tape, g, factors, exts, cfg):
        x = _f32(tape)
        d1 = self.d1(x)
        new_factors = [((d1[None] * _f32(S)).astype(S.dtype), sign)
                       for S, sign in factors]
        # residual: R = diag(f''(x) ∘ δ) per sample-unit (Eq. 26)
        resid = self.d2(x) * _f32(g)
        h = x.shape[-1]
        pos = jnp.sqrt(jnp.maximum(resid, 0.0))
        neg = jnp.sqrt(jnp.maximum(-resid, 0.0))
        eye = jnp.eye(h, dtype=jnp.float32)
        shape = (h,) + x.shape
        P = jnp.moveaxis(pos[..., None] * eye, -1, 0).reshape(shape)
        Nf = jnp.moveaxis(neg[..., None] * eye, -1, 0).reshape(shape)
        new_factors.append((P, 1.0))
        new_factors.append((Nf, -1.0))
        g_in = (d1 * _f32(g)).astype(g.dtype)
        return g_in, new_factors, {}


# ---------------------------------------------------------------------------
# combinators
# ---------------------------------------------------------------------------


class Sequential(Module):
    def __init__(self, mods: Sequence[Module]):
        self.mods = list(mods)

    def init(self, key):
        keys = jax.random.split(key, len(self.mods))
        return tuple(m.init(k) for m, k in zip(self.mods, keys))

    def param_axes(self):
        return tuple(m.param_axes() for m in self.mods)

    def apply(self, params, x):
        for m, p in zip(self.mods, params):
            x = m.apply(p, x)
        return x

    def forward_tape(self, params, x):
        tapes = []
        for m, p in zip(self.mods, params):
            x, t = m.forward_tape(p, x)
            tapes.append(t)
        return x, tuple(tapes)

    def backward(self, params, tape, g, exts, cfg):
        grads, stats = [None] * len(self.mods), [None] * len(self.mods)
        for i in reversed(range(len(self.mods))):
            g, grads[i], stats[i] = self.mods[i].backward(
                params[i], tape[i], g, exts, cfg
            )
            if g is None and i > 0:
                raise ValueError("cotangent vanished mid-chain")
        return g, tuple(grads), tuple(stats)

    def jac_t_mat(self, params, tape, M):
        for i in reversed(range(len(self.mods))):
            M = self.mods[i].jac_t_mat(params[i], tape[i], M)
        return M

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        curv = [None] * len(self.mods)
        for i in reversed(range(len(self.mods))):
            S, curv[i] = self.mods[i].curv_backward(
                params[i], tape[i], S, exts, cfg, ext_prefix
            )
        return S, tuple(curv)

    def kfra_backward(self, params, tape, Gbar, exts, cfg):
        stats = [None] * len(self.mods)
        for i in reversed(range(len(self.mods))):
            Gbar, stats[i] = self.mods[i].kfra_backward(
                params[i], tape[i], Gbar, exts, cfg
            )
        return Gbar, tuple(stats)

    def kfra_partials(self, params, tape, cfg):
        return tuple(m.kfra_partials(p, t, cfg)
                     for m, p, t in zip(self.mods, params, tape))

    def kfra_apply(self, params, Gbar, partials, exts, cfg):
        stats = [None] * len(self.mods)
        for i in reversed(range(len(self.mods))):
            Gbar, stats[i] = self.mods[i].kfra_apply(
                params[i], Gbar, partials[i], exts, cfg
            )
        return Gbar, tuple(stats)

    def hess_backward(self, params, tape, g, factors, exts, cfg):
        stats = [None] * len(self.mods)
        for i in reversed(range(len(self.mods))):
            g, factors, stats[i] = self.mods[i].hess_backward(
                params[i], tape[i], g, factors, exts, cfg
            )
        return g, factors, tuple(stats)

    def decode_step(self, params, x, cache):
        new_cache = list(cache)
        for i, (m, p) in enumerate(zip(self.mods, params)):
            x, new_cache[i] = m.decode_step(p, x, cache[i])
        return x, tuple(new_cache)

    def init_cache(self, params, batch, max_len, dtype):
        return tuple(
            m.init_cache(p, batch, max_len, dtype)
            for m, p in zip(self.mods, params)
        )

    def cache_axes(self):
        return tuple(m.cache_axes() for m in self.mods)


class Parallel(Module):
    """Apply each child to the same input; output = tuple of child outputs."""

    def __init__(self, mods: Sequence[Module]):
        self.mods = list(mods)

    def init(self, key):
        keys = jax.random.split(key, len(self.mods))
        return tuple(m.init(k) for m, k in zip(self.mods, keys))

    def param_axes(self):
        return tuple(m.param_axes() for m in self.mods)

    def apply(self, params, x):
        return tuple(m.apply(p, x) for m, p in zip(self.mods, params))

    def forward_tape(self, params, x):
        outs, tapes = [], []
        for m, p in zip(self.mods, params):
            o, t = m.forward_tape(p, x)
            outs.append(o)
            tapes.append(t)
        return tuple(outs), tuple(tapes)

    def backward(self, params, tape, g, exts, cfg):
        g_in = None
        grads, stats = [], []
        for m, p, t, gi in zip(self.mods, params, tape, g):
            gx, gr, st = m.backward(p, t, gi, exts, cfg)
            grads.append(gr)
            stats.append(st)
            g_in = gx if g_in is None else jax.tree.map(jnp.add, g_in, gx)
        return g_in, tuple(grads), tuple(stats)

    def jac_t_mat(self, params, tape, M):
        out = None
        for m, p, t, Mi in zip(self.mods, params, tape, M):
            r = m.jac_t_mat(p, t, Mi)
            out = r if out is None else jax.tree.map(jnp.add, out, r)
        return out

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        out = None
        curv = []
        for m, p, t, Si in zip(self.mods, params, tape, S):
            r, cv = m.curv_backward(p, t, Si, exts, cfg, ext_prefix)
            curv.append(cv)
            out = r if out is None else jax.tree.map(jnp.add, out, r)
        return out, tuple(curv)

    def decode_step(self, params, x, cache):
        outs, new_cache = [], list(cache)
        for i, (m, p) in enumerate(zip(self.mods, params)):
            o, new_cache[i] = m.decode_step(p, x, cache[i])
            outs.append(o)
        return tuple(outs), tuple(new_cache)

    def init_cache(self, params, batch, max_len, dtype):
        return tuple(
            m.init_cache(p, batch, max_len, dtype)
            for m, p in zip(self.mods, params)
        )

    def cache_axes(self):
        return tuple(m.cache_axes() for m in self.mods)


class Residual(Module):
    """y = x + inner(x)."""

    def __init__(self, inner: Module):
        self.inner = inner

    def init(self, key):
        return self.inner.init(key)

    def param_axes(self):
        return self.inner.param_axes()

    def apply(self, params, x):
        return x + self.inner.apply(params, x)

    def forward_tape(self, params, x):
        y, t = self.inner.forward_tape(params, x)
        return x + y, t

    def backward(self, params, tape, g, exts, cfg):
        gx, grads, stats = self.inner.backward(params, tape, g, exts, cfg)
        return g + gx, grads, stats

    def jac_t_mat(self, params, tape, M):
        return M + self.inner.jac_t_mat(params, tape, M)

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        S_in, curv = self.inner.curv_backward(params, tape, S, exts, cfg, ext_prefix)
        return S + S_in, curv

    def decode_step(self, params, x, cache):
        y, cache = self.inner.decode_step(params, x, cache)
        return x + y, cache

    def init_cache(self, params, batch, max_len, dtype):
        return self.inner.init_cache(params, batch, max_len, dtype)

    def cache_axes(self):
        return self.inner.cache_axes()


_PER_SAMPLE_KEYS = ("batch_grad", "batch_l2", "batch_dot")


def _swap_sample_axis(stats):
    """Scan stacks stats as [L, N, ...]; per-sample stats mirror the stacked
    params ([L, ...]) with a *leading* sample axis, i.e. [N, L, ...]."""

    def rec(node, under_ps):
        if isinstance(node, dict):
            return {k: rec(v, under_ps or k in _PER_SAMPLE_KEYS)
                    for k, v in node.items()}
        if isinstance(node, (tuple, list)):
            return tuple(rec(c, under_ps) for c in node)
        if node is None or not hasattr(node, "ndim"):
            return node
        return jnp.moveaxis(node, 0, 1) if under_ps else node

    return rec(stats, False)


class ScanStack(Module):
    """L homogeneous blocks, scanned — generalized backprop through lax.scan.

    Beyond the paper: BackPACK v1 cannot handle weight sharing or scan-style
    stacking; here tapes/stats are stacked along a leading layer axis and the
    cotangent (resp. GGN factor) is the scan carry.
    """

    def __init__(self, block: Module, n_layers: int, remat: bool = False,
                 seq_constraint=None):
        self.block, self.L = block, n_layers
        self.remat = remat
        self.seq_constraint = seq_constraint

    def _constrain(self, z):
        if self.seq_constraint is None:
            return z
        wsc = jax.lax.with_sharding_constraint
        if isinstance(z, tuple):
            return (wsc(z[0], self.seq_constraint),) + z[1:]
        return wsc(z, self.seq_constraint)

    def init(self, key):
        keys = jax.random.split(key, self.L)
        return jax.vmap(self.block.init)(keys)

    def param_axes(self):
        return jax.tree.map(lambda a: a.prepend("layers"),
                            self.block.param_axes(), is_leaf=is_axes)

    def apply(self, params, x):
        f = self.block.apply
        if self.remat:
            f = jax.checkpoint(f)

        def body(z, p):
            return self._constrain(f(p, z)), None

        with jax.named_scope(f"scanstack_T{self.L}"):
            z, _ = jax.lax.scan(body, x, params)
        return z

    def forward_tape(self, params, x):
        def body(z, p):
            z2, t = self.block.forward_tape(p, z)
            return self._constrain(z2), t

        with jax.named_scope(f"scanstack_T{self.L}"):
            z, tapes = jax.lax.scan(body, x, params)
        return z, tapes

    def backward(self, params, tape, g, exts, cfg):
        def body(gc, pt):
            p, t = pt
            g_in, grads, stats = self.block.backward(p, t, gc, exts, cfg)
            return g_in, (grads, stats)

        with jax.named_scope(f"scanstack_T{self.L}"):
            g_in, (grads, stats) = jax.lax.scan(body, g, (params, tape),
                                                reverse=True)
        return g_in, grads, _swap_sample_axis(stats)

    def jac_t_mat(self, params, tape, M):
        def body(Mc, pt):
            p, t = pt
            return self.block.jac_t_mat(p, t, Mc), None

        with jax.named_scope(f"scanstack_T{self.L}"):
            M_in, _ = jax.lax.scan(body, M, (params, tape), reverse=True)
        return M_in

    def curv_backward(self, params, tape, S, exts, cfg, ext_prefix):
        def body(Sc, pt):
            p, t = pt
            S_in, curv = self.block.curv_backward(p, t, Sc, exts, cfg, ext_prefix)
            return S_in, curv

        with jax.named_scope(f"scanstack_T{self.L}"):
            S_in, curv = jax.lax.scan(body, S, (params, tape), reverse=True)
        return S_in, curv

    def decode_step(self, params, x, cache):
        def body(z, pc):
            p, c = pc
            z2, c2 = self.block.decode_step(p, z, c)
            return z2, c2

        with jax.named_scope(f"scanstack_T{self.L}"):
            x, cache = jax.lax.scan(body, x, (params, cache))
        return x, cache

    def init_cache(self, params, batch, max_len, dtype):
        return jax.vmap(
            lambda p: self.block.init_cache(p, batch, max_len, dtype)
        )(params)

    def cache_axes(self):
        return jax.tree.map(lambda a: a.prepend("layers"),
                            self.block.cache_axes(), is_leaf=is_axes)
