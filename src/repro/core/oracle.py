"""Autodiff oracles — slow, obviously-correct references for tests/benchmarks.

These implement the *naive* approaches the paper compares against:
  * per-sample gradients via ``vmap(grad)`` (and a literal python for-loop
    for the Fig. 3 benchmark),
  * the exact GGN via explicit Jacobians (Eq. 6),
  * the exact Hessian diagonal via ``jax.hessian``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree


def loss_fn(model, loss, params, inputs, targets):
    z = model.apply(params, inputs)
    return loss.value(z, targets)


def grad(model, loss, params, inputs, targets):
    return jax.grad(lambda p: loss_fn(model, loss, p, inputs, targets))(params)


def per_sample_grads(model, loss, params, inputs, targets):
    """g_n = ∇ of the n-th sample's contribution to the mean loss.

    Matches the paper's ``(1/N) ∇ℓ_n`` convention: the returned gradients
    sum (over n) to the batch gradient.
    """
    n = jax.tree.leaves(inputs)[0].shape[0]

    def one(inp, tgt):
        def f(p):
            z = model.apply(p, jax.tree.map(lambda a: a[None], inp))
            return loss.value(z, jax.tree.map(lambda a: a[None], tgt))

        return jax.grad(f)(params)

    gs = jax.vmap(one)(inputs, targets)
    return jax.tree.map(lambda g: g / float(n), gs)


def per_sample_grads_loop(model, loss, params, inputs, targets):
    """Literal for-loop (the paper's Fig. 3 baseline)."""
    n = jax.tree.leaves(inputs)[0].shape[0]
    outs = []
    gfun = jax.jit(
        lambda p, inp, tgt: jax.grad(
            lambda pp: loss_fn(model, loss, pp, inp, tgt)
        )(p)
    )
    for i in range(n):
        inp = jax.tree.map(lambda a: a[i: i + 1], inputs)
        tgt = jax.tree.map(lambda a: a[i: i + 1], targets)
        outs.append(jax.tree.map(lambda g: g / float(n), gfun(params, inp, tgt)))
    return jax.tree.map(lambda *gs: jnp.stack(gs), *outs)


def _unit_loss(loss, z, y):
    """Loss of a single output unit, WITHOUT the 1/m mean factor."""
    if loss.name == "cross_entropy":
        logp = jax.nn.log_softmax(z.astype(jnp.float32))
        return -logp[y.astype(jnp.int32)]
    return 0.5 * jnp.sum((z.astype(jnp.float32) - y) ** 2)


def ggn_matrix(model, loss, params, inputs, targets):
    """Exact full GGN of the mean objective (Eq. 6). Tiny nets only.

    Returns a ``[P, P]`` matrix over the raveled parameter vector.
    """
    flat, unravel = ravel_pytree(params)

    def net(pf):
        z = model.apply(unravel(pf), inputs)
        return z.reshape(-1, z.shape[-1])

    J = jax.jacobian(net)(flat)  # [m, C, P]
    z = net(flat)
    m, C, P = J.shape
    if loss.name == "cross_entropy":
        ys = targets.reshape(-1)
    else:
        ys = targets.reshape(-1, targets.shape[-1])
    G = jnp.zeros((P, P), jnp.float32)
    for i in range(m):
        if loss.name == "cross_entropy" and int(ys[i]) < 0:
            continue
        Hi = jax.hessian(lambda zz: _unit_loss(loss, zz, ys[i]))(z[i])
        G = G + J[i].T @ Hi.astype(jnp.float32) @ J[i]
    return G / float(m)


def ggn_diag(model, loss, params, inputs, targets):
    return jnp.diag(ggn_matrix(model, loss, params, inputs, targets))


def hessian_diag(model, loss, params, inputs, targets):
    flat, unravel = ravel_pytree(params)
    H = jax.hessian(
        lambda pf: loss_fn(model, loss, unravel(pf), inputs, targets)
    )(flat)
    return jnp.diag(H)


def flat_blocks(params, tree):
    """Ravel a stats tree the same way ravel_pytree ravels params."""
    return ravel_pytree(tree)[0]
