"""Render a JSONL observability trace into the per-phase summary table.

The offline half of ``obs.report()``: load a trace written by
``obs.enable(trace_jsonl=...)`` (e.g. ``repro.launch.train
--trace-jsonl``) and print the aggregated span tree — calls, total and
mean wall time, summed numeric attrs — plus counters and gauges.  A
truncated final line (preempted run killed mid-write) is tolerated.

Usage::

    python tools/obs_report.py trace.jsonl
"""
from __future__ import annotations

import argparse
import os
import sys

# runnable from the repo root without PYTHONPATH=src
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.reporting import load_jsonl, render  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="render a JSONL obs trace as a per-phase summary table")
    ap.add_argument("trace", help="JSONL trace file written by "
                                  "obs.enable(trace_jsonl=...)")
    args = ap.parse_args(argv)
    events = load_jsonl(args.trace)
    if not events:
        print(f"{args.trace}: no events", file=sys.stderr)
        return 1
    print(render(events))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
