"""Relative-link checker for the docs site and README.

Scans markdown files for inline links/images, resolves relative targets
against each file's directory, and fails on targets that do not exist —
including ``#anchor`` fragments, which are checked against the target
file's heading slugs (external ``http(s)``/``mailto`` links are skipped:
CI must not depend on the network).  This is the offline half of the docs
CI lane; ``mkdocs build --strict`` covers nav and cross-page rendering.

Usage::

    python tools/check_links.py docs README.md
"""
from __future__ import annotations

import argparse
import os
import re
import sys

# inline markdown links/images: [text](target) / ![alt](target); stops at
# the first unescaped ')' — none of our targets contain parentheses.
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$")
_EXTERNAL = ("http://", "https://", "mailto:")


def slugify(heading: str) -> str:
    """Approximate the mkdocs/GitHub heading-anchor slug."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\s-]", "", h)
    return re.sub(r"[\s]+", "-", h).strip("-")


def heading_slugs(path: str) -> set:
    slugs = set()
    with open(path, encoding="utf-8") as f:
        in_code = False
        for line in f:
            if line.lstrip().startswith("```"):
                in_code = not in_code
                continue
            if in_code:
                continue
            m = _HEADING.match(line)
            if m:
                slugs.add(slugify(m.group(1)))
    return slugs


def md_files(paths):
    for p in paths:
        if os.path.isdir(p):
            for root, _, files in os.walk(p):
                for f in sorted(files):
                    if f.endswith(".md"):
                        yield os.path.join(root, f)
        else:
            yield p


def check_file(path: str) -> list:
    """Return a list of '(path) target: reason' failure strings."""
    failures = []
    base = os.path.dirname(path)
    with open(path, encoding="utf-8") as f:
        text = f.read()
    # drop fenced code blocks — example links in tutorials are not claims
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for target in _LINK.findall(text):
        if target.startswith(_EXTERNAL):
            continue
        target, _, anchor = target.partition("#")
        dest = path if not target else os.path.normpath(
            os.path.join(base, target))
        if target and not os.path.exists(dest):
            failures.append(f"{path}: broken link -> {target}")
            continue
        if anchor and dest.endswith(".md"):
            if slugify(anchor) not in heading_slugs(dest):
                failures.append(
                    f"{path}: broken anchor -> {target}#{anchor}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+",
                    help="markdown files and/or directories to scan")
    args = ap.parse_args(argv)
    failures, checked = [], 0
    for path in md_files(args.paths):
        checked += 1
        failures.extend(check_file(path))
    for f in failures:
        print(f"FAIL {f}")
    if failures:
        print(f"link check: {len(failures)} failure(s) "
              f"across {checked} file(s)")
        return 1
    print(f"link check: {checked} file(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
