"""DP-SGD-style per-sample gradient clipping — the classic BackPACK
application: clip each sample's gradient to a norm bound WITHOUT
materializing per-sample gradients for the clip-norm computation
(BatchL2 gives the norms from the fused Gram-trick kernel path).

    PYTHONPATH=src python examples/per_sample_clipping.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (
    Activation,
    BatchGrad,
    BatchL2,
    CrossEntropyLoss,
    Dense,
    Sequential,
    run,
)

CLIP = 0.05

model = Sequential([Dense(64, 64), Activation("tanh"), Dense(64, 10)])
params = model.init(jax.random.PRNGKey(0))
X = jax.random.normal(jax.random.PRNGKey(1), (16, 64)) * 3.0
y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, 10)
loss = CrossEntropyLoss()


@jax.jit
def clipped_grad(params):
    res = run(model, params, X, y, loss, extensions=(BatchGrad, BatchL2))
    # total per-sample norms across all parameters (from the L2 extension —
    # no [N, D] materialization needed for the norms themselves)
    total_sq = sum(jnp.sum(l.reshape(l.shape[0], -1), -1) if l.ndim > 1 else l
                   for l in jax.tree.leaves(res["batch_l2"]))
    norms = jnp.sqrt(total_sq)
    scale = jnp.minimum(1.0, CLIP / (norms + 1e-12))  # [N]
    clipped = jax.tree.map(
        lambda bg: jnp.einsum("n,n...->...", scale, bg), res["batch_grad"])
    return res.loss, norms, clipped


lv, norms, g = clipped_grad(params)
print(f"loss {float(lv):.4f}")
print("per-sample grad norms:", jnp.round(norms, 4))
print(f"clipped fraction: {float(jnp.mean(norms > CLIP)):.2f}")
print("clipped-gradient norm per leaf:")
for i, leaf in enumerate(jax.tree.leaves(g)):
    print(f"  leaf {i}: {float(jnp.linalg.norm(leaf)):.5f}")
