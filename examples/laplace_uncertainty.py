"""Laplace uncertainty end-to-end: train → fit posterior → tune prior via
marginal likelihood → calibrated next-token predictions.

    PYTHONPATH=src python examples/laplace_uncertainty.py [--steps 60]

Trains a small transformer LM on the deterministic synthetic token stream
(``repro.data.synthetic``) with the online-marglik callback watching the
evidence, then fits a last-layer Kronecker Laplace posterior around the
trained weights, tunes the prior precision by evidence ascent (no
validation set), and serves calibrated next-token predictions: GLM mean ±
predictive std via the fused ``predictive_var`` kernel path, with MacKay's
probit-corrected probabilities next to the raw softmax.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro import laplace
from repro.configs import SHAPES
from repro.configs.base import ModelConfig
from repro.core import CrossEntropyLoss, ExtensionConfig
from repro.data.synthetic import DataConfig, lm_batch
from repro.laplace.posterior import split_last_dense
from repro.nn.models import build_model
from repro.optim import adamw
from repro.train.loop import LoopConfig, fit

CFG = ModelConfig(
    name="laplace-demo", kind="dense", family="dense",
    n_layers=2, d_model=128, n_heads=4, kv_heads=4, d_ff=256,
    vocab=256, act="gelu", norm="rmsnorm", glu=False, dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    model = build_model(CFG)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=args.seq,
                                global_batch=args.batch)

    print("=== train (online marglik every 20 steps) ===")
    params, _, hist, _ = fit(
        model, CFG, shape, adamw(3e-4),
        LoopConfig(steps=args.steps, log_every=20, marglik_every=20))

    print("\n=== fit last-layer Kronecker Laplace + tune prior ===")
    loss = CrossEntropyLoss()
    dc = DataConfig(vocab=CFG.vocab, seq_len=args.seq,
                    global_batch=args.batch)
    batch = lm_batch(dc, step=0)
    post = laplace.fit_posterior(
        model, params, batch["inputs"], batch["labels"], loss,
        structure="kron", last_layer=True,
        options=laplace.FitOptions(mc=True, cfg=ExtensionConfig(mc_seed=0)))
    before = float(laplace.log_marglik(post))
    post, res = laplace.optimize_marglik(post, n_steps=100, lr=0.1)
    print(f"log-evidence {before:.1f} → {float(laplace.log_marglik(post)):.1f}"
          f"  (prior_prec {res.prior_prec:.3g})")

    print("\n=== calibrated next-token predictions ===")
    feats, head, f_params, h_params = split_last_dense(model, params)
    phi = feats.apply(f_params, batch["inputs"])          # [N, T, d]
    mean, var = laplace.glm_predictive(head, h_params, post.inner,
                                       phi[:, -1])        # [N, V]
    probs_map = jax.nn.softmax(mean, axis=-1)
    probs_cal = laplace.probit_predictive(mean, var)
    for n in range(min(3, mean.shape[0])):
        t = int(jnp.argmax(mean[n]))
        print(f"  prompt {n}: top tok{t} logit "
              f"{float(mean[n, t]):.2f}±{float(jnp.sqrt(var[n, t])):.2f}  "
              f"p_map {float(probs_map[n, t]):.3f} → "
              f"p_laplace {float(probs_cal[n, t]):.3f}")
    shrink = float(jnp.mean(jnp.max(probs_cal, -1) / jnp.max(probs_map, -1)))
    print(f"mean top-1 confidence shrink under uncertainty: {shrink:.3f}")


if __name__ == "__main__":
    main()
