"""End-to-end driver (deliverable b): train a ~100M-param transformer with
the paper's preconditioned update.  NOTE: defaults are sized for a real
accelerator; on CPU use --steps 20 --seq 32 --batch 4 (~15 min).

Original summary: train a ~100M-param transformer for a
few hundred steps with the paper's damped curvature-preconditioned update
(Eq. 7), KFAC backend, against an AdamW baseline.

    PYTHONPATH=src python examples/curvature_training.py [--steps 300]

Model: 12L, d=768, 12 heads, d_ff=3072, vocab=8192 ≈ 98M params — runs on
CPU in minutes with seq 64/batch 8 (same code paths as the pod-scale
configs; see repro.launch.train for the full-size entry).
"""
import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import SHAPES
from repro.configs.base import ModelConfig
from repro.core import DiagGGNMC, ExtensionConfig, KFAC
from repro.nn.models import build_model
from repro.optim import adamw, curvature_optimizer
from repro.train.loop import LoopConfig, fit

CFG_100M = ModelConfig(
    name="demo-100m", kind="dense", family="dense",
    n_layers=12, d_model=768, n_heads=12, kv_heads=12, d_ff=3072,
    vocab=8192, act="gelu", norm="rmsnorm", glu=False, dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    model = build_model(CFG_100M)
    n_params = CFG_100M.param_count(model)
    print(f"model: {n_params/1e6:.1f}M params")
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=args.seq,
                                global_batch=args.batch)
    loop = LoopConfig(steps=args.steps, log_every=20)

    t0 = time.time()
    print("\n=== AdamW baseline ===")
    _, _, hist_adam, _ = fit(model, CFG_100M, shape, adamw(3e-4), loop)

    print("\n=== KFAC-preconditioned (paper Eq. 7) ===")
    opt = curvature_optimizer(0.1, damping=0.3, curvature="kfac",
                              stat_decay=0.95)
    _, _, hist_kfac, _ = fit(model, CFG_100M, shape, opt,
                             loop, extensions=(KFAC,),
                             ext_cfg=ExtensionConfig(mc_samples=1))

    print("\n=== DiagGGN-MC-preconditioned ===")
    opt = curvature_optimizer(0.05, damping=0.3, curvature="diag_ggn_mc")
    _, _, hist_dg, _ = fit(model, CFG_100M, shape, opt,
                           loop, extensions=(DiagGGNMC,),
                           ext_cfg=ExtensionConfig(mc_samples=1))

    print(f"\nfinal losses after {args.steps} steps "
          f"({time.time()-t0:.0f}s total):")
    print(f"  adamw        {hist_adam[-1]['loss']:.4f}")
    print(f"  kfac         {hist_kfac[-1]['loss']:.4f}")
    print(f"  diag_ggn_mc  {hist_dg[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
