"""Quickstart — the paper's Fig. 1 workflow in this framework.

PyTorch/BackPACK:                       repro (JAX):
    model = extend(Sequential(...))         model = Sequential(...)
    with backpack(Variance()):              res = run(model, params, X, y,
        loss.backward()                               loss, extensions=(Variance(),))
    param.grad / param.var                  res.grads / res["variance"]

One generalized backward pass returns the batch gradient AND the requested
extension quantities.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (
    Activation,
    BatchGrad,
    BatchL2,
    CrossEntropyLoss,
    Dense,
    DiagGGNMC,
    KFAC,
    Sequential,
    Variance,
    run,
)

# a small classifier (the paper's MNIST logistic-regression example, widened)
model = Sequential([Dense(784, 128), Activation("relu"), Dense(128, 10)])
params = model.init(jax.random.PRNGKey(0))

X = jax.random.normal(jax.random.PRNGKey(1), (32, 784))
y = jax.random.randint(jax.random.PRNGKey(2), (32,), 0, 10)
loss = CrossEntropyLoss()

res = run(model, params, X, y, loss,
          extensions=(BatchGrad, BatchL2, Variance, DiagGGNMC, KFAC),
          rng=jax.random.PRNGKey(3))

print(f"loss                      : {float(res.loss):.4f}")
w_grad = res.grads[0]["w"]
print(f"grad (layer-0 W)          : shape {w_grad.shape}")
print(f"per-sample grads          : shape {res['batch_grad'][0]['w'].shape}")
print(f"per-sample L2 norms       : {jnp.round(res['batch_l2'][0]['w'][:5], 6)}")
print(f"gradient variance (mean)  : {float(jnp.mean(res['variance'][0]['w'])):.3e}")
print(f"DiagGGN-MC (layer-0, mean): {float(jnp.mean(res['diag_ggn_mc'][0]['w'])):.3e}")
kf = res["kfac"][0]["w"]
print(f"KFAC factors (layer 0)    : A {kf['A'].shape}  B {kf['B'].shape}")
print("\nAll of the above came out of ONE extended backward pass.")
