"""Gradient-noise-scale telemetry from the Variance extension — the
adaptive-batch-size signal of Balles et al. (2017) (paper §1), computed
during training at marginal cost.

    PYTHONPATH=src python examples/noise_scale.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES
from repro.core import CrossEntropyLoss, ExtensionConfig, Variance, run
from repro.data.synthetic import batch_for
from repro.nn.models import build_model
from repro.optim import adamw
from repro.optim.optimizers import apply_updates

cfg = ARCHS["stablelm-1.6b"].reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=16)
loss = CrossEntropyLoss()
opt = adamw(1e-3)
opt_state = opt.init(params)


@jax.jit
def step(params, opt_state, batch):
    res = run(model, params, batch["inputs"], batch["labels"], loss,
              extensions=(Variance,))
    # simple gradient noise scale:  tr(Σ) / ‖g‖²   (critical batch size)
    tr_sigma = sum(jnp.sum(v) for v in jax.tree.leaves(res["variance"]))
    g_sq = sum(jnp.sum(g.astype(jnp.float32) ** 2)
               for g in jax.tree.leaves(res.grads))
    noise_scale = tr_sigma / (g_sq + 1e-12)
    ups, opt_state = opt.update(res.grads, opt_state, params)
    return apply_updates(params, ups), opt_state, res.loss, noise_scale


print(f"{'step':>5s} {'loss':>8s} {'noise_scale':>12s}  (critical batch ~ noise scale)")
for i in range(30):
    batch = batch_for(cfg, shape, i)
    params, opt_state, lv, ns = step(params, opt_state, batch)
    if i % 5 == 0:
        print(f"{i:5d} {float(lv):8.4f} {float(ns):12.1f}")
print("\nRising noise scale => larger batches pay off (Balles et al. 2017).")
