"""Batched serving demo: prefill + KV-cache decode on three architecture
families (GQA transformer, RWKV6 recurrent state, Whisper enc-dec).

    PYTHONPATH=src python examples/serving.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.nn.models import build_model
from repro.serve.engine import ServeConfig, generate, generate_whisper

for arch in ("stablelm-1.6b", "rwkv6-3b", "whisper-tiny"):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    t0 = time.time()
    if cfg.kind == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
        toks = generate_whisper(model, params, frames,
                                ServeConfig(max_len=16))
    else:
        prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0,
                                     cfg.vocab)
        toks = generate(model, params, prompts,
                        ServeConfig(max_len=24, temperature=0.8),
                        rng=jax.random.PRNGKey(2))
    dt = time.time() - t0
    print(f"{arch:16s} generated {toks.shape} in {dt:.1f}s "
          f"(incl. compile); first row: {list(map(int, toks[0][:10]))}")
